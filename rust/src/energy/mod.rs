//! Energy & area model (the Design Compiler / VCS / CACTI-McPAT
//! substitute — DESIGN.md §3).
//!
//! Constants are 45 nm-class figures from the public literature (Horowitz,
//! "Computing's energy problem", ISSCC'14; CACTI-style SRAM scaling).
//! The paper's results are *relative* (speedup, % energy, % area); what
//! matters is that the same constants price both the baseline and the MoR
//! configuration, and that a binCU operation is an order of magnitude
//! cheaper than an 8-bit MAC — which is exactly the XNOR+popcount vs
//! multiplier gap.

use crate::config::AcceleratorConfig;
use crate::sim::SimStats;

/// Energy constants (picojoules).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// 8-bit MAC (multiply + accumulate), pJ/op.
    pub mac8_pj: f64,
    /// 1-bit XNOR + popcount lane, pJ/op.
    pub bin_pj: f64,
    /// Input SRAM (16 KB class) read, pJ/byte.
    pub input_sram_pj_byte: f64,
    /// BinWeight SRAM (2 KB class) read, pJ/byte.
    pub binw_sram_pj_byte: f64,
    /// LPDDR4 access energy, pJ/byte.
    pub dram_pj_byte: f64,
    /// Static power of the baseline accelerator, mW.
    pub static_base_mw: f64,
    /// Additional static power of the predictor datapath, mW.
    pub static_predictor_mw: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            mac8_pj: 0.25,            // 8b mult 0.2 + 32b add share
            bin_pj: 0.012,            // XNOR + popcount lane (~20x cheaper)
            input_sram_pj_byte: 0.65, // 16 KB SRAM ~5.2 pJ / 8 B access
            binw_sram_pj_byte: 0.30,  // 2 KB SRAM is cheaper per byte
            dram_pj_byte: 32.0,       // LPDDR4 ~4 pJ/bit
            static_base_mw: 18.0,
            static_predictor_mw: 0.9,
        }
    }
}

/// Energy breakdown for one simulated run, nanojoules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub mac_nj: f64,
    pub bin_nj: f64,
    pub sram_nj: f64,
    pub dram_nj: f64,
    pub static_nj: f64,
}

impl EnergyBreakdown {
    pub fn total_nj(&self) -> f64 {
        self.mac_nj + self.bin_nj + self.sram_nj + self.dram_nj + self.static_nj
    }
}

impl EnergyModel {
    /// Price a simulation run. `freq_mhz` converts cycles to time for the
    /// static component; `predictor_on` adds the predictor's leakage.
    pub fn price(&self, st: &SimStats, freq_mhz: u64, predictor_on: bool) -> EnergyBreakdown {
        let time_s = st.cycles as f64 / (freq_mhz as f64 * 1e6);
        let static_mw = self.static_base_mw
            + if predictor_on {
                self.static_predictor_mw
            } else {
                0.0
            };
        EnergyBreakdown {
            mac_nj: st.macs as f64 * self.mac8_pj * 1e-3,
            bin_nj: st.bin_ops as f64 * self.bin_pj * 1e-3,
            sram_nj: (st.input_sram_read_bytes as f64 * self.input_sram_pj_byte
                + st.binw_sram_read_bytes as f64 * self.binw_sram_pj_byte)
                * 1e-3,
            dram_nj: st.dram_bytes as f64 * self.dram_pj_byte * 1e-3,
            static_nj: static_mw * 1e-3 * time_s * 1e9,
        }
    }
}

// ---------------------------------------------------------------------------
// Area model
// ---------------------------------------------------------------------------

/// Area constants (mm², 45 nm class).
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// One 8-bit MAC (multiplier + adder + pipeline regs).
    pub mac8_mm2: f64,
    /// One binCU lane (XNOR + popcount slice).
    pub bin_lane_mm2: f64,
    /// SRAM, mm² per KB (single-port, CACTI-class).
    pub sram_mm2_per_kb: f64,
    /// Control logic per controller block (layer/row/neuron controllers).
    pub controller_mm2: f64,
    /// Per-CU control overhead (sequencer, psum reg, memory interface).
    pub cu_ctrl_mm2: f64,
    /// Per-binCU control overhead (simpler: no external memory interface).
    pub bincu_ctrl_mm2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            mac8_mm2: 0.0030, // 8b multiplier + 32b accumulator + pipeline regs
            bin_lane_mm2: 0.000010, // XNOR + popcount slice: ~10 gates
            sram_mm2_per_kb: 0.0060,
            controller_mm2: 0.010,
            cu_ctrl_mm2: 0.0040, // sequencer + psum + DRAM interface
            bincu_ctrl_mm2: 0.0003, // no external memory interface (Sec 4.4)
        }
    }
}

/// Area report for an accelerator configuration.
#[derive(Clone, Copy, Debug)]
pub struct AreaReport {
    pub base_mm2: f64,
    pub predictor_mm2: f64,
}

impl AreaReport {
    pub fn total_mm2(&self) -> f64 {
        self.base_mm2 + self.predictor_mm2
    }

    /// The paper's headline: predictor area / baseline area (5.3%).
    pub fn overhead_frac(&self) -> f64 {
        self.predictor_mm2 / self.base_mm2
    }
}

impl AreaModel {
    pub fn area(&self, cfg: &AcceleratorConfig) -> AreaReport {
        let cu = cfg.cu_width as f64 * self.mac8_mm2
            + self.cu_ctrl_mm2
            + (cfg.cu_buffer_bytes as f64 / 1024.0) * self.sram_mm2_per_kb;
        let base = cfg.num_cus as f64 * cu
            + (cfg.input_sram_bytes as f64 / 1024.0) * self.sram_mm2_per_kb
            + 3.0 * self.controller_mm2; // layer + row + neurons controllers

        let bincu = cfg.bincu_width as f64 * self.bin_lane_mm2 + self.bincu_ctrl_mm2;
        // Table 1 lists ONE shared binCU buffer (0.56 KB), not one per unit
        let predictor = if cfg.predictor {
            cfg.num_bincus as f64 * bincu
                + (cfg.bincu_buffer_bytes as f64 / 1024.0) * self.sram_mm2_per_kb
                + (cfg.binweight_sram_bytes as f64 / 1024.0) * self.sram_mm2_per_kb
        } else {
            0.0
        };
        AreaReport {
            base_mm2: base,
            predictor_mm2: predictor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;

    #[test]
    fn bin_op_much_cheaper_than_mac() {
        let e = EnergyModel::default();
        assert!(e.mac8_pj / e.bin_pj > 10.0);
    }

    #[test]
    fn area_overhead_in_paper_band() {
        // Table 1 configuration must land near the paper's 5.3% overhead
        let a = AreaModel::default().area(&AcceleratorConfig::default());
        let ov = a.overhead_frac();
        assert!(
            (0.02..=0.09).contains(&ov),
            "area overhead {ov:.3} out of the plausible band around 5.3%"
        );
    }

    #[test]
    fn baseline_has_zero_predictor_area() {
        let a = AreaModel::default().area(&AcceleratorConfig::baseline());
        assert_eq!(a.predictor_mm2, 0.0);
        assert!(a.base_mm2 > 0.0);
    }

    #[test]
    fn energy_price_scales_with_work() {
        let e = EnergyModel::default();
        let mut s1 = SimStats::default();
        s1.macs = 1000;
        s1.cycles = 100;
        let mut s2 = s1;
        s2.macs = 2000;
        let b1 = e.price(&s1, 1200, true);
        let b2 = e.price(&s2, 1200, true);
        assert!(b2.mac_nj > b1.mac_nj);
        assert_eq!(b1.static_nj, b2.static_nj);
    }

    #[test]
    fn dram_dominates_at_equal_bytes() {
        // sanity: moving a byte from DRAM costs far more than SRAM
        let e = EnergyModel::default();
        assert!(e.dram_pj_byte / e.input_sram_pj_byte > 10.0);
    }
}
