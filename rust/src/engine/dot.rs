//! Dot-product hot kernels for the functional engine.
//!
//! `dot_i8` is the base-precision CU operation (int8 x int8 → int32);
//! the binary path lives in [`crate::util::bits`]. Both are written so
//! LLVM auto-vectorizes the inner loop (verified in the perf pass —
//! see EXPERIMENTS.md §Perf).
//!
//! [`dot_i8_sparse`] is the input-zero-skipping variant (EXPERIMENTS.md
//! §Sparse): it consumes a compressed nonzero-lane list instead of the
//! dense activation vector and is **exact** — the lanes it elides are
//! zero, and integer addition of zero products changes nothing. The
//! same kernel doubles as the *weight*-zero-skipping variant under an
//! operand swap (a compressed filter against a dense patch), and
//! [`dot_i8_sparse_sparse`] closes the doubly-sparse corner where a
//! compressed filter meets a compressed patch (EXPERIMENTS.md
//! §Weights).

/// Largest dot length the VNNI offset-trick kernel accepts. The
/// unsigned decomposition accumulates `Σ (x+128)·w`, whose magnitude is
/// bounded by `Σ|w| · 255 ≤ 128 · 255 · K`; at `K = 2^16` that is
/// 2,139,095,040 < 2³¹ − 1, so the offset accumulator provably cannot
/// overflow for any input at or below this length — no per-model
/// analysis needed. Longer dots (none exist in practice: the structural
/// ceiling is K ≤ 2^16) fall back to AVX2. `mor lint --numeric` reports
/// the same bound per layer (`num.vnni`, [`crate::plan::ranges`]).
pub const VNNI_K_MAX: usize = 1 << 16;

/// int8 dot product with int32 accumulation.
///
/// The i32 accumulator cannot overflow: `mor lint --numeric`
/// ([`crate::plan::ranges`]) statically proves `Σ|w| · max|x| < 2³¹`
/// per filter for every compiled plan (diagnostic `num.acc`), and even
/// the structural ceiling K ≤ 2^16 gives `|Σ x·w| ≤ 2^16 · 128² = 2³⁰`.
/// The bound dominates every partial sum under any accumulation order,
/// so it covers the scalar chunks and the AVX2 lane sums alike. The
/// VNNI path accumulates in an offset domain with its own (wider)
/// bound — see [`VNNI_K_MAX`].
///
/// Dispatch is by [`super::isa::active`] (detection ∧ `MOR_ISA` ∧
/// [`super::isa::force`]); every tier is bit-identical, so the choice
/// is invisible to everything but the clock.
///
/// §Perf: products are formed in i16 (i8·i8 fits: |p| ≤ 16384) and widened
/// to i32 — this is the shape LLVM turns into `pmaddwd`-style SIMD with
/// `target-cpu=native`; the naive i32-product loop vectorizes much worse
/// (before/after in EXPERIMENTS.md §Perf).
#[inline]
pub fn dot_i8(x: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    #[cfg(all(target_arch = "x86_64", mor_avx512))]
    {
        if super::isa::vnni_enabled() && x.len() <= VNNI_K_MAX {
            // SAFETY: features checked at runtime; slices have equal length.
            return unsafe { dot_i8_vnni(x, w) };
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: feature checked at runtime; slices have equal length.
            return unsafe { dot_i8_avx2(x, w) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if super::isa::neon_enabled() {
            // SAFETY: NEON is baseline on aarch64; slices have equal length.
            return unsafe { dot_i8_neon(x, w) };
        }
    }
    dot_i8_scalar(x, w)
}

/// AVX2 dispatch predicate — re-exported from the single detection/
/// override point ([`super::isa`]); kept here because this is where the
/// historical call sites import it from.
pub use super::isa::avx2_enabled;

/// Portable fallback.
#[inline]
pub fn dot_i8_scalar(x: &[i8], w: &[i8]) -> i32 {
    let mut acc: i32 = 0;
    let n = x.len();
    let chunks = n / 16;
    for ci in 0..chunks {
        let base = ci * 16;
        let mut local: i32 = 0;
        for j in 0..16 {
            local += (x[base + j] as i16 * w[base + j] as i16) as i32;
        }
        acc += local;
    }
    for j in chunks * 16..n {
        acc += (x[j] as i16 * w[j] as i16) as i32;
    }
    acc
}

/// AVX2 path: sign-extend 16 i8 lanes to i16 (`vpmovsxbw`), multiply-add
/// pairs into i32 (`vpmaddwd`), accumulate in a 256-bit register.
/// Exact: i8·i8 products fit i16, pairwise sums fit i32, and the i32
/// lane accumulators cannot overflow — `mor lint --numeric` proves the
/// per-filter `Σ|w| · max|x|` bound (`num.acc`, [`crate::plan::ranges`])
/// which dominates every lane's partial sum.
///
/// # Safety
///
/// * The CPU must support AVX2 — callers dispatch through
///   [`avx2_enabled`] (`is_x86_feature_detected!`), never directly.
/// * `x` and `w` must have equal length (the unaligned 16-byte loads
///   index both slices by the same `i`, bounded by `x.len()`).
///
/// No alignment requirement: the loads are `_mm_loadu_si128`
/// (unaligned), and the tail past the last full 16-lane chunk is safe
/// slice-indexed scalar code.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(x: &[i8], w: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(x.len(), w.len());
    let n = x.len();
    // SAFETY: AVX2 is available per the fn contract. The only memory
    // operations are the two `_mm_loadu_si128` (unaligned) loads, and
    // `i + 16 <= n == x.len() == w.len()` bounds both inside their
    // slices; the tail loop is safe slice indexing.
    unsafe {
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i + 16 <= n {
            let xv =
                _mm256_cvtepi8_epi16(_mm_loadu_si128(x.as_ptr().add(i) as *const __m128i));
            let wv =
                _mm256_cvtepi8_epi16(_mm_loadu_si128(w.as_ptr().add(i) as *const __m128i));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(xv, wv));
            i += 16;
        }
        // horizontal sum of 8 i32 lanes
        let hi = _mm256_extracti128_si256(acc, 1);
        let lo = _mm256_castsi256_si128(acc);
        let s = _mm_add_epi32(hi, lo);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        let mut total = _mm_cvtsi128_si32(s);
        while i < n {
            total += (x[i] as i16 * w[i] as i16) as i32;
            i += 1;
        }
        total
    }
}

/// AVX-512 VNNI path: `vpdpbusd` multiplies **unsigned** bytes by signed
/// bytes, so the signed activations are lifted into the unsigned domain
/// with the offset trick — `x ⊕ 0x80` reinterpreted as u8 equals
/// `x + 128`, giving
///
/// ```text
/// Σ (x+128)·w  =  Σ x·w  +  128·Σ w
/// ```
///
/// and the true dot is recovered by subtracting `128·Σw`, where `Σw` is
/// accumulated in the same loop by a second `vpdpbusd` against an
/// all-ones unsigned vector. Exact by construction: both accumulations
/// are exact i32 sums (offset sum bounded by `128·255·K < 2³¹` for
/// `K ≤` [`VNNI_K_MAX`], which the dispatcher enforces; `128·|Σw| ≤
/// 2¹⁴·K ≤ 2³⁰`), and the algebra above is an identity over ℤ.
///
/// # Safety
///
/// * The CPU must support AVX-512 F+VNNI — callers dispatch through
///   [`super::isa::vnni_enabled`], never directly.
/// * `x` and `w` must have equal length, at most [`VNNI_K_MAX`] (the
///   unaligned 64-byte loads index both slices by the same `i`, bounded
///   by `x.len()`; the length cap is the overflow proof above).
#[cfg(all(target_arch = "x86_64", mor_avx512))]
#[target_feature(enable = "avx512f,avx512vnni")]
unsafe fn dot_i8_vnni(x: &[i8], w: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(x.len(), w.len());
    debug_assert!(x.len() <= VNNI_K_MAX);
    let n = x.len();
    // SAFETY: AVX-512 F+VNNI available per the fn contract. The only
    // memory operations are the `_mm512_loadu_si512` (unaligned) loads,
    // and `i + 64 <= n == x.len() == w.len()` bounds both inside their
    // slices; the tail loop is safe slice indexing.
    unsafe {
        let sign = _mm512_set1_epi8(-128i8); // 0x80: XOR flips the sign bit
        let ones = _mm512_set1_epi8(1);
        let mut acc = _mm512_setzero_si512();
        let mut wsum = _mm512_setzero_si512();
        let mut i = 0;
        while i + 64 <= n {
            let xv = _mm512_loadu_si512(x.as_ptr().add(i) as *const _);
            let wv = _mm512_loadu_si512(w.as_ptr().add(i) as *const _);
            // (x ⊕ 0x80) as u8 == x + 128
            acc = _mm512_dpbusd_epi32(acc, _mm512_xor_si512(xv, sign), wv);
            wsum = _mm512_dpbusd_epi32(wsum, ones, wv);
            i += 64;
        }
        let mut total =
            _mm512_reduce_add_epi32(acc) - 128 * _mm512_reduce_add_epi32(wsum);
        while i < n {
            total += (x[i] as i16 * w[i] as i16) as i32;
            i += 1;
        }
        total
    }
}

/// NEON path: `vmull_s8` widens i8×i8 products to i16 exactly
/// (|p| ≤ 16384), `vpadalq_s16` pairwise-widens and accumulates them
/// into four i32 lanes. Exact: the pairwise add happens *after*
/// widening to i32, so no i16 partial sum is ever formed, and the lane
/// accumulators inherit the `Σ|w| · max|x|` bound (`num.acc`) that
/// dominates every lane subset.
///
/// # Safety
///
/// * NEON must be available — guaranteed by the aarch64 baseline;
///   callers dispatch through [`super::isa::neon_enabled`].
/// * `x` and `w` must have equal length (the 16-byte `vld1q_s8` loads
///   index both slices by the same `i`, bounded by `x.len()`).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_i8_neon(x: &[i8], w: &[i8]) -> i32 {
    use std::arch::aarch64::*;
    debug_assert_eq!(x.len(), w.len());
    let n = x.len();
    // SAFETY: NEON is baseline on aarch64. The only memory operations
    // are the `vld1q_s8` loads, and `i + 16 <= n == x.len() == w.len()`
    // bounds both inside their slices; the tail loop is safe indexing.
    unsafe {
        let mut acc = vdupq_n_s32(0);
        let mut i = 0;
        while i + 16 <= n {
            let xv = vld1q_s8(x.as_ptr().add(i));
            let wv = vld1q_s8(w.as_ptr().add(i));
            let lo = vmull_s8(vget_low_s8(xv), vget_low_s8(wv));
            let hi = vmull_s8(vget_high_s8(xv), vget_high_s8(wv));
            acc = vpadalq_s16(acc, lo);
            acc = vpadalq_s16(acc, hi);
            i += 16;
        }
        let mut total = vaddvq_s32(acc);
        while i < n {
            total += (x[i] as i16 * w[i] as i16) as i32;
            i += 1;
        }
        total
    }
}

/// Sparse int8 dot product over a compressed nonzero-lane list:
/// `sum(val[j] * w[idx[j]])`. Bit-identical to `dot_i8(x, w)` when
/// `(idx, val)` lists exactly the nonzero lanes of `x` — the skipped
/// lanes are zero and contribute exactly 0 to the integer sum.
///
/// §Sparse: four independent accumulator streams so the gather-multiply
/// chains pipeline; products form in i16 (exact for i8·i8) and widen to
/// i32. The four partial accumulators cannot overflow: the proven
/// `Σ|w| · max|x|` bound of `mor lint --numeric` (`num.acc`,
/// [`crate::plan::ranges`]) covers every lane subset, so it holds for
/// each stream individually and for their sum.
#[inline]
pub fn dot_i8_sparse(idx: &[u16], val: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(idx.len(), val.len());
    let n = idx.len();
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
    for ci in 0..chunks {
        let b = ci * 4;
        a0 += (val[b] as i16 * w[idx[b] as usize] as i16) as i32;
        a1 += (val[b + 1] as i16 * w[idx[b + 1] as usize] as i16) as i32;
        a2 += (val[b + 2] as i16 * w[idx[b + 2] as usize] as i16) as i32;
        a3 += (val[b + 3] as i16 * w[idx[b + 3] as usize] as i16) as i32;
    }
    let mut acc = a0 + a1 + a2 + a3;
    for j in chunks * 4..n {
        acc += (val[j] as i16 * w[idx[j] as usize] as i16) as i32;
    }
    acc
}

/// Doubly-sparse int8 dot product: two compressed nonzero-lane lists,
/// both sorted ascending by lane index (gather and prepack both build
/// them by a linear scan, so this holds by construction), merged with a
/// two-pointer walk — only lanes present in **both** lists multiply.
/// Bit-identical to `dot_i8(x, w)` when the lists exactly cover the
/// nonzero lanes of `x` and `w`: every elided product has a zero factor.
///
/// §Weights: cost is O(nnz_x + nnz_w) independent of K — the
/// multiplicative-sparsity payoff Cnvlutin2/SparseNN predict. The i32
/// accumulator is exact: the intersection sums a subset of the full
/// dot's lanes, and the `Σ|w| · max|x|` bound `mor lint --numeric`
/// proves (`num.acc`, [`crate::plan::ranges`]) dominates every lane
/// subset.
#[inline]
pub fn dot_i8_sparse_sparse(a_idx: &[u16], a_val: &[i8], b_idx: &[u16], b_val: &[i8]) -> i32 {
    debug_assert_eq!(a_idx.len(), a_val.len());
    debug_assert_eq!(b_idx.len(), b_val.len());
    let (mut i, mut j) = (0usize, 0usize);
    let mut acc = 0i32;
    while i < a_idx.len() && j < b_idx.len() {
        let (ai, bj) = (a_idx[i], b_idx[j]);
        if ai == bj {
            acc += (a_val[i] as i16 * b_val[j] as i16) as i32;
            i += 1;
            j += 1;
        } else if ai < bj {
            i += 1;
        } else {
            j += 1;
        }
    }
    acc
}

/// Lanes where the activation is nonzero but the weight is zero — the
/// ineffectual-weight pool among *performed* MACs, disjoint from the
/// input-zero pool (`x == 0` lanes) by construction. Both engines count
/// `OpsStats::macs_skipped_weight_zero` with exactly this definition:
/// the scalar reference calls this directly; the tiled engine computes
/// the same quantity as `nnz(x) - popcount(nzmask(x) & wmask(w))`.
#[inline]
pub fn weight_zero_lanes(x: &[i8], w: &[i8]) -> u64 {
    debug_assert_eq!(x.len(), w.len());
    x.iter().zip(w).filter(|&(&xv, &wv)| xv != 0 && wv == 0).count() as u64
}

/// Quantize a float slice to int8 with round-half-away and saturation,
/// matching jnp.clip(jnp.round(x / sx), -127, 127).
///
/// NOTE jnp.round is round-half-to-EVEN; we match it exactly because the
/// calibration taps were produced by the jnp path and bit-equality between
/// the rust engine and the python artifacts keeps the fitted lines valid.
#[inline]
pub fn quantize_i8(x: &[f32], sx: f32, out: &mut Vec<i8>) {
    out.clear();
    out.reserve(x.len());
    let inv = 1.0 / sx;
    for &v in x {
        out.push(quantize_one(v, inv));
    }
}

#[inline]
pub fn quantize_one(v: f32, inv_sx: f32) -> i8 {
    let scaled = v * inv_sx;
    let r = round_half_even(scaled);
    r.clamp(-127.0, 127.0) as i8
}

/// f32 round-half-to-even (banker's rounding), like jnp.round / IEEE 754
/// roundTiesToEven.
#[inline]
pub fn round_half_even(v: f32) -> f32 {
    // `round_ties_even` stabilized in rust 1.77
    v.round_ties_even()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::property;

    fn dot_ref(x: &[i8], w: &[i8]) -> i64 {
        x.iter().zip(w).map(|(&a, &b)| a as i64 * b as i64).sum()
    }

    #[test]
    fn dot_matches_reference() {
        property("dot_i8 == i64 reference", 300, |g| {
            let n = g.usize(0, 600);
            let x = g.vec_i8(n);
            let w = g.vec_i8(n);
            let got = dot_i8(&x, &w) as i64;
            let want = dot_ref(&x, &w);
            crate::prop_assert!(g, got == want, "n={n} got={got} want={want}");
            Ok(())
        });
    }

    #[test]
    fn dot_extreme_no_overflow() {
        let k = 1440; // largest K in the model zoo
        let x = vec![-128i8; k];
        let w = vec![-128i8; k];
        assert_eq!(dot_i8(&x, &w), 128 * 128 * k as i32);
    }

    #[test]
    #[cfg_attr(miri, ignore = "2^16-lane dot is too slow interpreted")]
    fn dot_boundary_k_max_all_extreme() {
        // the structural ceiling: K = 2^16 of all-(−128) products is
        // exactly 2^30 — half of i32::MAX, the kernels' absolute worst
        let k = 1 << 16;
        let x = vec![-128i8; k];
        let w = vec![-128i8; k];
        assert_eq!(dot_i8(&x, &w), 1 << 30);
        assert_eq!(dot_i8_scalar(&x, &w), 1 << 30);
    }

    #[test]
    #[cfg_attr(miri, ignore = "2^16-lane dot is too slow interpreted")]
    fn dot_boundary_k_max_vnni_offset_worst_case() {
        // worst case for the VNNI offset accumulator: x = +127 (255 in
        // the unsigned domain) against w = −128 at the K = 2^16 ceiling
        // puts the offset sum at −2,139,095,040 — ~8.4M inside i32 —
        // and the 128·Σw correction must restore the true dot exactly.
        // Runs on every host (dispatch picks the best tier; the bound
        // argument is only *needed* on VNNI ones).
        let k = 1usize << 16;
        let x = vec![127i8; k];
        let w = vec![-128i8; k];
        let want = 127 * -128 * k as i32;
        assert_eq!(dot_i8(&x, &w), want);
        assert_eq!(dot_i8_scalar(&x, &w), want);
    }

    #[test]
    fn dot_tail_lanes_cross_every_simd_width() {
        // lengths straddling the 16-lane (AVX2/NEON) and 64-lane (VNNI)
        // chunk widths force the scalar tails of each kernel
        for n in [0usize, 1, 15, 16, 17, 63, 64, 65, 127, 128, 129] {
            let x: Vec<i8> = (0..n).map(|i| (i as i8).wrapping_mul(37)).collect();
            let w: Vec<i8> = (0..n).map(|i| (i as i8).wrapping_mul(91).wrapping_sub(3)).collect();
            assert_eq!(dot_i8(&x, &w) as i64, dot_ref(&x, &w), "n={n}");
        }
    }

    /// Compress `x` into the (idx, val) nonzero-lane lists the sparse
    /// kernel consumes.
    fn compress(x: &[i8]) -> (Vec<u16>, Vec<i8>) {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &v) in x.iter().enumerate() {
            if v != 0 {
                idx.push(i as u16);
                val.push(v);
            }
        }
        (idx, val)
    }

    #[test]
    fn sparse_dot_matches_dense_at_every_density() {
        property("dot_i8_sparse == dot_i8 on compressed lanes", 300, |g| {
            let n = g.usize(0, 600);
            // density spans dense → empty, including the all-zero patch
            let keep_pct = g.usize(0, 100);
            let x: Vec<i8> = (0..n)
                .map(|_| {
                    if g.usize(0, 99) < keep_pct {
                        g.rng().int8()
                    } else {
                        0
                    }
                })
                .collect();
            let w = g.vec_i8(n);
            let (idx, val) = compress(&x);
            let got = dot_i8_sparse(&idx, &val, &w);
            let want = dot_i8(&x, &w);
            crate::prop_assert!(
                g,
                got == want,
                "n={n} nnz={} got={got} want={want}",
                idx.len()
            );
            Ok(())
        });
    }

    #[test]
    fn sparse_dot_empty_lanes_is_zero() {
        assert_eq!(dot_i8_sparse(&[], &[], &[1, 2, 3]), 0);
    }

    #[test]
    fn sparse_dot_extreme_no_overflow() {
        // same worst-case bound as the dense kernel
        let k = 1440usize;
        let idx: Vec<u16> = (0..k as u16).collect();
        let val = vec![-128i8; k];
        let w = vec![-128i8; k];
        assert_eq!(dot_i8_sparse(&idx, &val, &w), 128 * 128 * k as i32);
    }

    #[test]
    #[cfg_attr(miri, ignore = "2^16-lane dot is too slow interpreted")]
    fn sparse_dot_boundary_k_max_all_extreme() {
        // fully dense compressed list at the K = 2^16 ceiling: the four
        // accumulator streams sum to exactly 2^30 with no overflow
        let k = 1usize << 16;
        let idx: Vec<u16> = (0..k).map(|i| i as u16).collect();
        let val = vec![-128i8; k];
        let w = vec![-128i8; k];
        assert_eq!(dot_i8_sparse(&idx, &val, &w), 1 << 30);
    }

    #[test]
    fn sparse_sparse_dot_matches_dense_at_every_density_pair() {
        property("dot_i8_sparse_sparse == dot_i8 on compressed pairs", 300, |g| {
            let n = g.usize(0, 600);
            let keep_x = g.usize(0, 100);
            let keep_w = g.usize(0, 100);
            let mk = |g: &mut crate::util::prop::Gen, keep: usize| -> Vec<i8> {
                (0..n)
                    .map(|_| if g.usize(0, 99) < keep { g.rng().int8() } else { 0 })
                    .collect()
            };
            let x = mk(g, keep_x);
            let w = mk(g, keep_w);
            let (xi, xv) = compress(&x);
            let (wi, wv) = compress(&w);
            let got = dot_i8_sparse_sparse(&xi, &xv, &wi, &wv);
            let want = dot_i8(&x, &w);
            crate::prop_assert!(
                g,
                got == want,
                "n={n} nnz_x={} nnz_w={} got={got} want={want}",
                xi.len(),
                wi.len()
            );
            // operand order is symmetric
            let swapped = dot_i8_sparse_sparse(&wi, &wv, &xi, &xv);
            crate::prop_assert!(g, swapped == want, "swap got={swapped} want={want}");
            Ok(())
        });
    }

    #[test]
    fn sparse_sparse_dot_empty_and_disjoint() {
        assert_eq!(dot_i8_sparse_sparse(&[], &[], &[0, 1], &[5, 5]), 0);
        assert_eq!(dot_i8_sparse_sparse(&[0, 2], &[3, 3], &[], &[]), 0);
        // disjoint supports never multiply
        assert_eq!(dot_i8_sparse_sparse(&[0, 2, 4], &[7, 7, 7], &[1, 3, 5], &[7, 7, 7]), 0);
    }

    #[test]
    fn sparse_sparse_dot_extreme_no_overflow() {
        let k = 1440usize;
        let idx: Vec<u16> = (0..k as u16).collect();
        let val = vec![-128i8; k];
        assert_eq!(
            dot_i8_sparse_sparse(&idx, &val, &idx, &val),
            128 * 128 * k as i32
        );
    }

    #[test]
    #[cfg_attr(miri, ignore = "2^16-lane dot is too slow interpreted")]
    fn sparse_sparse_dot_boundary_k_max_all_extreme() {
        // full-overlap intersection at the K = 2^16 ceiling: every lane
        // multiplies, the sum is exactly 2^30
        let k = 1usize << 16;
        let idx: Vec<u16> = (0..k).map(|i| i as u16).collect();
        let val = vec![-128i8; k];
        assert_eq!(dot_i8_sparse_sparse(&idx, &val, &idx, &val), 1 << 30);
    }

    #[test]
    fn weight_zero_lanes_counts_only_live_x_dead_w() {
        //        x: 1  0  2  0  3
        //        w: 0  0  5  6  0
        // wz lanes: ^           ^   (x != 0 && w == 0)
        assert_eq!(weight_zero_lanes(&[1, 0, 2, 0, 3], &[0, 0, 5, 6, 0]), 2);
        assert_eq!(weight_zero_lanes(&[], &[]), 0);
        property("weight_zero_lanes + effectual + x-zero == K", 100, |g| {
            let n = g.usize(0, 300);
            let x = g.vec_i8(n);
            let w = g.vec_i8(n);
            let wz = weight_zero_lanes(&x, &w);
            let xz = x.iter().filter(|&&v| v == 0).count() as u64;
            let eff = x
                .iter()
                .zip(&w)
                .filter(|&(&xv, &wv)| xv != 0 && wv != 0)
                .count() as u64;
            crate::prop_assert!(g, wz + xz + eff == n as u64, "lanes must partition K");
            Ok(())
        });
    }

    #[test]
    fn round_half_even_cases() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.4999), 1.0);
    }

    #[test]
    fn quantize_saturates() {
        let mut out = Vec::new();
        quantize_i8(&[10.0, -10.0, 0.0, 0.004], 0.01, &mut out);
        assert_eq!(out, vec![127, -127, 0, 0]); // 0.4 rounds to 0
        quantize_i8(&[0.015], 0.01, &mut out);
        assert_eq!(out, vec![2]); // 1.5 → 2? no: half-even(1.5) = 2
    }
}
