//! Cache-blocked, row-batched int8 GEMM micro-kernels for the tiled engine.
//!
//! The per-neuron GEMV path (one `dot_i8` per output position) re-reads the
//! patch once per filter and re-slices the weight tensor on every call. The
//! kernels here restructure that dataflow the way SparseNN/Cnvlutin-class
//! accelerators do: weights are prepacked **once per model** into
//! filter-major, zero-padded, contiguous blocks ([`PrepackedFilters`]),
//! patches are gathered into row tiles of [`TILE_ROWS`] ([`PatchTile`]),
//! and the micro-kernel evaluates up to [`NR`] filters per patch load
//! (AVX2 `vpmovsxbw` + `vpmaddwd`, with a portable fallback).
//!
//! A tile's rows need not come from one sample: the batch-native forward
//! ([`crate::predictor::exec::run_batch`]) fills tiles across request
//! boundaries, so the serving coordinator's micro-batches keep these
//! kernels running at full occupancy even when each request contributes
//! only a handful of rows (e.g. an FC layer's single row per request).
//!
//! The engine is triple-sided sparse: besides the predictor's
//! output-side skipping, [`PatchTile`] optionally carries a compressed
//! nonzero-lane list per patch and the `*_sparse` kernel variants
//! iterate only those lanes — Cnvlutin2/SparseNN-style
//! ineffectual-input elision, selected per tile row by a density
//! crossover ([`sparse_auto_cutoff`]) — and [`PrepackedFilters`]
//! carries a compressed nonzero-lane list per *filter*, which the
//! `*_wsparse` kernel variants walk instead of the dense weight row
//! (Cnvlutin2's weight-lane elision), selected per layer at
//! plan-compile time from the frozen prepack density
//! ([`crate::engine::crossover::weight_sparse_cutoff`]). Where a
//! compressed filter meets a compressed patch the `*_wsparse_x`
//! variants run the doubly-sparse index-intersection dot
//! ([`dot::dot_i8_sparse_sparse`]).
//!
//! All kernels are exact int8×int8→int32 sums, so the tiled engine is
//! bit-identical to the scalar reference path by construction — the
//! property suite in `rust/tests/engine_equivalence.rs` proves it, and
//! `rust/tests/input_sparsity.rs` / `rust/tests/weight_sparsity.rs`
//! prove the sparse/dense kernel choices are invisible in logits,
//! stats and traces.

use crate::engine::dot;
use crate::model::{Model, Node};
use crate::util::bits::PackedVec;
use crate::util::reserve_capacity;

/// Filters evaluated per micro-kernel invocation (accumulator registers).
pub const NR: usize = 8;
/// Patches per row tile: a filter block loaded once serves this many rows.
pub const TILE_ROWS: usize = 16;
/// Dot-length alignment of prepacked filters and tile rows (one 128-bit
/// int8 load, sign-extended to a 256-bit i16 vector).
pub const K_ALIGN: usize = 16;

/// Round a dot length up to the kernel alignment.
#[inline]
pub fn pad_k(k_len: usize) -> usize {
    k_len.max(1).div_ceil(K_ALIGN) * K_ALIGN
}

/// One layer's weights, repacked filter-major with each filter zero-padded
/// to [`K_ALIGN`] so the micro-kernel needs no tail handling. Padding lanes
/// multiply against zero patch lanes and contribute nothing, keeping every
/// dot product exactly equal to the unpadded `dot_i8`.
///
/// Alongside the dense layout the prepack scans every filter for zero
/// weight lanes and records, per filter:
///
/// * a nonzero-weight **bitmask** ([`PrepackedFilters::wmask`],
///   [`PrepackedFilters::mask_words`] u64 words per filter, bits beyond
///   `k_len` clear) — intersected with the tile's nonzero-activation
///   mask for the engine-independent `macs_skipped_weight_zero`
///   accounting;
/// * a compressed **lane list** ([`PrepackedFilters::lanes`], `(u16
///   idx, i8 w)` sorted ascending by lane — the mirror of
///   [`PatchTile::lanes`]) that the `*_wsparse` kernels walk instead of
///   the dense row. Lists are only built when `k_len` fits the u16
///   index range ([`SPARSE_K_MAX`]; [`PrepackedFilters::has_lanes`]),
///   exactly like the input side's fallback.
///
/// Both views describe **true zeros** in the weights as loaded — any
/// magnitude pruning (`WeightSparsity::Threshold`) has already zeroed
/// lanes at session build, so one compressed format serves the `exact`
/// and `threshold` modes alike, and the prepack stays config-free.
#[derive(Clone, Debug)]
pub struct PrepackedFilters {
    pub cout: usize,
    pub k_len: usize,
    pub k_pad: usize,
    data: Vec<i8>,
    /// Nonzero-weight bitmask, `mask_words` words per filter.
    w_mask: Vec<u64>,
    /// u64 words per filter in `w_mask` (= `k_len.div_ceil(64)`).
    mask_words: usize,
    /// Compressed weight lanes: filter `f` owns
    /// `w_idx[w_off[f]..w_off[f+1]]` / `w_val[..]`. Empty (with
    /// `w_off` empty) when `k_len > SPARSE_K_MAX`.
    w_idx: Vec<u16>,
    w_val: Vec<i8>,
    w_off: Vec<usize>,
    /// Nonzero weight lanes across all filters (mask popcount — present
    /// even when the lane lists are not).
    nnz_total: usize,
    /// Per-filter `Σ max(w, 0)` — with `w_neg_sum`, the signed
    /// magnitude decomposition the numeric analyzer
    /// ([`crate::plan::ranges`]) turns into per-filter accumulator
    /// bounds (`Σ|w| · max|x|`) instead of the blanket `127·128·K`
    /// worst case.
    w_pos_sum: Vec<i64>,
    /// Per-filter `Σ min(w, 0)` (non-positive).
    w_neg_sum: Vec<i64>,
}

impl PrepackedFilters {
    pub fn new(node: &Node) -> PrepackedFilters {
        let k_len = node.k_len();
        let cout = node.cout();
        let k_pad = pad_k(k_len);
        let mask_words = k_len.div_ceil(64);
        let build_lanes = k_len <= SPARSE_K_MAX;
        let mut data = vec![0i8; cout * k_pad];
        let mut w_mask = vec![0u64; cout * mask_words];
        let mut w_idx = Vec::new();
        let mut w_val = Vec::new();
        let mut w_off = Vec::new();
        if build_lanes {
            w_off.reserve(cout + 1);
            w_off.push(0);
        }
        let mut nnz_total = 0usize;
        let mut w_pos_sum = vec![0i64; cout];
        let mut w_neg_sum = vec![0i64; cout];
        for f in 0..cout {
            data[f * k_pad..f * k_pad + k_len].copy_from_slice(node.filter(f));
            let mask = &mut w_mask[f * mask_words..(f + 1) * mask_words];
            for (k, &w) in node.filter(f).iter().enumerate() {
                if w != 0 {
                    mask[k / 64] |= 1u64 << (k % 64);
                    nnz_total += 1;
                    if build_lanes {
                        w_idx.push(k as u16);
                        w_val.push(w);
                    }
                    if w > 0 {
                        w_pos_sum[f] += w as i64;
                    } else {
                        w_neg_sum[f] += w as i64;
                    }
                }
            }
            if build_lanes {
                w_off.push(w_idx.len());
            }
        }
        PrepackedFilters {
            cout,
            k_len,
            k_pad,
            data,
            w_mask,
            mask_words,
            w_idx,
            w_val,
            w_off,
            nnz_total,
            w_pos_sum,
            w_neg_sum,
        }
    }

    /// Padded weight row for filter `f` (length `k_pad`).
    #[inline]
    pub fn filter(&self, f: usize) -> &[i8] {
        &self.data[f * self.k_pad..(f + 1) * self.k_pad]
    }

    /// Nonzero-weight bitmask of filter `f` ([`PrepackedFilters::mask_words`]
    /// u64 words, bits beyond `k_len` clear).
    #[inline]
    pub fn wmask(&self, f: usize) -> &[u64] {
        &self.w_mask[f * self.mask_words..(f + 1) * self.mask_words]
    }

    /// u64 words per filter bitmask (= `k_len.div_ceil(64)`).
    #[inline]
    pub fn mask_words(&self) -> usize {
        self.mask_words
    }

    /// Whether the per-filter compressed lane lists were built (`k_len`
    /// within the u16 index range — mirrors [`PatchTile::has_sparse`]).
    #[inline]
    pub fn has_lanes(&self) -> bool {
        !self.w_off.is_empty()
    }

    /// Compressed nonzero weight lanes of filter `f`: `(indices,
    /// values)`, sorted ascending by lane index. Only valid when
    /// [`PrepackedFilters::has_lanes`] is true.
    #[inline]
    pub fn lanes(&self, f: usize) -> (&[u16], &[i8]) {
        let (a, b) = (self.w_off[f], self.w_off[f + 1]);
        (&self.w_idx[a..b], &self.w_val[a..b])
    }

    /// Signed weight-sum decomposition of filter `f`:
    /// `(Σ max(w, 0), Σ min(w, 0))`, both exact in i64. Against an
    /// activation interval `[qlo, qhi]` the exact dot range is
    /// `[pos·qlo + neg·qhi, pos·qhi + neg·qlo]` — the per-filter bound
    /// [`crate::plan::ranges`] proves `num.acc` with.
    #[inline]
    pub fn filter_sums(&self, f: usize) -> (i64, i64) {
        (self.w_pos_sum[f], self.w_neg_sum[f])
    }

    /// `Σ|w|` of filter `f` — times `max|x|` this bounds the magnitude
    /// of **every** partial sum under **any** accumulation order or lane
    /// subset (each elided lane contributes 0), which is why one number
    /// covers the dense, input-sparse, weight-sparse and doubly-sparse
    /// kernels alike.
    #[inline]
    pub fn abs_weight_sum(&self, f: usize) -> i64 {
        self.w_pos_sum[f] - self.w_neg_sum[f]
    }

    /// Nonzero-weight density across the whole layer (`1.0` for a layer
    /// with no zero lane; `0.0` for an all-zero layer) — the quantity
    /// the plan compiler compares against
    /// [`crate::engine::crossover::weight_sparse_cutoff`].
    #[inline]
    pub fn density(&self) -> f32 {
        self.nnz_total as f32 / (self.cout * self.k_len).max(1) as f32
    }
}

/// Popcount of the lane-wise AND of two equal-length bitmasks — the
/// number of lanes nonzero in **both** a patch and a filter. The tiled
/// engine's weight-zero accounting is
/// `nnz(x) - masked_nnz(xmask, wmask)`, identical by construction to
/// the scalar reference's [`dot::weight_zero_lanes`] scan.
#[inline]
pub fn masked_nnz(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x & y).count_ones() as u64).sum()
}

/// Prepacked weight blocks for every compute node of a model, built once
/// (see [`crate::model::Model::prepacked`]) and shared read-only across
/// forward passes and worker threads.
#[derive(Clone, Debug, Default)]
pub struct PrepackedModel {
    pub layers: Vec<Option<PrepackedFilters>>,
}

impl PrepackedModel {
    pub fn new(model: &Model) -> PrepackedModel {
        PrepackedModel {
            layers: model
                .nodes
                .iter()
                .map(|n| n.is_compute().then(|| PrepackedFilters::new(n)))
                .collect(),
        }
    }

    /// Prepacked filters of compute node `i`.
    #[inline]
    pub fn layer(&self, i: usize) -> &PrepackedFilters {
        self.layers[i]
            .as_ref()
            .expect("prepacked filters requested for a non-compute node")
    }
}

/// A tile of up to [`TILE_ROWS`] im2col patches, each zero-padded to the
/// prepack alignment, plus the packed ±1 activation planes the binary
/// predictor consumes and (optionally) a compressed nonzero-lane
/// representation per patch for the input-sparsity kernels. The buffers
/// live in a [`crate::plan::Workspace`] (one tile per row-tile worker)
/// and are re-dimensioned per layer with [`PatchTile::reset`], which
/// never shrinks capacity — steady-state forwards re-use one high-water
/// allocation across every layer.
pub struct PatchTile {
    pub k_len: usize,
    pub k_pad: usize,
    data: Vec<i8>,
    packed: Vec<PackedVec>,
    /// Nonzero lanes per row (always tracked — it feeds the
    /// `macs_skipped_input_zero` accounting even when the sparse
    /// kernels are disabled).
    nnz: [usize; TILE_ROWS],
    /// Compressed nonzero-lane lists, row-major with stride `k_len`
    /// (`nz_idx[r*k_len..r*k_len+nnz[r]]` are the lane indices,
    /// `nz_val` the matching activation values). Only valid while
    /// `sparse` is set (builder on and `k_len` within the u16 range).
    nz_idx: Vec<u16>,
    nz_val: Vec<i8>,
    /// Whether the compressed-lane builder is active for this layer.
    sparse: bool,
    /// Nonzero-activation bitmask per row, `mask_words` words per row
    /// (always tracked, copied from `PatchGather::nzmask` — feeds the
    /// `macs_skipped_weight_zero` accounting via [`masked_nnz`]).
    xmask: Vec<u64>,
    /// u64 words per row bitmask (= `k_len.div_ceil(64)`).
    mask_words: usize,
}

/// Largest dot length the compressed u16 lane indices can address.
pub const SPARSE_K_MAX: usize = u16::MAX as usize + 1;

impl PatchTile {
    /// `build_sparse` enables the compressed-lane builder; whether a
    /// given row actually pays the compression pass is decided per row
    /// at [`PatchTile::set_row`] time (`InputSparsity::Off` passes
    /// false here). Dot lengths beyond [`SPARSE_K_MAX`] silently fall
    /// back to dense-only.
    pub fn new(k_len: usize, build_sparse: bool) -> PatchTile {
        let mut t = PatchTile::empty();
        t.reset(k_len, build_sparse);
        t
    }

    /// An unsized tile (no heap allocation) — [`PatchTile::reset`]
    /// dimensions it before first use.
    pub fn empty() -> PatchTile {
        PatchTile {
            k_len: 0,
            k_pad: 0,
            data: Vec::new(),
            packed: Vec::new(),
            nnz: [0; TILE_ROWS],
            nz_idx: Vec::new(),
            nz_val: Vec::new(),
            sparse: false,
            xmask: Vec::new(),
            mask_words: 0,
        }
    }

    /// Re-dimension the tile for a layer with dot length `k_len`,
    /// reusing the existing buffers (capacity never shrinks, so after
    /// the largest layer has been seen this allocates nothing). The
    /// patch storage is re-zeroed so the alignment-padding lanes of
    /// every row are 0 regardless of what a previous layer left behind
    /// — `set_row` only writes the first `k_len` bytes of a row and the
    /// dense kernels rely on zero padding for exactness.
    pub fn reset(&mut self, k_len: usize, build_sparse: bool) {
        self.k_len = k_len;
        self.k_pad = pad_k(k_len);
        self.sparse = build_sparse && k_len <= SPARSE_K_MAX;
        self.data.clear();
        self.data.resize(TILE_ROWS * self.k_pad, 0);
        let words = k_len.div_ceil(64);
        if self.packed.len() < TILE_ROWS {
            self.packed.resize_with(TILE_ROWS, || PackedVec::zeros(0));
        }
        for p in &mut self.packed {
            p.bits.clear();
            p.bits.resize(words, 0);
            p.valid.clear();
            p.valid.resize(words, 0);
            p.len = k_len;
        }
        self.nnz = [0; TILE_ROWS];
        self.mask_words = words;
        self.xmask.clear();
        self.xmask.resize(TILE_ROWS * words, 0);
        if self.sparse {
            // no clear: `lanes(r)` only ever reads the prefix `set_row`
            // wrote for row r, so stale tails need no re-zeroing — this
            // avoids a per-layer memset of up to TILE_ROWS * k_len lanes
            self.nz_idx.resize(TILE_ROWS * k_len, 0);
            self.nz_val.resize(TILE_ROWS * k_len, 0);
        }
    }

    /// Grow the tile's buffers so a later [`PatchTile::reset`] at any
    /// dot length up to `k_len` (with compressed lanes up to
    /// `lanes_k_len`; pass 0 when the lane builder never runs) is
    /// allocation-free — warmup presizing for workspaces. Contents and
    /// current dimensions are untouched.
    pub fn reserve(&mut self, k_len: usize, lanes_k_len: usize) {
        reserve_capacity(&mut self.data, TILE_ROWS * pad_k(k_len));
        if self.packed.len() < TILE_ROWS {
            self.packed.resize_with(TILE_ROWS, || PackedVec::zeros(0));
        }
        let words = k_len.div_ceil(64);
        for p in &mut self.packed {
            reserve_capacity(&mut p.bits, words);
            reserve_capacity(&mut p.valid, words);
        }
        reserve_capacity(&mut self.xmask, TILE_ROWS * words);
        let lk = lanes_k_len.min(SPARSE_K_MAX);
        reserve_capacity(&mut self.nz_idx, TILE_ROWS * lk);
        reserve_capacity(&mut self.nz_val, TILE_ROWS * lk);
    }

    /// Store one gathered patch (its packed sign plane, nonzero-lane
    /// bitmask, nonzero count and — when `build_lanes` is set —
    /// compressed lane lists) as tile row `r`. `nnz` and `nzmask` are
    /// the patch's nonzero-lane count and bitmask, tracked by
    /// [`crate::engine::PatchGather`] during the gather.
    ///
    /// `build_lanes` is the caller's per-row kernel decision: the
    /// O(k_len) compression pass only runs for rows that will actually
    /// use the sparse kernel, so dense rows under `InputSparsity::Auto`
    /// pay nothing beyond the one density compare. [`PatchTile::lanes`]
    /// is only valid for rows stored with `build_lanes = true`.
    #[inline]
    pub fn set_row(
        &mut self,
        r: usize,
        patch: &[i8],
        packed: &PackedVec,
        nnz: usize,
        nzmask: &[u64],
        build_lanes: bool,
    ) {
        debug_assert_eq!(patch.len(), self.k_len);
        debug_assert_eq!(nzmask.len(), self.mask_words);
        self.data[r * self.k_pad..r * self.k_pad + self.k_len].copy_from_slice(patch);
        let p = &mut self.packed[r];
        p.bits.copy_from_slice(&packed.bits);
        p.valid.copy_from_slice(&packed.valid);
        p.len = packed.len;
        self.nnz[r] = nnz;
        self.xmask[r * self.mask_words..(r + 1) * self.mask_words].copy_from_slice(nzmask);
        if build_lanes && self.sparse {
            let base = r * self.k_len;
            let mut n = 0usize;
            for (i, &v) in patch.iter().enumerate() {
                if v != 0 {
                    self.nz_idx[base + n] = i as u16;
                    self.nz_val[base + n] = v;
                    n += 1;
                }
            }
            debug_assert_eq!(n, nnz, "gather nnz disagrees with the patch content");
        }
    }

    /// Padded patch for tile row `r` (length `k_pad`).
    #[inline]
    pub fn patch(&self, r: usize) -> &[i8] {
        &self.data[r * self.k_pad..(r + 1) * self.k_pad]
    }

    /// Packed ±1 activation plane for tile row `r`.
    #[inline]
    pub fn packed(&self, r: usize) -> &PackedVec {
        &self.packed[r]
    }

    /// Nonzero lanes of tile row `r`'s patch.
    #[inline]
    pub fn nnz(&self, r: usize) -> usize {
        self.nnz[r]
    }

    /// Nonzero-activation bitmask of tile row `r`'s patch
    /// ([`mask_words`](PatchTile::reset) u64 words, bits beyond `k_len`
    /// clear) — [`masked_nnz`] against a filter's
    /// [`PrepackedFilters::wmask`] yields the effectual-lane count.
    #[inline]
    pub fn xmask(&self, r: usize) -> &[u64] {
        &self.xmask[r * self.mask_words..(r + 1) * self.mask_words]
    }

    /// Whether the compressed-lane lists are being built for this tile.
    #[inline]
    pub fn has_sparse(&self) -> bool {
        self.sparse
    }

    /// Heap bytes currently held (workspace accounting).
    pub fn heap_bytes(&self) -> usize {
        self.data.capacity()
            + self
                .packed
                .iter()
                .map(|p| (p.bits.capacity() + p.valid.capacity()) * 8)
                .sum::<usize>()
            + self.nz_idx.capacity() * 2
            + self.nz_val.capacity()
            + self.xmask.capacity() * 8
    }

    /// Compressed nonzero lanes of tile row `r`: `(indices, values)`,
    /// both of length [`PatchTile::nnz`]`(r)`. Only valid when
    /// [`PatchTile::has_sparse`] is true.
    #[inline]
    pub fn lanes(&self, r: usize) -> (&[u16], &[i8]) {
        let base = r * self.k_len;
        (
            &self.nz_idx[base..base + self.nnz[r]],
            &self.nz_val[base..base + self.nnz[r]],
        )
    }
}

/// Evaluate a contiguous block of `nf <= NR` filters (`f0..f0+nf`) against
/// one padded patch. `out[j]` receives the exact int32 dot of the patch
/// with filter `f0 + j`.
pub fn dot_block(patch: &[i8], pf: &PrepackedFilters, f0: usize, nf: usize, out: &mut [i32; NR]) {
    debug_assert!(nf <= NR && f0 + nf <= pf.cout);
    debug_assert_eq!(patch.len(), pf.k_pad);
    #[cfg(all(target_arch = "x86_64", mor_avx512))]
    {
        if super::isa::vnni_enabled() && pf.k_pad <= dot::VNNI_K_MAX {
            let mut ptrs = [std::ptr::null::<i8>(); NR];
            let mut sums = [0i32; NR];
            for j in 0..nf {
                ptrs[j] = pf.filter(f0 + j).as_ptr();
                let (pos, neg) = pf.filter_sums(f0 + j);
                sums[j] = (pos + neg) as i32;
            }
            // SAFETY: features checked; every pointer addresses k_pad
            // bytes and patch.len() == k_pad (multiples of K_ALIGN);
            // k_pad ≤ VNNI_K_MAX is the offset-overflow bound.
            unsafe { dot_block_vnni(patch.as_ptr(), &ptrs, &sums, nf, pf.k_pad, out) };
            return;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if dot::avx2_enabled() {
            let mut ptrs = [std::ptr::null::<i8>(); NR];
            for (j, p) in ptrs.iter_mut().enumerate().take(nf) {
                *p = pf.filter(f0 + j).as_ptr();
            }
            // SAFETY: feature checked; every pointer addresses k_pad bytes
            // and patch.len() == k_pad (both multiples of K_ALIGN).
            unsafe { dot_block_avx2(patch.as_ptr(), &ptrs, nf, pf.k_pad, out) };
            return;
        }
    }
    // Portable fallback — `dot_i8` re-dispatches per the active ISA, so
    // this is the NEON path on aarch64 and exact scalar elsewhere.
    for (j, o) in out.iter_mut().enumerate().take(nf) {
        *o = dot::dot_i8(patch, pf.filter(f0 + j));
    }
}

/// Like [`dot_block`] but over an arbitrary set of filter indices — the
/// shape the predict-then-evaluate dataflow needs (cluster proxies and
/// surviving (row, filter) pairs are scattered).
pub fn dot_block_indexed(patch: &[i8], pf: &PrepackedFilters, idx: &[usize], out: &mut [i32; NR]) {
    debug_assert!(idx.len() <= NR);
    debug_assert_eq!(patch.len(), pf.k_pad);
    #[cfg(all(target_arch = "x86_64", mor_avx512))]
    {
        if super::isa::vnni_enabled() && pf.k_pad <= dot::VNNI_K_MAX {
            let mut ptrs = [std::ptr::null::<i8>(); NR];
            let mut sums = [0i32; NR];
            for (j, &f) in idx.iter().enumerate() {
                ptrs[j] = pf.filter(f).as_ptr();
                let (pos, neg) = pf.filter_sums(f);
                sums[j] = (pos + neg) as i32;
            }
            // SAFETY: as in dot_block.
            unsafe { dot_block_vnni(patch.as_ptr(), &ptrs, &sums, idx.len(), pf.k_pad, out) };
            return;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if dot::avx2_enabled() {
            let mut ptrs = [std::ptr::null::<i8>(); NR];
            for (p, &f) in ptrs.iter_mut().zip(idx) {
                *p = pf.filter(f).as_ptr();
            }
            // SAFETY: as in dot_block.
            unsafe { dot_block_avx2(patch.as_ptr(), &ptrs, idx.len(), pf.k_pad, out) };
            return;
        }
    }
    // Portable fallback — NEON via `dot_i8` dispatch on aarch64.
    for (o, &f) in out.iter_mut().zip(idx) {
        *o = dot::dot_i8(patch, pf.filter(f));
    }
}

/// Like [`dot_block`] but iterating only the patch's nonzero input
/// lanes (`(idx, val)` from [`PatchTile::lanes`]). Exact: the elided
/// lanes are zero and contribute 0 to every integer dot, so `out`
/// is bit-identical to the dense kernel's.
pub fn dot_block_sparse(
    idx: &[u16],
    val: &[i8],
    pf: &PrepackedFilters,
    f0: usize,
    nf: usize,
    out: &mut [i32; NR],
) {
    debug_assert!(nf <= NR && f0 + nf <= pf.cout);
    for (j, o) in out.iter_mut().enumerate().take(nf) {
        *o = dot::dot_i8_sparse(idx, val, pf.filter(f0 + j));
    }
}

/// Like [`dot_block_indexed`] but over the compressed nonzero lanes —
/// the shape the predict-then-evaluate dataflow needs for proxies and
/// surviving (row, filter) pairs when the row is sparse.
pub fn dot_block_indexed_sparse(
    idx: &[u16],
    val: &[i8],
    pf: &PrepackedFilters,
    filters: &[usize],
    out: &mut [i32; NR],
) {
    debug_assert!(filters.len() <= NR);
    for (o, &f) in out.iter_mut().zip(filters) {
        *o = dot::dot_i8_sparse(idx, val, pf.filter(f));
    }
}

/// Weight-sparse block: evaluate a contiguous block of `nf <= NR`
/// **compressed** filters (`f0..f0+nf`, lanes from
/// [`PrepackedFilters::lanes`]) against one dense padded patch —
/// [`dot::dot_i8_sparse`] under an operand swap. Exact: the elided
/// weight lanes are zero, so `out` is bit-identical to [`dot_block`]'s.
pub fn dot_block_wsparse(
    patch: &[i8],
    pf: &PrepackedFilters,
    f0: usize,
    nf: usize,
    out: &mut [i32; NR],
) {
    debug_assert!(nf <= NR && f0 + nf <= pf.cout);
    debug_assert!(pf.has_lanes());
    for (j, o) in out.iter_mut().enumerate().take(nf) {
        let (wi, wv) = pf.lanes(f0 + j);
        *o = dot::dot_i8_sparse(wi, wv, patch);
    }
}

/// Like [`dot_block_wsparse`] but over an arbitrary set of filter
/// indices (cluster proxies and surviving (row, filter) pairs).
pub fn dot_block_indexed_wsparse(
    patch: &[i8],
    pf: &PrepackedFilters,
    filters: &[usize],
    out: &mut [i32; NR],
) {
    debug_assert!(filters.len() <= NR);
    debug_assert!(pf.has_lanes());
    for (o, &f) in out.iter_mut().zip(filters) {
        let (wi, wv) = pf.lanes(f);
        *o = dot::dot_i8_sparse(wi, wv, patch);
    }
}

/// Doubly-sparse block: compressed filters against a compressed patch
/// (`(x_idx, x_val)` from [`PatchTile::lanes`]) — the index-intersection
/// dot [`dot::dot_i8_sparse_sparse`] per filter. Exact for the same
/// reason as every sparse variant: every elided product has a zero
/// factor.
pub fn dot_block_wsparse_x(
    x_idx: &[u16],
    x_val: &[i8],
    pf: &PrepackedFilters,
    f0: usize,
    nf: usize,
    out: &mut [i32; NR],
) {
    debug_assert!(nf <= NR && f0 + nf <= pf.cout);
    debug_assert!(pf.has_lanes());
    for (j, o) in out.iter_mut().enumerate().take(nf) {
        let (wi, wv) = pf.lanes(f0 + j);
        *o = dot::dot_i8_sparse_sparse(x_idx, x_val, wi, wv);
    }
}

/// Like [`dot_block_wsparse_x`] but over an arbitrary set of filter
/// indices.
pub fn dot_block_indexed_wsparse_x(
    x_idx: &[u16],
    x_val: &[i8],
    pf: &PrepackedFilters,
    filters: &[usize],
    out: &mut [i32; NR],
) {
    debug_assert!(filters.len() <= NR);
    debug_assert!(pf.has_lanes());
    for (o, &f) in out.iter_mut().zip(filters) {
        let (wi, wv) = pf.lanes(f);
        *o = dot::dot_i8_sparse_sparse(x_idx, x_val, wi, wv);
    }
}

/// Density below which the compressed-lane kernel beats the dense block
/// kernel on this host (`InputSparsity::Auto`'s crossover). The dense
/// AVX2 kernel retires 16 lanes per instruction pair, so the scalar
/// gather-multiply loop only wins at low density; against the portable
/// scalar fallback the crossover sits much higher. Any choice is
/// correctness-neutral — both kernels are exact — so this is purely a
/// host-throughput heuristic. The constant itself lives with its
/// rationale in [`crate::engine::crossover`]; this wrapper keeps the
/// historical call sites working.
pub fn sparse_auto_cutoff() -> f32 {
    crate::engine::crossover::input_sparse_cutoff()
}

/// `InputSparsity::Auto`'s per-row decision: use the sparse kernel when
/// the measured density `nnz / k_len` is below [`sparse_auto_cutoff`].
#[inline]
pub fn sparse_wins(nnz: usize, k_len: usize) -> bool {
    (nnz as f32) < sparse_auto_cutoff() * k_len.max(1) as f32
}

/// AVX2 multi-filter micro-kernel: one sign-extended patch load feeds up
/// to NR `vpmaddwd` accumulator chains. Exact: i8·i8 products fit i16,
/// pairwise sums fit i32, and the i32 accumulators cannot overflow —
/// `mor lint --numeric` proves `Σ|w| · max|x| < 2³¹` per filter for
/// every compiled plan (diagnostic `num.acc`, see `dot_i8_avx2` and
/// [`crate::plan::ranges`]).
///
/// # Safety
///
/// * The CPU must support AVX2 — callers dispatch through
///   [`dot::avx2_enabled`], never directly.
/// * `patch` must address at least `k_pad` readable bytes.
/// * `filt[..nf]` must each address at least `k_pad` readable bytes
///   (`nf <= NR`; the remaining entries may dangle — they are never
///   read). [`PrepackedFilters`] guarantees this: every filter is
///   zero-padded to exactly `k_pad` bytes at prepack time.
/// * `k_pad` must be a multiple of [`K_ALIGN`] (what [`pad_k`]
///   produces), so the `K_ALIGN`-stride loop covers `[0, k_pad)` with
///   no tail — `k + K_ALIGN <= k_pad` is the loads' bounds proof.
///
/// No alignment requirement: all loads are `_mm_loadu_si128`
/// (unaligned).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_block_avx2(
    patch: *const i8,
    filt: &[*const i8; NR],
    nf: usize,
    k_pad: usize,
    out: &mut [i32; NR],
) {
    use std::arch::x86_64::*;
    // SAFETY: AVX2 available and every pointer addresses k_pad bytes per
    // the fn contract; k + K_ALIGN <= k_pad bounds each 16-byte
    // unaligned load, and only filt[..nf] (the valid entries) are read.
    unsafe {
        let mut acc = [_mm256_setzero_si256(); NR];
        let mut k = 0usize;
        while k + K_ALIGN <= k_pad {
            let xv = _mm256_cvtepi8_epi16(_mm_loadu_si128(patch.add(k) as *const __m128i));
            for j in 0..nf {
                let wv =
                    _mm256_cvtepi8_epi16(_mm_loadu_si128(filt[j].add(k) as *const __m128i));
                acc[j] = _mm256_add_epi32(acc[j], _mm256_madd_epi16(xv, wv));
            }
            k += K_ALIGN;
        }
        for j in 0..nf {
            out[j] = hsum_epi32(acc[j]);
        }
    }
}

/// AVX-512 VNNI multi-filter micro-kernel: one offset-lifted patch load
/// (`x ⊕ 0x80`, see `dot::dot_i8_vnni`) feeds up to NR `vpdpbusd`
/// accumulator chains, 64 lanes per step with a masked tail. The true
/// dots are recovered per filter by subtracting `128·Σw`, which the
/// prepack already knows ([`PrepackedFilters::filter_sums`]) — the
/// correction is free here, unlike the free-function kernel which must
/// accumulate `Σw` on the fly.
///
/// Exact: the offset accumulation is an exact i32 sum (bounded by
/// `128·255·k_pad < 2³¹` for `k_pad ≤` [`dot::VNNI_K_MAX`], which the
/// dispatchers enforce), `Σ (x+128)·w − 128·Σw = Σ x·w` is an identity
/// over ℤ, and the masked tail zeroes both operand tails so padding
/// contributes nothing to either sum.
///
/// # Safety
///
/// * The CPU must support AVX-512 F+BW+VNNI — callers dispatch through
///   [`super::isa::vnni_enabled`], never directly.
/// * `patch` must address at least `k_pad` readable bytes.
/// * `filt[..nf]` must each address at least `k_pad` readable bytes
///   (`nf <= NR`; the remaining entries may dangle — never read).
/// * `sums[j]` must be `Σw` over filter `j`'s `k_pad` bytes (zero
///   padding contributes 0, so the prepack's per-filter sum is it).
/// * `k_pad` must be a multiple of [`K_ALIGN`] and at most
///   [`dot::VNNI_K_MAX`] (the offset-overflow bound above).
#[cfg(all(target_arch = "x86_64", mor_avx512))]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn dot_block_vnni(
    patch: *const i8,
    filt: &[*const i8; NR],
    sums: &[i32; NR],
    nf: usize,
    k_pad: usize,
    out: &mut [i32; NR],
) {
    use std::arch::x86_64::*;
    debug_assert!(k_pad % K_ALIGN == 0 && k_pad <= dot::VNNI_K_MAX);
    // SAFETY: AVX-512 F+BW+VNNI available and every pointer addresses
    // k_pad bytes per the fn contract; `k + 64 <= k_pad` bounds the full
    // loads, the tail load's mask covers exactly the remaining
    // `k_pad - k < 64` bytes (masked-off lanes are not read), and only
    // filt[..nf] (the valid entries) are read.
    unsafe {
        let sign = _mm512_set1_epi8(-128i8); // 0x80: XOR flips the sign bit
        let mut acc = [_mm512_setzero_si512(); NR];
        let mut k = 0usize;
        while k + 64 <= k_pad {
            let xv = _mm512_loadu_si512(patch.add(k) as *const _);
            let xu = _mm512_xor_si512(xv, sign);
            for j in 0..nf {
                let wv = _mm512_loadu_si512(filt[j].add(k) as *const _);
                acc[j] = _mm512_dpbusd_epi32(acc[j], xu, wv);
            }
            k += 64;
        }
        if k < k_pad {
            let rem = k_pad - k; // in (0, 64), multiple of K_ALIGN
            let m: __mmask64 = (1u64 << rem) - 1;
            let xu = _mm512_xor_si512(_mm512_maskz_loadu_epi8(m, patch.add(k)), sign);
            // masked-off patch lanes become 128 after the offset, but the
            // matching filter lanes are masked to 0, so they contribute 0
            for j in 0..nf {
                let wv = _mm512_maskz_loadu_epi8(m, filt[j].add(k));
                acc[j] = _mm512_dpbusd_epi32(acc[j], xu, wv);
            }
        }
        for j in 0..nf {
            out[j] = _mm512_reduce_add_epi32(acc[j]) - 128 * sums[j];
        }
    }
}

/// Horizontal sum of 8 i32 lanes.
///
/// # Safety
///
/// The CPU must support AVX2 (the only unsafe ingredient — the fn is
/// register-only, touching no memory); called exclusively from
/// [`dot_block_avx2`], which has the same contract.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: std::arch::x86_64::__m256i) -> i32 {
    use std::arch::x86_64::*;
    // SAFETY: AVX2 available per the fn contract; register-only ops.
    unsafe {
        let hi = _mm256_extracti128_si256(v, 1);
        let lo = _mm256_castsi256_si128(v);
        let s = _mm_add_epi32(hi, lo);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        _mm_cvtsi128_si32(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dot::dot_i8;
    use crate::util::prop::property;
    use crate::util::rng::Rng;

    fn fc_node(cin: usize, cout: usize, seed: u64) -> Node {
        let mut rng = Rng::new(seed);
        Node::Fc {
            cin,
            cout,
            sw: 0.01,
            sx: 0.01,
            w: (0..cin * cout).map(|_| rng.int8()).collect(),
            bn: None,
            relu: false,
            res_from: None,
            consumes: -1,
        }
    }

    #[test]
    fn prepack_pads_with_zeros() {
        let node = fc_node(13, 3, 1);
        let pf = PrepackedFilters::new(&node);
        assert_eq!(pf.k_len, 13);
        assert_eq!(pf.k_pad, 16);
        for f in 0..3 {
            let row = pf.filter(f);
            assert_eq!(&row[..13], node.filter(f));
            assert!(row[13..].iter().all(|&v| v == 0));
        }
    }

    #[test]
    fn dot_block_matches_dot_i8() {
        property("dot_block == per-filter dot_i8", 100, |g| {
            let k = g.usize(1, 200);
            let cout = g.usize(1, 20);
            let node = fc_node(k, cout, g.seed);
            let pf = PrepackedFilters::new(&node);
            let x = g.vec_i8(k);
            let mut patch = vec![0i8; pf.k_pad];
            patch[..k].copy_from_slice(&x);
            let mut out = [0i32; NR];
            let mut f0 = 0;
            while f0 < cout {
                let nf = NR.min(cout - f0);
                dot_block(&patch, &pf, f0, nf, &mut out);
                for j in 0..nf {
                    let want = dot_i8(&x, node.filter(f0 + j));
                    crate::prop_assert!(
                        g,
                        out[j] == want,
                        "k={k} cout={cout} f={} got={} want={want}",
                        f0 + j,
                        out[j]
                    );
                }
                f0 += NR;
            }
            Ok(())
        });
    }

    #[test]
    fn dot_block_indexed_scattered() {
        property("dot_block_indexed == per-filter dot_i8", 60, |g| {
            let k = g.usize(1, 120);
            let cout = g.usize(1, 24);
            let node = fc_node(k, cout, g.seed ^ 1);
            let pf = PrepackedFilters::new(&node);
            let x = g.vec_i8(k);
            let mut patch = vec![0i8; pf.k_pad];
            patch[..k].copy_from_slice(&x);
            // random subset of filters, shuffled
            let mut idx: Vec<usize> = (0..cout).filter(|_| g.bool()).collect();
            g.shuffle(&mut idx);
            let mut out = [0i32; NR];
            for chunk in idx.chunks(NR) {
                dot_block_indexed(&patch, &pf, chunk, &mut out);
                for (j, &f) in chunk.iter().enumerate() {
                    let want = dot_i8(&x, node.filter(f));
                    crate::prop_assert!(g, out[j] == want, "f={f} got={} want={want}", out[j]);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn patch_tile_roundtrip() {
        let mut tile = PatchTile::new(10, false);
        assert_eq!(tile.k_pad, 16);
        assert!(!tile.has_sparse());
        let patch: Vec<i8> = (0..10).map(|v| v as i8 - 5).collect();
        let packed = PackedVec::from_acts(&patch);
        tile.set_row(3, &patch, &packed, 9, &nzmask_of(&patch), false);
        assert_eq!(&tile.patch(3)[..10], &patch[..]);
        assert!(tile.patch(3)[10..].iter().all(|&v| v == 0));
        assert_eq!(tile.packed(3), &packed);
        assert_eq!(tile.nnz(3), 9); // lane 5 holds value 0
        assert_eq!(tile.xmask(3), &nzmask_of(&patch)[..]);
        assert_eq!(tile.xmask(3)[0].count_ones(), 9);
        // untouched rows stay zero-padded
        assert!(tile.patch(2).iter().all(|&v| v == 0));
    }

    fn nnz_of(patch: &[i8]) -> usize {
        patch.iter().filter(|&&v| v != 0).count()
    }

    fn nzmask_of(patch: &[i8]) -> Vec<u64> {
        let mut m = vec![0u64; patch.len().div_ceil(64)];
        for (i, &v) in patch.iter().enumerate() {
            if v != 0 {
                m[i / 64] |= 1u64 << (i % 64);
            }
        }
        m
    }

    #[test]
    fn compressed_builder_all_zero_patch() {
        // all-zero patch: empty lane list, and the sparse kernel
        // produces the same (zero) dots as the dense one
        let mut tile = PatchTile::new(13, true);
        let patch = vec![0i8; 13];
        tile.set_row(0, &patch, &PackedVec::from_acts(&patch), 0, &nzmask_of(&patch), true);
        assert_eq!(tile.nnz(0), 0);
        let (idx, val) = tile.lanes(0);
        assert!(idx.is_empty() && val.is_empty());
        let node = fc_node(13, 5, 3);
        let pf = PrepackedFilters::new(&node);
        let (mut sp, mut de) = ([0i32; NR], [0i32; NR]);
        dot_block_sparse(idx, val, &pf, 0, 5, &mut sp);
        dot_block(tile.patch(0), &pf, 0, 5, &mut de);
        assert_eq!(sp, de);
        assert!(sp[..5].iter().all(|&v| v == 0));
    }

    #[test]
    fn compressed_builder_fully_dense_patch() {
        // no zero lane at all: the list is the identity mapping and the
        // kernels still agree
        let mut tile = PatchTile::new(9, true);
        let patch: Vec<i8> = (0..9).map(|v| v as i8 + 1).collect();
        tile.set_row(2, &patch, &PackedVec::from_acts(&patch), 9, &nzmask_of(&patch), true);
        let (idx, val) = tile.lanes(2);
        assert_eq!(idx, (0..9u16).collect::<Vec<_>>().as_slice());
        assert_eq!(val, &patch[..]);
        let node = fc_node(9, 3, 5);
        let pf = PrepackedFilters::new(&node);
        let (mut sp, mut de) = ([0i32; NR], [0i32; NR]);
        dot_block_sparse(idx, val, &pf, 0, 3, &mut sp);
        dot_block(tile.patch(2), &pf, 0, 3, &mut de);
        assert_eq!(sp, de);
    }

    #[test]
    fn compressed_builder_skips_padding_lanes() {
        // interior zeros and the k_len → k_pad alignment padding both
        // stay out of the lane list; the sparse dot still matches the
        // padded dense dot exactly
        property("sparse block == dense block on random sparse rows", 80, |g| {
            let k = g.usize(1, 150);
            let cout = g.usize(1, 20);
            let node = fc_node(k, cout, g.seed ^ 3);
            let pf = PrepackedFilters::new(&node);
            // force plenty of zero lanes
            let patch: Vec<i8> = (0..k)
                .map(|_| if g.bool() { 0 } else { g.rng().int8() })
                .collect();
            let nnz = nnz_of(&patch);
            let mut tile = PatchTile::new(k, true);
            tile.set_row(1, &patch, &PackedVec::from_acts(&patch), nnz, &nzmask_of(&patch), true);
            let (idx, val) = tile.lanes(1);
            crate::prop_assert!(g, idx.len() == nnz, "list len {} != nnz {nnz}", idx.len());
            crate::prop_assert!(
                g,
                idx.iter().all(|&i| (i as usize) < k),
                "padding lane leaked into the list"
            );
            let (mut sp, mut de) = ([0i32; NR], [0i32; NR]);
            let mut filters: Vec<usize> = (0..cout).filter(|_| g.bool()).collect();
            g.shuffle(&mut filters);
            for chunk in filters.chunks(NR) {
                dot_block_indexed_sparse(idx, val, &pf, chunk, &mut sp);
                dot_block_indexed(tile.patch(1), &pf, chunk, &mut de);
                for j in 0..chunk.len() {
                    crate::prop_assert!(
                        g,
                        sp[j] == de[j],
                        "k={k} f={} sparse={} dense={}",
                        chunk[j],
                        sp[j],
                        de[j]
                    );
                }
            }
            Ok(())
        });
    }

    /// FC node whose weights have roughly `zero_pct`% zero lanes.
    fn sparse_fc_node(cin: usize, cout: usize, zero_pct: usize, seed: u64) -> Node {
        let mut rng = Rng::new(seed);
        let w: Vec<i8> = (0..cin * cout)
            .map(|_| {
                if (rng.int_in(0, 99) as usize) < zero_pct {
                    0
                } else {
                    rng.int8()
                }
            })
            .collect();
        Node::Fc {
            cin,
            cout,
            sw: 0.01,
            sx: 0.01,
            w,
            bn: None,
            relu: false,
            res_from: None,
            consumes: -1,
        }
    }

    #[test]
    fn prepack_builds_weight_lanes_and_masks() {
        let node = sparse_fc_node(70, 6, 50, 9);
        let pf = PrepackedFilters::new(&node);
        assert!(pf.has_lanes());
        assert_eq!(pf.mask_words(), 2); // 70 lanes → 2 u64 words
        let mut nnz_total = 0usize;
        for f in 0..6 {
            let w = node.filter(f);
            let (wi, wv) = pf.lanes(f);
            // lists exactly cover the nonzero lanes, sorted ascending
            let want: Vec<(u16, i8)> = w
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0)
                .map(|(i, &v)| (i as u16, v))
                .collect();
            let got: Vec<(u16, i8)> = wi.iter().copied().zip(wv.iter().copied()).collect();
            assert_eq!(got, want, "filter {f} lane list");
            assert!(wi.windows(2).all(|p| p[0] < p[1]), "filter {f} not sorted");
            // bitmask agrees, lane for lane, with bits beyond k_len clear
            for (i, &v) in w.iter().enumerate() {
                assert_eq!(pf.wmask(f)[i / 64] >> (i % 64) & 1 == 1, v != 0);
            }
            assert_eq!(pf.wmask(f)[1] >> (70 - 64), 0);
            nnz_total += wi.len();
        }
        let want_density = nnz_total as f32 / (70 * 6) as f32;
        assert_eq!(pf.density(), want_density);
        assert!(pf.density() > 0.2 && pf.density() < 0.8);
    }

    #[test]
    fn weight_sparse_blocks_match_dense_at_every_density() {
        property("wsparse kernels == dense kernels", 80, |g| {
            let k = g.usize(1, 150);
            let cout = g.usize(1, 20);
            let zero_pct = g.usize(0, 100);
            let node = sparse_fc_node(k, cout, zero_pct, g.seed ^ 7);
            let pf = PrepackedFilters::new(&node);
            let patch_raw: Vec<i8> = (0..k)
                .map(|_| if g.bool() { 0 } else { g.rng().int8() })
                .collect();
            let nnz = nnz_of(&patch_raw);
            let mut tile = PatchTile::new(k, true);
            tile.set_row(0, &patch_raw, &PackedVec::from_acts(&patch_raw), nnz, &nzmask_of(&patch_raw), true);
            let (xi, xv) = tile.lanes(0);
            let patch = tile.patch(0);
            let mut want = [0i32; NR];
            let (mut ws, mut wsx) = ([0i32; NR], [0i32; NR]);
            let mut f0 = 0;
            while f0 < cout {
                let nf = NR.min(cout - f0);
                dot_block(patch, &pf, f0, nf, &mut want);
                dot_block_wsparse(patch, &pf, f0, nf, &mut ws);
                dot_block_wsparse_x(xi, xv, &pf, f0, nf, &mut wsx);
                for j in 0..nf {
                    crate::prop_assert!(
                        g,
                        ws[j] == want[j] && wsx[j] == want[j],
                        "k={k} zero_pct={zero_pct} f={} dense={} wsparse={} doubly={}",
                        f0 + j,
                        want[j],
                        ws[j],
                        wsx[j]
                    );
                }
                f0 += NR;
            }
            // the indexed variants on a scattered filter subset
            let mut filters: Vec<usize> = (0..cout).filter(|_| g.bool()).collect();
            g.shuffle(&mut filters);
            for chunk in filters.chunks(NR) {
                dot_block_indexed(patch, &pf, chunk, &mut want);
                dot_block_indexed_wsparse(patch, &pf, chunk, &mut ws);
                dot_block_indexed_wsparse_x(xi, xv, &pf, chunk, &mut wsx);
                for j in 0..chunk.len() {
                    crate::prop_assert!(
                        g,
                        ws[j] == want[j] && wsx[j] == want[j],
                        "indexed f={} dense={} wsparse={} doubly={}",
                        chunk[j],
                        want[j],
                        ws[j],
                        wsx[j]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prepack_filter_sums_decompose_by_sign() {
        let node = sparse_fc_node(120, 5, 40, 17);
        let pf = PrepackedFilters::new(&node);
        for f in 0..5 {
            let w = node.filter(f);
            let pos: i64 = w.iter().filter(|&&v| v > 0).map(|&v| v as i64).sum();
            let neg: i64 = w.iter().filter(|&&v| v < 0).map(|&v| v as i64).sum();
            assert_eq!(pf.filter_sums(f), (pos, neg), "filter {f}");
            assert_eq!(pf.abs_weight_sum(f), pos - neg, "filter {f}");
            let abs: i64 = w.iter().map(|&v| (v as i64).abs()).sum();
            assert_eq!(pf.abs_weight_sum(f), abs, "filter {f}");
        }
    }

    /// Extremal boundary for the weight-sparse block kernels: all-(−128)
    /// weights against an all-(−128) patch at K = [`SPARSE_K_MAX`] (the
    /// largest dot the compressed lanes can address). Every per-filter
    /// accumulator lands on exactly 128·128·65536 = 2³⁰ < i32::MAX — the
    /// worst case the numeric analyzer ([`crate::plan::ranges`]) assumes
    /// when it proves `num.acc`.
    #[test]
    #[cfg_attr(miri, ignore = "2^16-lane kernels are too slow interpreted")]
    fn wsparse_blocks_extreme_no_overflow() {
        let k = SPARSE_K_MAX;
        let cout = 2usize;
        let node = Node::Fc {
            cin: k,
            cout,
            sw: 0.01,
            sx: 0.01,
            w: vec![-128i8; k * cout],
            bn: None,
            relu: false,
            res_from: None,
            consumes: -1,
        };
        let pf = PrepackedFilters::new(&node);
        assert!(pf.has_lanes(), "K = SPARSE_K_MAX must still build lanes");
        assert_eq!(pf.abs_weight_sum(0), 128 * k as i64);
        let patch = {
            let mut p = vec![-128i8; k];
            p.resize(pf.k_pad, 0);
            p
        };
        let x_idx: Vec<u16> = (0..k).map(|i| i as u16).collect();
        let x_val = vec![-128i8; k];
        let want = (128i64 * 128 * k as i64) as i32; // 2^30, exact in i32
        let (mut ws, mut wsx, mut wsi, mut wsxi) =
            ([0i32; NR], [0i32; NR], [0i32; NR], [0i32; NR]);
        dot_block_wsparse(&patch, &pf, 0, cout, &mut ws);
        dot_block_wsparse_x(&x_idx, &x_val, &pf, 0, cout, &mut wsx);
        let filters = [1usize, 0];
        dot_block_indexed_wsparse(&patch, &pf, &filters, &mut wsi);
        dot_block_indexed_wsparse_x(&x_idx, &x_val, &pf, &filters, &mut wsxi);
        for f in 0..cout {
            assert_eq!(ws[f], want, "wsparse filter {f}");
            assert_eq!(wsx[f], want, "wsparse_x filter {f}");
            assert_eq!(wsi[f], want, "indexed wsparse filter {f}");
            assert_eq!(wsxi[f], want, "indexed wsparse_x filter {f}");
        }
    }

    #[test]
    fn all_zero_filter_has_empty_lane_list() {
        let mut node = sparse_fc_node(20, 3, 0, 11);
        if let Node::Fc { w, .. } = &mut node {
            w[..20].fill(1); // filter 0: all ones (a surely-nonzero dot)
            w[20..40].fill(0); // filter 1: entirely zero
        }
        let pf = PrepackedFilters::new(&node);
        let (wi, wv) = pf.lanes(1);
        assert!(wi.is_empty() && wv.is_empty());
        assert!(pf.wmask(1).iter().all(|&w| w == 0));
        let patch = vec![3i8; pf.k_pad];
        let mut out = [0i32; NR];
        dot_block_wsparse(&patch, &pf, 0, 3, &mut out);
        assert_eq!(out[1], 0);
        assert_ne!(out[0], 0); // dense neighbours unaffected
    }

    #[test]
    fn prepack_overflow_k_skips_lanes_but_keeps_masks() {
        // k_len beyond the u16 index range: no lane lists (the kernels
        // fall back to dense), but the accounting masks are still built
        let node = sparse_fc_node(SPARSE_K_MAX + 1, 1, 90, 13);
        let pf = PrepackedFilters::new(&node);
        assert!(!pf.has_lanes());
        assert_eq!(pf.mask_words(), (SPARSE_K_MAX + 1).div_ceil(64));
        let want_nnz = node.filter(0).iter().filter(|&&v| v != 0).count() as u64;
        let full = vec![u64::MAX; pf.mask_words()];
        assert_eq!(masked_nnz(pf.wmask(0), &full), want_nnz);
        assert!(pf.density() < 0.2);
    }

    #[test]
    fn masked_nnz_agrees_with_scalar_weight_zero_scan() {
        property("mask algebra == reference scan", 100, |g| {
            let k = g.usize(1, 300);
            let node = sparse_fc_node(k, 1, g.usize(0, 100), g.seed ^ 21);
            let pf = PrepackedFilters::new(&node);
            let x: Vec<i8> = (0..k)
                .map(|_| if g.bool() { 0 } else { g.rng().int8() })
                .collect();
            let nnz_x = nnz_of(&x) as u64;
            let tiled_wz = nnz_x - masked_nnz(&nzmask_of(&x), pf.wmask(0));
            let scalar_wz = dot::weight_zero_lanes(&x, node.filter(0));
            crate::prop_assert!(
                g,
                tiled_wz == scalar_wz,
                "k={k} tiled={tiled_wz} scalar={scalar_wz}"
            );
            Ok(())
        });
    }

    #[test]
    fn auto_threshold_crossover_picks_dense_kernel() {
        // rows denser than the crossover go dense, sparser rows go
        // sparse; the boundary is exclusive (nnz == cutoff*k is dense)
        let k = 100usize;
        let cut = sparse_auto_cutoff();
        let below = (cut * k as f32).ceil() as usize - 1;
        let above = (cut * k as f32).floor() as usize + 1;
        assert!(sparse_wins(below, k), "density {below}/{k} should pick sparse");
        assert!(!sparse_wins(above, k), "density {above}/{k} should pick dense");
        assert!(!sparse_wins(k, k), "fully dense row must pick the dense kernel");
        assert!(sparse_wins(0, k), "all-zero row must pick the sparse kernel");
        // k_len beyond the u16 index range: builder silently disabled
        let big = PatchTile::new(SPARSE_K_MAX + 1, true);
        assert!(!big.has_sparse());
    }

    #[test]
    fn lane_build_is_gated_per_row_and_refreshes_on_reuse() {
        // a dense-decided row skips the compression pass entirely; when
        // the reused tile row later stores a sparse-decided patch, the
        // lists reflect the new patch, not the stale one
        let mut tile = PatchTile::new(8, true);
        let dense: Vec<i8> = (1i8..=8).collect();
        tile.set_row(0, &dense, &PackedVec::from_acts(&dense), 8, &nzmask_of(&dense), false);
        assert_eq!(tile.nnz(0), 8); // nnz tracked even without lists
        let sparse = vec![0i8, 7, 0, 0, -3, 0, 0, 0];
        tile.set_row(0, &sparse, &PackedVec::from_acts(&sparse), 2, &nzmask_of(&sparse), true);
        assert_eq!(tile.xmask(0), &[0b10010u64][..]); // mask refreshed too
        let (idx, val) = tile.lanes(0);
        assert_eq!(idx, &[1u16, 4][..]);
        assert_eq!(val, &[7i8, -3][..]);
    }
}
