//! Cache-blocked, row-batched int8 GEMM micro-kernels for the tiled engine.
//!
//! The per-neuron GEMV path (one `dot_i8` per output position) re-reads the
//! patch once per filter and re-slices the weight tensor on every call. The
//! kernels here restructure that dataflow the way SparseNN/Cnvlutin-class
//! accelerators do: weights are prepacked **once per model** into
//! filter-major, zero-padded, contiguous blocks ([`PrepackedFilters`]),
//! patches are gathered into row tiles of [`TILE_ROWS`] ([`PatchTile`]),
//! and the micro-kernel evaluates up to [`NR`] filters per patch load
//! (AVX2 `vpmovsxbw` + `vpmaddwd`, with a portable fallback).
//!
//! A tile's rows need not come from one sample: the batch-native forward
//! ([`crate::predictor::exec::run_batch`]) fills tiles across request
//! boundaries, so the serving coordinator's micro-batches keep these
//! kernels running at full occupancy even when each request contributes
//! only a handful of rows (e.g. an FC layer's single row per request).
//!
//! All kernels are exact int8×int8→int32 sums, so the tiled engine is
//! bit-identical to the scalar reference path by construction — the
//! property suite in `rust/tests/engine_equivalence.rs` proves it.

use crate::engine::dot;
use crate::model::{Model, Node};
use crate::util::bits::PackedVec;

/// Filters evaluated per micro-kernel invocation (accumulator registers).
pub const NR: usize = 8;
/// Patches per row tile: a filter block loaded once serves this many rows.
pub const TILE_ROWS: usize = 16;
/// Dot-length alignment of prepacked filters and tile rows (one 128-bit
/// int8 load, sign-extended to a 256-bit i16 vector).
pub const K_ALIGN: usize = 16;

/// Round a dot length up to the kernel alignment.
#[inline]
pub fn pad_k(k_len: usize) -> usize {
    k_len.max(1).div_ceil(K_ALIGN) * K_ALIGN
}

/// One layer's weights, repacked filter-major with each filter zero-padded
/// to [`K_ALIGN`] so the micro-kernel needs no tail handling. Padding lanes
/// multiply against zero patch lanes and contribute nothing, keeping every
/// dot product exactly equal to the unpadded `dot_i8`.
#[derive(Clone, Debug)]
pub struct PrepackedFilters {
    pub cout: usize,
    pub k_len: usize,
    pub k_pad: usize,
    data: Vec<i8>,
}

impl PrepackedFilters {
    pub fn new(node: &Node) -> PrepackedFilters {
        let k_len = node.k_len();
        let cout = node.cout();
        let k_pad = pad_k(k_len);
        let mut data = vec![0i8; cout * k_pad];
        for f in 0..cout {
            data[f * k_pad..f * k_pad + k_len].copy_from_slice(node.filter(f));
        }
        PrepackedFilters {
            cout,
            k_len,
            k_pad,
            data,
        }
    }

    /// Padded weight row for filter `f` (length `k_pad`).
    #[inline]
    pub fn filter(&self, f: usize) -> &[i8] {
        &self.data[f * self.k_pad..(f + 1) * self.k_pad]
    }
}

/// Prepacked weight blocks for every compute node of a model, built once
/// (see [`crate::model::Model::prepacked`]) and shared read-only across
/// forward passes and worker threads.
#[derive(Clone, Debug, Default)]
pub struct PrepackedModel {
    pub layers: Vec<Option<PrepackedFilters>>,
}

impl PrepackedModel {
    pub fn new(model: &Model) -> PrepackedModel {
        PrepackedModel {
            layers: model
                .nodes
                .iter()
                .map(|n| n.is_compute().then(|| PrepackedFilters::new(n)))
                .collect(),
        }
    }

    /// Prepacked filters of compute node `i`.
    #[inline]
    pub fn layer(&self, i: usize) -> &PrepackedFilters {
        self.layers[i]
            .as_ref()
            .expect("prepacked filters requested for a non-compute node")
    }
}

/// A tile of up to [`TILE_ROWS`] im2col patches, each zero-padded to the
/// prepack alignment, plus the packed ±1 activation planes the binary
/// predictor consumes. Buffers are allocated once per worker and reused
/// for every tile.
pub struct PatchTile {
    pub k_len: usize,
    pub k_pad: usize,
    data: Vec<i8>,
    packed: Vec<PackedVec>,
}

impl PatchTile {
    pub fn new(k_len: usize) -> PatchTile {
        let k_pad = pad_k(k_len);
        PatchTile {
            k_len,
            k_pad,
            // padding lanes are written once here and never overwritten:
            // set_row only touches the first k_len bytes of each row
            data: vec![0i8; TILE_ROWS * k_pad],
            packed: vec![PackedVec::zeros(k_len); TILE_ROWS],
        }
    }

    /// Store one gathered patch (and its packed sign plane) as tile row `r`.
    #[inline]
    pub fn set_row(&mut self, r: usize, patch: &[i8], packed: &PackedVec) {
        debug_assert_eq!(patch.len(), self.k_len);
        self.data[r * self.k_pad..r * self.k_pad + self.k_len].copy_from_slice(patch);
        let p = &mut self.packed[r];
        p.bits.copy_from_slice(&packed.bits);
        p.valid.copy_from_slice(&packed.valid);
        p.len = packed.len;
    }

    /// Padded patch for tile row `r` (length `k_pad`).
    #[inline]
    pub fn patch(&self, r: usize) -> &[i8] {
        &self.data[r * self.k_pad..(r + 1) * self.k_pad]
    }

    /// Packed ±1 activation plane for tile row `r`.
    #[inline]
    pub fn packed(&self, r: usize) -> &PackedVec {
        &self.packed[r]
    }
}

/// Evaluate a contiguous block of `nf <= NR` filters (`f0..f0+nf`) against
/// one padded patch. `out[j]` receives the exact int32 dot of the patch
/// with filter `f0 + j`.
pub fn dot_block(patch: &[i8], pf: &PrepackedFilters, f0: usize, nf: usize, out: &mut [i32; NR]) {
    debug_assert!(nf <= NR && f0 + nf <= pf.cout);
    debug_assert_eq!(patch.len(), pf.k_pad);
    #[cfg(target_arch = "x86_64")]
    {
        if dot::avx2_enabled() {
            let mut ptrs = [std::ptr::null::<i8>(); NR];
            for (j, p) in ptrs.iter_mut().enumerate().take(nf) {
                *p = pf.filter(f0 + j).as_ptr();
            }
            // SAFETY: feature checked; every pointer addresses k_pad bytes
            // and patch.len() == k_pad (both multiples of K_ALIGN).
            unsafe { dot_block_avx2(patch.as_ptr(), &ptrs, nf, pf.k_pad, out) };
            return;
        }
    }
    for (j, o) in out.iter_mut().enumerate().take(nf) {
        *o = dot::dot_i8_scalar(patch, pf.filter(f0 + j));
    }
}

/// Like [`dot_block`] but over an arbitrary set of filter indices — the
/// shape the predict-then-evaluate dataflow needs (cluster proxies and
/// surviving (row, filter) pairs are scattered).
pub fn dot_block_indexed(patch: &[i8], pf: &PrepackedFilters, idx: &[usize], out: &mut [i32; NR]) {
    debug_assert!(idx.len() <= NR);
    debug_assert_eq!(patch.len(), pf.k_pad);
    #[cfg(target_arch = "x86_64")]
    {
        if dot::avx2_enabled() {
            let mut ptrs = [std::ptr::null::<i8>(); NR];
            for (p, &f) in ptrs.iter_mut().zip(idx) {
                *p = pf.filter(f).as_ptr();
            }
            // SAFETY: as in dot_block.
            unsafe { dot_block_avx2(patch.as_ptr(), &ptrs, idx.len(), pf.k_pad, out) };
            return;
        }
    }
    for (o, &f) in out.iter_mut().zip(idx) {
        *o = dot::dot_i8_scalar(patch, pf.filter(f));
    }
}

/// AVX2 multi-filter micro-kernel: one sign-extended patch load feeds up
/// to NR `vpmaddwd` accumulator chains. Exact: i8·i8 products fit i16 and
/// pairwise sums fit i32 (see `dot_i8_avx2`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_block_avx2(
    patch: *const i8,
    filt: &[*const i8; NR],
    nf: usize,
    k_pad: usize,
    out: &mut [i32; NR],
) {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_si256(); NR];
    let mut k = 0usize;
    while k + K_ALIGN <= k_pad {
        let xv = _mm256_cvtepi8_epi16(_mm_loadu_si128(patch.add(k) as *const __m128i));
        for j in 0..nf {
            let wv = _mm256_cvtepi8_epi16(_mm_loadu_si128(filt[j].add(k) as *const __m128i));
            acc[j] = _mm256_add_epi32(acc[j], _mm256_madd_epi16(xv, wv));
        }
        k += K_ALIGN;
    }
    for j in 0..nf {
        out[j] = hsum_epi32(acc[j]);
    }
}

/// Horizontal sum of 8 i32 lanes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: std::arch::x86_64::__m256i) -> i32 {
    use std::arch::x86_64::*;
    let hi = _mm256_extracti128_si256(v, 1);
    let lo = _mm256_castsi256_si128(v);
    let s = _mm_add_epi32(hi, lo);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
    _mm_cvtsi128_si32(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dot::dot_i8;
    use crate::util::prop::property;
    use crate::util::rng::Rng;

    fn fc_node(cin: usize, cout: usize, seed: u64) -> Node {
        let mut rng = Rng::new(seed);
        Node::Fc {
            cin,
            cout,
            sw: 0.01,
            sx: 0.01,
            w: (0..cin * cout).map(|_| rng.int8()).collect(),
            bn: None,
            relu: false,
            res_from: None,
            consumes: -1,
        }
    }

    #[test]
    fn prepack_pads_with_zeros() {
        let node = fc_node(13, 3, 1);
        let pf = PrepackedFilters::new(&node);
        assert_eq!(pf.k_len, 13);
        assert_eq!(pf.k_pad, 16);
        for f in 0..3 {
            let row = pf.filter(f);
            assert_eq!(&row[..13], node.filter(f));
            assert!(row[13..].iter().all(|&v| v == 0));
        }
    }

    #[test]
    fn dot_block_matches_dot_i8() {
        property("dot_block == per-filter dot_i8", 100, |g| {
            let k = g.usize(1, 200);
            let cout = g.usize(1, 20);
            let node = fc_node(k, cout, g.seed);
            let pf = PrepackedFilters::new(&node);
            let x = g.vec_i8(k);
            let mut patch = vec![0i8; pf.k_pad];
            patch[..k].copy_from_slice(&x);
            let mut out = [0i32; NR];
            let mut f0 = 0;
            while f0 < cout {
                let nf = NR.min(cout - f0);
                dot_block(&patch, &pf, f0, nf, &mut out);
                for j in 0..nf {
                    let want = dot_i8(&x, node.filter(f0 + j));
                    crate::prop_assert!(
                        g,
                        out[j] == want,
                        "k={k} cout={cout} f={} got={} want={want}",
                        f0 + j,
                        out[j]
                    );
                }
                f0 += NR;
            }
            Ok(())
        });
    }

    #[test]
    fn dot_block_indexed_scattered() {
        property("dot_block_indexed == per-filter dot_i8", 60, |g| {
            let k = g.usize(1, 120);
            let cout = g.usize(1, 24);
            let node = fc_node(k, cout, g.seed ^ 1);
            let pf = PrepackedFilters::new(&node);
            let x = g.vec_i8(k);
            let mut patch = vec![0i8; pf.k_pad];
            patch[..k].copy_from_slice(&x);
            // random subset of filters, shuffled
            let mut idx: Vec<usize> = (0..cout).filter(|_| g.bool()).collect();
            g.shuffle(&mut idx);
            let mut out = [0i32; NR];
            for chunk in idx.chunks(NR) {
                dot_block_indexed(&patch, &pf, chunk, &mut out);
                for (j, &f) in chunk.iter().enumerate() {
                    let want = dot_i8(&x, node.filter(f));
                    crate::prop_assert!(g, out[j] == want, "f={f} got={} want={want}", out[j]);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn patch_tile_roundtrip() {
        let mut tile = PatchTile::new(10);
        assert_eq!(tile.k_pad, 16);
        let patch: Vec<i8> = (0..10).map(|v| v as i8 - 5).collect();
        let packed = PackedVec::from_acts(&patch);
        tile.set_row(3, &patch, &packed);
        assert_eq!(&tile.patch(3)[..10], &patch[..]);
        assert!(tile.patch(3)[10..].iter().all(|&v| v == 0));
        assert_eq!(tile.packed(3), &packed);
        // untouched rows stay zero-padded
        assert!(tile.patch(2).iter().all(|&v| v == 0));
    }
}
