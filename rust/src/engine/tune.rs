//! On-machine autotuner: measures the actual host's kernel crossovers
//! and freezes the winners into a [`TuneProfile`] that rides in
//! `RunOpts` and is baked into every `ModelPlan`.
//!
//! The hardwired heuristics this replaces — the [`super::crossover`]
//! density cutoffs, the [`super::gemm::TILE_ROWS`] tile height, the
//! thread fan-out — were measured on one machine. Cnvlutin2 and
//! SparseNN both observe that sparse-vs-dense profitability is
//! hardware-dependent; [`calibrate`] re-measures it where the model
//! will actually run:
//!
//! * **Input/weight crossover** — time the dense block kernel against
//!   the compressed-lane kernels over a density grid and fit the
//!   break-even density (the highest density where sparse still wins).
//! * **Tile height** — time the real loop nest (filter block held hot
//!   across the tile's rows) at each candidate height ≤ `TILE_ROWS`.
//! * **Thread fan-out** — time the row-partitioned workload at rising
//!   thread counts and keep the smallest count within 3% of the best
//!   aggregate throughput (over-subscription is a loss on small tiles).
//!
//! Everything in the profile is a *host-performance* knob: every kernel
//! the cutoffs choose between is bit-identical (the i32-dot contract),
//! so a wrong profile can only cost time, never correctness — which is
//! why profiles may be calibrated once and shipped to a fleet
//! (`--tune-profile`, [`TuneProfile::save`] / [`TuneProfile::load`]).
//!
//! The **default** profile ([`TuneProfile::host_default`]) is fast and
//! deterministic: no measurement, just the compiled-in
//! [`super::crossover`] constants for the active ISA tier. Plans
//! compiled without opting in (`SessionBuilder::autotune`, `[engine]
//! autotune`, `--autotune`) are byte-identical to the pre-autotuner
//! ones.

use super::crossover;
use super::gemm::{self, NR, TILE_ROWS};
use super::isa::{self, Isa};
use crate::model::Node;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Frozen kernel-choice decisions for one host. `Copy` on purpose: it
/// rides inside `RunOpts` (itself `Copy`) into every compiled
/// `ModelPlan`, so the plan verifier can re-derive each step's frozen
/// decision from the same numbers that produced it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TuneProfile {
    /// ISA tier the profile was calibrated for (provenance — dispatch
    /// still follows [`isa::active`] at run time).
    pub isa: Isa,
    /// Input-side density crossover: a tile row with `nnz/k_len` below
    /// this takes the compressed-lane kernel under `InputSparsity::Auto`.
    pub input_cutoff: f32,
    /// Weight-side density crossover: a layer whose prepacked density is
    /// below this bakes the weight-sparse kernel into its plan step.
    pub weight_cutoff: f32,
    /// Row-tile height the executor should use (1..=[`TILE_ROWS`] — the
    /// compiled-in constant is the hard buffer bound, so tuning can only
    /// shrink it).
    pub tile_rows: usize,
    /// Suggested intra-op thread count; 0 = no suggestion (keep the
    /// caller's `RunOpts::threads`).
    pub threads: usize,
}

impl TuneProfile {
    /// The deterministic compiled-in profile for a given ISA tier: the
    /// [`crossover`] constants, full tile height, no thread suggestion.
    pub fn default_for(isa: Isa) -> TuneProfile {
        let simd = isa > Isa::Scalar;
        TuneProfile {
            isa,
            input_cutoff: if simd {
                crossover::INPUT_CUTOFF_AVX2
            } else {
                crossover::INPUT_CUTOFF_SCALAR
            },
            weight_cutoff: if simd {
                crossover::WEIGHT_CUTOFF_AVX2
            } else {
                crossover::WEIGHT_CUTOFF_SCALAR
            },
            tile_rows: TILE_ROWS,
            threads: 0,
        }
    }

    /// The default profile for the ISA tier that is active right now —
    /// what `RunOpts::default()` carries, and therefore what every plan
    /// compiled without autotuning freezes. Matches
    /// [`crossover::input_sparse_cutoff`] / [`crossover::weight_sparse_cutoff`]
    /// by construction.
    pub fn host_default() -> TuneProfile {
        TuneProfile::default_for(isa::active())
    }

    /// Range-check every field (used on load and by `mor lint` when a
    /// profile is supplied).
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [("input_cutoff", self.input_cutoff), ("weight_cutoff", self.weight_cutoff)] {
            if !(v.is_finite() && v > 0.0 && v < 1.0) {
                bail!("tune profile: {name} = {v} must be a density fraction in (0, 1)");
            }
        }
        if self.tile_rows == 0 || self.tile_rows > TILE_ROWS {
            bail!(
                "tune profile: tile_rows = {} must be in 1..={TILE_ROWS} (the compiled buffer bound)",
                self.tile_rows
            );
        }
        if self.threads > 4096 {
            bail!("tune profile: threads = {} is not plausible", self.threads);
        }
        Ok(())
    }

    /// Stable FNV-1a content hash — recorded in `BENCH_*.json`
    /// provenance so perf trajectories are comparable across hosts, and
    /// printed by `mor info`.
    pub fn hash(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h = (h ^ b as u64).wrapping_mul(0x1_0000_0000_01b3);
            }
        };
        eat(self.isa.name().as_bytes());
        eat(&self.input_cutoff.to_bits().to_le_bytes());
        eat(&self.weight_cutoff.to_bits().to_le_bytes());
        eat(&(self.tile_rows as u64).to_le_bytes());
        eat(&(self.threads as u64).to_le_bytes());
        h
    }

    /// Serialize as the versioned key=value profile format (see
    /// EXPERIMENTS.md §Tune).
    pub fn to_text(&self) -> String {
        format!(
            "# mor tune profile\nversion = 1\nisa = {}\ninput_cutoff = {}\nweight_cutoff = {}\ntile_rows = {}\nthreads = {}\n",
            self.isa.name(),
            self.input_cutoff,
            self.weight_cutoff,
            self.tile_rows,
            self.threads,
        )
    }

    /// Parse the profile format ([`TuneProfile::to_text`]); unknown keys
    /// are rejected so typos fail loudly, and the parsed profile is
    /// validated before it is returned.
    pub fn from_text(text: &str) -> Result<TuneProfile> {
        let mut p = TuneProfile::default_for(Isa::Scalar);
        let mut version = None;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("tune profile line {}: expected key = value", ln + 1))?;
            let (key, val) = (key.trim(), val.trim());
            match key {
                "version" => version = Some(val.parse::<u32>().context("bad profile version")?),
                "isa" => {
                    p.isa = Isa::parse(val)
                        .with_context(|| format!("unknown isa '{val}' in tune profile"))?
                }
                "input_cutoff" => p.input_cutoff = val.parse().context("bad input_cutoff")?,
                "weight_cutoff" => p.weight_cutoff = val.parse().context("bad weight_cutoff")?,
                "tile_rows" => p.tile_rows = val.parse().context("bad tile_rows")?,
                "threads" => p.threads = val.parse().context("bad threads")?,
                other => bail!("tune profile: unknown key '{other}'"),
            }
        }
        match version {
            Some(1) => {}
            Some(v) => bail!("tune profile version {v} not supported (this build reads 1)"),
            None => bail!("not a tune profile: missing 'version = 1'"),
        }
        p.validate()?;
        Ok(p)
    }

    /// Write the profile to a file (`--tune-profile` save side).
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing tune profile to {path}"))
    }

    /// Read a profile from a file (`--tune-profile` load side).
    pub fn load(path: &str) -> Result<TuneProfile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tune profile from {path}"))?;
        TuneProfile::from_text(&text).with_context(|| format!("parsing tune profile {path}"))
    }
}

impl Default for TuneProfile {
    fn default() -> Self {
        TuneProfile::host_default()
    }
}

/// Calibration workload shape: a mid-sized conv-like layer (K = 3·3·128,
/// 64 filters) — big enough that the kernels run out of L1 the way real
/// layers do, small enough that the whole pass stays well under a second.
const CAL_K: usize = 1152;
const CAL_COUT: usize = 64;
/// Density grid the crossover fit walks (fractions of nonzero lanes).
const CAL_GRID: [f32; 10] = [0.05, 0.10, 0.15, 0.20, 0.30, 0.40, 0.50, 0.65, 0.80, 0.95];
/// Per-measurement time budget. Each point is measured until both this
/// budget and a minimum repetition count are reached, so one stray
/// scheduler tick cannot decide a crossover.
const CAL_BUDGET: std::time::Duration = std::time::Duration::from_micros(1500);
const CAL_MIN_REPS: u32 = 8;

/// Time `f` (ns per call): warm twice, then repeat until the budget and
/// the minimum rep count are both met.
fn measure<F: FnMut()>(mut f: F) -> f64 {
    f();
    f();
    let start = Instant::now();
    let mut reps = 0u32;
    loop {
        f();
        reps += 1;
        if reps >= CAL_MIN_REPS && start.elapsed() >= CAL_BUDGET {
            break;
        }
    }
    start.elapsed().as_nanos() as f64 / reps as f64
}

/// A patch of length `k` with ~`density` nonzero lanes plus its
/// compressed (idx, val) form, deterministic in `seed`.
fn cal_patch(k: usize, density: f32, seed: u64) -> (Vec<i8>, Vec<u16>, Vec<i8>) {
    let mut rng = Rng::new(seed);
    let mut patch = vec![0i8; gemm::pad_k(k)];
    let (mut idx, mut val) = (Vec::new(), Vec::new());
    for lane in 0..k {
        if (rng.int_in(0, 9999) as f32) < density * 10000.0 {
            let mut v = rng.int8();
            if v == 0 {
                v = 1;
            }
            patch[lane] = v;
            idx.push(lane as u16);
            val.push(v);
        }
    }
    (patch, idx, val)
}

/// An FC node whose weights have ~`density` nonzero lanes.
fn cal_node(density: f32, seed: u64) -> Node {
    let mut rng = Rng::new(seed);
    let w: Vec<i8> = (0..CAL_K * CAL_COUT)
        .map(|_| {
            if (rng.int_in(0, 9999) as f32) < density * 10000.0 {
                let v = rng.int8();
                if v == 0 {
                    1
                } else {
                    v
                }
            } else {
                0
            }
        })
        .collect();
    Node::Fc {
        cin: CAL_K,
        cout: CAL_COUT,
        sw: 0.01,
        sx: 0.01,
        w,
        bn: None,
        relu: false,
        res_from: None,
        consumes: -1,
    }
}

/// Fit a crossover from per-grid-point (sparse_ns, dense_ns) pairs: the
/// midpoint between the last density where sparse wins and the first
/// where dense wins. Sparse-never-wins → 0.02; sparse-always-wins → 0.98.
fn fit_cutoff(times: &[(f32, f64, f64)]) -> f32 {
    let mut last_sparse_win = None;
    let mut first_dense_win = None;
    for &(d, sparse_ns, dense_ns) in times {
        if sparse_ns < dense_ns {
            last_sparse_win = Some(d);
        } else if first_dense_win.is_none() {
            first_dense_win = Some(d);
        }
    }
    let cut = match (last_sparse_win, first_dense_win) {
        (None, _) => 0.02,
        (Some(_), None) => 0.98,
        (Some(s), Some(f)) => (s + f) / 2.0,
    };
    cut.clamp(0.02, 0.98)
}

/// Microbenchmark this machine and return the fitted profile. Wall time
/// is bounded by the per-point budget (~60 points ≈ 150 ms release).
/// The measurement itself is inherently noisy — determinism guarantees
/// attach to a *given* profile (same profile in ⇒ same plan out), not
/// to repeated calibration runs.
pub fn calibrate() -> TuneProfile {
    let mut out = [0i32; NR];
    let mut sink = 0i32;

    // --- input-side crossover: dense block vs compressed-lane block ---
    let dense_node = cal_node(1.0, 11);
    let dense_pf = gemm::PrepackedFilters::new(&dense_node);
    let mut input_times = Vec::with_capacity(CAL_GRID.len());
    for (gi, &d) in CAL_GRID.iter().enumerate() {
        let (patch, idx, val) = cal_patch(CAL_K, d, 100 + gi as u64);
        let dense_ns = measure(|| {
            let mut f0 = 0;
            while f0 < CAL_COUT {
                gemm::dot_block(&patch, &dense_pf, f0, NR.min(CAL_COUT - f0), &mut out);
                sink = sink.wrapping_add(out[0]);
                f0 += NR;
            }
        });
        let sparse_ns = measure(|| {
            let mut f0 = 0;
            while f0 < CAL_COUT {
                gemm::dot_block_sparse(&idx, &val, &dense_pf, f0, NR.min(CAL_COUT - f0), &mut out);
                sink = sink.wrapping_add(out[0]);
                f0 += NR;
            }
        });
        input_times.push((d, sparse_ns, dense_ns));
    }
    let input_cutoff = fit_cutoff(&input_times);

    // --- weight-side crossover: dense block vs compressed-filter block ---
    let (dense_patch, _, _) = cal_patch(CAL_K, 1.0, 7);
    let mut weight_times = Vec::with_capacity(CAL_GRID.len());
    for (gi, &d) in CAL_GRID.iter().enumerate() {
        let node = cal_node(d, 200 + gi as u64);
        let pf = gemm::PrepackedFilters::new(&node);
        let dense_ns = measure(|| {
            let mut f0 = 0;
            while f0 < CAL_COUT {
                gemm::dot_block(&dense_patch, &pf, f0, NR.min(CAL_COUT - f0), &mut out);
                sink = sink.wrapping_add(out[0]);
                f0 += NR;
            }
        });
        let wsparse_ns = measure(|| {
            let mut f0 = 0;
            while f0 < CAL_COUT {
                gemm::dot_block_wsparse(&dense_patch, &pf, f0, NR.min(CAL_COUT - f0), &mut out);
                sink = sink.wrapping_add(out[0]);
                f0 += NR;
            }
        });
        weight_times.push((d, wsparse_ns, dense_ns));
    }
    let weight_cutoff = fit_cutoff(&weight_times);

    // --- tile height: the real loop nest (filter block hot across the
    // tile's rows) over a 64-row workload at each candidate height ---
    let rows: Vec<(Vec<i8>, Vec<u16>, Vec<i8>)> =
        (0..64).map(|r| cal_patch(CAL_K, 0.6, 300 + r as u64)).collect();
    let mut best_tile = (TILE_ROWS, f64::INFINITY);
    for tr in [4usize, 8, TILE_ROWS] {
        let ns = measure(|| {
            let mut t0 = 0;
            while t0 < rows.len() {
                let t1 = (t0 + tr).min(rows.len());
                let mut f0 = 0;
                while f0 < CAL_COUT {
                    let nf = NR.min(CAL_COUT - f0);
                    for row in &rows[t0..t1] {
                        gemm::dot_block(&row.0, &dense_pf, f0, nf, &mut out);
                        sink = sink.wrapping_add(out[0]);
                    }
                    f0 += NR;
                }
                t0 = t1;
            }
        });
        if ns < best_tile.1 {
            best_tile = (tr, ns);
        }
    }

    // --- thread fan-out: row-partitioned workload, keep the smallest
    // count within 3% of the best aggregate throughput ---
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut thread_times: Vec<(usize, f64)> = Vec::new();
    for t in [1usize, 2, 4, 8] {
        if t > avail {
            break;
        }
        let ns = measure(|| {
            std::thread::scope(|s| {
                for part in rows.chunks(rows.len().div_ceil(t)) {
                    let dense_pf = &dense_pf;
                    s.spawn(move || {
                        let mut out = [0i32; NR];
                        let mut local = 0i32;
                        for row in part {
                            let mut f0 = 0;
                            while f0 < CAL_COUT {
                                gemm::dot_block(&row.0, &dense_pf, f0, NR.min(CAL_COUT - f0), &mut out);
                                local = local.wrapping_add(out[0]);
                                f0 += NR;
                            }
                        }
                        std::hint::black_box(local);
                    });
                }
            });
        });
        thread_times.push((t, ns));
    }
    let best_ns = thread_times.iter().map(|&(_, ns)| ns).fold(f64::INFINITY, f64::min);
    let threads = thread_times
        .iter()
        .find(|&&(_, ns)| ns <= best_ns * 1.03)
        .map(|&(t, _)| t)
        .unwrap_or(1);

    std::hint::black_box(sink);
    let profile = TuneProfile {
        isa: isa::active(),
        input_cutoff,
        weight_cutoff,
        tile_rows: best_tile.0,
        threads,
    };
    debug_assert!(profile.validate().is_ok());
    profile
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profile_matches_compiled_in_cutoffs() {
        // the no-autotune path must freeze exactly what the pre-tuner
        // code froze — the crossover module's host cutoffs
        let p = TuneProfile::host_default();
        assert_eq!(p.input_cutoff, crossover::input_sparse_cutoff());
        assert_eq!(p.weight_cutoff, crossover::weight_sparse_cutoff());
        assert_eq!(p.tile_rows, TILE_ROWS);
        assert_eq!(p.threads, 0);
        assert!(p.validate().is_ok());
        let scalar = TuneProfile::default_for(Isa::Scalar);
        assert_eq!(scalar.input_cutoff, crossover::INPUT_CUTOFF_SCALAR);
        let simd = TuneProfile::default_for(Isa::Avx2);
        assert_eq!(simd.input_cutoff, crossover::INPUT_CUTOFF_AVX2);
        assert_eq!(TuneProfile::default_for(Isa::Neon).input_cutoff, simd.input_cutoff);
    }

    #[test]
    fn profile_text_round_trips() {
        for p in [
            TuneProfile::default_for(Isa::Scalar),
            TuneProfile::default_for(Isa::Avx512Vnni),
            TuneProfile { isa: Isa::Neon, input_cutoff: 0.31, weight_cutoff: 0.11, tile_rows: 8, threads: 4 },
        ] {
            let text = p.to_text();
            let q = TuneProfile::from_text(&text).unwrap();
            assert_eq!(p, q, "round trip through:\n{text}");
            assert_eq!(p.hash(), q.hash());
        }
    }

    #[test]
    fn profile_parse_rejects_junk() {
        assert!(TuneProfile::from_text("").is_err(), "missing version");
        assert!(TuneProfile::from_text("version = 2\nisa = avx2\n").is_err(), "future version");
        assert!(TuneProfile::from_text("version = 1\nisa = mmx\n").is_err(), "unknown isa");
        assert!(TuneProfile::from_text("version = 1\nwat = 3\n").is_err(), "unknown key");
        let bad_cut = "version = 1\nisa = avx2\ninput_cutoff = 1.5\n";
        assert!(TuneProfile::from_text(bad_cut).is_err(), "cutoff out of range");
        let bad_tile = "version = 1\nisa = avx2\ntile_rows = 99\n";
        assert!(TuneProfile::from_text(bad_tile).is_err(), "tile_rows beyond buffer bound");
    }

    #[test]
    fn hash_distinguishes_fields() {
        let base = TuneProfile::default_for(Isa::Avx2);
        let mut seen = vec![base.hash()];
        for p in [
            TuneProfile { isa: Isa::Scalar, ..base },
            TuneProfile { input_cutoff: 0.21, ..base },
            TuneProfile { weight_cutoff: 0.19, ..base },
            TuneProfile { tile_rows: 8, ..base },
            TuneProfile { threads: 2, ..base },
        ] {
            let h = p.hash();
            assert!(!seen.contains(&h), "hash collision for {p:?}");
            seen.push(h);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock microbenchmarks are meaningless interpreted")]
    fn calibrate_produces_a_valid_profile() {
        let p = calibrate();
        p.validate().unwrap();
        assert_eq!(p.isa, isa::active());
        assert!(p.tile_rows >= 1 && p.tile_rows <= TILE_ROWS);
        assert!(p.threads >= 1, "calibration must suggest a thread count");
    }

    #[test]
    fn fit_cutoff_edges() {
        // sparse never wins → floor; always wins → ceiling; the normal
        // case lands between the flanking grid points
        assert_eq!(fit_cutoff(&[(0.1, 5.0, 1.0), (0.5, 5.0, 1.0)]), 0.02);
        assert_eq!(fit_cutoff(&[(0.1, 1.0, 5.0), (0.5, 1.0, 5.0)]), 0.98);
        let mid = fit_cutoff(&[(0.1, 1.0, 5.0), (0.3, 1.0, 5.0), (0.5, 9.0, 5.0)]);
        assert!((mid - 0.4).abs() < 1e-6, "got {mid}");
    }
}
