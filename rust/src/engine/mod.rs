//! Functional int8 inference engine — numeric primitives.
//!
//! Mirrors python/compile/quantize.py's integer dataflow contract exactly:
//! activations travel as float32 between nodes, every compute node
//! quantizes its input with its own `sx`, dot products are exact
//! int8×int8→int32, everything after the dot is float32 in the same
//! operation order. The MoR-aware forward lives in [`crate::predictor`];
//! this module provides tensors, im2col patch gathering, pooling and the
//! dot kernels.
//!
//! The engine is **triple-sided sparse**: besides the MoR predictor's
//! output-side skipping, zero-valued *input* activation lanes (ReLU
//! guarantees the previous layer's output is highly sparse) can be
//! elided per tile row through a compressed nonzero-lane representation
//! ([`gemm::PatchTile`]) and sparse kernels ([`dot::dot_i8_sparse`],
//! [`gemm::dot_block_sparse`]), and zero *weight* lanes (pruned or
//! naturally-dead filter taps) can be elided per layer through a
//! prepack-time compressed filter representation
//! ([`gemm::PrepackedFilters`]) and weight-sparse kernels (including
//! the doubly-sparse index-intersection dot
//! [`dot::dot_i8_sparse_sparse`]). Zero lanes contribute exactly zero
//! to the integer dot, so both sparse paths are bit-identical to the
//! dense one — [`InputSparsity`] and [`WeightSparsity::Exact`] are
//! purely host-performance knobs; only [`WeightSparsity::Threshold`]
//! (magnitude pruning) changes results (see EXPERIMENTS.md §Sparse and
//! §Weights). Kernel-choice crossover points live in [`crossover`].

pub mod crossover;
pub mod dot;
pub mod gemm;
pub mod isa;
pub mod tune;

use crate::model::Node;
use crate::util::bits::PackedVec;
use anyhow::{bail, Result};

/// Input-side sparsity mode: whether the tiled engine skips zero-valued
/// input activation lanes (Cnvlutin2/SparseNN-style "ineffectual input"
/// elision, complementary to the MoR output predictor).
///
/// All three modes produce **bit-identical** results — logits,
/// `OpsStats` (including `macs_skipped_input_zero`, which is a property
/// of the data, not of the kernel that ran), `PredStats` and traces —
/// because a zero int8 lane contributes exactly 0 to the integer dot.
/// The mode only selects which kernel executes on the host.
///
/// Surface: `RunOpts::input_sparsity`, TOML `[engine] input_sparsity =
/// "auto"|"on"|"off"`, CLI `--input-sparsity`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InputSparsity {
    /// Per tile row, use the compressed-lane kernel only when the
    /// measured nonzero density is below the crossover
    /// ([`gemm::sparse_auto_cutoff`]) — the default.
    #[default]
    Auto,
    /// Always use the compressed-lane kernel (when the layer's dot
    /// length fits the u16 lane index; falls back to dense otherwise).
    On,
    /// Never build or use the compressed representation.
    Off,
}

impl InputSparsity {
    /// Every mode, in presentation order.
    pub const ALL: [InputSparsity; 3] =
        [InputSparsity::Auto, InputSparsity::On, InputSparsity::Off];

    /// Parse a CLI / TOML mode name (`auto`, `on`, `off`).
    pub fn parse(name: &str) -> Result<InputSparsity> {
        match name {
            "auto" => Ok(InputSparsity::Auto),
            "on" => Ok(InputSparsity::On),
            "off" => Ok(InputSparsity::Off),
            other => bail!("unknown input-sparsity mode '{other}' (expected auto, on or off)"),
        }
    }

    /// Stable CLI / config identifier.
    pub fn name(self) -> &'static str {
        match self {
            InputSparsity::Auto => "auto",
            InputSparsity::On => "on",
            InputSparsity::Off => "off",
        }
    }
}

/// Weight-side sparsity mode: whether the tiled engine elides zero
/// weight lanes through the prepack-time compressed filter lists
/// ([`gemm::PrepackedFilters`]) — the third ineffectual source next to
/// MoR output prediction and [`InputSparsity`] input-zero skipping
/// (Cnvlutin2-style weight-lane elision).
///
/// [`WeightSparsity::Off`] and [`WeightSparsity::Exact`] are
/// **bit-identical** — `Exact` elides only true-zero lanes, which
/// contribute exactly 0 to the integer dot, and the
/// `macs_skipped_weight_zero` counter is a property of the data that is
/// recorded in every mode. [`WeightSparsity::Threshold`] additionally
/// zeroes small-magnitude weights at session build (magnitude pruning),
/// which **does change results**; its accuracy cost is measured and
/// reported by `mor run`.
///
/// Surface: `RunOpts::weight_sparsity`, TOML `[engine] weight_sparsity
/// = "off"|"exact"|<t>`, CLI `--weight-sparsity`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum WeightSparsity {
    /// Dense weight kernels everywhere (the default). The
    /// weight-zero accounting still runs so `OpsStats` are
    /// mode-independent.
    #[default]
    Off,
    /// Elide true-zero weight lanes where the per-layer density makes
    /// the compressed kernel profitable ([`crossover`]); bit-identical
    /// to `Off` by construction.
    Exact,
    /// Zero every weight with dequantized magnitude `|w|·sw` below the
    /// threshold when the session is built, then elide as `Exact`.
    /// Accuracy-affecting and opt-in.
    Threshold(f32),
}

impl WeightSparsity {
    /// The result-preserving modes (a threshold is an open set and not
    /// enumerable) — what the equivalence suites sweep.
    pub const EXACT_MODES: [WeightSparsity; 2] = [WeightSparsity::Off, WeightSparsity::Exact];

    /// Parse a CLI / TOML mode (`off`, `exact`, or a threshold > 0).
    pub fn parse(name: &str) -> Result<WeightSparsity> {
        match name {
            "off" => Ok(WeightSparsity::Off),
            "exact" => Ok(WeightSparsity::Exact),
            other => match other.parse::<f32>() {
                Ok(t) if t > 0.0 && t.is_finite() => Ok(WeightSparsity::Threshold(t)),
                _ => bail!(
                    "unknown weight-sparsity mode '{other}' (expected off, exact or a threshold > 0)"
                ),
            },
        }
    }

    /// Stable CLI / config identifier (threshold renders its value).
    pub fn name(self) -> String {
        match self {
            WeightSparsity::Off => "off".into(),
            WeightSparsity::Exact => "exact".into(),
            WeightSparsity::Threshold(t) => format!("{t}"),
        }
    }

    /// The magnitude-pruning threshold, 0 for the non-pruning modes.
    pub fn threshold(self) -> f32 {
        match self {
            WeightSparsity::Threshold(t) => t,
            _ => 0.0,
        }
    }
}

/// A (H, W, C) float32 activation tensor, row-major.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(h: usize, w: usize, c: usize) -> Tensor {
        Tensor {
            h,
            w,
            c,
            data: vec![0.0; h * w * c],
        }
    }

    pub fn from_slice(h: usize, w: usize, c: usize, data: &[f32]) -> Tensor {
        assert_eq!(data.len(), h * w * c);
        Tensor {
            h,
            w,
            c,
            data: data.to_vec(),
        }
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn at_mut(&mut self, y: usize, x: usize, ch: usize) -> &mut f32 {
        &mut self.data[(y * self.w + x) * self.c + ch]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Re-dimension this tensor to `(h, w, c)` with zeroed contents,
    /// reusing the existing heap buffer — the workspace path's
    /// allocation-free replacement for [`Tensor::new`].
    #[inline]
    pub fn reset(&mut self, h: usize, w: usize, c: usize) {
        self.h = h;
        self.w = w;
        self.c = c;
        self.data.clear();
        self.data.resize(h * w * c, 0.0);
    }

    /// Re-dimension and fill from a slice, reusing the heap buffer
    /// (allocation-free once the buffer has reached its high-water size).
    #[inline]
    pub fn assign(&mut self, h: usize, w: usize, c: usize, data: &[f32]) {
        assert_eq!(data.len(), h * w * c);
        self.h = h;
        self.w = w;
        self.c = c;
        self.data.clear();
        self.data.extend_from_slice(data);
    }
}

/// Convolution output geometry + SAME padding offsets (matches the python
/// `_same_pad`: `total = max(0, (out-1)*stride + k - size)`, low = total/2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConvGeom {
    pub oh: usize,
    pub ow: usize,
    pub pad_top: usize,
    pub pad_left: usize,
}

pub fn conv_geom(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad_same: bool,
) -> ConvGeom {
    if pad_same {
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let total_h = ((oh - 1) * stride + kh).saturating_sub(h);
        let total_w = ((ow - 1) * stride + kw).saturating_sub(w);
        ConvGeom {
            oh,
            ow,
            pad_top: total_h / 2,
            pad_left: total_w / 2,
        }
    } else {
        ConvGeom {
            oh: (h - kh) / stride + 1,
            ow: (w - kw) / stride + 1,
            pad_top: 0,
            pad_left: 0,
        }
    }
}

/// A layer input quantized once with the layer's `sx`; shared read-only by
/// every [`PatchGather`] (one per row-tile worker thread).
pub struct QuantizedTensor {
    /// quantized input, row-major (h, w, c)
    pub q: Vec<i8>,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl QuantizedTensor {
    pub fn new(input: &Tensor, sx: f32) -> QuantizedTensor {
        let mut qt = QuantizedTensor::empty();
        qt.requantize(input, sx);
        qt
    }

    /// An empty quantized buffer (no heap allocation) — workspace slots
    /// start here and grow to their high-water size on first use.
    pub fn empty() -> QuantizedTensor {
        QuantizedTensor { q: Vec::new(), h: 0, w: 0, c: 0 }
    }

    /// Quantize `input` into this buffer, reusing its capacity
    /// (allocation-free once the buffer has seen the largest layer
    /// input). Bit-identical to [`QuantizedTensor::new`].
    pub fn requantize(&mut self, input: &Tensor, sx: f32) {
        dot::quantize_i8(&input.data, sx, &mut self.q);
        self.h = input.h;
        self.w = input.w;
        self.c = input.c;
    }
}

/// Reusable im2col patch scratch. Owns no source: the
/// [`QuantizedTensor`] to gather from is passed per call, so one
/// `PatchGather` (held in a [`crate::plan::Workspace`], one per row-tile
/// worker) serves every layer and every sample of a batch without
/// reallocating.
pub struct PatchGather {
    /// current patch, (kh, kw, cin) order — matches the weight layout
    pub patch: Vec<i8>,
    /// packed ±1 activations of the current patch (padding lanes invalid)
    pub packed: PackedVec,
    /// nonzero lanes in the current patch (padding lanes are zero and
    /// never counted) — feeds the sparsity accounting
    /// (`OpsStats::macs_skipped_input_zero`) and the compressed-lane
    /// kernel selection.
    pub nnz: usize,
    /// nonzero-activation bitmask of the current patch, one bit per
    /// lane (`lane/64` word, `lane%64` bit; bits beyond `k_len` stay
    /// clear) — intersected with the per-filter nonzero-weight mask
    /// ([`gemm::PrepackedFilters::wmask`]) for the weight-zero
    /// accounting (`OpsStats::macs_skipped_weight_zero`).
    pub nzmask: Vec<u64>,
}

impl Default for PatchGather {
    fn default() -> Self {
        Self::new()
    }
}

impl PatchGather {
    pub fn new() -> PatchGather {
        PatchGather {
            patch: Vec::new(),
            packed: PackedVec::zeros(0),
            nnz: 0,
            nzmask: Vec::new(),
        }
    }

    /// Gather the (kh,kw,cin) patch for output position (oy, ox).
    /// Out-of-bounds (SAME padding) cells are 0 in `patch` and *invalid*
    /// in `packed` — so they contribute 0 to both dot products, exactly
    /// like the jnp path (which zero-pads both the int8 and the binarized
    /// tensor).
    ///
    /// §Perf: buffers are reused across calls (no allocation on the row
    /// loop) and interior channel runs are copied slice-wise.
    #[allow(clippy::too_many_arguments)]
    pub fn gather(
        &mut self,
        src: &QuantizedTensor,
        geom: ConvGeom,
        kh: usize,
        kw: usize,
        stride: usize,
        oy: usize,
        ox: usize,
    ) {
        let (h, w, c) = (src.h, src.w, src.c);
        let k_len = kh * kw * c;
        self.reset_buffers(k_len);
        let base_y = (oy * stride) as isize - geom.pad_top as isize;
        let base_x = (ox * stride) as isize - geom.pad_left as isize;
        let mut idx = 0;
        for dy in 0..kh {
            let y = base_y + dy as isize;
            for dx in 0..kw {
                let x = base_x + dx as isize;
                if y >= 0 && (y as usize) < h && x >= 0 && (x as usize) < w {
                    let off = ((y as usize) * w + x as usize) * c;
                    self.patch[idx..idx + c].copy_from_slice(&src.q[off..off + c]);
                    for ch in 0..c {
                        let v = src.q[off + ch];
                        self.packed.push_lane(idx + ch, v > 0);
                        if v != 0 {
                            self.nnz += 1;
                            self.nzmask[(idx + ch) / 64] |= 1u64 << ((idx + ch) % 64);
                        }
                    }
                    idx += c;
                } else {
                    idx += c; // padding: patch stays 0, lanes invalid
                }
            }
        }
    }

    /// Grow the gather buffers for dot lengths up to `k_len` without
    /// touching their contents — warmup presizing, so the per-row
    /// [`PatchGather::gather`] calls never allocate (mirrors
    /// [`gemm::PatchTile::reserve`]).
    pub fn reserve(&mut self, k_len: usize) {
        crate::util::reserve_capacity(&mut self.patch, k_len);
        let words = k_len.div_ceil(64);
        crate::util::reserve_capacity(&mut self.packed.bits, words);
        crate::util::reserve_capacity(&mut self.packed.valid, words);
        crate::util::reserve_capacity(&mut self.nzmask, words);
    }

    /// FC "gather": the patch is simply the (h*w-position) channel vector.
    pub fn gather_fc(&mut self, src: &QuantizedTensor, pos: usize) {
        let c = src.c;
        self.reset_buffers(c);
        self.patch.copy_from_slice(&src.q[pos * c..(pos + 1) * c]);
        for i in 0..c {
            let v = self.patch[i];
            self.packed.push_lane(i, v > 0);
            if v != 0 {
                self.nnz += 1;
                self.nzmask[i / 64] |= 1u64 << (i % 64);
            }
        }
    }

    /// Clear + resize the reusable patch/packed buffers without freeing.
    #[inline]
    fn reset_buffers(&mut self, k_len: usize) {
        self.patch.clear();
        self.patch.resize(k_len, 0);
        let words = k_len.div_ceil(64);
        if self.packed.bits.len() != words {
            self.packed.bits.resize(words, 0);
            self.packed.valid.resize(words, 0);
        }
        self.packed.bits.fill(0);
        self.packed.valid.fill(0);
        self.packed.len = k_len;
        if self.nzmask.len() != words {
            self.nzmask.resize(words, 0);
        }
        self.nzmask.fill(0);
        self.nnz = 0;
    }
}

/// Float max-pool (size x size, stride = size, VALID), window clamped to
/// the tensor width for W=1 sequence layouts — matches the jnp path.
pub fn maxpool(input: &Tensor, size: usize) -> Tensor {
    let mut out = Tensor::new(0, 0, 0);
    maxpool_into(input, size, &mut out);
    out
}

/// [`maxpool`] into a reusable output tensor (allocation-free once the
/// buffer has reached its high-water size) — the workspace path.
pub fn maxpool_into(input: &Tensor, size: usize, out: &mut Tensor) {
    let kw = size.min(input.w);
    let oh = input.h / size;
    let ow = (input.w / size).max(1);
    out.reset(oh, ow, input.c);
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..input.c {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..size {
                    for dx in 0..kw {
                        m = m.max(input.at(oy * size + dy, ox * size + dx, ch));
                    }
                }
                *out.at_mut(oy, ox, ch) = m;
            }
        }
    }
}

/// Global average pool over H and W → (1, 1, C).
pub fn gap(input: &Tensor) -> Tensor {
    let mut out = Tensor::new(0, 0, 0);
    gap_into(input, &mut out);
    out
}

/// [`gap`] into a reusable output tensor — the workspace path.
pub fn gap_into(input: &Tensor, out: &mut Tensor) {
    out.reset(1, 1, input.c);
    let n = (input.h * input.w) as f32;
    for ch in 0..input.c {
        let mut s = 0.0;
        for y in 0..input.h {
            for x in 0..input.w {
                s += input.at(y, x, ch);
            }
        }
        out.data[ch] = s / n;
    }
}

/// Elementwise ReLU.
pub fn relu(input: &Tensor) -> Tensor {
    let mut out = Tensor::new(0, 0, 0);
    relu_into(input, &mut out);
    out
}

/// [`relu`] into a reusable output tensor — the workspace path.
pub fn relu_into(input: &Tensor, out: &mut Tensor) {
    out.h = input.h;
    out.w = input.w;
    out.c = input.c;
    out.data.clear();
    out.data.extend(input.data.iter().map(|v| v.max(0.0)));
}

/// Per-neuron post-dot transform: dequant → BN affine → (+ residual).
/// Returns the ReLU *input* (pre-activation) value.
#[inline]
pub fn relu_input(
    dot: i32,
    dq: f32,
    bn: Option<&(Vec<f32>, Vec<f32>)>,
    neuron: usize,
    residual: f32,
) -> f32 {
    let mut v = dot as f32 * dq;
    if let Some((scale, shift)) = bn {
        v = v * scale[neuron] + shift[neuron];
    }
    v + residual
}

/// Number of MACs a node performs per output element (= K).
pub fn macs_per_output(node: &Node) -> u64 {
    node.k_len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geom_same_matches_python() {
        // python _same_pad(16, 3, 1) = (1, 1); out = 16
        let g = conv_geom(16, 16, 3, 3, 1, true);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (16, 16, 1, 1));
        // stride 2: out = ceil(16/2) = 8; total = (8-1)*2+3-16 = 1; lo = 0
        let g = conv_geom(16, 16, 3, 3, 2, true);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (8, 8, 0, 0));
        // 1-wide W (sequence models): kw=1 → no pad
        let g = conv_geom(32, 1, 5, 1, 1, true);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (32, 1, 2, 0));
    }

    #[test]
    fn conv_geom_valid() {
        let g = conv_geom(10, 8, 3, 3, 2, false);
        assert_eq!((g.oh, g.ow), (4, 3));
        assert_eq!((g.pad_top, g.pad_left), (0, 0));
    }

    #[test]
    fn conv_geom_stride_exceeds_kernel() {
        // SAME, stride 3 > kernel 2: oh = ceil(10/3) = 4,
        // total_h = (4-1)*3 + 2 - 10 = 1 → pad_top = 0 (low half)
        let g = conv_geom(10, 7, 2, 2, 3, true);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (4, 3, 0, 0));
        // VALID, stride 3 > kernel 2: oh = (10-2)/3 + 1 = 3
        let g = conv_geom(10, 7, 2, 2, 3, false);
        assert_eq!((g.oh, g.ow), (3, 2));
    }

    #[test]
    fn conv_geom_one_by_one_same() {
        // pointwise conv never pads
        let g = conv_geom(5, 9, 1, 1, 1, true);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (5, 9, 0, 0));
        let g = conv_geom(5, 9, 1, 1, 2, true);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (3, 5, 0, 0));
    }

    #[test]
    fn conv_geom_non_square_input() {
        // H != W with asymmetric padding needs
        let g = conv_geom(7, 4, 3, 3, 2, true);
        // oh = 4: total_h = 3*2+3-7 = 2 → pad_top 1; ow = 2: total_w = 2+3-4 = 1 → pad_left 0
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (4, 2, 1, 0));
    }

    #[test]
    fn gather_interior_and_padding() {
        // 3x3x1 input with values 1..9, k=3 SAME, look at corner (0,0)
        let t = Tensor::from_slice(3, 3, 1, &[1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        let qt = QuantizedTensor::new(&t, 1.0 / 1.0);
        let mut pg = PatchGather::new();
        let geom = conv_geom(3, 3, 3, 3, 1, true);
        pg.gather(&qt, geom, 3, 3, 1, 0, 0);
        // top-left corner: first row and column padded
        assert_eq!(pg.patch, vec![0, 0, 0, 0, 1, 2, 0, 4, 5]);
        // padding lanes invalid; interior lanes valid
        let valid: Vec<bool> = (0..9).map(|i| pg.packed.valid[0] >> i & 1 == 1).collect();
        assert_eq!(
            valid,
            vec![false, false, false, false, true, true, false, true, true]
        );
        // nonzero-lane count excludes the padding lanes
        assert_eq!(pg.nnz, 4);
        // center position: fully interior
        pg.gather(&qt, geom, 3, 3, 1, 1, 1);
        assert_eq!(pg.patch, vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(pg.nnz, 9);
    }

    #[test]
    fn gather_counts_true_zero_activations() {
        // interior zeros (quantized-to-zero activations) count as zero
        // lanes too, not just SAME-padding cells
        let t = Tensor::from_slice(2, 2, 1, &[3., 0., 0., -2.]);
        let qt = QuantizedTensor::new(&t, 1.0);
        let mut pg = PatchGather::new();
        pg.gather_fc(&qt, 0);
        assert_eq!(pg.nnz, 1);
        pg.gather_fc(&qt, 1);
        assert_eq!(pg.nnz, 0);
        pg.gather_fc(&qt, 3);
        assert_eq!(pg.nnz, 1);
    }

    #[test]
    fn input_sparsity_parse_round_trips() {
        for m in InputSparsity::ALL {
            assert_eq!(InputSparsity::parse(m.name()).unwrap(), m);
        }
        assert!(InputSparsity::parse("dense").is_err());
        assert_eq!(InputSparsity::default(), InputSparsity::Auto);
    }

    #[test]
    fn weight_sparsity_parse_round_trips() {
        assert_eq!(WeightSparsity::parse("off").unwrap(), WeightSparsity::Off);
        assert_eq!(WeightSparsity::parse("exact").unwrap(), WeightSparsity::Exact);
        assert_eq!(
            WeightSparsity::parse("0.02").unwrap(),
            WeightSparsity::Threshold(0.02)
        );
        for m in WeightSparsity::EXACT_MODES {
            assert_eq!(WeightSparsity::parse(&m.name()).unwrap(), m);
        }
        assert_eq!(WeightSparsity::default(), WeightSparsity::Off);
        assert_eq!(WeightSparsity::Threshold(0.5).threshold(), 0.5);
        assert_eq!(WeightSparsity::Exact.threshold(), 0.0);
        // rejected: negative, zero, NaN, junk
        assert!(WeightSparsity::parse("-1").is_err());
        assert!(WeightSparsity::parse("0").is_err());
        assert!(WeightSparsity::parse("NaN").is_err());
        assert!(WeightSparsity::parse("dense").is_err());
    }

    #[test]
    fn gather_builds_nonzero_mask() {
        let t = Tensor::from_slice(2, 2, 1, &[3., 0., 0., -2.]);
        let qt = QuantizedTensor::new(&t, 1.0);
        let mut pg = PatchGather::new();
        // SAME 3x3 over the 2x2 input at (0,0): patch lanes 4,5,7,8 are
        // interior, with values [3, 0, 0, -2] → nonzero at lanes 4 and 8
        let geom = conv_geom(2, 2, 3, 3, 1, true);
        pg.gather(&qt, geom, 3, 3, 1, 0, 0);
        assert_eq!(pg.nnz, 2);
        assert_eq!(pg.nzmask, vec![(1u64 << 4) | (1u64 << 8)]);
        // mask matches the patch, lane for lane, and resets on reuse
        pg.gather_fc(&qt, 1);
        assert_eq!(pg.nzmask, vec![0]);
        pg.gather_fc(&qt, 3);
        assert_eq!(pg.nzmask, vec![1]);
        for (i, &v) in pg.patch.iter().enumerate() {
            assert_eq!(pg.nzmask[i / 64] >> (i % 64) & 1 == 1, v != 0);
        }
    }

    #[test]
    fn gather_binary_dot_padding_contributes_zero() {
        let t = Tensor::from_slice(2, 2, 1, &[5., -5., 5., -5.]);
        let qt = QuantizedTensor::new(&t, 1.0);
        let mut pg = PatchGather::new();
        let geom = conv_geom(2, 2, 3, 3, 1, true);
        pg.gather(&qt, geom, 3, 3, 1, 0, 0);
        let w = vec![1i8; 9];
        let wp = crate::util::bits::PackedVec::from_weights(&w);
        // valid lanes: the 2x2 interior = acts (+1,-1,+1,-1) → dot 0
        assert_eq!(pg.packed.dot(&wp), 0);
    }

    #[test]
    fn maxpool_and_gap() {
        let t = Tensor::from_slice(2, 2, 1, &[1., 2., 3., 4.]);
        let p = maxpool(&t, 2);
        assert_eq!((p.h, p.w, p.c), (1, 1, 1));
        assert_eq!(p.data, vec![4.0]);
        let g = gap(&t);
        assert_eq!(g.data, vec![2.5]);
    }

    #[test]
    fn maxpool_seq_width1() {
        let t = Tensor::from_slice(4, 1, 2, &[1., -1., 2., -2., 3., -3., 4., -4.]);
        let p = maxpool(&t, 2);
        assert_eq!((p.h, p.w, p.c), (2, 1, 2));
        assert_eq!(p.data, vec![2., -1., 4., -3.]);
    }

    #[test]
    fn relu_input_bn_residual() {
        let bn = (vec![2.0f32], vec![0.5f32]);
        let v = relu_input(100, 0.01, Some(&bn), 0, 0.25);
        assert!((v - (1.0 * 2.0 + 0.5 + 0.25)).abs() < 1e-6);
        let v2 = relu_input(100, 0.01, None, 0, 0.0);
        assert!((v2 - 1.0).abs() < 1e-6);
    }
}
