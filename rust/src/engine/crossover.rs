//! Sparse-kernel crossover points — the single home for every
//! "compressed vs dense" density cutoff (they used to live as literals
//! scattered through `gemm.rs` and `plan/compile.rs`).
//!
//! ## Rationale
//!
//! The dense kernel ([`super::dot::dot_i8`]) streams every lane; with
//! AVX2 it retires ~16 MACs per `vpmaddwd` and is limited by loads, so
//! its per-lane cost is tiny. The compressed kernels
//! ([`super::dot::dot_i8_sparse`] and friends) pay an indexed gather
//! per *nonzero* lane: cheaper only when enough lanes are zero. The
//! break-even density was measured with `cargo bench` (perf_hotpaths,
//! EXPERIMENTS.md §Sparse): the sparse kernel wins below ~20% nonzero
//! density against the AVX2 dense kernel, and below ~75% against the
//! scalar fallback (where the dense kernel has no SIMD advantage).
//!
//! The *weight*-sparse kernel is the same gather loop under an operand
//! swap — a compressed filter walking a dense patch instead of a
//! compressed patch walking a dense filter — so its break-even point
//! against the same dense kernel is the same, and the weight-side
//! cutoffs deliberately share the input-side constants. They are named
//! separately because they are *used* differently: the input cutoff is
//! applied per tile row at execute time (activation density is data),
//! while the weight cutoff is applied per layer at plan-compile time
//! (weight density is frozen at prepack).
//!
//! All cutoffs are host-performance knobs only: the kernels they choose
//! between are bit-identical (zero lanes contribute exactly 0 to the
//! integer dot).

/// Nonzero-density cutoff for the per-row compressed-*input* kernel
/// against the AVX2 dense kernel.
pub const INPUT_CUTOFF_AVX2: f32 = 0.20;
/// ... and against the scalar dense fallback (no SIMD to beat, so the
/// compressed kernel stays profitable much longer).
pub const INPUT_CUTOFF_SCALAR: f32 = 0.75;
/// Nonzero-density cutoff for the per-layer compressed-*weight* kernel
/// against the AVX2 dense kernel — shared with the input side because
/// the kernel is the same gather loop under an operand swap.
pub const WEIGHT_CUTOFF_AVX2: f32 = INPUT_CUTOFF_AVX2;
/// ... and against the scalar dense fallback.
pub const WEIGHT_CUTOFF_SCALAR: f32 = INPUT_CUTOFF_SCALAR;

/// The input-side crossover for this host (from the active ISA tier —
/// see [`super::isa`]): a tile row with `nnz/k_len` below this should
/// take the compressed-lane kernel under `InputSparsity::Auto`.
///
/// These are the *compiled-in defaults* — what a [`super::tune::TuneProfile`]
/// starts from, and what plans compiled without autotuning freeze. Any
/// SIMD tier (NEON, AVX2, VNNI) takes the SIMD cutoff: the dense kernel
/// it must beat retires ≥ 16 MACs per instruction on all of them.
#[inline]
pub fn input_sparse_cutoff() -> f32 {
    if simd() {
        INPUT_CUTOFF_AVX2
    } else {
        INPUT_CUTOFF_SCALAR
    }
}

/// The weight-side crossover for this host: a layer whose prepacked
/// nonzero-weight density is below this should bake the weight-sparse
/// kernel into its `ModelPlan` step (under `WeightSparsity::Exact` /
/// `Threshold`).
#[inline]
pub fn weight_sparse_cutoff() -> f32 {
    if simd() {
        WEIGHT_CUTOFF_AVX2
    } else {
        WEIGHT_CUTOFF_SCALAR
    }
}

/// Whether the active dispatch tier has a SIMD dense kernel to beat.
#[inline]
fn simd() -> bool {
    super::isa::active() > super::isa::Isa::Scalar
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cutoffs_are_sane_fractions() {
        for c in [
            INPUT_CUTOFF_AVX2,
            INPUT_CUTOFF_SCALAR,
            WEIGHT_CUTOFF_AVX2,
            WEIGHT_CUTOFF_SCALAR,
            input_sparse_cutoff(),
            weight_sparse_cutoff(),
        ] {
            assert!(c > 0.0 && c < 1.0, "cutoff {c} must be a density fraction");
        }
        // the SIMD dense kernel is harder to beat: its cutoff is lower
        assert!(INPUT_CUTOFF_AVX2 < INPUT_CUTOFF_SCALAR);
        assert!(WEIGHT_CUTOFF_AVX2 < WEIGHT_CUTOFF_SCALAR);
    }

    #[test]
    fn weight_and_input_sides_share_the_operand_swap_constants() {
        assert_eq!(WEIGHT_CUTOFF_AVX2, INPUT_CUTOFF_AVX2);
        assert_eq!(WEIGHT_CUTOFF_SCALAR, INPUT_CUTOFF_SCALAR);
        assert_eq!(weight_sparse_cutoff(), input_sparse_cutoff());
    }
}
