//! CPU ISA detection and dispatch override — the single
//! detection/override point for every kernel in the engine.
//!
//! Every SIMD dispatch question (`avx2_enabled`, `vnni_enabled`,
//! `neon_enabled`) funnels through [`active`], which combines three
//! inputs, cached once per process:
//!
//! 1. **Detection** ([`detected`], `OnceLock`): `is_x86_feature_detected!`
//!    on x86-64 (AVX-512 VNNI requires the `mor_avx512` build-probe cfg —
//!    rustc ≥ 1.89 stabilized the intrinsics; older toolchains top out at
//!    AVX2), baseline NEON on aarch64, scalar elsewhere. Under Miri the
//!    intrinsics are unsupported, so detection reports [`Isa::Scalar`]
//!    and every kernel takes the portable path — that is what keeps the
//!    property suites Miri-runnable.
//! 2. **Environment override** (`MOR_ISA=scalar|avx2|avx512vnni|neon`,
//!    read once): caps dispatch at the named tier. Used by the CI
//!    forced-ISA matrix to run the whole test suite per tier.
//! 3. **Programmatic override** ([`force`]): same cap, settable from
//!    tests. It is process-global — tests that use it serialize on a
//!    mutex (see `tests/isa_equivalence.rs`).
//!
//! Overrides can only *lower* the tier (`min` with detection): forcing
//! AVX2 on a scalar-only host still runs scalar, so an override can
//! never select an unsupported instruction. All tiers are bit-identical
//! by the engine's i32-dot contract, so the override is purely a
//! dispatch knob — the equivalence suites double as the cross-ISA
//! oracle.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// A dispatchable kernel tier, ordered from most portable to fastest.
/// The numeric order is the override `min` lattice: NEON sits between
/// scalar and the x86 tiers but never coexists with them at runtime
/// (an architecture has one SIMD family).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Isa {
    /// Portable integer loops — the bit-exactness oracle everywhere.
    Scalar = 0,
    /// aarch64 NEON (`smull`/`vpadal` widening dot) — baseline on every
    /// aarch64 target, so detection is compile-time.
    Neon = 1,
    /// x86-64 AVX2 (`vpmovsxbw` + `vpmaddwd`).
    Avx2 = 2,
    /// x86-64 AVX-512 VNNI (`vpdpbusd`, unsigned×signed with the
    /// `x ⊕ 0x80` offset trick — see `dot::dot_i8_vnni`). Requires the
    /// `mor_avx512` cfg from the build probe *and* runtime
    /// avx512f/avx512bw/avx512vnni (BW for the masked-tail byte loads).
    Avx512Vnni = 3,
}

impl Isa {
    /// Every tier, in lattice order.
    pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Neon, Isa::Avx2, Isa::Avx512Vnni];

    /// Stable identifier used by `MOR_ISA`, bench provenance and
    /// `TuneProfile` serialization.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Neon => "neon",
            Isa::Avx2 => "avx2",
            Isa::Avx512Vnni => "avx512vnni",
        }
    }

    /// Parse a `MOR_ISA` / profile identifier (`vnni` is accepted as an
    /// alias for `avx512vnni`).
    pub fn parse(name: &str) -> Option<Isa> {
        match name {
            "scalar" => Some(Isa::Scalar),
            "neon" => Some(Isa::Neon),
            "avx2" => Some(Isa::Avx2),
            "avx512vnni" | "vnni" => Some(Isa::Avx512Vnni),
            _ => None,
        }
    }

    fn from_rank(rank: u8) -> Isa {
        match rank {
            0 => Isa::Scalar,
            1 => Isa::Neon,
            2 => Isa::Avx2,
            _ => Isa::Avx512Vnni,
        }
    }
}

/// The best tier this host supports (cached; Miri always reports
/// scalar — the intrinsics are uninterpretable there).
pub fn detected() -> Isa {
    if cfg!(miri) {
        return Isa::Scalar;
    }
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(detect)
}

fn detect() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        #[cfg(mor_avx512)]
        {
            if std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512bw")
                && std::arch::is_x86_feature_detected!("avx512vnni")
            {
                return Isa::Avx512Vnni;
            }
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
        Isa::Scalar
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the aarch64 baseline — no runtime probe needed.
        Isa::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Isa::Scalar
    }
}

/// Programmatic override slot: 0..=3 = forced rank, `UNSET` = none.
static FORCED: AtomicU8 = AtomicU8::new(UNSET);
const UNSET: u8 = u8::MAX;

/// The `MOR_ISA` environment cap, read once. Invalid values warn to
/// stderr and are ignored rather than silently selecting a tier.
fn env_cap() -> Option<Isa> {
    static ENV: OnceLock<Option<Isa>> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("MOR_ISA") {
        Ok(v) => {
            let isa = Isa::parse(&v);
            if isa.is_none() {
                eprintln!(
                    "warning: MOR_ISA='{v}' not recognized (expected scalar|neon|avx2|avx512vnni); ignoring"
                );
            }
            isa
        }
        Err(_) => None,
    })
}

/// Cap dispatch at `isa` (or clear the cap with `None`) for the whole
/// process. Testing knob only — callers must serialize (the override is
/// global) and the cap still `min`s with [`detected`], so it can never
/// enable an unsupported tier.
pub fn force(isa: Option<Isa>) {
    FORCED.store(isa.map(|i| i as u8).unwrap_or(UNSET), Ordering::Relaxed);
}

/// The tier kernels actually dispatch to right now:
/// `min(detected, MOR_ISA cap, forced cap)`.
#[inline]
pub fn active() -> Isa {
    let mut isa = detected();
    let forced = FORCED.load(Ordering::Relaxed);
    if forced != UNSET {
        isa = isa.min(Isa::from_rank(forced));
    } else if let Some(cap) = env_cap() {
        isa = isa.min(cap);
    }
    isa
}

/// Every tier this host can actually run (always includes scalar) —
/// what the cross-ISA equivalence suite sweeps and `mor info` prints.
pub fn available() -> Vec<Isa> {
    let top = detected();
    Isa::ALL
        .iter()
        .copied()
        .filter(|&i| {
            i <= top
                && match i {
                    Isa::Neon => cfg!(target_arch = "aarch64"),
                    Isa::Avx2 | Isa::Avx512Vnni => cfg!(target_arch = "x86_64"),
                    Isa::Scalar => true,
                }
        })
        .collect()
}

/// AVX2 dispatch predicate (false off-x86). The former
/// `dot::avx2_enabled` — every AVX2 kernel call site funnels here.
#[inline]
pub fn avx2_enabled() -> bool {
    cfg!(target_arch = "x86_64") && active() >= Isa::Avx2
}

/// AVX-512 VNNI dispatch predicate (false off-x86 and on pre-1.89
/// toolchains, where the kernels aren't compiled).
#[inline]
pub fn vnni_enabled() -> bool {
    cfg!(all(target_arch = "x86_64", mor_avx512)) && active() == Isa::Avx512Vnni
}

/// NEON dispatch predicate (false off-aarch64).
#[inline]
pub fn neon_enabled() -> bool {
    cfg!(target_arch = "aarch64") && active() == Isa::Neon
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("vnni"), Some(Isa::Avx512Vnni));
        assert_eq!(Isa::parse("sse9"), None);
    }

    #[test]
    fn lattice_order_is_portability_order() {
        assert!(Isa::Scalar < Isa::Neon);
        assert!(Isa::Neon < Isa::Avx2);
        assert!(Isa::Avx2 < Isa::Avx512Vnni);
        for isa in Isa::ALL {
            assert_eq!(Isa::from_rank(isa as u8), isa);
        }
    }

    #[test]
    fn available_starts_at_scalar_and_is_ordered() {
        let avail = available();
        assert_eq!(avail.first(), Some(&Isa::Scalar));
        assert!(avail.windows(2).all(|w| w[0] < w[1]));
        assert!(avail.contains(&detected()) || detected() == Isa::Scalar);
    }

    // NOTE [`force`] is deliberately untested here: the override is
    // process-global and the in-crate unit tests run multithreaded, so
    // mutating it would race every dispatch-reading test. The
    // force/clamp behaviour is covered by `tests/isa_equivalence.rs`,
    // which owns its process and serializes on a mutex.

    #[test]
    fn active_never_exceeds_detection() {
        assert!(active() <= detected());
    }
}
