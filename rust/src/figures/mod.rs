//! Figure/table harness: regenerates every table and figure of the paper's
//! evaluation from the built artifacts (see DESIGN.md §6 for the index).
//!
//! Each `figXX` function returns a [`Table`] whose rows are the series the
//! paper plots; `cargo bench` benches and `mor figures` both call these.

use crate::config::{Config, PredictorConfig};
use crate::energy::{AreaModel, EnergyModel};
use crate::engine::{self, PatchGather, Tensor};
use crate::model::{Artifacts, Node};
use crate::predictor::strategies::{Strategy, ZeroPredictor};
use crate::predictor::{EvalSummary, MorRun, RunOpts};
use crate::session::Session;
use crate::sim::Simulator;
use crate::util::bench::Table;
use anyhow::Result;

/// Default evaluation sample counts (kept modest so `cargo bench` finishes
/// in minutes; `mor figures --samples N` raises them).
pub const EVAL_SAMPLES: usize = 64;
pub const SIM_SAMPLES: usize = 8;

pub fn load_all(dir: &str) -> Result<Vec<Artifacts>> {
    crate::MODELS
        .iter()
        .map(|m| Artifacts::load(dir, m))
        .collect()
}

/// A session over an artifact bundle with the given predictor config —
/// the unit every evaluation below runs through. Derive the dense
/// baseline with [`Session::with_policy`]`(None)` so the model (and its
/// prepacked weights) is cloned once per figure, not once per run.
fn session_with(arts: &Artifacts, cfg: PredictorConfig) -> Session {
    Session::from_artifacts(arts, cfg)
}

// ---------------------------------------------------------------------------
// Fig 1 — % of computations producing negative ReLU inputs
// ---------------------------------------------------------------------------

pub fn fig01(artifacts: &[Artifacts], samples: usize) -> Table {
    let mut t = Table::new(
        "Fig 1 — % of MACs producing negative (zeroed) ReLU inputs \
         [paper: 35–69%, avg 55%]",
        &["model", "neg_relu_macs_pct", "relu_macs_pct_of_total"],
    );
    let mut fracs = Vec::new();
    for a in artifacts {
        let dense = Session::build(&a.model).finish();
        let s = MorRun::evaluate(a, &dense, samples);
        let frac = s.ops.neg_relu_macs as f64 / s.ops.macs_total.max(1) as f64;
        let relu_frac = s.ops.relu_macs as f64 / s.ops.macs_total.max(1) as f64;
        fracs.push(frac);
        t.row(&[
            a.meta.name.clone(),
            format!("{:.1}", frac * 100.0),
            format!("{:.1}", relu_frac * 100.0),
        ]);
    }
    t.row(&[
        "average".into(),
        format!("{:.1}", crate::util::mean(&fracs) * 100.0),
        String::new(),
    ]);
    t
}

// ---------------------------------------------------------------------------
// Fig 3 — % of MACs per layer type
// ---------------------------------------------------------------------------

pub fn fig03(artifacts: &[Artifacts]) -> Table {
    let mut t = Table::new(
        "Fig 3 — MAC breakdown per layer type (%)",
        &["model", "conv_relu", "fc_relu", "conv_bn_relu", "conv_bn_res_relu", "no_relu"],
    );
    for a in artifacts {
        let macs = a.model.mac_counts();
        let total: u64 = macs.iter().sum();
        let relu_set = a.model.relu_layers();
        let mut cats = [0u64; 5];
        for (i, nd) in a.model.nodes.iter().enumerate() {
            if !nd.is_compute() {
                continue;
            }
            let is_relu = relu_set.contains(&i);
            let idx = match nd {
                _ if !is_relu => 4,
                Node::Fc { .. } => 1,
                Node::Conv { bn, res_from, .. } => {
                    if bn.is_some() && res_from.is_some() {
                        3
                    } else if bn.is_some() {
                        2
                    } else {
                        0
                    }
                }
                _ => 4,
            };
            cats[idx] += macs[i];
        }
        let pct = |v: u64| format!("{:.1}", v as f64 / total as f64 * 100.0);
        t.row(&[
            a.meta.name.clone(),
            pct(cats[0]),
            pct(cats[1]),
            pct(cats[2]),
            pct(cats[3]),
            pct(cats[4]),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 4 — binary vs base dot products for one neuron (scatter series)
// ---------------------------------------------------------------------------

/// Plain forward that returns every node's output tensor.
fn node_outputs(model: &crate::model::Model, input: &[f32]) -> Vec<Tensor> {
    let (h, w, c) = model.input_shape;
    let input_t = Tensor::from_slice(h, w, c, input);
    let mut outs: Vec<Tensor> = Vec::with_capacity(model.nodes.len());
    for node in &model.nodes {
        let src = if node.consumes() < 0 {
            &input_t
        } else {
            &outs[node.consumes() as usize]
        };
        let out = match node {
            Node::Conv { .. } | Node::Fc { .. } => {
                forward_compute_plain(model, node, src, &outs)
            }
            Node::MaxPool { size, .. } => engine::maxpool(src, *size),
            Node::Gap { .. } => engine::gap(src),
            Node::Relu { .. } => engine::relu(src),
        };
        outs.push(out);
    }
    outs
}

fn forward_compute_plain(
    _model: &crate::model::Model,
    node: &Node,
    src: &Tensor,
    outs: &[Tensor],
) -> Tensor {
    let r = exec_single(node, src, outs);
    r.0
}

/// Compute one layer densely; also return (p_bin, p_base_dequant) per output.
fn exec_single(node: &Node, src: &Tensor, outs: &[Tensor]) -> (Tensor, Vec<(i32, f32)>) {
    let (sx, sw, bn, relu_on, kh, kw, stride, pad_same) = match node {
        Node::Conv { sx, sw, bn, relu, kh, kw, stride, pad_same, .. } => {
            (*sx, *sw, bn.as_ref(), *relu, *kh, *kw, *stride, *pad_same)
        }
        Node::Fc { sx, sw, bn, relu, .. } => (*sx, *sw, bn.as_ref(), *relu, 0, 0, 1, false),
        _ => unreachable!(),
    };
    let residual = match node {
        Node::Conv { res_from, .. } | Node::Fc { res_from, .. } => res_from.map(|r| &outs[r]),
        _ => None,
    };
    let cout = node.cout();
    let geom = if kh > 0 {
        engine::conv_geom(src.h, src.w, kh, kw, stride, pad_same)
    } else {
        engine::ConvGeom { oh: src.h, ow: src.w, pad_top: 0, pad_left: 0 }
    };
    let rows = geom.oh * geom.ow;
    let mut out = Tensor::new(geom.oh, geom.ow, cout);
    let mut taps = Vec::with_capacity(rows * cout);
    let qt = engine::QuantizedTensor::new(src, sx);
    let mut pg = PatchGather::new();
    let dq = sw * sx;
    for row in 0..rows {
        if kh > 0 {
            pg.gather(&qt, geom, kh, kw, stride, row / geom.ow, row % geom.ow);
        } else {
            pg.gather_fc(&qt, row);
        }
        for f in 0..cout {
            let d = engine::dot::dot_i8(&pg.patch, node.filter(f));
            let pb = pg.packed.dot(&crate::util::bits::PackedVec::from_weights(node.filter(f)));
            let ri = engine::relu_input(
                d,
                dq,
                bn,
                f,
                residual.map(|r| r.data[row * cout + f]).unwrap_or(0.0),
            );
            out.data[row * cout + f] = if relu_on { ri.max(0.0) } else { ri };
            taps.push((pb, d as f32 * dq));
        }
    }
    (out, taps)
}

pub fn fig04(arts: &Artifacts, samples: usize) -> Table {
    // pick the neuron with the median correlation in the first ReLU layer
    // that has FC-like high correlation — the paper shows a TDS neuron with
    // r = 0.78; we pick the neuron whose |c| is closest to 0.78.
    let (&layer, lp) = arts
        .predictor
        .layers
        .iter()
        .next()
        .expect("predictor has layers");
    let mut neuron = 0;
    let mut best = f32::MAX;
    for (i, &c) in lp.c.iter().enumerate() {
        let d = (c - 0.78).abs();
        if d < best {
            best = d;
            neuron = i;
        }
    }
    let mut t = Table::new(
        &format!(
            "Fig 4 — binary vs base ReLU inputs, {} layer {layer} neuron {neuron} \
             (c = {:.2}; paper's example: 0.78)",
            arts.meta.name, lp.c[neuron]
        ),
        &["p_bin", "p_base_dequant"],
    );
    let n = samples.min(arts.data.n_calib());
    for s in 0..n {
        let input = arts.data.calib_sample(s);
        let outs = node_outputs(&arts.model, input);
        // recompute the taps for the target layer only
        let node = &arts.model.nodes[layer];
        let src_idx = node.consumes();
        let src = if src_idx < 0 {
            let (h, w, c) = arts.model.input_shape;
            Tensor::from_slice(h, w, c, input)
        } else {
            outs[src_idx as usize].clone()
        };
        let (_, taps) = exec_single(node, &src, &outs);
        let cout = node.cout();
        for row in 0..(taps.len() / cout) {
            let (pb, pbase) = taps[row * cout + neuron];
            t.row(&[format!("{pb}"), format!("{pbase:.4}")]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 5 — distribution of per-neuron Pearson correlation
// ---------------------------------------------------------------------------

pub fn fig05(artifacts: &[Artifacts]) -> Table {
    let buckets = [0.0, 0.2, 0.4, 0.6, 0.8, 0.9, 1.01];
    let labels = ["<0.2", "0.2-0.4", "0.4-0.6", "0.6-0.8", "0.8-0.9", ">0.9"];
    let mut t = Table::new(
        "Fig 5 — distribution of binary/base Pearson correlation per neuron (%)",
        &["model", labels[0], labels[1], labels[2], labels[3], labels[4], labels[5]],
    );
    for a in artifacts {
        let mut counts = [0usize; 6];
        let mut total = 0usize;
        for lp in a.predictor.layers.values() {
            for &c in &lp.c {
                let c = c.max(0.0);
                for b in 0..6 {
                    if c >= buckets[b] && c < buckets[b + 1] {
                        counts[b] += 1;
                        break;
                    }
                }
                total += 1;
            }
        }
        let mut row = vec![a.meta.name.clone()];
        for b in 0..6 {
            row.push(format!("{:.1}", counts[b] as f64 / total.max(1) as f64 * 100.0));
        }
        t.row(&row);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 6 / Fig 9 — threshold sweeps (binary-only / hybrid)
// ---------------------------------------------------------------------------

pub const SWEEP_THRESHOLDS: [f32; 7] = [1.0, 0.95, 0.9, 0.85, 0.8, 0.7, 0.6];

/// Threshold sweep for a named strategy: Fig 6 is `binary`, Fig 9 is
/// `mor`. The policy is prepared once per model and re-thresholded per
/// candidate (sign bits packed once).
pub fn threshold_sweep(artifacts: &[Artifacts], samples: usize, strategy: Strategy) -> Table {
    let title = match strategy {
        Strategy::Mor => {
            "Fig 9 — hybrid MoR: accuracy loss vs % computations avoided \
             (threshold sweep 1.0 → 0.6)"
        }
        Strategy::Binary => {
            "Fig 6 — binary predictor alone: accuracy loss vs % operations saved \
             (threshold sweep 1.0 → 0.6)"
        }
        _ => "threshold sweep",
    };
    let mut t = Table::new(title, &["model", "predictor", "threshold", "ops_saved_pct", "accuracy_loss_pct"]);
    for a in artifacts {
        let sess = session_with(a, PredictorConfig { strategy, ..Default::default() });
        let base = MorRun::evaluate(a, &sess.with_policy(None), samples);
        for &thr in &SWEEP_THRESHOLDS {
            let s = MorRun::evaluate(a, &sess.with_threshold(thr), samples);
            t.row(&[
                a.meta.name.clone(),
                strategy.name().to_string(),
                format!("{thr:.2}"),
                format!("{:.2}", s.ops.macs_saved_frac() * 100.0),
                format!("{:.2}", (base.accuracy - s.accuracy) * 100.0),
            ]);
        }
    }
    t
}

/// Strategy ablation: every named strategy on the same samples, plus the
/// tight-angle-gate hybrid variant — replaces the old hand-rolled
/// component-toggle matrix (the paper's "the hybrid yields much better
/// results than any of its two components in isolation").
pub fn strategy_ablation(artifacts: &[Artifacts], samples: usize) -> Table {
    let mut t = Table::new(
        "Ablation — named strategies on equal footing (default T)",
        &["model", "predictor", "ops_saved_pct", "accuracy_loss_pct", "incorrect_zero_pct"],
    );
    for a in artifacts {
        // one model clone + prepack per model; every variant swaps only
        // the policy on the shared session
        let dense = Session::build(&a.model).finish();
        let base = MorRun::evaluate(a, &dense, samples);
        let variants: Vec<(String, PredictorConfig)> = Strategy::ALL
            .iter()
            .map(|&s| (s.name().to_string(), PredictorConfig { strategy: s, ..Default::default() }))
            .chain(std::iter::once((
                "mor+tight-angle-gate(80)".to_string(),
                PredictorConfig { max_cluster_angle_deg: 80.0, ..Default::default() },
            )))
            .collect();
        for (label, cfg) in variants {
            let pol = (cfg.strategy != Strategy::None)
                .then(|| crate::predictor::MorPolicy::new(&a.model, &a.predictor, cfg));
            let s = MorRun::evaluate(a, &dense.with_policy(pol), samples);
            t.row(&[
                a.meta.name.clone(),
                label,
                format!("{:.2}", s.ops.macs_saved_frac() * 100.0),
                format!("{:.2}", (base.accuracy - s.accuracy) * 100.0),
                format!("{:.2}", s.pred.frac(s.pred.incorrect_zero) * 100.0),
            ]);
        }
    }
    t
}

/// Triple-sided MAC accounting (§Sparse, §Weights): for each model, how
/// the dense MAC budget splits between output-prediction savings (MoR
/// skips), ineffectual input-zero MACs among the work that remained,
/// ineffectual weight-zero MACs (lanes where the weight is zero but the
/// activation is not), and the effectual rest — the Cnvlutin2/SparseNN
/// observation that input-side, weight-side and output-side sparsity
/// compound. The three pools are disjoint by construction, so the four
/// columns partition the evaluated MACs exactly.
pub fn sparsity_table(artifacts: &[Artifacts], samples: usize) -> Table {
    let mut t = Table::new(
        "Triple-sided sparsity — output-prediction vs input-zero vs weight-zero \
         MAC savings (%)",
        &["model", "predictor", "output_pred_saved_pct", "input_zero_of_done_pct",
          "weight_zero_of_done_pct", "effectual_macs_pct", "combined_elidable_pct"],
    );
    for a in artifacts {
        let sess = session_with(a, PredictorConfig::default());
        for policied in [false, true] {
            let s = if policied {
                MorRun::evaluate(a, &sess, samples)
            } else {
                MorRun::evaluate(a, &sess.with_policy(None), samples)
            };
            let o = &s.ops;
            let total = o.macs_total.max(1) as f64;
            t.row(&[
                a.meta.name.clone(),
                if policied { sess.predictor_name().to_string() } else { "none".into() },
                format!("{:.2}", o.macs_saved_frac() * 100.0),
                format!("{:.2}", o.input_zero_frac() * 100.0),
                format!("{:.2}", o.weight_zero_frac() * 100.0),
                format!("{:.2}", o.effectual_macs() as f64 / total * 100.0),
                format!(
                    "{:.2}",
                    (o.macs_total - o.effectual_macs()) as f64 / total * 100.0
                ),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 8 — distribution of closest-neighbour angles
// ---------------------------------------------------------------------------

pub fn fig08(artifacts: &[Artifacts]) -> Table {
    let edges = [0.0f32, 50.0, 60.0, 70.0, 80.0, 90.0, 180.0];
    let labels = ["<50", "50-60", "60-70", "70-80", "80-90", ">90"];
    let mut t = Table::new(
        "Fig 8 — angle to closest neuron (%) [paper: majority in 70–80°]",
        &["model", labels[0], labels[1], labels[2], labels[3], labels[4], labels[5]],
    );
    for a in artifacts {
        let mut counts = [0usize; 6];
        let mut total = 0usize;
        for lp in a.predictor.layers.values() {
            for &ang in &lp.closest_angle_deg {
                for b in 0..6 {
                    if ang >= edges[b] && ang < edges[b + 1] {
                        counts[b] += 1;
                        break;
                    }
                }
                total += 1;
            }
        }
        let mut row = vec![a.meta.name.clone()];
        for b in 0..6 {
            row.push(format!("{:.1}", counts[b] as f64 / total.max(1) as f64 * 100.0));
        }
        t.row(&row);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 12 — prediction outcome breakdown
// ---------------------------------------------------------------------------

pub fn fig12(artifacts: &[Artifacts], samples: usize) -> (Table, Vec<EvalSummary>) {
    let mut t = Table::new(
        "Fig 12 — prediction outcomes (% of ReLU-layer outputs) \
         [paper: correct-zero 7–11%, incorrect-zero 0.4–3.6%, correct-nonzero 10–13%]",
        &["model", "correct_zero", "incorrect_zero", "correct_nonzero",
          "incorrect_nonzero", "not_applied", "accuracy_loss_pct"],
    );
    let mut sums = Vec::new();
    for a in artifacts {
        // per-DNN threshold from training data, as in the paper
        let thr = crate::predictor::choose_threshold(a, &PredictorConfig::default(), 3.2, 32);
        let sess = session_with(a, PredictorConfig { threshold: thr, ..Default::default() });
        let base = MorRun::evaluate(a, &sess.with_policy(None), samples);
        let s = MorRun::evaluate(a, &sess, samples);
        let p = &s.pred;
        t.row(&[
            format!("{} (T={thr})", a.meta.name),
            format!("{:.2}", p.frac(p.correct_zero) * 100.0),
            format!("{:.2}", p.frac(p.incorrect_zero) * 100.0),
            format!("{:.2}", p.frac(p.correct_nonzero) * 100.0),
            format!("{:.2}", p.frac(p.incorrect_nonzero) * 100.0),
            format!("{:.2}", p.frac(p.not_applied) * 100.0),
            format!("{:.2}", (base.accuracy - s.accuracy) * 100.0),
        ]);
        sums.push(s);
    }
    (t, sums)
}

// ---------------------------------------------------------------------------
// Fig 13 — speedup and energy savings on the accelerator
// ---------------------------------------------------------------------------

pub struct Fig13Row {
    pub model: String,
    pub speedup: f64,
    pub energy_savings: f64,
    pub base_cycles: u64,
    pub mor_cycles: u64,
}

pub fn fig13(artifacts: &[Artifacts], samples: usize, cfg: &Config) -> (Table, Vec<Fig13Row>) {
    let mut t = Table::new(
        "Fig 13 — speedup (a) and energy savings (b) vs baseline accelerator \
         [paper: 1.2x speedup, 16.5% energy savings on average]",
        &["model", "speedup", "energy_savings_pct", "base_cycles/sample", "mor_cycles/sample"],
    );
    let em = EnergyModel::default();
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    let mut esavs = Vec::new();
    for a in artifacts {
        // per-DNN threshold from training data, as in the paper; the
        // session's strategy comes from the config (--predictor)
        let thr = crate::predictor::choose_threshold(a, &cfg.predictor, 3.2, 32);
        let sess = session_with(
            a,
            PredictorConfig { threshold: thr, ..cfg.predictor.clone() },
        )
        .with_opts(
            // trace generation is the host-side bottleneck of fig13:
            // use every core for the tiled forward, and honour the
            // configured input-sparsity kernel mode (results identical)
            RunOpts {
                oracle: false,
                collect_trace: true,
                input_sparsity: cfg.engine.input_sparsity,
                ..Default::default()
            }
            .parallel(),
        );
        let pol = sess.policy();
        let sim = Simulator::new(cfg.clone());
        let n = samples.min(a.data.n_test());
        // the baseline simulation consumes no trace, so it is identical
        // for every sample: run it once and scale
        let sb = sim.simulate_sample(&a.model, None, None);
        let base_cycles = sb.cycles * n as u64;
        let base_nj = em.price(&sb, cfg.accel.frequency_mhz, false).total_nj() * n as f64;
        let mut mor_cycles = 0u64;
        let mut mor_nj = 0.0;
        for i in 0..n {
            let r = sess.run_sample(a.data.test_sample(i));
            let sm = sim.simulate_sample(&a.model, pol, Some(&r.traces));
            mor_cycles += sm.cycles;
            mor_nj += em.price(&sm, cfg.accel.frequency_mhz, true).total_nj();
        }
        let speedup = base_cycles as f64 / mor_cycles.max(1) as f64;
        let esav = 1.0 - mor_nj / base_nj.max(1e-9);
        speedups.push(speedup);
        esavs.push(esav);
        t.row(&[
            a.meta.name.clone(),
            format!("{speedup:.3}"),
            format!("{:.1}", esav * 100.0),
            format!("{}", base_cycles / n as u64),
            format!("{}", mor_cycles / n as u64),
        ]);
        rows.push(Fig13Row {
            model: a.meta.name.clone(),
            speedup,
            energy_savings: esav,
            base_cycles,
            mor_cycles,
        });
    }
    t.row(&[
        "average".into(),
        format!("{:.3}", crate::util::geomean(&speedups)),
        format!("{:.1}", crate::util::mean(&esavs) * 100.0),
        String::new(),
        String::new(),
    ]);
    (t, rows)
}

// ---------------------------------------------------------------------------
// Table 1 + area overhead + Monte Carlo
// ---------------------------------------------------------------------------

pub fn table1(cfg: &Config) -> Table {
    let mut t = Table::new("Table 1 — simulation parameters", &["parameter"]);
    for line in cfg.table1().lines() {
        t.row(&[line.to_string()]);
    }
    t
}

pub fn area_table(cfg: &Config) -> Table {
    let rep = AreaModel::default().area(&cfg.accel);
    let mut t = Table::new(
        "Area overhead of the MoR predictor [paper: 5.3%]",
        &["component", "mm2"],
    );
    t.row(&["baseline accelerator".into(), format!("{:.4}", rep.base_mm2)]);
    t.row(&["predictor (binCUs + binWeight SRAM)".into(), format!("{:.4}", rep.predictor_mm2)]);
    t.row(&["overhead".into(), format!("{:.2}%", rep.overhead_frac() * 100.0)]);
    t
}

pub fn montecarlo_table(samples: usize) -> Table {
    let mut t = Table::new(
        "Monte Carlo validation of Eq. 3-6: P[sign mismatch] = 2θ/360 in any dimension",
        &["dim", "theta_deg", "measured", "analytic", "abs_err"],
    );
    for &dim in &[2usize, 16, 128, 1024] {
        for &theta in &[15.0f64, 45.0, 75.0, 90.0] {
            let p = crate::cluster::montecarlo_mismatch_prob(dim, theta, samples, 1234);
            let want = 2.0 * theta / 360.0;
            t.row(&[
                format!("{dim}"),
                format!("{theta}"),
                format!("{p:.4}"),
                format!("{want:.4}"),
                format!("{:.4}", (p - want).abs()),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_thresholds_descend() {
        let mut prev = f32::INFINITY;
        for &t in &SWEEP_THRESHOLDS {
            assert!(t < prev);
            prev = t;
        }
        assert_eq!(SWEEP_THRESHOLDS[0], 1.0);
        assert_eq!(*SWEEP_THRESHOLDS.last().unwrap(), 0.6);
    }

    #[test]
    fn montecarlo_table_rows() {
        let t = montecarlo_table(2_000);
        assert_eq!(t.rows.len(), 16);
        // spot-check analytic column
        assert_eq!(t.rows[1][3], "0.2500");
    }
}
