//! [`Session`] — the single entry point for running inference.
//!
//! A session owns everything a forward pass needs: the model (with its
//! prepacked GEMM weight blocks warmed), the prepared predictor policy
//! (one [`crate::predictor::strategies::LayerState`] per predictable
//! layer), and the execution options (engine, row-tile threads, trace
//! collection). Callers that used to hand-thread
//! `(Model, PredictorParams, MorPolicy, RunOpts)` through evaluation,
//! serving and the figure harness build one of these instead:
//!
//! ```no_run
//! # use mor::model::Artifacts;
//! # use mor::session::Session;
//! # let arts = Artifacts::load("artifacts", "tds").unwrap();
//! let session = Session::build(&arts.model)
//!     .params(&arts.predictor)
//!     .predictor("mor").unwrap()
//!     .threads(4)
//!     .finish();
//! let result = session.run_sample(arts.data.test_sample(0));
//! ```
//!
//! Sessions are cheap to derive from: [`Session::with_threshold`]
//! re-thresholds the cached policy without re-packing filter sign bits,
//! and [`Session::with_policy`] swaps the policy while sharing the
//! model (and its prepacked weights) — the units of work behind
//! [`crate::predictor::choose_threshold`]'s sweep and the figure
//! harness's ablations.
//!
//! Internally the model and policy live behind `Arc`s, so the serving
//! coordinator's worker threads share one prepacked copy.
//!
//! `finish()` also **compiles the model into a
//! [`crate::plan::ModelPlan`]** (tiled engine) and owns a
//! [`crate::plan::WorkspacePool`]: per-layer geometry, strategy state,
//! sparsity decisions and scratch sizes are frozen once, and every
//! forward after that reuses pooled working memory — the steady-state
//! request path performs zero heap allocations (see the [`crate::plan`]
//! docs). Serving workers check one workspace out for their whole
//! lifetime via [`Session::checkout_workspace`] and drive
//! [`Session::run_batch_into`].

use crate::config::PredictorConfig;
use crate::engine::tune::{self, TuneProfile};
use crate::model::{Artifacts, Model, PredictorParams};
use crate::plan::{self, ModelPlan, PooledWorkspace, Workspace, WorkspacePool};
use crate::predictor::strategies::{Strategy, ZeroPredictor};
use crate::predictor::{exec, EngineSel, InputSparsity, MorPolicy, RunOpts, RunResult, WeightSparsity};
use anyhow::Result;
use std::sync::Arc;

/// A prepared inference context: model + policy + execution options,
/// plus the compiled [`ModelPlan`] and workspace pool the steady-state
/// forward path runs on.
#[derive(Clone)]
pub struct Session {
    model: Arc<Model>,
    policy: Option<Arc<MorPolicy>>,
    opts: RunOpts,
    /// Compiled execution plan (`None` for the unplanned `ScalarRef`
    /// reference engine).
    plan: Option<Arc<ModelPlan>>,
    /// Reusable forward workspaces, shared with derived sessions (the
    /// buffers fit any plan of the same model).
    pool: Arc<WorkspacePool>,
}

impl Session {
    /// Start building a session for `model`. The model is cloned once
    /// at [`SessionBuilder::finish`]; the original stays usable.
    ///
    /// ```
    /// use mor::model::synth;
    /// use mor::session::Session;
    ///
    /// let model = synth::tiny_serving_model(1);
    /// let params = synth::predictor_for(&model, 2);
    /// let session = Session::build(&model)
    ///     .params(&params)
    ///     .predictor("mor").unwrap()
    ///     .threshold(0.5)
    ///     .threads(2)
    ///     .finish();
    /// assert_eq!(session.predictor_name(), "mor");
    ///
    /// let (h, w, c) = model.input_shape;
    /// let x = vec![0.25f32; h * w * c];
    /// let r = session.run_sample(&x);
    /// assert_eq!(r.logits.len(), 4); // tiny_serving_model has 4 classes
    /// ```
    pub fn build(model: &Model) -> SessionBuilder<'_> {
        SessionBuilder {
            model,
            params: None,
            cfg: PredictorConfig::default(),
            opts: RunOpts::default(),
            autotune: false,
            profile_set: false,
            threads_set: false,
        }
    }

    /// Convenience: a session over an artifact bundle's model and
    /// offline predictor params with the given config.
    pub fn from_artifacts(arts: &Artifacts, cfg: PredictorConfig) -> Session {
        Session::build(&arts.model)
            .params(&arts.predictor)
            .config(cfg)
            .finish()
    }

    /// Run one sample through the session.
    pub fn run_sample(&self, input: &[f32]) -> RunResult {
        self.run_batch(&[input])
            .pop()
            .expect("run_batch returns one result per input")
    }

    /// Run a micro-batch; bit-identical to mapping [`Session::run_sample`]
    /// over the inputs (see `rust/tests/batch_equivalence.rs`). On the
    /// tiled engine this executes the session's cached [`ModelPlan`]
    /// over a pooled workspace (no per-request compilation or buffer
    /// allocation beyond the result envelope).
    pub fn run_batch(&self, inputs: &[&[f32]]) -> Vec<RunResult> {
        let mut ws = WorkspacePool::checkout(&self.pool);
        self.run_batch_in(&mut ws, inputs)
    }

    /// Like [`Session::run_batch`], but over a caller-held workspace —
    /// serving workers check one out once ([`Session::checkout_workspace`])
    /// and reuse it for their whole lifetime.
    pub fn run_batch_in(&self, ws: &mut Workspace, inputs: &[&[f32]]) -> Vec<RunResult> {
        let mut results = Vec::new();
        self.run_batch_into(ws, inputs, &mut results);
        results
    }

    /// The fully allocation-free form: reuses the caller's workspace
    /// *and* result vector (logits buffers included). After warmup this
    /// performs zero heap allocations per request in the
    /// single-threaded, non-tracing configuration — the property
    /// `rust/tests/plan_contracts.rs` pins with a counting allocator.
    pub fn run_batch_into(
        &self,
        ws: &mut Workspace,
        inputs: &[&[f32]],
        results: &mut Vec<RunResult>,
    ) {
        match &self.plan {
            Some(p) => {
                plan::execute_into(p, &self.model, self.policy.as_deref(), ws, inputs, results)
            }
            None => {
                *results = exec::run_batch(&self.model, self.policy.as_deref(), inputs, self.opts)
            }
        }
    }

    /// Check a reusable workspace out of the session's pool (grows under
    /// contention; returned on drop).
    pub fn checkout_workspace(&self) -> PooledWorkspace {
        WorkspacePool::checkout(&self.pool)
    }

    /// The compiled execution plan (`None` for the `ScalarRef` engine).
    pub fn plan(&self) -> Option<&Arc<ModelPlan>> {
        self.plan.as_ref()
    }

    /// The session's workspace pool (shared with derived sessions).
    pub fn workspace_pool(&self) -> &Arc<WorkspacePool> {
        &self.pool
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The shared model handle (serving workers share it by cloning the
    /// whole session; this exposes just the model Arc).
    pub fn model_arc(&self) -> Arc<Model> {
        Arc::clone(&self.model)
    }

    pub fn policy(&self) -> Option<&MorPolicy> {
        self.policy.as_deref()
    }

    pub fn opts(&self) -> RunOpts {
        self.opts
    }

    /// The strategy that actually executes: the prepared policy's, or
    /// `none` when the session runs dense (no offline params supplied,
    /// or the `none` strategy requested). A requested strategy that
    /// could not be prepared is deliberately *not* reported — reports
    /// describe what ran.
    pub fn strategy(&self) -> Strategy {
        self.policy
            .as_deref()
            .map(|p| p.strategy())
            .unwrap_or(Strategy::None)
    }

    /// Stable name of the active strategy, for reports and bench JSON.
    pub fn predictor_name(&self) -> &'static str {
        self.strategy().name()
    }

    /// A derived session with a different (or no) policy, sharing the
    /// model and its prepacked weights. The plan is recompiled (cheap —
    /// graph metadata only) because the set of policied layers may
    /// change; the workspace pool is shared.
    pub fn with_policy(&self, policy: Option<MorPolicy>) -> Session {
        let policy = policy.map(Arc::new);
        Session {
            model: Arc::clone(&self.model),
            plan: compile_plan(&self.model, policy.as_deref(), self.opts),
            policy,
            opts: self.opts,
            pool: Arc::clone(&self.pool),
        }
    }

    /// A derived session at candidate threshold `t`: the cached policy
    /// is re-thresholded (enabled sets only), packed filter sign bits
    /// and the model are shared — and so is the compiled [`ModelPlan`]
    /// itself, since a threshold change keeps the policied-layer set
    /// and every frozen per-layer decision intact. Dense sessions stay
    /// dense.
    pub fn with_threshold(&self, t: f32) -> Session {
        Session {
            model: Arc::clone(&self.model),
            policy: self.policy.as_deref().map(|p| Arc::new(p.with_threshold(t))),
            opts: self.opts,
            plan: self.plan.clone(),
            pool: Arc::clone(&self.pool),
        }
    }

    /// A derived session with different execution options (same model,
    /// same policy); the plan is recompiled for the new options.
    pub fn with_opts(&self, opts: RunOpts) -> Session {
        Session {
            model: Arc::clone(&self.model),
            policy: self.policy.clone(),
            opts,
            plan: compile_plan(&self.model, self.policy.as_deref(), opts),
            pool: Arc::clone(&self.pool),
        }
    }
}

/// Compile the session's plan (tiled engine only — `ScalarRef` runs the
/// unplanned reference path). Debug builds re-verify every freshly
/// compiled plan through the static verifier ([`plan::verify`]) — the
/// same pass `mor lint` runs — so a compiler regression that mis-wires
/// a slot or undersizes a scratch mark fails loudly at `finish()`
/// instead of corrupting activations at serve time, and additionally
/// through the numeric range analyzer ([`plan::ranges::analyze`], the
/// `mor lint --numeric` pass) so an accumulator-overflow or
/// requantization-range hazard is rejected before a single inference
/// runs. Release builds skip both checks (they are O(nodes²) but, more
/// importantly, redundant: plans are only produced by `compile`, which
/// debug CI lints).
fn compile_plan(
    model: &Model,
    policy: Option<&MorPolicy>,
    opts: RunOpts,
) -> Option<Arc<ModelPlan>> {
    (opts.engine == EngineSel::Tiled).then(|| {
        let compiled = plan::compile(model, policy, opts);
        #[cfg(debug_assertions)]
        {
            let report = plan::verify(&compiled, model, policy);
            debug_assert!(
                report.errors() == 0,
                "plan verifier found {} error(s) for model '{}':\n{report}",
                report.errors(),
                model.name
            );
            let numeric = plan::ranges::analyze(&compiled, model, policy);
            debug_assert!(
                numeric.lint.errors() == 0,
                "numeric range analysis found {} error(s) for model '{}':\n{numeric}",
                numeric.lint.errors(),
                model.name
            );
        }
        Arc::new(compiled)
    })
}

/// Builder for [`Session`]; every knob has the same default as the
/// loose-argument API it replaces.
pub struct SessionBuilder<'a> {
    model: &'a Model,
    params: Option<&'a PredictorParams>,
    cfg: PredictorConfig,
    opts: RunOpts,
    /// Run the calibration pass at `finish()` (unless an explicit
    /// profile was supplied).
    autotune: bool,
    /// An explicit [`TuneProfile`] was supplied — calibration is
    /// skipped even under `autotune(true)`.
    profile_set: bool,
    /// [`SessionBuilder::threads`] was called — the profile's thread
    /// fan-out is not adopted.
    threads_set: bool,
}

impl<'a> SessionBuilder<'a> {
    /// Offline predictor parameters (fitted lines, clusters). Without
    /// them the session runs dense regardless of strategy.
    pub fn params(mut self, params: &'a PredictorParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Select the skip strategy by name (`mor`, `binary`, `cluster`,
    /// `oracle`, `none`) — the `--predictor` CLI surface.
    pub fn predictor(mut self, name: &str) -> Result<Self> {
        self.cfg.strategy = Strategy::parse(name)?;
        Ok(self)
    }

    /// Select the skip strategy directly.
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    /// Replace the whole predictor config (strategy, threshold, margin,
    /// angle gate).
    pub fn config(mut self, cfg: PredictorConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Correlation threshold T.
    pub fn threshold(mut self, t: f32) -> Self {
        self.cfg.threshold = t;
        self
    }

    /// Row-tile worker threads per forward pass. Calling this pins the
    /// thread count: a tune profile's measured fan-out is then ignored.
    pub fn threads(mut self, n: usize) -> Self {
        self.opts.threads = n;
        self.threads_set = true;
        self
    }

    /// Run the [`tune::calibrate`] microbenchmark pass at `finish()`
    /// and freeze its measured crossovers / tile height / thread
    /// fan-out into the compiled plan — the `--autotune` CLI surface.
    /// Purely a host-performance knob: every kernel the tuner chooses
    /// between is bit-identical. Ignored when an explicit
    /// [`SessionBuilder::tune_profile`] is supplied (the saved profile
    /// IS the calibration result).
    pub fn autotune(mut self, on: bool) -> Self {
        self.autotune = on;
        self
    }

    /// Use an explicit [`TuneProfile`] (e.g. loaded from
    /// `--tune-profile <path>`) instead of the host default or a fresh
    /// calibration. The same profile always freezes the same plan
    /// decisions — profiles are how tuned configurations are made
    /// reproducible across runs.
    pub fn tune_profile(mut self, profile: TuneProfile) -> Self {
        self.opts.tune = profile;
        self.profile_set = true;
        self
    }

    /// Compute-engine implementation (tiled GEMM vs scalar reference).
    pub fn engine(mut self, engine: EngineSel) -> Self {
        self.opts.engine = engine;
        self
    }

    /// Input-side sparsity mode (`auto`/`on`/`off`): whether the tiled
    /// engine skips zero-valued input activation lanes. Bit-identical
    /// in every mode — the `--input-sparsity` CLI surface.
    pub fn input_sparsity(mut self, mode: InputSparsity) -> Self {
        self.opts.input_sparsity = mode;
        self
    }

    /// Weight-side sparsity mode (`off`/`exact`/threshold): whether the
    /// engines elide zero-weight lanes through the compressed per-filter
    /// lane lists built at prepack time. `exact` is bit-identical by
    /// construction; a numeric threshold additionally magnitude-prunes
    /// the cloned model's weights at [`SessionBuilder::finish`] (a
    /// lossy, accuracy-measured transformation) — the
    /// `--weight-sparsity` CLI surface.
    pub fn weight_sparsity(mut self, mode: WeightSparsity) -> Self {
        self.opts.weight_sparsity = mode;
        self
    }

    /// Compute the true value of skipped outputs (Fig-12 categories).
    pub fn oracle(mut self, on: bool) -> Self {
        self.opts.oracle = on;
        self
    }

    /// Collect per-layer skip traces for the cycle-level simulator.
    pub fn collect_trace(mut self, on: bool) -> Self {
        self.opts.collect_trace = on;
        self
    }

    /// Build the session: clone the model behind an `Arc` (magnitude-
    /// pruning the clone first under `WeightSparsity::Threshold` — the
    /// caller's model is never mutated), warm its prepacked weight
    /// blocks (tiled engine), prepare the policy through the configured
    /// strategy, and compile the [`crate::plan::ModelPlan`] the request
    /// path executes.
    pub fn finish(mut self) -> Session {
        if self.autotune && !self.profile_set {
            self.opts.tune = tune::calibrate();
        }
        // adopt the profile's measured thread fan-out unless the caller
        // pinned a count (0 in a profile means "no opinion")
        if !self.threads_set && self.opts.tune.threads > 0 {
            self.opts.threads = self.opts.tune.threads;
        }
        let mut model = self.model.clone();
        if let WeightSparsity::Threshold(t) = self.opts.weight_sparsity {
            model.prune_weights_below(t);
        }
        let model = Arc::new(model);
        if self.opts.engine == EngineSel::Tiled {
            model.prepacked();
        }
        let policy = match (self.params, self.cfg.strategy) {
            // dense execution needs no per-layer state at all
            (_, Strategy::None) | (None, _) => None,
            (Some(p), _) => Some(Arc::new(MorPolicy::new(&model, p, self.cfg))),
        };
        let plan = compile_plan(&model, policy.as_deref(), self.opts);
        Session {
            model,
            policy,
            opts: self.opts,
            plan,
            pool: Arc::new(WorkspacePool::new()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth;
    use crate::util::rng::Rng;

    fn input(model: &Model, seed: u64) -> Vec<f32> {
        let (h, w, c) = model.input_shape;
        let mut rng = Rng::new(seed);
        (0..h * w * c).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn dense_session_matches_exec() {
        let m = synth::tiny_serving_model(3);
        let x = input(&m, 4);
        let s = Session::build(&m).finish();
        assert_eq!(s.predictor_name(), "none");
        let want = exec::run_sample(&m, None, &x, RunOpts::default());
        let got = s.run_sample(&x);
        assert_eq!(want.logits, got.logits);
        assert_eq!(want.ops, got.ops);
    }

    #[test]
    fn predictor_by_name_builds_policy() {
        let m = synth::tiny_serving_model(5);
        let params = synth::predictor_for(&m, 6);
        let s = Session::build(&m)
            .params(&params)
            .predictor("mor")
            .unwrap()
            .threshold(0.5)
            .finish();
        assert_eq!(s.predictor_name(), "mor");
        assert!(s.policy().is_some());
        assert!(Session::build(&m).predictor("bogus").is_err());
    }

    #[test]
    fn none_strategy_is_dense_even_with_params() {
        let m = synth::tiny_serving_model(7);
        let params = synth::predictor_for(&m, 8);
        let s = Session::build(&m)
            .params(&params)
            .strategy(Strategy::None)
            .finish();
        assert!(s.policy().is_none());
        assert_eq!(s.predictor_name(), "none");
    }

    #[test]
    fn with_threshold_shares_packed_weights() {
        let m = synth::tiny_serving_model(9);
        let s = Session::from_artifacts(
            &synth::artifacts_for(m, 11, 2, 2),
            PredictorConfig { threshold: 0.9, ..Default::default() },
        );
        let t = s.with_threshold(0.2);
        let (a, b) = (s.policy().unwrap(), t.policy().unwrap());
        assert_eq!(b.cfg.threshold, 0.2);
        for (l, st) in &a.layers {
            // same Arc — the sign bits were not re-packed
            assert!(Arc::ptr_eq(&st.packed_w, &b.layers[l].packed_w));
            // lower T enables at least as many neurons
            let on_a = st.enabled.iter().filter(|&&e| e).count();
            let on_b = b.layers[l].enabled.iter().filter(|&&e| e).count();
            assert!(on_b >= on_a);
        }
    }

    #[test]
    fn input_sparsity_knob_threads_through() {
        let m = synth::tiny_serving_model(15);
        let s = Session::build(&m).input_sparsity(InputSparsity::Off).finish();
        assert_eq!(s.opts().input_sparsity, InputSparsity::Off);
        assert_eq!(
            Session::build(&m).finish().opts().input_sparsity,
            InputSparsity::Auto
        );
    }

    #[test]
    fn weight_sparsity_knob_threads_through() {
        let m = synth::tiny_serving_model(25);
        let s = Session::build(&m).weight_sparsity(WeightSparsity::Exact).finish();
        assert_eq!(s.opts().weight_sparsity, WeightSparsity::Exact);
        assert_eq!(
            Session::build(&m).finish().opts().weight_sparsity,
            WeightSparsity::Off
        );
    }

    #[test]
    fn threshold_mode_prunes_the_session_clone_only() {
        let m = synth::tiny_serving_model(27);
        let before = m.weight_zero_fraction();
        // a huge threshold zeroes every weight in the session's clone
        let s = Session::build(&m)
            .weight_sparsity(WeightSparsity::Threshold(1e9))
            .finish();
        assert_eq!(s.model().weight_zero_fraction(), 1.0);
        // the caller's model is untouched
        assert_eq!(m.weight_zero_fraction(), before);
        // exact mode never prunes
        let e = Session::build(&m).weight_sparsity(WeightSparsity::Exact).finish();
        assert_eq!(e.model().weight_zero_fraction(), before);
    }

    #[test]
    fn tune_profile_freezes_plan_decisions_and_thread_fanout() {
        let m = synth::tiny_serving_model(29);
        let mut p = TuneProfile::host_default();
        p.threads = 3;
        // an extreme cutoff flips every Auto layer to "dense always"
        p.input_cutoff = 0.02;
        let s = Session::build(&m).tune_profile(p).finish();
        assert_eq!(s.opts().threads, 3, "profile fan-out adopted");
        assert_eq!(s.opts().tune, p);
        let plan = s.plan().unwrap();
        for step in &plan.steps {
            if let plan::StepPlan::Compute(c) = step {
                assert_eq!(c.sparse_cutoff, 0.02 * c.k_len as f32);
            }
        }
        // an explicit thread count beats the profile's
        let s2 = Session::build(&m).tune_profile(p).threads(2).finish();
        assert_eq!(s2.opts().threads, 2);
        // results are bit-identical to the default profile's
        let x = input(&m, 30);
        let want = Session::build(&m).finish().run_sample(&x);
        assert_eq!(s.run_sample(&x).logits, want.logits);
    }

    #[test]
    fn same_profile_compiles_identical_plan_decisions() {
        // tuner determinism contract: profile in ⇒ frozen decisions out,
        // with no dependence on when/where the plan is compiled
        let m = synth::tiny_serving_model(31);
        let mut p = TuneProfile::host_default();
        p.input_cutoff = 0.33;
        p.weight_cutoff = 0.44;
        p.tile_rows = 8;
        let a = Session::build(&m)
            .tune_profile(p)
            .weight_sparsity(WeightSparsity::Exact)
            .finish();
        let b = Session::build(&m)
            .tune_profile(p)
            .weight_sparsity(WeightSparsity::Exact)
            .finish();
        let (pa, pb) = (a.plan().unwrap(), b.plan().unwrap());
        for (sa, sb) in pa.steps.iter().zip(&pb.steps) {
            if let (plan::StepPlan::Compute(ca), plan::StepPlan::Compute(cb)) = (sa, sb) {
                assert_eq!(ca.sparse_cutoff, cb.sparse_cutoff);
                assert_eq!(ca.w_sparse, cb.w_sparse);
                assert_eq!(ca.lanes, cb.lanes);
            }
        }
        assert_eq!(pa.opts.tune.hash(), pb.opts.tune.hash());
    }

    #[test]
    fn with_policy_shares_model() {
        let m = synth::tiny_serving_model(13);
        let s = Session::build(&m).finish();
        let d = s.with_policy(None);
        assert!(Arc::ptr_eq(&s.model_arc(), &d.model_arc()));
    }

    #[test]
    fn tiled_session_compiles_a_plan_scalar_does_not() {
        let m = synth::tiny_serving_model(17);
        let tiled = Session::build(&m).finish();
        assert!(tiled.plan().is_some());
        let scalar = Session::build(&m).engine(crate::predictor::EngineSel::ScalarRef).finish();
        assert!(scalar.plan().is_none());
        // both produce identical logits
        let x = input(&m, 18);
        assert_eq!(tiled.run_sample(&x).logits, scalar.run_sample(&x).logits);
    }

    #[test]
    fn with_threshold_shares_the_compiled_plan_and_pool() {
        let m = synth::tiny_serving_model(19);
        let s = Session::from_artifacts(
            &synth::artifacts_for(m, 20, 2, 2),
            PredictorConfig { threshold: 0.9, ..Default::default() },
        );
        let t = s.with_threshold(0.3);
        // a threshold re-plan is free: same plan, same pool
        assert!(Arc::ptr_eq(s.plan().unwrap(), t.plan().unwrap()));
        assert!(Arc::ptr_eq(s.workspace_pool(), t.workspace_pool()));
        // with_opts / with_policy recompile but keep the pool
        let o = s.with_opts(s.opts());
        assert!(!Arc::ptr_eq(s.plan().unwrap(), o.plan().unwrap()));
        assert!(Arc::ptr_eq(s.workspace_pool(), o.workspace_pool()));
    }

    #[test]
    fn run_batch_into_reuses_result_buffers() {
        let m = synth::tiny_serving_model(23);
        let s = Session::build(&m).finish();
        let x = input(&m, 24);
        let xs = [x.as_slice(), x.as_slice()];
        let mut ws = s.checkout_workspace();
        let mut results = Vec::new();
        s.run_batch_into(&mut ws, &xs, &mut results);
        assert_eq!(results.len(), 2);
        let want = results[0].logits.clone();
        let cap_before = results[0].logits.capacity();
        s.run_batch_into(&mut ws, &xs, &mut results);
        assert_eq!(results[0].logits, want);
        assert_eq!(results[0].logits.capacity(), cap_before);
    }
}
