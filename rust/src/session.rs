//! [`Session`] — the single entry point for running inference.
//!
//! A session owns everything a forward pass needs: the model (with its
//! prepacked GEMM weight blocks warmed), the prepared predictor policy
//! (one [`crate::predictor::strategies::LayerState`] per predictable
//! layer), and the execution options (engine, row-tile threads, trace
//! collection). Callers that used to hand-thread
//! `(Model, PredictorParams, MorPolicy, RunOpts)` through evaluation,
//! serving and the figure harness build one of these instead:
//!
//! ```no_run
//! # use mor::model::Artifacts;
//! # use mor::session::Session;
//! # let arts = Artifacts::load("artifacts", "tds").unwrap();
//! let session = Session::build(&arts.model)
//!     .params(&arts.predictor)
//!     .predictor("mor").unwrap()
//!     .threads(4)
//!     .finish();
//! let result = session.run_sample(arts.data.test_sample(0));
//! ```
//!
//! Sessions are cheap to derive from: [`Session::with_threshold`]
//! re-thresholds the cached policy without re-packing filter sign bits,
//! and [`Session::with_policy`] swaps the policy while sharing the
//! model (and its prepacked weights) — the units of work behind
//! [`crate::predictor::choose_threshold`]'s sweep and the figure
//! harness's ablations.
//!
//! Internally the model and policy live behind `Arc`s, so the serving
//! coordinator's worker threads share one prepacked copy.

use crate::config::PredictorConfig;
use crate::model::{Artifacts, Model, PredictorParams};
use crate::predictor::strategies::{Strategy, ZeroPredictor};
use crate::predictor::{exec, EngineSel, InputSparsity, MorPolicy, RunOpts, RunResult};
use anyhow::Result;
use std::sync::Arc;

/// A prepared inference context: model + policy + execution options.
#[derive(Clone)]
pub struct Session {
    model: Arc<Model>,
    policy: Option<Arc<MorPolicy>>,
    opts: RunOpts,
}

impl Session {
    /// Start building a session for `model`. The model is cloned once
    /// at [`SessionBuilder::finish`]; the original stays usable.
    ///
    /// ```
    /// use mor::model::synth;
    /// use mor::session::Session;
    ///
    /// let model = synth::tiny_serving_model(1);
    /// let params = synth::predictor_for(&model, 2);
    /// let session = Session::build(&model)
    ///     .params(&params)
    ///     .predictor("mor").unwrap()
    ///     .threshold(0.5)
    ///     .threads(2)
    ///     .finish();
    /// assert_eq!(session.predictor_name(), "mor");
    ///
    /// let (h, w, c) = model.input_shape;
    /// let x = vec![0.25f32; h * w * c];
    /// let r = session.run_sample(&x);
    /// assert_eq!(r.logits.len(), 4); // tiny_serving_model has 4 classes
    /// ```
    pub fn build(model: &Model) -> SessionBuilder<'_> {
        SessionBuilder {
            model,
            params: None,
            cfg: PredictorConfig::default(),
            opts: RunOpts::default(),
        }
    }

    /// Convenience: a session over an artifact bundle's model and
    /// offline predictor params with the given config.
    pub fn from_artifacts(arts: &Artifacts, cfg: PredictorConfig) -> Session {
        Session::build(&arts.model)
            .params(&arts.predictor)
            .config(cfg)
            .finish()
    }

    /// Run one sample through the session.
    pub fn run_sample(&self, input: &[f32]) -> RunResult {
        exec::run_sample(&self.model, self.policy.as_deref(), input, self.opts)
    }

    /// Run a micro-batch; bit-identical to mapping [`Session::run_sample`]
    /// over the inputs (see `rust/tests/batch_equivalence.rs`).
    pub fn run_batch(&self, inputs: &[&[f32]]) -> Vec<RunResult> {
        exec::run_batch(&self.model, self.policy.as_deref(), inputs, self.opts)
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The shared model handle (serving workers clone this).
    pub fn model_arc(&self) -> Arc<Model> {
        Arc::clone(&self.model)
    }

    pub fn policy(&self) -> Option<&MorPolicy> {
        self.policy.as_deref()
    }

    /// The shared policy handle (serving workers clone this).
    pub fn policy_arc(&self) -> Option<Arc<MorPolicy>> {
        self.policy.clone()
    }

    pub fn opts(&self) -> RunOpts {
        self.opts
    }

    /// The strategy that actually executes: the prepared policy's, or
    /// `none` when the session runs dense (no offline params supplied,
    /// or the `none` strategy requested). A requested strategy that
    /// could not be prepared is deliberately *not* reported — reports
    /// describe what ran.
    pub fn strategy(&self) -> Strategy {
        self.policy
            .as_deref()
            .map(|p| p.strategy())
            .unwrap_or(Strategy::None)
    }

    /// Stable name of the active strategy, for reports and bench JSON.
    pub fn predictor_name(&self) -> &'static str {
        self.strategy().name()
    }

    /// A derived session with a different (or no) policy, sharing the
    /// model and its prepacked weights.
    pub fn with_policy(&self, policy: Option<MorPolicy>) -> Session {
        Session {
            model: Arc::clone(&self.model),
            policy: policy.map(Arc::new),
            opts: self.opts,
        }
    }

    /// A derived session at candidate threshold `t`: the cached policy
    /// is re-thresholded (enabled sets only), packed filter sign bits
    /// and the model are shared. Dense sessions stay dense.
    pub fn with_threshold(&self, t: f32) -> Session {
        self.with_policy(self.policy.as_deref().map(|p| p.with_threshold(t)))
    }

    /// A derived session with different execution options (same model,
    /// same policy).
    pub fn with_opts(&self, opts: RunOpts) -> Session {
        Session { opts, ..self.clone() }
    }
}

/// Builder for [`Session`]; every knob has the same default as the
/// loose-argument API it replaces.
pub struct SessionBuilder<'a> {
    model: &'a Model,
    params: Option<&'a PredictorParams>,
    cfg: PredictorConfig,
    opts: RunOpts,
}

impl<'a> SessionBuilder<'a> {
    /// Offline predictor parameters (fitted lines, clusters). Without
    /// them the session runs dense regardless of strategy.
    pub fn params(mut self, params: &'a PredictorParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Select the skip strategy by name (`mor`, `binary`, `cluster`,
    /// `oracle`, `none`) — the `--predictor` CLI surface.
    pub fn predictor(mut self, name: &str) -> Result<Self> {
        self.cfg.strategy = Strategy::parse(name)?;
        Ok(self)
    }

    /// Select the skip strategy directly.
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    /// Replace the whole predictor config (strategy, threshold, margin,
    /// angle gate).
    pub fn config(mut self, cfg: PredictorConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Correlation threshold T.
    pub fn threshold(mut self, t: f32) -> Self {
        self.cfg.threshold = t;
        self
    }

    /// Row-tile worker threads per forward pass.
    pub fn threads(mut self, n: usize) -> Self {
        self.opts.threads = n;
        self
    }

    /// Compute-engine implementation (tiled GEMM vs scalar reference).
    pub fn engine(mut self, engine: EngineSel) -> Self {
        self.opts.engine = engine;
        self
    }

    /// Input-side sparsity mode (`auto`/`on`/`off`): whether the tiled
    /// engine skips zero-valued input activation lanes. Bit-identical
    /// in every mode — the `--input-sparsity` CLI surface.
    pub fn input_sparsity(mut self, mode: InputSparsity) -> Self {
        self.opts.input_sparsity = mode;
        self
    }

    /// Compute the true value of skipped outputs (Fig-12 categories).
    pub fn oracle(mut self, on: bool) -> Self {
        self.opts.oracle = on;
        self
    }

    /// Collect per-layer skip traces for the cycle-level simulator.
    pub fn collect_trace(mut self, on: bool) -> Self {
        self.opts.collect_trace = on;
        self
    }

    /// Build the session: clone the model behind an `Arc`, warm its
    /// prepacked weight blocks (tiled engine), and prepare the policy
    /// through the configured strategy.
    pub fn finish(self) -> Session {
        let model = Arc::new(self.model.clone());
        if self.opts.engine == EngineSel::Tiled {
            model.prepacked();
        }
        let policy = match (self.params, self.cfg.strategy) {
            // dense execution needs no per-layer state at all
            (_, Strategy::None) | (None, _) => None,
            (Some(p), _) => Some(Arc::new(MorPolicy::new(&model, p, self.cfg))),
        };
        Session { model, policy, opts: self.opts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth;
    use crate::util::rng::Rng;

    fn input(model: &Model, seed: u64) -> Vec<f32> {
        let (h, w, c) = model.input_shape;
        let mut rng = Rng::new(seed);
        (0..h * w * c).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn dense_session_matches_exec() {
        let m = synth::tiny_serving_model(3);
        let x = input(&m, 4);
        let s = Session::build(&m).finish();
        assert_eq!(s.predictor_name(), "none");
        let want = exec::run_sample(&m, None, &x, RunOpts::default());
        let got = s.run_sample(&x);
        assert_eq!(want.logits, got.logits);
        assert_eq!(want.ops, got.ops);
    }

    #[test]
    fn predictor_by_name_builds_policy() {
        let m = synth::tiny_serving_model(5);
        let params = synth::predictor_for(&m, 6);
        let s = Session::build(&m)
            .params(&params)
            .predictor("mor")
            .unwrap()
            .threshold(0.5)
            .finish();
        assert_eq!(s.predictor_name(), "mor");
        assert!(s.policy().is_some());
        assert!(Session::build(&m).predictor("bogus").is_err());
    }

    #[test]
    fn none_strategy_is_dense_even_with_params() {
        let m = synth::tiny_serving_model(7);
        let params = synth::predictor_for(&m, 8);
        let s = Session::build(&m)
            .params(&params)
            .strategy(Strategy::None)
            .finish();
        assert!(s.policy().is_none());
        assert_eq!(s.predictor_name(), "none");
    }

    #[test]
    fn with_threshold_shares_packed_weights() {
        let m = synth::tiny_serving_model(9);
        let s = Session::from_artifacts(
            &synth::artifacts_for(m, 11, 2, 2),
            PredictorConfig { threshold: 0.9, ..Default::default() },
        );
        let t = s.with_threshold(0.2);
        let (a, b) = (s.policy().unwrap(), t.policy().unwrap());
        assert_eq!(b.cfg.threshold, 0.2);
        for (l, st) in &a.layers {
            // same Arc — the sign bits were not re-packed
            assert!(Arc::ptr_eq(&st.packed_w, &b.layers[l].packed_w));
            // lower T enables at least as many neurons
            let on_a = st.enabled.iter().filter(|&&e| e).count();
            let on_b = b.layers[l].enabled.iter().filter(|&&e| e).count();
            assert!(on_b >= on_a);
        }
    }

    #[test]
    fn input_sparsity_knob_threads_through() {
        let m = synth::tiny_serving_model(15);
        let s = Session::build(&m).input_sparsity(InputSparsity::Off).finish();
        assert_eq!(s.opts().input_sparsity, InputSparsity::Off);
        assert_eq!(
            Session::build(&m).finish().opts().input_sparsity,
            InputSparsity::Auto
        );
    }

    #[test]
    fn with_policy_shares_model() {
        let m = synth::tiny_serving_model(13);
        let s = Session::build(&m).finish();
        let d = s.with_policy(None);
        assert!(Arc::ptr_eq(&s.model_arc(), &d.model_arc()));
    }
}
