//! Angle-based neuron clustering (paper Section 3.2.2) — rust
//! re-implementation of the offline stage plus the Monte Carlo validation
//! of the sign-agreement analysis (Eq. 3–6).
//!
//! The clustering is intentionally implemented twice (python for the
//! artifacts, rust here): an integration test asserts both produce the
//! same clusters on the shipped artifacts, and the property tests check
//! the algorithm's invariants independently of the implementation.

use crate::util::rng::Rng;

/// Pairwise angle (degrees, [0, 180]) between two weight vectors.
pub fn angle_deg(a: &[i8], b: &[i8]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut dot = 0f64;
    let mut na = 0f64;
    let mut nb = 0f64;
    for i in 0..a.len() {
        let x = a[i] as f64;
        let y = b[i] as f64;
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na == 0.0 || nb == 0.0 {
        return 90.0; // degenerate zero vector: define as uncorrelated
    }
    let cos = (dot / (na.sqrt() * nb.sqrt())).clamp(-1.0, 1.0);
    cos.acos().to_degrees()
}

/// For each filter of a layer (filter-major weights), the index and angle
/// of its closest peer.
pub fn closest_neighbors(filters: &[&[i8]]) -> (Vec<usize>, Vec<f64>) {
    let n = filters.len();
    let mut idx = vec![0usize; n];
    let mut ang = vec![f64::INFINITY; n];
    // cache angles symmetrically (n is at most a few hundred per layer)
    for i in 0..n {
        for j in (i + 1)..n {
            let a = angle_deg(filters[i], filters[j]);
            // strict '<' keeps the *first* minimum, like numpy argmin
            if a < ang[i] {
                ang[i] = a;
                idx[i] = j;
            }
            if a < ang[j] {
                ang[j] = a;
                idx[j] = i;
            }
        }
    }
    (idx, ang)
}

/// The paper's clustering algorithm (identical to
/// python/compile/calibrate.py::cluster_by_angle):
///
/// 1. directed graph: each neuron → its closest neuron (edge dropped above
///    `max_angle_deg`);
/// 2. process nodes by descending indegree (ties by index);
/// 3. highest-indegree live node becomes a *proxy*; live nodes pointing at
///    it become its members; all removed; repeat.
///
/// Returns clusters as `[proxy, member, ...]` covering every neuron once.
pub fn cluster_by_angle(filters: &[&[i8]], max_angle_deg: f64) -> Vec<Vec<usize>> {
    let n = filters.len();
    if n == 0 {
        return Vec::new();
    }
    let (nearest, near_angle) = closest_neighbors(filters);
    let edge_to: Vec<Option<usize>> = (0..n)
        .map(|i| (near_angle[i] <= max_angle_deg).then_some(nearest[i]))
        .collect();

    let mut indegree = vec![0usize; n];
    let mut incoming: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (src, dst) in edge_to.iter().enumerate() {
        if let Some(d) = dst {
            indegree[*d] += 1;
            incoming[*d].push(src);
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(indegree[i]), i));

    let mut alive = vec![true; n];
    let mut clusters = Vec::new();
    for node in order {
        if !alive[node] {
            continue;
        }
        let members: Vec<usize> = incoming[node]
            .iter()
            .copied()
            .filter(|&m| alive[m] && m != node)
            .collect();
        let mut cl = Vec::with_capacity(members.len() + 1);
        cl.push(node);
        cl.extend_from_slice(&members);
        alive[node] = false;
        for &m in &members {
            alive[m] = false;
        }
        clusters.push(cl);
    }
    debug_assert_eq!(clusters.iter().map(|c| c.len()).sum::<usize>(), n);
    clusters
}

/// Extract filter slices from a compute node.
pub fn node_filters(node: &crate::model::Node) -> Vec<&[i8]> {
    (0..node.cout()).map(|f| node.filter(f)).collect()
}

// ---------------------------------------------------------------------------
// Monte Carlo validation of Eq. 3–6 (the paper verified the 2-D analysis
// holds in higher dimensions "through a Montecarlo simulation")
// ---------------------------------------------------------------------------

/// Estimate P[sign(C·A) != sign(C·B)] for random C in `dim` dimensions,
/// with B constructed at exactly `theta_deg` degrees from A.
/// Eq. 3+4 predict `2 * theta / 360`.
pub fn montecarlo_mismatch_prob(dim: usize, theta_deg: f64, samples: usize, seed: u64) -> f64 {
    assert!(dim >= 2);
    let mut rng = Rng::new(seed);
    // random unit vector a, then b at angle theta in the plane (a, perp)
    let a = unit(&mut rng, dim);
    let mut p: Vec<f64> = rng.normal_vec(dim);
    let pa: f64 = p.iter().zip(&a).map(|(x, y)| x * y).sum();
    for i in 0..dim {
        p[i] -= pa * a[i];
    }
    let pn = norm(&p);
    for v in &mut p {
        *v /= pn;
    }
    let th = theta_deg.to_radians();
    let b: Vec<f64> = (0..dim)
        .map(|i| th.cos() * a[i] + th.sin() * p[i])
        .collect();

    let mut mismatches = 0usize;
    for _ in 0..samples {
        let c = rng.normal_vec(dim);
        let ca: f64 = c.iter().zip(&a).map(|(x, y)| x * y).sum();
        let cb: f64 = c.iter().zip(&b).map(|(x, y)| x * y).sum();
        if (ca > 0.0) != (cb > 0.0) {
            mismatches += 1;
        }
    }
    mismatches as f64 / samples as f64
}

fn unit(rng: &mut Rng, dim: usize) -> Vec<f64> {
    let mut v = rng.normal_vec(dim);
    let n = norm(&v);
    for x in &mut v {
        *x /= n;
    }
    v
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::property;

    #[test]
    fn angle_known_cases() {
        assert!((angle_deg(&[1, 0], &[0, 1]) - 90.0).abs() < 1e-4);
        assert!((angle_deg(&[1, 0], &[-1, 0]) - 180.0).abs() < 1e-4);
        assert!((angle_deg(&[1, 1], &[1, 1]) - 0.0).abs() < 1e-4);
        assert!((angle_deg(&[1, 0], &[1, 1]) - 45.0).abs() < 1e-4);
        assert_eq!(angle_deg(&[0, 0], &[1, 1]), 90.0); // degenerate
    }

    #[test]
    fn cluster_partition_property() {
        property("clusters partition the neurons", 100, |g| {
            let n = g.usize(1, 50);
            let k = g.usize(2, 24);
            let store: Vec<Vec<i8>> = (0..n).map(|_| g.vec_i8(k)).collect();
            let filters: Vec<&[i8]> = store.iter().map(|v| v.as_slice()).collect();
            let clusters = cluster_by_angle(&filters, 90.0);
            let mut seen = vec![false; n];
            for cl in &clusters {
                crate::prop_assert!(g, !cl.is_empty(), "empty cluster");
                for &m in cl {
                    crate::prop_assert!(g, m < n, "member out of range");
                    crate::prop_assert!(g, !seen[m], "neuron {m} in two clusters");
                    seen[m] = true;
                }
                // proxy not repeated among members
                crate::prop_assert!(
                    g,
                    !cl[1..].contains(&cl[0]),
                    "proxy duplicated in members"
                );
            }
            crate::prop_assert!(g, seen.iter().all(|&s| s), "not a full cover");
            Ok(())
        });
    }

    #[test]
    fn parallel_vectors_cluster_together() {
        // five copies of one direction + five scattered vectors
        let mut store: Vec<Vec<i8>> = Vec::new();
        for i in 0..5 {
            let mut v = vec![10i8, 20, -30, 40, 50, -60, 70, 80];
            v[0] += i; // near-parallel
            store.push(v);
        }
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..5 {
            store.push((0..8).map(|_| rng.int8()).collect());
        }
        let filters: Vec<&[i8]> = store.iter().map(|v| v.as_slice()).collect();
        let clusters = cluster_by_angle(&filters, 90.0);
        // closest-neighbour graphs don't guarantee ONE cluster for a
        // parallel bundle (the paper's algorithm deliberately avoids
        // chaining); but every cluster containing one of the bundle must
        // contain ONLY bundle vectors, and at least one real group forms.
        let mut grouped = 0;
        for cl in &clusters {
            let bundle: Vec<_> = cl.iter().filter(|&&m| m < 5).collect();
            if !bundle.is_empty() {
                assert_eq!(
                    bundle.len(),
                    cl.len(),
                    "bundle vectors grouped with scattered ones: {clusters:?}"
                );
                grouped = grouped.max(cl.len());
            }
        }
        assert!(grouped >= 2, "no grouping happened at all: {clusters:?}");
    }

    #[test]
    fn zero_gate_makes_singletons() {
        let store: Vec<Vec<i8>> = (0..6).map(|i| vec![i as i8 + 1, -(i as i8) - 2, 3]).collect();
        let filters: Vec<&[i8]> = store.iter().map(|v| v.as_slice()).collect();
        let clusters = cluster_by_angle(&filters, -1.0);
        assert_eq!(clusters.len(), 6);
        assert!(clusters.iter().all(|c| c.len() == 1));
    }

    #[test]
    fn montecarlo_matches_eq34_dim2() {
        for theta in [30.0, 60.0, 90.0, 120.0] {
            let p = montecarlo_mismatch_prob(2, theta, 100_000, 42);
            let want = 2.0 * theta / 360.0;
            assert!((p - want).abs() < 0.01, "theta={theta}: p={p} want={want}");
        }
    }

    #[test]
    fn montecarlo_matches_eq34_high_dim() {
        // "We verified that this analysis holds for higher dimensions
        //  through a Montecarlo simulation" — paper §3.2.2
        for dim in [8, 64, 512] {
            let p = montecarlo_mismatch_prob(dim, 45.0, 100_000, 7);
            assert!((p - 0.25).abs() < 0.01, "dim={dim}: p={p}");
        }
    }
}
