//! Hand-rolled CLI argument parser (clap is not in the offline vendor set).
//!
//! Grammar: `mor <command> [--flag] [--key value] [positional...]`.
//! Flags may appear in any order; `--key=value` is accepted too, and a
//! bare `--` ends option parsing (everything after is positional).
//!
//! Boolean flags are a known set ([`BOOLEAN_FLAGS`]): a bare token after
//! one of them is a positional, never the flag's value — so
//! `mor serve --no-predictor model.toml` does not swallow the positional.
//! Unknown `--keys` keep the historical lookahead rule (a following
//! non-`--` token is their value), which also accepts negative numbers:
//! `--threshold -0.5`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Flags that never take a value. Keep this in sync with USAGE; `--config`
/// is *not* here because `simulate --config <file>` takes a value (the
/// valueless `info --config` form still parses via lookahead).
pub const BOOLEAN_FLAGS: &[&str] = &[
    "all",
    "autotune",
    "json",
    "no-binary",
    "no-clusters",
    "no-predictor",
    "no-steal",
    "numeric",
    "oracle",
    "verbose",
];

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (element 0 = program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut args = Args::default();
        if let Some(cmd) = it.next() {
            if cmd.starts_with('-') {
                bail!("expected a command before options, got '{cmd}'");
            }
            args.command = cmd;
        }
        let mut options_done = false;
        while let Some(tok) = it.next() {
            if options_done {
                args.positional.push(tok);
                continue;
            }
            if tok == "--" {
                options_done = true;
                continue;
            }
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if BOOLEAN_FLAGS.contains(&key) {
                    args.flags.push(key.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }
}

pub const USAGE: &str = "\
mor — Mixture-of-Rookies reproduction (rust coordinator)

USAGE:
    mor <COMMAND> [OPTIONS]

COMMANDS:
    run        Run MoR inference on a model's test split, report prediction
               stats, accuracy and computation savings
                 --model <name>        tds|cnn10|darknet19m|resnet18m (default: all)
                 --artifacts <dir>     artifacts directory (default: artifacts)
                 --predictor <name>    skip strategy: mor|binary|cluster|oracle|none
                                       (default: mor; see `mor predictors`)
                 --threshold <T>       correlation threshold (default: 0.85)
                 --no-clusters         legacy alias for --predictor binary
                 --no-binary           legacy alias for --predictor cluster
                 --input-sparsity <m>  input-zero lane skipping: auto|on|off
                                       (default: auto; bit-identical either way,
                                       see EXPERIMENTS.md §Sparse)
                 --weight-sparsity <m> weight-zero lane elision: off|exact|<t>
                                       (default: off; exact is bit-identical,
                                       a numeric threshold t magnitude-prunes
                                       weights below t and reports the accuracy
                                       delta, see EXPERIMENTS.md §Weights)
                 --autotune            microbenchmark-calibrate the kernel
                                       crossovers / tile height / thread
                                       fan-out at session build and freeze
                                       them into the plan (bit-identical;
                                       see EXPERIMENTS.md §Tune)
                 --tune-profile <f>    load a saved tune profile (with
                                       --autotune: calibrate, then save
                                       the measured profile to <f>)
                 --samples <n>         cap evaluated samples
    simulate   Cycle-level accelerator simulation (baseline vs MoR)
                 --model/--artifacts/--predictor/--threshold as above
                 --input-sparsity <m>  as above
                 --weight-sparsity <m> as above
                 --config <file>       accelerator TOML (default: Table 1)
                 --samples <n>         samples to simulate (default: 16)
    figures    Regenerate paper figures/tables
                 --all | <id>...       positional ids: fig1,fig3,...,fig13,
                                       ablation,sparsity,table1,area
                                       (no ids and no --all = everything)
                 --out <dir>           CSV output directory (default: figures_out)
                 --predictor <name>    strategy for fig13/simulate paths
                 --input-sparsity <m>  input-zero lane skipping: auto|on|off
                 --weight-sparsity <m> weight-zero lane elision: off|exact|<t>
    serve      Run the serving coordinator on a synthetic request stream
                 --model <name>        model to serve (default: tds)
                 --rps <r>             request rate (default: 200)
                 --duration <s>        seconds of simulated load (default: 5)
                 --workers <n>         worker threads (default: 4)
                 --intra-threads <n>   row-tile threads per sample (default: 1)
                 --max-batch <n>       requests per engine micro-batch
                                       (default: 1 = no batching)
                 --batch-wait-us <t>   max linger for a partial batch
                                       (default: 200)
                 --arrival <kind>      poisson|steady|bursty|diurnal|
                                       flashcrowd|closed (default: poisson;
                                       closed ignores arrival times and keeps
                                       --concurrency requests outstanding;
                                       diurnal/flashcrowd are the tier's
                                       time-varying overload traces)
                 --concurrency <n>     closed-loop outstanding requests
                                       (default: workers * max-batch)
               Serving-tier mode (any of --models/--tenants/--deadline-ms
               routes to the sharded multi-model tier, EXPERIMENTS.md §Tier):
                 --models <a,b,...>    serve several models in one process,
                                       each with its own session + queue +
                                       replica pool (default: --model)
                 --replicas <n>        workers per model (default: 2)
                 --tenants <spec>      weighted fair sharing classes, e.g.
                                       gold:2,free:1 (default: all:1)
                 --deadline-ms <t>     per-request deadline: admission
                                       rejects arrivals whose projected wait
                                       exceeds it, dequeue sheds requests
                                       that can no longer finish in time
                                       (default: 0 = no deadline)
                 --no-steal            disable work stealing between idle
                                       replicas of different models
                 --predictor <name>    skip strategy (default: mor)
                 --input-sparsity <m>  input-zero lane skipping: auto|on|off
                 --weight-sparsity <m> weight-zero lane elision: off|exact|<t>
                 --no-predictor        serve the dense baseline (alias for
                                       --predictor none)
                 --autotune            calibrate kernel crossovers at build
                 --tune-profile <f>    load (or with --autotune, save) a
                                       tune profile
                 --runtime pjrt|engine execution backend (default: engine;
                                       pjrt needs --features pjrt at build)
    lint       Statically verify compiled ModelPlans (slot liveness,
               scratch marks, frozen sparsity/policy decisions — see
               EXPERIMENTS.md §Lint) over the synthetic model zoo, or
               over a real artifact model
                 --model <name>        lint one artifact model instead of
                                       the synthetic zoo
                 --artifacts <dir>     artifacts directory (default: artifacts)
                 --seed <n>            synthetic-zoo base seed (default: 7)
                 --random-models <n>   extra random graphs to lint (default: 8)
                 --numeric             also run the quantized-numerics
                                       abstract interpreter: per-layer value
                                       intervals from the actual prepacked
                                       weights prove accumulator non-overflow,
                                       requantization range safety and
                                       predictor-threshold soundness
                                       (diagnostics num.*, see
                                       EXPERIMENTS.md §Numeric)
                 --acc-bits <n>        with --numeric: claim an <n>-bit
                                       accumulator; layers whose proven
                                       bound needs more report num.width
                                       (the VNNI offset bound reports
                                       num.vnni — it is wider than the
                                       true dot's; default: 32)
                 --tune-profile <f>    audit every plan's frozen kernel
                                       decisions against the saved
                                       profile instead of its own
                 --json                machine-readable findings on stdout
               exit status 1 if any error-severity finding is reported
    predictors List the available zero-predictor strategies
    info       Print artifact + configuration info, detected CPU ISA
               tiers, the active kernel set and the tune profile
                 --config              print Table 1
                 --tune-profile <f>    report a saved profile (+ hash)
                                       instead of the host default
                 --artifacts <dir>
    help       Show this help
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        let mut v = vec!["mor".to_string()];
        v.extend(toks.iter().map(|s| s.to_string()));
        Args::parse(v).unwrap()
    }

    #[test]
    fn basic_command() {
        let a = parse(&["run", "--model", "tds", "--no-clusters"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.opt("model"), Some("tds"));
        assert!(a.flag("no-clusters"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["run", "--threshold=0.7"]);
        assert_eq!(a.opt_f64("threshold", 0.0).unwrap(), 0.7);
    }

    #[test]
    fn positional_args() {
        let a = parse(&["figures", "fig6", "fig9", "--out", "x"]);
        assert_eq!(a.positional, vec!["fig6", "fig9"]);
        assert_eq!(a.opt("out"), Some("x"));
    }

    #[test]
    fn autotune_is_boolean_tune_profile_takes_a_value() {
        let a = parse(&["run", "--autotune", "--tune-profile", "p.tune", "--acc-bits", "24"]);
        assert!(a.flag("autotune"));
        assert_eq!(a.opt("tune-profile"), Some("p.tune"));
        assert_eq!(a.opt_usize("acc-bits", 32).unwrap(), 24);
        // --autotune never swallows a following positional
        let a = parse(&["serve", "--autotune", "tds"]);
        assert!(a.flag("autotune"));
        assert_eq!(a.positional, vec!["tds"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["run", "--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["run", "--threshold", "abc"]);
        assert!(a.opt_f64("threshold", 0.0).is_err());
    }

    #[test]
    fn option_before_command_rejected() {
        let v = vec!["mor".to_string(), "--x".to_string()];
        assert!(Args::parse(v).is_err());
    }

    #[test]
    fn boolean_flag_does_not_swallow_positional() {
        // the old lookahead rule parsed this as oracle=model.toml
        let a = parse(&["serve", "--oracle", "model.toml"]);
        assert!(a.flag("oracle"));
        assert_eq!(a.opt("oracle"), None);
        assert_eq!(a.positional, vec!["model.toml"]);

        let a = parse(&["run", "--no-predictor", "extra", "--model", "tds"]);
        assert!(a.flag("no-predictor"));
        assert_eq!(a.positional, vec!["extra"]);
        assert_eq!(a.opt("model"), Some("tds"));
    }

    #[test]
    fn tier_flags_parse() {
        let a = parse(&["serve", "--no-steal", "--models", "tds,cnn10", "--tenants", "gold:2,free:1", "--deadline-ms", "20"]);
        assert!(a.flag("no-steal"));
        assert_eq!(a.opt("models"), Some("tds,cnn10"));
        assert_eq!(a.opt("tenants"), Some("gold:2,free:1"));
        assert_eq!(a.opt_f64("deadline-ms", 0.0).unwrap(), 20.0);
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["run", "--threshold", "-0.5"]);
        assert_eq!(a.opt_f64("threshold", 0.0).unwrap(), -0.5);
        let a = parse(&["run", "--threshold=-1.5"]);
        assert_eq!(a.opt_f64("threshold", 0.0).unwrap(), -1.5);
    }

    #[test]
    fn double_dash_terminates_options() {
        let a = parse(&["figures", "--out", "x", "--", "--fig6", "plain"]);
        assert_eq!(a.opt("out"), Some("x"));
        assert_eq!(a.positional, vec!["--fig6", "plain"]);
        assert!(a.flags.is_empty());
    }

    #[test]
    fn equals_form_on_boolean_named_key_still_works() {
        // --key=value always wins over the flag set
        let a = parse(&["run", "--oracle=yes"]);
        assert_eq!(a.opt("oracle"), Some("yes"));
        assert!(!a.flag("oracle"));
    }
}
