//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! rust (the pattern of /opt/xla-example/load_hlo).
//!
//! The interchange format is HLO **text**: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). aot.py lowers
//! with `return_tuple=True`, so results unwrap with `to_tuple1`.
//!
//! One [`Executable`] is compiled per model and reused for every request —
//! compilation happens once at coordinator startup, never on the hot path.

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled model executable on the PJRT CPU client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub input_len: usize,
    pub input_shape: (usize, usize, usize),
}

/// PJRT client wrapper; create once, load many executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo<P: AsRef<Path>>(
        &self,
        path: P,
        input_shape: (usize, usize, usize),
    ) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            input_len: input_shape.0 * input_shape.1 * input_shape.2,
            input_shape,
        })
    }
}

impl Executable {
    /// Run the forward pass on one sample (H*W*C floats) → logits.
    pub fn forward(&self, sample: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            sample.len() == self.input_len,
            "input length {} != expected {}",
            sample.len(),
            self.input_len
        );
        let (h, w, c) = self.input_shape;
        let lit = xla::Literal::vec1(sample).reshape(&[h as i64, w as i64, c as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full round-trip tests need `make artifacts` and live in rust/tests/;
    // here we only check error paths that need no artifacts.

    #[test]
    fn missing_hlo_is_error() {
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(_) => return, // PJRT unavailable: skip
        };
        assert!(rt.load_hlo("/nonexistent.hlo.txt", (1, 1, 1)).is_err());
    }
}
