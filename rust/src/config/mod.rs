//! Configuration system: accelerator (Table 1), predictor, host-engine
//! and workload parameters, loadable from TOML files (configs/*.toml)
//! with CLI overrides. Accelerator/DRAM defaults are *exactly* the
//! paper's Table 1; `[engine]` holds host-side kernel knobs. Input
//! sparsity and `weight_sparsity = "exact"` never change results; a
//! numeric `weight_sparsity` threshold prunes small weights and *does*
//! change them (accuracy is measured and reported by `mor run`).

use crate::engine::{InputSparsity, WeightSparsity};
use crate::predictor::strategies::Strategy;
use crate::util::toml::Toml;
use anyhow::{Context, Result};
use std::path::Path;

/// Accelerator configuration (paper Table 1).
#[derive(Clone, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// Core + memory clock (the paper runs both at the same frequency).
    pub frequency_mhz: u64,
    /// Input SRAM capacity in bytes (Table 1: 16 KB).
    pub input_sram_bytes: u64,
    /// Binary-weight SRAM in bytes (Table 1: 2 KB) — predictor-only.
    pub binweight_sram_bytes: u64,
    /// Number of base-precision compute units (Table 1: 8).
    pub num_cus: usize,
    /// Parallel MACs per CU per cycle (Table 1: "CU width" 8).
    pub cu_width: usize,
    /// Number of binary CUs (Table 1: 8) — predictor-only.
    pub num_bincus: usize,
    /// Binary lanes per binCU per cycle (XNOR+popcount width).
    pub bincu_width: usize,
    /// Per-CU weight buffer in bytes (Table 1: 1 KB).
    pub cu_buffer_bytes: u64,
    /// Per-binCU buffer in bytes (Table 1: 0.56 KB).
    pub bincu_buffer_bytes: u64,
    /// Enable the Mixture-of-Rookies predictor datapath.
    pub predictor: bool,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        AcceleratorConfig {
            frequency_mhz: 1200,
            input_sram_bytes: 16 * 1024,
            binweight_sram_bytes: 2 * 1024,
            num_cus: 8,
            cu_width: 8,
            num_bincus: 8,
            bincu_width: 64,
            cu_buffer_bytes: 1024,
            bincu_buffer_bytes: 573, // 0.56 KB
            predictor: true,
        }
    }
}

impl AcceleratorConfig {
    /// The baseline the paper compares against: identical accelerator
    /// without binWeight SRAM / binCUs (Section 6).
    pub fn baseline() -> Self {
        AcceleratorConfig {
            predictor: false,
            ..Default::default()
        }
    }

    /// Peak MAC throughput per cycle (Table 1: 8 CUs x 8 wide = 64).
    pub fn peak_macs_per_cycle(&self) -> u64 {
        (self.num_cus * self.cu_width) as u64
    }
}

/// External LPDDR4 memory configuration (paper Table 1 + LPDDR4-2400-class
/// timings expressed in memory-clock cycles).
#[derive(Clone, Debug, PartialEq)]
pub struct DramConfig {
    pub frequency_mhz: u64,
    pub capacity_bytes: u64,
    /// Data-port width in bytes per memory cycle (Table 1: 8 B).
    pub port_bytes: u64,
    /// Burst length in bytes (Table 1: 64 B).
    pub burst_bytes: u64,
    pub num_banks: usize,
    pub row_bytes: u64,
    /// Activate-to-read delay (tRCD), cycles.
    pub t_rcd: u64,
    /// Precharge (tRP), cycles.
    pub t_rp: u64,
    /// CAS latency (tCL), cycles.
    pub t_cl: u64,
    /// Minimum row-open time (tRAS), cycles.
    pub t_ras: u64,
    /// Column-to-column (tCCD), cycles.
    pub t_ccd: u64,
    /// Refresh interval (tREFI), cycles; 0 disables refresh modelling.
    pub t_refi: u64,
    /// Refresh duration (tRFC), cycles.
    pub t_rfc: u64,
}

impl Default for DramConfig {
    fn default() -> Self {
        // LPDDR4-2400-class timings at 1200 MHz I/O clock (Table 1).
        DramConfig {
            frequency_mhz: 1200,
            capacity_bytes: 1 << 30, // 1 GB
            port_bytes: 8,
            burst_bytes: 64,
            num_banks: 8,
            row_bytes: 2048,
            t_rcd: 22,
            t_rp: 22,
            t_cl: 22,
            t_ras: 51,
            t_ccd: 8,
            t_refi: 4680, // 3.9 us at 1200 MHz
            t_rfc: 216,   // 180 ns
        }
    }
}

impl DramConfig {
    /// Cycles the data bus is busy transferring one burst.
    pub fn burst_cycles(&self) -> u64 {
        crate::util::ceil_div(self.burst_bytes, self.port_bytes)
    }
}

/// Zero-predictor configuration (offline parameters live in the
/// artifacts; this selects and tunes the online policy).
#[derive(Clone, Debug, PartialEq)]
pub struct PredictorConfig {
    /// Which skip strategy runs: `mor` (hybrid, the paper default),
    /// `binary`, `cluster`, `oracle`, or `none`. TOML key
    /// `predictor.strategy`, CLI `--predictor`.
    pub strategy: Strategy,
    /// Pearson-correlation threshold T (Section 3.2.1). Neurons with
    /// c < T never use the binary predictor.
    pub threshold: f32,
    /// Optional angle gate for cluster membership (ablation; the paper's
    /// default keeps every closest-neighbour edge → 90°).
    pub max_cluster_angle_deg: f32,
    /// Skip-confidence margin: a neuron is only skipped when the estimated
    /// ReLU input is at least `margin_sigmas` regression-residual stds
    /// below zero. 0.0 recovers the paper's raw rule; the default 1.0
    /// trades a little savings for a large cut in wrong skips (see the
    /// ablation bench).
    pub margin_sigmas: f32,
}

impl Default for PredictorConfig {
    fn default() -> Self {
        PredictorConfig {
            strategy: Strategy::Mor,
            threshold: 0.85,
            max_cluster_angle_deg: 90.0,
            margin_sigmas: 1.0,
        }
    }
}

/// Host engine configuration (kernel selection knobs; everything except
/// a numeric weight-sparsity threshold is result-neutral).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EngineConfig {
    /// Input-side sparsity mode for the tiled engine: skip zero-valued
    /// input activation lanes via the compressed-lane kernels. TOML key
    /// `engine.input_sparsity` (`"auto"`/`"on"`/`"off"`), CLI
    /// `--input-sparsity`. All modes are bit-identical.
    pub input_sparsity: InputSparsity,
    /// Weight-side sparsity mode: elide zero weight lanes via the
    /// compressed-weight kernels. TOML key `engine.weight_sparsity` —
    /// `"off"`, `"exact"` (bit-identical by construction), or a number
    /// `t > 0` (magnitude-prune lanes with dequantized `|w|·sw < t` at
    /// session build; changes results, accuracy is reported). CLI
    /// `--weight-sparsity`.
    pub weight_sparsity: WeightSparsity,
    /// Run the kernel-calibration microbenchmark pass at session build
    /// and freeze its measured crossovers / tile height / thread fan-out
    /// into the plan. TOML key `engine.autotune`, CLI `--autotune`.
    /// Result-neutral (kernel selection only); off by default so plans
    /// stay deterministic without a saved profile.
    pub autotune: bool,
}

/// Top-level config bundle.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub accel: AcceleratorConfig,
    pub dram: DramConfig,
    pub predictor: PredictorConfig,
    pub engine: EngineConfig,
}

impl Config {
    /// Load from a TOML file; missing keys keep Table 1 defaults.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Config> {
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        let t = Toml::parse(&src).context("parsing config TOML")?;
        Config::from_toml(&t)
    }

    pub fn from_toml(t: &Toml) -> Result<Config> {
        let d = Config::default();
        // strategy selection: the named `predictor.strategy` key wins;
        // the legacy `use_clusters` / `use_binary` component toggles are
        // still honoured when it is absent
        let strategy = match t.get("predictor.strategy") {
            Some(v) => {
                let name = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("predictor.strategy must be a string"))?;
                Strategy::parse(name)?
            }
            None => Strategy::from_components(
                t.bool_or("predictor.use_clusters", true),
                t.bool_or("predictor.use_binary", true),
            ),
        };
        let input_sparsity = match t.get("engine.input_sparsity") {
            Some(v) => {
                let name = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("engine.input_sparsity must be a string"))?;
                InputSparsity::parse(name)?
            }
            None => d.engine.input_sparsity,
        };
        // string ("off"/"exact") or numeric threshold — both spellings
        // funnel through WeightSparsity::parse's validation
        let weight_sparsity = match t.get("engine.weight_sparsity") {
            Some(v) => match v.as_str() {
                Some(name) => WeightSparsity::parse(name)?,
                None => {
                    let num = v.as_f64().ok_or_else(|| {
                        anyhow::anyhow!(
                            "engine.weight_sparsity must be \"off\", \"exact\" or a number"
                        )
                    })?;
                    WeightSparsity::parse(&format!("{num}"))?
                }
            },
            None => d.engine.weight_sparsity,
        };
        Ok(Config {
            accel: AcceleratorConfig {
                frequency_mhz: t.i64_or("accelerator.frequency_mhz", d.accel.frequency_mhz as i64) as u64,
                input_sram_bytes: t.i64_or("accelerator.input_sram_bytes", d.accel.input_sram_bytes as i64) as u64,
                binweight_sram_bytes: t.i64_or("accelerator.binweight_sram_bytes", d.accel.binweight_sram_bytes as i64) as u64,
                num_cus: t.i64_or("accelerator.num_cus", d.accel.num_cus as i64) as usize,
                cu_width: t.i64_or("accelerator.cu_width", d.accel.cu_width as i64) as usize,
                num_bincus: t.i64_or("accelerator.num_bincus", d.accel.num_bincus as i64) as usize,
                bincu_width: t.i64_or("accelerator.bincu_width", d.accel.bincu_width as i64) as usize,
                cu_buffer_bytes: t.i64_or("accelerator.cu_buffer_bytes", d.accel.cu_buffer_bytes as i64) as u64,
                bincu_buffer_bytes: t.i64_or("accelerator.bincu_buffer_bytes", d.accel.bincu_buffer_bytes as i64) as u64,
                predictor: t.bool_or("accelerator.predictor", d.accel.predictor),
            },
            dram: DramConfig {
                frequency_mhz: t.i64_or("dram.frequency_mhz", d.dram.frequency_mhz as i64) as u64,
                capacity_bytes: t.i64_or("dram.capacity_bytes", d.dram.capacity_bytes as i64) as u64,
                port_bytes: t.i64_or("dram.port_bytes", d.dram.port_bytes as i64) as u64,
                burst_bytes: t.i64_or("dram.burst_bytes", d.dram.burst_bytes as i64) as u64,
                num_banks: t.i64_or("dram.num_banks", d.dram.num_banks as i64) as usize,
                row_bytes: t.i64_or("dram.row_bytes", d.dram.row_bytes as i64) as u64,
                t_rcd: t.i64_or("dram.t_rcd", d.dram.t_rcd as i64) as u64,
                t_rp: t.i64_or("dram.t_rp", d.dram.t_rp as i64) as u64,
                t_cl: t.i64_or("dram.t_cl", d.dram.t_cl as i64) as u64,
                t_ras: t.i64_or("dram.t_ras", d.dram.t_ras as i64) as u64,
                t_ccd: t.i64_or("dram.t_ccd", d.dram.t_ccd as i64) as u64,
                t_refi: t.i64_or("dram.t_refi", d.dram.t_refi as i64) as u64,
                t_rfc: t.i64_or("dram.t_rfc", d.dram.t_rfc as i64) as u64,
            },
            predictor: PredictorConfig {
                strategy,
                threshold: t.f64_or("predictor.threshold", d.predictor.threshold as f64) as f32,
                max_cluster_angle_deg: t.f64_or(
                    "predictor.max_cluster_angle_deg",
                    d.predictor.max_cluster_angle_deg as f64,
                ) as f32,
                margin_sigmas: t.f64_or(
                    "predictor.margin_sigmas",
                    d.predictor.margin_sigmas as f64,
                ) as f32,
            },
            engine: EngineConfig {
                input_sparsity,
                weight_sparsity,
                autotune: t.bool_or("engine.autotune", d.engine.autotune),
            },
        })
    }

    /// Render Table 1 (used by `mor info --config` and the table1 bench).
    pub fn table1(&self) -> String {
        let a = &self.accel;
        let d = &self.dram;
        format!(
            "DNN Accelerator\n\
             \x20 Frequency        {} MHz\n\
             \x20 Input SRAM       {} KB\n\
             \x20 BinWeight SRAM   {} KB\n\
             \x20 Number binCUs    {}\n\
             \x20 Number of CUs    {}\n\
             \x20 CU width         {}\n\
             \x20 CU precision     8 b\n\
             \x20 CU Buffer        {} KB\n\
             \x20 binCU buffer     {:.2} KB\n\
             External Memory      LPDDR4\n\
             \x20 Frequency        {} MHz\n\
             \x20 Capacity         {} GB\n\
             \x20 Port Width       {} B\n\
             \x20 Burst Size       {} B",
            a.frequency_mhz,
            a.input_sram_bytes / 1024,
            a.binweight_sram_bytes / 1024,
            a.num_bincus,
            a.num_cus,
            a.cu_width,
            a.cu_buffer_bytes / 1024,
            a.bincu_buffer_bytes as f64 / 1024.0,
            d.frequency_mhz,
            d.capacity_bytes >> 30,
            d.port_bytes,
            d.burst_bytes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = Config::default();
        assert_eq!(c.accel.frequency_mhz, 1200);
        assert_eq!(c.accel.input_sram_bytes, 16 * 1024);
        assert_eq!(c.accel.binweight_sram_bytes, 2 * 1024);
        assert_eq!(c.accel.num_cus, 8);
        assert_eq!(c.accel.cu_width, 8);
        assert_eq!(c.accel.num_bincus, 8);
        assert_eq!(c.accel.peak_macs_per_cycle(), 64);
        assert_eq!(c.dram.port_bytes, 8);
        assert_eq!(c.dram.burst_bytes, 64);
        assert_eq!(c.dram.capacity_bytes, 1 << 30);
    }

    #[test]
    fn baseline_disables_predictor_only() {
        let b = AcceleratorConfig::baseline();
        let d = AcceleratorConfig::default();
        assert!(!b.predictor && d.predictor);
        assert_eq!(b.num_cus, d.num_cus);
    }

    #[test]
    fn toml_overrides() {
        let t = Toml::parse(
            "[accelerator]\nnum_cus = 16\npredictor = false\n[predictor]\nthreshold = 0.7\n",
        )
        .unwrap();
        let c = Config::from_toml(&t).unwrap();
        assert_eq!(c.accel.num_cus, 16);
        assert!(!c.accel.predictor);
        assert!((c.predictor.threshold - 0.7).abs() < 1e-6);
        // untouched keys keep defaults
        assert_eq!(c.accel.cu_width, 8);
        assert_eq!(c.predictor.strategy, Strategy::Mor);
    }

    #[test]
    fn toml_strategy_key() {
        let t = Toml::parse("[predictor]\nstrategy = \"oracle\"\n").unwrap();
        assert_eq!(Config::from_toml(&t).unwrap().predictor.strategy, Strategy::Oracle);
        let bad = Toml::parse("[predictor]\nstrategy = \"learned\"\n").unwrap();
        assert!(Config::from_toml(&bad).is_err());
    }

    #[test]
    fn toml_engine_input_sparsity_key() {
        // default is auto
        assert_eq!(Config::default().engine.input_sparsity, InputSparsity::Auto);
        let t = Toml::parse("[engine]\ninput_sparsity = \"off\"\n").unwrap();
        assert_eq!(
            Config::from_toml(&t).unwrap().engine.input_sparsity,
            InputSparsity::Off
        );
        let t = Toml::parse("[engine]\ninput_sparsity = \"on\"\n").unwrap();
        assert_eq!(
            Config::from_toml(&t).unwrap().engine.input_sparsity,
            InputSparsity::On
        );
        let bad = Toml::parse("[engine]\ninput_sparsity = \"dense\"\n").unwrap();
        assert!(Config::from_toml(&bad).is_err());
    }

    #[test]
    fn toml_engine_weight_sparsity_key() {
        // default off; both string and numeric spellings accepted
        assert_eq!(Config::default().engine.weight_sparsity, WeightSparsity::Off);
        let t = Toml::parse("[engine]\nweight_sparsity = \"exact\"\n").unwrap();
        assert_eq!(
            Config::from_toml(&t).unwrap().engine.weight_sparsity,
            WeightSparsity::Exact
        );
        let t = Toml::parse("[engine]\nweight_sparsity = 0.02\n").unwrap();
        assert_eq!(
            Config::from_toml(&t).unwrap().engine.weight_sparsity,
            WeightSparsity::Threshold(0.02)
        );
        // integers work too (1 → threshold 1.0)
        let t = Toml::parse("[engine]\nweight_sparsity = 1\n").unwrap();
        assert_eq!(
            Config::from_toml(&t).unwrap().engine.weight_sparsity,
            WeightSparsity::Threshold(1.0)
        );
        for bad in [
            "[engine]\nweight_sparsity = \"dense\"\n",
            "[engine]\nweight_sparsity = -0.5\n",
            "[engine]\nweight_sparsity = 0\n",
            "[engine]\nweight_sparsity = true\n",
        ] {
            let t = Toml::parse(bad).unwrap();
            assert!(Config::from_toml(&t).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn toml_engine_autotune_key() {
        assert!(!Config::default().engine.autotune);
        let t = Toml::parse("[engine]\nautotune = true\n").unwrap();
        assert!(Config::from_toml(&t).unwrap().engine.autotune);
        let t = Toml::parse("[engine]\nautotune = false\n").unwrap();
        assert!(!Config::from_toml(&t).unwrap().engine.autotune);
    }

    #[test]
    fn toml_legacy_component_toggles_map_to_strategies() {
        let cases = [
            ("use_clusters = false\n", Strategy::Binary),
            ("use_binary = false\n", Strategy::Cluster),
            ("use_clusters = false\nuse_binary = false\n", Strategy::None),
        ];
        for (body, want) in cases {
            let t = Toml::parse(&format!("[predictor]\n{body}")).unwrap();
            assert_eq!(Config::from_toml(&t).unwrap().predictor.strategy, want);
        }
        // the named key wins over legacy toggles
        let t = Toml::parse("[predictor]\nstrategy = \"mor\"\nuse_binary = false\n").unwrap();
        assert_eq!(Config::from_toml(&t).unwrap().predictor.strategy, Strategy::Mor);
    }

    #[test]
    fn burst_cycles() {
        assert_eq!(DramConfig::default().burst_cycles(), 8);
    }

    #[test]
    fn table1_render_contains_key_rows() {
        let s = Config::default().table1();
        assert!(s.contains("1200 MHz"));
        assert!(s.contains("Input SRAM       16 KB"));
        assert!(s.contains("Burst Size       64 B"));
    }
}
