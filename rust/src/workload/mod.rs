//! Synthetic request workloads for the serving coordinator.
//!
//! The paper's deployment scenario is frame-by-frame, low-latency edge
//! inference (Section 4: "the input will be processed frame-by-frame ...
//! to minimize word-to-transcription latency"). The generator produces an
//! arrival stream of inference requests over a model's test split, which
//! the coordinator serves. Three open-loop arrival shapes are supported —
//! Poisson (memoryless), Steady (fixed interval, e.g. a camera's frame
//! clock) and Bursty (on/off modulated Poisson, the utterance-shaped
//! traffic the batcher must absorb). Closed-loop issue-on-completion is a
//! coordinator mode ([`crate::coordinator::ServeOpts::closed_loop`]) —
//! there the arrival times generated here are ignored.

use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Index into the model's test split.
    pub sample_idx: usize,
    /// Arrival time in microseconds from stream start.
    pub arrival_us: u64,
}

/// Open-loop arrival process shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Poisson process: exponential inter-arrival gaps at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// Fixed-interval arrivals at `rate_per_s` (frame-clock traffic).
    Steady { rate_per_s: f64 },
    /// On/off modulated Poisson (2-state MMPP): exponential ON/OFF dwell
    /// times; arrivals only during ON periods, at `rate_on_per_s`. The
    /// long-run average rate is `rate_on_per_s * on / (on + off)`.
    Bursty {
        rate_on_per_s: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
}

impl Arrival {
    /// Build an [`Arrival`] from a CLI name and a target *average* rate.
    /// `bursty` uses a 25% duty cycle (0.1 s ON / 0.3 s OFF), so its ON
    /// rate is 4x the average. `closed` is not an open-loop shape — the
    /// coordinator handles it — but maps to Poisson so the request list
    /// (sample indices, count) is still generated.
    pub fn from_cli(kind: &str, rate_per_s: f64) -> Result<Arrival> {
        Ok(match kind {
            "poisson" | "closed" => Arrival::Poisson { rate_per_s },
            "steady" => Arrival::Steady { rate_per_s },
            "bursty" => Arrival::Bursty {
                rate_on_per_s: rate_per_s * 4.0,
                mean_on_s: 0.1,
                mean_off_s: 0.3,
            },
            other => bail!("--arrival must be poisson|steady|bursty|closed, got '{other}'"),
        })
    }
}

/// Arrival process over `n_samples` test samples.
pub struct RequestStream {
    rng: Rng,
    arrival: Arrival,
    n_samples: usize,
    next_id: u64,
    clock_us: f64,
    /// Bursty state: currently in an ON period, and when it flips.
    burst_on: bool,
    burst_end_us: f64,
}

impl RequestStream {
    /// Poisson stream (the historical default shape).
    pub fn new(rate_per_s: f64, n_samples: usize, seed: u64) -> RequestStream {
        assert!(rate_per_s > 0.0);
        Self::with_arrival(Arrival::Poisson { rate_per_s }, n_samples, seed)
    }

    pub fn with_arrival(arrival: Arrival, n_samples: usize, seed: u64) -> RequestStream {
        assert!(n_samples > 0);
        match arrival {
            Arrival::Poisson { rate_per_s } | Arrival::Steady { rate_per_s } => {
                assert!(rate_per_s > 0.0)
            }
            Arrival::Bursty {
                rate_on_per_s,
                mean_on_s,
                mean_off_s,
            } => assert!(rate_on_per_s > 0.0 && mean_on_s > 0.0 && mean_off_s >= 0.0),
        }
        RequestStream {
            rng: Rng::new(seed),
            arrival,
            n_samples,
            next_id: 0,
            clock_us: 0.0,
            burst_on: false,
            burst_end_us: 0.0,
        }
    }

    /// Generate requests arriving within the next `duration_s` seconds.
    /// The stream keeps its clock (and burst state) across calls, so ids
    /// stay unique and arrivals stay monotonic.
    pub fn generate(&mut self, duration_s: f64) -> Vec<Request> {
        let end_us = self.clock_us + duration_s * 1e6;
        let mut out = Vec::new();
        while let Some(t) = self.next_arrival(end_us) {
            self.clock_us = t;
            out.push(Request {
                id: self.next_id,
                sample_idx: self.rng.index(self.n_samples),
                arrival_us: t as u64,
            });
            self.next_id += 1;
        }
        self.clock_us = end_us;
        out
    }

    /// Next arrival strictly before `end_us`, or None (window exhausted).
    fn next_arrival(&mut self, end_us: f64) -> Option<f64> {
        match self.arrival {
            Arrival::Poisson { rate_per_s } => {
                let t = self.clock_us + self.rng.exponential(rate_per_s) * 1e6;
                (t < end_us).then_some(t)
            }
            Arrival::Steady { rate_per_s } => {
                let t = self.clock_us + 1e6 / rate_per_s;
                (t < end_us).then_some(t)
            }
            Arrival::Bursty {
                rate_on_per_s,
                mean_on_s,
                mean_off_s,
            } => {
                let mut now = self.clock_us;
                loop {
                    if now >= self.burst_end_us {
                        // dwell expired: flip state, draw the next dwell
                        self.burst_on = !self.burst_on;
                        let mean = if self.burst_on { mean_on_s } else { mean_off_s };
                        self.burst_end_us = now + self.rng.exponential(1.0 / mean.max(1e-9)) * 1e6;
                    }
                    if !self.burst_on {
                        // silent period: jump to its end
                        now = self.burst_end_us;
                        if now >= end_us {
                            return None;
                        }
                        continue;
                    }
                    let t = now + self.rng.exponential(rate_on_per_s) * 1e6;
                    if t >= self.burst_end_us {
                        // the gap crossed into the OFF state: advance and
                        // let the state machine flip. Checked *before* the
                        // window bound — an overshooting gap must not end
                        // the window while later bursts still fit in it
                        // (the dwell state persists across windows).
                        now = self.burst_end_us;
                        if now >= end_us {
                            return None;
                        }
                        continue;
                    }
                    if t >= end_us {
                        return None;
                    }
                    return Some(t);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_is_respected() {
        let mut s = RequestStream::new(1000.0, 16, 1);
        let reqs = s.generate(2.0);
        // ~2000 expected; Poisson 3-sigma ≈ ±134
        assert!(
            (1800..2200).contains(&reqs.len()),
            "got {} requests",
            reqs.len()
        );
    }

    #[test]
    fn arrivals_are_monotonic_and_bounded() {
        let mut s = RequestStream::new(500.0, 4, 2);
        let reqs = s.generate(1.0);
        for w in reqs.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
        assert!(reqs.iter().all(|r| r.arrival_us < 1_000_000));
        assert!(reqs.iter().all(|r| r.sample_idx < 4));
    }

    #[test]
    fn ids_unique_across_batches() {
        let mut s = RequestStream::new(300.0, 8, 3);
        let a = s.generate(0.5);
        let b = s.generate(0.5);
        let max_a = a.iter().map(|r| r.id).max().unwrap_or(0);
        assert!(b.iter().all(|r| r.id > max_a));
    }

    #[test]
    fn steady_is_exactly_periodic() {
        let mut s = RequestStream::with_arrival(Arrival::Steady { rate_per_s: 100.0 }, 8, 4);
        let reqs = s.generate(1.0);
        // arrivals at 10ms, 20ms, ..., 90ms... strictly before 1s: 99
        assert_eq!(reqs.len(), 99);
        for w in reqs.windows(2) {
            let gap = w[1].arrival_us - w[0].arrival_us;
            assert!((9_999..=10_001).contains(&gap), "gap {gap}");
        }
        // phase survives across generate() windows
        let next = s.generate(0.05);
        assert!(!next.is_empty());
        assert!(next[0].arrival_us >= 1_000_000);
    }

    #[test]
    fn bursty_alternates_silence_and_bursts() {
        let arr = Arrival::Bursty {
            rate_on_per_s: 2000.0,
            mean_on_s: 0.05,
            mean_off_s: 0.15,
        };
        let mut s = RequestStream::with_arrival(arr, 8, 5);
        let reqs = s.generate(10.0);
        // average rate ≈ 2000 * 0.25 = 500/s → ~5000 over 10 s (loose band:
        // dwell-time variance is high)
        assert!(
            (1500..9000).contains(&reqs.len()),
            "got {} requests",
            reqs.len()
        );
        for w in reqs.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
        // silence gaps must show up (OFF periods ≫ ON inter-arrival gaps)
        let max_gap = reqs
            .windows(2)
            .map(|w| w[1].arrival_us - w[0].arrival_us)
            .max()
            .unwrap();
        assert!(max_gap > 20_000, "no silent period found (max gap {max_gap} µs)");
        // bursts must keep coming for the whole window: an overshooting
        // ON-gap crossing a dwell boundary must not truncate the stream
        let last = reqs.last().unwrap().arrival_us;
        assert!(last > 8_000_000, "stream truncated at {last} µs of a 10 s window");
    }

    #[test]
    fn arrival_from_cli_names() {
        assert!(matches!(
            Arrival::from_cli("poisson", 10.0),
            Ok(Arrival::Poisson { .. })
        ));
        assert!(matches!(
            Arrival::from_cli("steady", 10.0),
            Ok(Arrival::Steady { .. })
        ));
        assert!(matches!(
            Arrival::from_cli("bursty", 10.0),
            Ok(Arrival::Bursty { .. })
        ));
        assert!(matches!(
            Arrival::from_cli("closed", 10.0),
            Ok(Arrival::Poisson { .. })
        ));
        assert!(Arrival::from_cli("nope", 10.0).is_err());
    }
}
