//! Synthetic request workloads for the serving coordinator.
//!
//! The paper's deployment scenario is frame-by-frame, low-latency edge
//! inference (Section 4: "the input will be processed frame-by-frame ...
//! to minimize word-to-transcription latency"). The generator produces a
//! Poisson arrival stream of inference requests over a model's test
//! split, which the coordinator serves.

use crate::util::rng::Rng;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Index into the model's test split.
    pub sample_idx: usize,
    /// Arrival time in microseconds from stream start.
    pub arrival_us: u64,
}

/// Poisson arrival process over `n_samples` test samples.
pub struct RequestStream {
    rng: Rng,
    rate_per_s: f64,
    n_samples: usize,
    next_id: u64,
    clock_us: f64,
}

impl RequestStream {
    pub fn new(rate_per_s: f64, n_samples: usize, seed: u64) -> RequestStream {
        assert!(rate_per_s > 0.0 && n_samples > 0);
        RequestStream {
            rng: Rng::new(seed),
            rate_per_s,
            n_samples,
            next_id: 0,
            clock_us: 0.0,
        }
    }

    /// Generate requests arriving within the next `duration_s` seconds.
    pub fn generate(&mut self, duration_s: f64) -> Vec<Request> {
        let end_us = self.clock_us + duration_s * 1e6;
        let mut out = Vec::new();
        loop {
            let gap_s = self.rng.exponential(self.rate_per_s);
            let t = self.clock_us + gap_s * 1e6;
            if t >= end_us {
                self.clock_us = end_us;
                break;
            }
            self.clock_us = t;
            out.push(Request {
                id: self.next_id,
                sample_idx: self.rng.index(self.n_samples),
                arrival_us: t as u64,
            });
            self.next_id += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_is_respected() {
        let mut s = RequestStream::new(1000.0, 16, 1);
        let reqs = s.generate(2.0);
        // ~2000 expected; Poisson 3-sigma ≈ ±134
        assert!(
            (1800..2200).contains(&reqs.len()),
            "got {} requests",
            reqs.len()
        );
    }

    #[test]
    fn arrivals_are_monotonic_and_bounded() {
        let mut s = RequestStream::new(500.0, 4, 2);
        let reqs = s.generate(1.0);
        for w in reqs.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
        assert!(reqs.iter().all(|r| r.arrival_us < 1_000_000));
        assert!(reqs.iter().all(|r| r.sample_idx < 4));
    }

    #[test]
    fn ids_unique_across_batches() {
        let mut s = RequestStream::new(300.0, 8, 3);
        let a = s.generate(0.5);
        let b = s.generate(0.5);
        let max_a = a.iter().map(|r| r.id).max().unwrap_or(0);
        assert!(b.iter().all(|r| r.id > max_a));
    }
}
