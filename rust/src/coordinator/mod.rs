//! Serving coordinator: the L3 request path.
//!
//! The paper's deployment target is frame-by-frame edge inference; the
//! coordinator provides the serving shell around the compute engine:
//!
//! * a dispatcher replays a [`crate::workload::RequestStream`] in real
//!   time (arrival-faithful), pushing requests into a condvar-backed
//!   queue (backpressure surfaces as queue depth) — or, in closed-loop
//!   mode, keeps a fixed number of requests outstanding and issues the
//!   next one as each completes;
//! * a worker pool pops **micro-batches** (up to [`ServeOpts::max_batch`]
//!   requests, lingering at most [`ServeOpts::batch_wait_us`] for the
//!   batch to fill) and executes them on one of two backends:
//!   - `Engine` — the in-process functional int8 engine with the MoR
//!     predictor via the session's compiled plan
//!     ([`Session::run_batch_into`]), which advances the whole batch
//!     layer-by-layer so im2col row tiles mix patches from several
//!     requests. Each worker checks **one reusable
//!     [`crate::plan::Workspace`] out of the session's pool for its
//!     whole lifetime** (the model, plan and policy are shared
//!     read-only), so the steady-state serve loop allocates nothing per
//!     request beyond queue bookkeeping, or
//!   - `Pjrt` — the AOT-compiled HLO artifact on the PJRT CPU client
//!     (single owner thread; PJRT handles are not `Send`);
//! * per-request latency (queueing + service) and throughput metrics,
//!   plus drop accounting: a request whose execution fails is *counted*
//!   ([`ServeReport::dropped`]) and the first error is surfaced in the
//!   report — the worker keeps serving the rest of the trace.
//!
//! No async runtime is available offline (no tokio), so the coordinator
//! uses std threads + channels; the architecture (dispatcher → queue →
//! workers → collector) is the same shape as an async reactor.
//!
//! [`serve`] is the single-model path. The multi-model generalization —
//! several registered models with replicas, per-model weighted-fair
//! queues with work stealing, deadline-aware admission control and load
//! shedding, per-tenant QoS — lives in [`tier`] ([`tier::ServingTier`]),
//! which shares this module's [`ServeReport`] accounting and the queue
//! primitives in [`queue`].

pub mod queue;
pub mod tier;

use crate::model::Artifacts;
use crate::predictor::RunOpts;
use crate::session::Session;
use crate::util::{mean, percentile_sorted};
use crate::workload::Request;
use anyhow::Result;
use queue::SharedQueue;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Which execution backend serves requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Functional int8 engine (+ optional MoR policy), multi-worker.
    Engine,
    /// AOT HLO on the PJRT CPU client, single owner thread.
    Pjrt,
}

/// Serving knobs (everything except the workload itself).
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// Worker threads (Pjrt forces 1: handles live on one thread).
    pub workers: usize,
    /// Compresses the virtual arrival clock (e.g. 0.1 replays a 10 s
    /// trace in 1 s) — useful for tests; 1.0 is real time.
    pub time_scale: f64,
    /// Requests coalesced into one [`Session::run_batch_into`] call
    /// (1 = no batching).
    pub max_batch: usize,
    /// How long a worker lingers for a partial batch to fill, in µs of
    /// real time (ignored when `max_batch` is 1).
    pub batch_wait_us: u64,
    /// Closed-loop mode: ignore arrival times and keep `concurrency`
    /// requests outstanding, issuing the next as each completes —
    /// measures service capacity directly.
    pub closed_loop: bool,
    /// Outstanding requests in closed-loop mode (0 → `workers *
    /// max_batch`).
    pub concurrency: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            workers: 4,
            time_scale: 1.0,
            max_batch: 1,
            batch_wait_us: 200,
            closed_loop: false,
            concurrency: 0,
        }
    }
}

/// One served request's record.
#[derive(Clone, Copy, Debug)]
pub struct Served {
    pub id: u64,
    /// Tenant index (into the tier's tenant table; 0 for single-tenant).
    pub tenant: usize,
    /// Model index (into the tier's model table; 0 for single-model).
    pub model: usize,
    pub queue_us: u64,
    pub service_us: u64,
    pub correct: bool,
    /// Completed within its deadline (always true when no deadline is
    /// configured) — the numerator of goodput.
    pub deadline_ok: bool,
}

/// One shed (never-executed) request: rejected at admission because the
/// projected wait exceeded its deadline, or dropped at dequeue because
/// it could no longer finish in time. Kept as a record (not a bare
/// count) so shedding can be attributed per tenant and per model.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Shed {
    pub tenant: usize,
    pub model: usize,
    /// true = expired at dequeue; false = rejected at admission.
    pub expired: bool,
}

/// Raw counters a serving driver hands to [`ServeReport::from_records`].
/// Collecting them in one struct keeps the two drivers ([`serve`] and
/// [`tier::ServingTier`]) honest about reporting the same things.
#[derive(Clone, Debug, Default)]
pub(crate) struct Tally {
    /// Completed requests only — shed/dropped requests never produce a
    /// [`Served`] record, so the latency vector below is shed-free by
    /// construction.
    pub records: Vec<Served>,
    pub shed: Vec<Shed>,
    /// Requests lost to execution errors (distinct from `shed`).
    pub dropped: usize,
    pub first_error: Option<String>,
    /// Everything the driver was asked to serve; the conservation
    /// invariant is `records.len() + dropped + shed.len() == submitted`.
    pub submitted: usize,
    pub batches: usize,
    pub max_depth: usize,
}

/// What a worker reports to the collector.
enum Event {
    Done(Served),
    /// Requests lost to an execution error (first error text attached).
    Dropped { n: usize, error: String },
}

/// Latency/goodput stats for one request class (a tenant or a model).
#[derive(Clone, Debug, Default)]
pub struct GroupStats {
    pub name: String,
    /// Requests attributed to this group (`completed + shed`; error
    /// drops are not attributed to a group).
    pub submitted: usize,
    pub completed: usize,
    pub shed: usize,
    /// Completed-within-deadline per second over the busy window.
    pub goodput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Aggregate serving report.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Name of the skip strategy the engine served with (`mor`,
    /// `binary`, ..., `none`) — makes BENCH artifacts self-describing.
    pub predictor: String,
    /// Requests handed to the driver; see [`ServeReport::conserved`].
    pub submitted: usize,
    pub completed: usize,
    /// Requests lost to worker/backend errors (0 in the happy path).
    pub dropped: usize,
    /// Requests shed by load control (never executed): admission
    /// rejections plus deadline expiries — counted separately from
    /// error `dropped`.
    pub shed: usize,
    /// Shed at admission: projected wait already exceeded the deadline.
    pub shed_admission: usize,
    /// Shed at dequeue: the request could no longer finish in time.
    pub shed_expired: usize,
    /// Wall time of the whole serve call (includes arrival-replay tail).
    pub duration_s: f64,
    /// First arrival → last completion: the window the system was
    /// actually busy; the basis for `throughput_rps`.
    pub busy_s: f64,
    pub throughput_rps: f64,
    /// Completed-within-deadline per second over the busy window — the
    /// SLO-weighted throughput (equals `throughput_rps` when no
    /// deadline is configured).
    pub goodput_rps: f64,
    pub accuracy: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_service_ms: f64,
    pub max_queue_depth: usize,
    /// Mean requests per executed micro-batch (1.0 when batching is off).
    pub batch_occupancy: f64,
    /// First execution error, if any request was dropped.
    pub first_error: Option<String>,
    /// One entry per tenant, in tenant-table order.
    pub per_tenant: Vec<GroupStats>,
    /// One entry per registered model, in registration order.
    pub per_model: Vec<GroupStats>,
}

/// Aggregate one request class. Out-of-range indices clamp to the last
/// group, mirroring [`queue::TierQueue`]'s lane clamp, so a record can
/// never silently vanish from the per-group accounting.
fn group_stats(
    names: &[String],
    records: &[Served],
    shed: &[Shed],
    busy_s: f64,
    rec_key: impl Fn(&Served) -> usize,
    shed_key: impl Fn(&Shed) -> usize,
) -> Vec<GroupStats> {
    let last = names.len().saturating_sub(1);
    names
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let mut lat = Vec::new();
            let mut completed = 0usize;
            let mut good = 0usize;
            for r in records.iter().filter(|r| rec_key(r).min(last) == i) {
                completed += 1;
                good += r.deadline_ok as usize;
                lat.push((r.queue_us + r.service_us) as f64 / 1000.0);
            }
            lat.sort_by(f64::total_cmp);
            let shed_n = shed.iter().filter(|s| shed_key(s).min(last) == i).count();
            GroupStats {
                name: name.clone(),
                submitted: completed + shed_n,
                completed,
                shed: shed_n,
                goodput_rps: if completed == 0 {
                    0.0
                } else {
                    good as f64 / busy_s.max(1e-9)
                },
                p50_ms: percentile_sorted(&lat, 50.0),
                p99_ms: percentile_sorted(&lat, 99.0),
            }
        })
        .collect()
}

impl ServeReport {
    pub(crate) fn from_records(
        predictor: String,
        tally: Tally,
        wall_s: f64,
        busy_s: f64,
        tenant_names: &[String],
        model_names: &[String],
    ) -> ServeReport {
        let Tally { records, shed, dropped, first_error, submitted, batches, max_depth } =
            tally;
        let shed_admission = shed.iter().filter(|s| !s.expired).count();
        let base = ServeReport {
            predictor,
            submitted,
            dropped,
            shed: shed.len(),
            shed_admission,
            shed_expired: shed.len() - shed_admission,
            duration_s: wall_s,
            busy_s,
            max_queue_depth: max_depth,
            per_tenant: group_stats(tenant_names, &records, &shed, busy_s, |r| r.tenant, |s| {
                s.tenant
            }),
            per_model: group_stats(model_names, &records, &shed, busy_s, |r| r.model, |s| {
                s.model
            }),
            first_error,
            ..Default::default()
        };
        if records.is_empty() {
            // explicit zero shape: with no completions every latency,
            // accuracy and rate stat is exactly 0.0 — never a NaN from
            // a 0/0 — while the shed/dropped accounting above still
            // reports what happened to the submitted requests
            return base;
        }
        // Latency samples come from *completed* requests only: a shed
        // request never ran, so it has no latency — its cost is already
        // visible in `shed` and in the goodput gap. Sort once; every
        // percentile below reads the sorted vector.
        let mut lat: Vec<f64> = records
            .iter()
            .map(|r| (r.queue_us + r.service_us) as f64 / 1000.0)
            .collect();
        lat.sort_by(f64::total_cmp);
        let svc: Vec<f64> = records.iter().map(|r| r.service_us as f64 / 1000.0).collect();
        let correct = records.iter().filter(|r| r.correct).count();
        let good = records.iter().filter(|r| r.deadline_ok).count();
        ServeReport {
            completed: records.len(),
            throughput_rps: records.len() as f64 / busy_s.max(1e-9),
            goodput_rps: good as f64 / busy_s.max(1e-9),
            accuracy: correct as f64 / records.len() as f64,
            p50_ms: percentile_sorted(&lat, 50.0),
            p95_ms: percentile_sorted(&lat, 95.0),
            p99_ms: percentile_sorted(&lat, 99.0),
            mean_service_ms: mean(&svc),
            batch_occupancy: records.len() as f64 / batches.max(1) as f64,
            ..base
        }
    }

    /// The accounting invariant every serving driver must preserve:
    /// each submitted request is counted exactly once —
    /// `completed + dropped + shed == submitted`.
    pub fn conserved(&self) -> bool {
        self.completed + self.dropped + self.shed == self.submitted
    }

    pub fn print(&self, label: &str) {
        println!(
            "[serve:{label}] pred={} | {} reqs in {:.2}s busy ({:.2}s wall) → {:.1} rps | \
             acc {:.1}% | lat p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms | svc {:.2} ms | \
             maxq {} | batch {:.2}",
            if self.predictor.is_empty() { "?" } else { &self.predictor },
            self.completed,
            self.busy_s,
            self.duration_s,
            self.throughput_rps,
            self.accuracy * 100.0,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.mean_service_ms,
            self.max_queue_depth,
            self.batch_occupancy,
        );
        if self.shed > 0 || self.goodput_rps != self.throughput_rps {
            println!(
                "[serve:{label}] submitted {} | shed {} (admission {} / expired {}) | \
                 goodput {:.1} rps",
                self.submitted,
                self.shed,
                self.shed_admission,
                self.shed_expired,
                self.goodput_rps,
            );
        }
        for (kind, groups) in [("tenant", &self.per_tenant), ("model", &self.per_model)] {
            if groups.len() < 2 {
                continue;
            }
            for g in groups {
                println!(
                    "[serve:{label}]   {kind} {:>12}: {}/{} done ({} shed) | \
                     goodput {:.1} rps | p50 {:.2} ms p99 {:.2} ms",
                    g.name, g.completed, g.submitted, g.shed, g.goodput_rps, g.p50_ms, g.p99_ms,
                );
            }
        }
        if self.dropped > 0 {
            println!(
                "[serve:{label}] DROPPED {} request(s); first error: {}",
                self.dropped,
                self.first_error.as_deref().unwrap_or("unknown")
            );
        }
    }
}

/// Serve a pre-generated request list through a prepared [`Session`]
/// (which owns the model, its prepacked weights, the skip strategy and
/// the per-forward execution options — workers share them read-only).
///
/// Open loop (default): arrival times are replayed faithfully (scaled by
/// [`ServeOpts::time_scale`]). Closed loop: arrival times are ignored and
/// [`ServeOpts::concurrency`] requests stay outstanding.
pub fn serve(
    arts: &Artifacts,
    session: &Session,
    backend: Backend,
    requests: Vec<Request>,
    artifacts_dir: &str,
    opts: ServeOpts,
) -> Result<ServeReport> {
    #[cfg(not(feature = "pjrt"))]
    {
        anyhow::ensure!(
            backend != Backend::Pjrt,
            "the Pjrt backend needs a build with `--features pjrt`"
        );
        let _ = artifacts_dir;
    }
    let predictor_name = match backend {
        // the PJRT artifact is the dense AOT graph; no skip strategy runs
        Backend::Pjrt => "none".to_string(),
        Backend::Engine => session.predictor_name().to_string(),
    };
    if requests.is_empty() {
        return Ok(ServeReport { predictor: predictor_name, ..Default::default() });
    }
    let n_req = requests.len();
    // single-model path: one model group; tenants usually collapse to
    // one "all" class unless the trace was tagged (workload::merge of
    // for_tenant streams)
    let model_names = vec![arts.meta.name.clone()];
    let tenant_names: Vec<String> = {
        let n = requests.iter().map(|r| r.tenant).max().unwrap_or(0) + 1;
        if n == 1 {
            vec!["all".to_string()]
        } else {
            (0..n).map(|i| format!("tenant{i}")).collect()
        }
    };
    let max_batch = opts.max_batch.max(1);
    let batch_wait = Duration::from_micros(opts.batch_wait_us);

    let queue = Arc::new(SharedQueue::new());
    let (event_tx, event_rx) = mpsc::channel::<Event>();
    // closed loop: the collector returns one token per finished request
    // (completed or dropped) and the dispatcher issues the next on each
    let (token_tx, token_rx) = mpsc::channel::<()>();

    // shared read-only state for Engine workers: a serve-configured
    // derivation of the session (no oracle ground truth, no traces) —
    // its compiled plan, prepared policy and workspace pool are what
    // every worker clones and shares
    let serve_sess = session.with_opts(RunOpts {
        oracle: false,
        collect_trace: false,
        threads: session.opts().threads.max(1),
        engine: session.opts().engine,
        input_sparsity: session.opts().input_sparsity,
        weight_sparsity: session.opts().weight_sparsity,
    });
    let data = Arc::new((
        arts.data.test_x.clone(),
        arts.data.test_y.clone(),
        arts.data.sample_len(),
    ));

    let t0 = Instant::now();

    // dispatcher: replay arrivals (open loop) or refill on completion
    // (closed loop)
    let disp = {
        let queue = Arc::clone(&queue);
        let time_scale = opts.time_scale;
        let closed_loop = opts.closed_loop;
        let concurrency = if opts.concurrency > 0 {
            opts.concurrency
        } else {
            opts.workers.max(1) * max_batch
        };
        std::thread::spawn(move || {
            if closed_loop {
                let mut it = requests.into_iter();
                for req in it.by_ref().take(concurrency) {
                    queue.push(req);
                }
                while let Ok(()) = token_rx.recv() {
                    match it.next() {
                        Some(req) => queue.push(req),
                        None => break,
                    }
                }
            } else {
                for req in requests {
                    let due =
                        Duration::from_micros((req.arrival_us as f64 * time_scale) as u64);
                    let now = t0.elapsed();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    queue.push(req);
                }
            }
            queue.close();
        })
    };

    let n_workers = match backend {
        Backend::Engine => opts.workers.max(1),
        Backend::Pjrt => 1, // PJRT handles live on one thread
    };
    #[cfg(feature = "pjrt")]
    let hlo_path = Artifacts::hlo_path(artifacts_dir, &arts.meta.name);
    #[cfg(feature = "pjrt")]
    let input_shape = arts.meta.input_shape;
    let batches = Arc::new(std::sync::atomic::AtomicUsize::new(0));

    let mut handles = Vec::new();
    for _ in 0..n_workers {
        let queue = Arc::clone(&queue);
        let event_tx = event_tx.clone();
        let sess = serve_sess.clone();
        let data = Arc::clone(&data);
        let batches = Arc::clone(&batches);
        #[cfg(feature = "pjrt")]
        let hlo_path = hlo_path.clone();
        handles.push(std::thread::spawn(move || {
            // PJRT backend: compile once inside the owner thread; a
            // failure here drops every request this worker would serve,
            // which the drained-queue accounting below reports
            #[cfg(feature = "pjrt")]
            let pjrt_exe = match backend {
                Backend::Pjrt => {
                    let built = crate::runtime::Runtime::cpu()
                        .and_then(|rt| rt.load_hlo(&hlo_path, input_shape));
                    match built {
                        Ok(exe) => Some(exe),
                        Err(e) => {
                            // drain everything: with a dead backend the
                            // queue would never empty. Report batch by
                            // batch — in closed-loop mode the dispatcher
                            // only issues (and eventually closes) as drop
                            // tokens flow back, so one send-at-the-end
                            // would deadlock.
                            let msg = format!("pjrt setup: {e:#}");
                            while let Some(batch) =
                                queue.next_batch(usize::MAX, Duration::ZERO)
                            {
                                event_tx
                                    .send(Event::Dropped {
                                        n: batch.len(),
                                        error: msg.clone(),
                                    })
                                    .ok();
                            }
                            return;
                        }
                    }
                }
                Backend::Engine => None,
            };
            let (x, y, sample_len) = (&data.0, &data.1, data.2);
            // one workspace + reusable batch buffers per worker lifetime:
            // everything grows to the model's (and max_batch's)
            // high-water marks on the first batches and every later
            // request reuses them
            let mut ws = sess.checkout_workspace();
            let mut results = Vec::new();
            let mut samples: Vec<&[f32]> = Vec::new();
            let mut per_req: Vec<Result<usize>> = Vec::new();
            while let Some(batch) = queue.next_batch(max_batch, batch_wait) {
                batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let svc_t = Instant::now();
                samples.clear();
                samples.extend(batch.iter().map(|(req, _)| {
                    &x[req.sample_idx * sample_len..(req.sample_idx + 1) * sample_len]
                }));
                // per-request predictions: a poisoned request drops only
                // itself, never its batch-mates or the rest of the trace
                per_req.clear();
                match backend {
                    Backend::Engine => {
                        sess.run_batch_into(&mut ws, &samples, &mut results);
                        per_req.extend(
                            results
                                .iter()
                                .map(|r| Ok(crate::predictor::argmax(&r.logits))),
                        );
                    }
                    #[cfg(feature = "pjrt")]
                    Backend::Pjrt => {
                        let exe = pjrt_exe.as_ref().expect("pjrt exe built above");
                        per_req.extend(
                            samples
                                .iter()
                                .map(|&s| exe.forward(s).map(|lg| crate::predictor::argmax(&lg))),
                        );
                    }
                    #[cfg(not(feature = "pjrt"))]
                    Backend::Pjrt => unreachable!("rejected at serve() entry"),
                };
                let service_us = svc_t.elapsed().as_micros() as u64;
                for ((req, enqueued), res) in batch.iter().zip(per_req.drain(..)) {
                    match res {
                        Ok(pred_class) => {
                            let correct = pred_class == y[req.sample_idx] as usize;
                            event_tx
                                .send(Event::Done(Served {
                                    id: req.id,
                                    tenant: req.tenant,
                                    model: 0,
                                    queue_us: svc_t.duration_since(*enqueued).as_micros()
                                        as u64,
                                    service_us,
                                    correct,
                                    // the single-model path has no
                                    // deadline: every completion counts
                                    // toward goodput
                                    deadline_ok: true,
                                }))
                                .ok();
                        }
                        Err(e) => {
                            event_tx
                                .send(Event::Dropped {
                                    n: 1,
                                    error: format!("request {}: {e:#}", req.id),
                                })
                                .ok();
                        }
                    }
                }
            }
        }));
    }
    drop(event_tx);

    // collector: aggregate events, feed closed-loop tokens back
    let mut records = Vec::with_capacity(n_req);
    let mut dropped = 0usize;
    let mut first_error: Option<String> = None;
    let mut last_done: Option<Instant> = None;
    for ev in event_rx {
        match ev {
            Event::Done(served) => {
                records.push(served);
                last_done = Some(Instant::now());
                token_tx.send(()).ok();
            }
            Event::Dropped { n, error } => {
                dropped += n;
                first_error.get_or_insert(error);
                for _ in 0..n {
                    token_tx.send(()).ok();
                }
            }
        }
    }
    drop(token_tx);
    disp.join().expect("dispatcher panicked");
    for h in handles {
        h.join().expect("worker panicked");
    }
    let wall = t0.elapsed().as_secs_f64();
    let busy = match (queue.first_arrival(), last_done) {
        (Some(a), Some(d)) => d.duration_since(a).as_secs_f64(),
        _ => 0.0,
    };
    let max_depth = queue.depth_hwm();
    Ok(ServeReport::from_records(
        predictor_name,
        Tally {
            records,
            shed: Vec::new(), // no admission control on the legacy path
            dropped,
            first_error,
            submitted: n_req,
            batches: batches.load(std::sync::atomic::Ordering::Relaxed),
            max_depth,
        },
        wall,
        busy,
        &tenant_names,
        &model_names,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-backend serving is exercised end-to-end in
    // rust/tests/serving_pipeline.rs (synthetic artifacts); the
    // queue/batcher mechanics are unit-tested in queue.rs and
    // model-checked in rust/tests/loom_models.rs. Here: report math.

    fn served(id: u64, tenant: usize, model: usize, lat_us: u64, correct: bool) -> Served {
        Served {
            id,
            tenant,
            model,
            queue_us: 0,
            service_us: lat_us,
            correct,
            deadline_ok: true,
        }
    }

    fn names(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn report_percentiles() {
        let recs: Vec<Served> =
            (0..100).map(|i| served(i, 0, 0, (i + 1) * 1000, i % 2 == 0)).collect();
        let tally = Tally {
            records: recs,
            submitted: 100,
            batches: 100,
            max_depth: 7,
            ..Default::default()
        };
        let r = ServeReport::from_records(
            "mor".into(),
            tally,
            3.0,
            2.0,
            &names(&["all"]),
            &names(&["tiny"]),
        );
        assert_eq!(r.predictor, "mor");
        assert_eq!(r.completed, 100);
        assert_eq!(r.dropped, 0);
        assert!(r.conserved());
        assert!((r.duration_s - 3.0).abs() < 1e-9);
        assert!((r.busy_s - 2.0).abs() < 1e-9);
        // throughput is measured over the busy window, not the wall
        assert!((r.throughput_rps - 50.0).abs() < 1e-9);
        // no deadline → every completion is goodput
        assert!((r.goodput_rps - 50.0).abs() < 1e-9);
        assert!((r.accuracy - 0.5).abs() < 1e-9);
        assert!(r.p50_ms > 49.0 && r.p50_ms < 52.0);
        assert!(r.p99_ms > 98.0);
        assert_eq!(r.max_queue_depth, 7);
        assert!((r.batch_occupancy - 1.0).abs() < 1e-9);
        // single-group reports mirror the top-level numbers
        assert_eq!(r.per_tenant.len(), 1);
        assert_eq!(r.per_model.len(), 1);
        assert_eq!(r.per_model[0].name, "tiny");
        assert_eq!(r.per_model[0].completed, 100);
        assert!((r.per_tenant[0].p99_ms - r.p99_ms).abs() < 1e-9);
    }

    #[test]
    fn report_counts_drops_and_surfaces_error() {
        let recs: Vec<Served> = (0..4).map(|i| served(i, 0, 0, 100, true)).collect();
        let tally = Tally {
            records: recs,
            dropped: 3,
            first_error: Some("backend exploded".into()),
            submitted: 7,
            batches: 2,
            max_depth: 2,
            ..Default::default()
        };
        let r = ServeReport::from_records(
            "none".into(),
            tally,
            1.0,
            0.5,
            &names(&["all"]),
            &names(&["tiny"]),
        );
        assert_eq!(r.completed, 4);
        assert_eq!(r.dropped, 3);
        assert!(r.conserved());
        assert_eq!(r.first_error.as_deref(), Some("backend exploded"));
        assert!((r.batch_occupancy - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_request_list_gives_empty_report() {
        let r = ServeReport::default();
        assert_eq!(r.completed, 0);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.throughput_rps, 0.0);
        assert!(r.conserved());
    }

    #[test]
    fn report_zero_completed_is_nan_free() {
        // everything shed, nothing completed: the explicit early shape
        // must produce exact zeros (not 0/0 NaNs) in every stat — and
        // the shed split must still be fully reported
        let shed: Vec<Shed> = (0..5)
            .map(|i| Shed { tenant: i % 2, model: 0, expired: i % 2 == 1 })
            .collect();
        let tally = Tally { shed, submitted: 5, ..Default::default() };
        let r = ServeReport::from_records(
            "mor".into(),
            tally,
            1.0,
            0.0,
            &names(&["a", "b"]),
            &names(&["tiny"]),
        );
        assert_eq!(r.completed, 0);
        assert_eq!(r.shed, 5);
        assert_eq!(r.shed_admission, 3);
        assert_eq!(r.shed_expired, 2);
        assert!(r.conserved());
        for v in [
            r.throughput_rps,
            r.goodput_rps,
            r.accuracy,
            r.p50_ms,
            r.p95_ms,
            r.p99_ms,
            r.mean_service_ms,
            r.batch_occupancy,
        ] {
            assert!(v == 0.0, "expected exact 0.0, got {v}");
        }
        assert_eq!(r.per_tenant[0].shed, 3);
        assert_eq!(r.per_tenant[1].shed, 2);
        assert!(r.per_tenant[0].goodput_rps == 0.0);
        assert!(r.per_tenant[0].p99_ms == 0.0);
    }

    #[test]
    fn report_groups_split_by_tenant_and_model() {
        // tenant 0 → model 0 at 10 ms, tenant 1 → model 1 at 30 ms;
        // one shed for tenant 1 / model 1; one out-of-range tenant
        // index clamps into the last group instead of vanishing
        let mut recs = Vec::new();
        for i in 0..10 {
            recs.push(served(i, 0, 0, 10_000, true));
            recs.push(served(100 + i, 1, 1, 30_000, true));
        }
        recs.push(served(999, 7, 1, 30_000, true)); // clamps to tenant "b"
        let shed = vec![Shed { tenant: 1, model: 1, expired: false }];
        let tally = Tally { records: recs, shed, submitted: 22, ..Default::default() };
        let r = ServeReport::from_records(
            "mor".into(),
            tally,
            2.0,
            2.0,
            &names(&["a", "b"]),
            &names(&["m0", "m1"]),
        );
        assert!(r.conserved());
        let (a, b) = (&r.per_tenant[0], &r.per_tenant[1]);
        assert_eq!((a.completed, a.shed), (10, 0));
        assert_eq!((b.completed, b.shed), (11, 1));
        assert_eq!(b.submitted, 12);
        assert!(a.p50_ms < 11.0 && b.p50_ms > 29.0);
        // goodput split follows the completion split over the same window
        assert!((a.goodput_rps - 5.0).abs() < 1e-9);
        assert!((b.goodput_rps - 5.5).abs() < 1e-9);
        let (m0, m1) = (&r.per_model[0], &r.per_model[1]);
        assert_eq!(m0.name, "m0");
        assert_eq!((m0.completed, m1.completed), (10, 11));
        assert_eq!(m1.shed, 1);
    }

    #[test]
    fn report_goodput_counts_only_in_deadline_completions() {
        let mut recs: Vec<Served> = (0..8).map(|i| served(i, 0, 0, 1000, true)).collect();
        for r in recs.iter_mut().skip(6) {
            r.deadline_ok = false; // finished, but past its deadline
        }
        let tally = Tally { records: recs, submitted: 8, ..Default::default() };
        let r = ServeReport::from_records(
            "mor".into(),
            tally,
            2.0,
            2.0,
            &names(&["all"]),
            &names(&["tiny"]),
        );
        assert!((r.throughput_rps - 4.0).abs() < 1e-9);
        assert!((r.goodput_rps - 3.0).abs() < 1e-9);
        assert!((r.per_tenant[0].goodput_rps - 3.0).abs() < 1e-9);
    }
}
