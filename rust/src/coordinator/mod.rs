//! Serving coordinator: the L3 request path.
//!
//! The paper's deployment target is frame-by-frame edge inference; the
//! coordinator provides the serving shell around the compute engine:
//!
//! * a dispatcher replays a [`crate::workload::RequestStream`] in real
//!   time (arrival-faithful), pushing requests into a shared queue
//!   (backpressure surfaces as queue depth);
//! * a worker pool executes requests on one of two backends:
//!   - `Engine` — the in-process functional int8 engine with the MoR
//!     predictor (multi-threaded; the model and policy are shared
//!     read-only), or
//!   - `Pjrt` — the AOT-compiled HLO artifact on the PJRT CPU client
//!     (single owner thread; PJRT handles are not `Send`);
//! * per-request latency (queueing + service) and throughput metrics.
//!
//! No async runtime is available offline (no tokio), so the coordinator
//! uses std threads + channels; the architecture (dispatcher → queue →
//! workers → collector) is the same shape as an async reactor.

use crate::model::Artifacts;
use crate::predictor::{exec, MorPolicy, RunOpts};
use crate::util::percentile;
use crate::workload::Request;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Which execution backend serves requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Functional int8 engine (+ optional MoR policy), multi-worker.
    Engine,
    /// AOT HLO on the PJRT CPU client, single owner thread.
    Pjrt,
}

/// One served request's record.
#[derive(Clone, Copy, Debug)]
pub struct Served {
    pub id: u64,
    pub queue_us: u64,
    pub service_us: u64,
    pub correct: bool,
}

/// Aggregate serving report.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub completed: usize,
    pub duration_s: f64,
    pub throughput_rps: f64,
    pub accuracy: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_service_ms: f64,
    pub max_queue_depth: usize,
}

impl ServeReport {
    fn from_records(records: &[Served], duration_s: f64, max_depth: usize) -> ServeReport {
        let lat: Vec<f64> = records
            .iter()
            .map(|r| (r.queue_us + r.service_us) as f64 / 1000.0)
            .collect();
        let svc: Vec<f64> = records.iter().map(|r| r.service_us as f64 / 1000.0).collect();
        let correct = records.iter().filter(|r| r.correct).count();
        ServeReport {
            completed: records.len(),
            duration_s,
            throughput_rps: records.len() as f64 / duration_s.max(1e-9),
            accuracy: correct as f64 / records.len().max(1) as f64,
            p50_ms: percentile(&lat, 50.0),
            p95_ms: percentile(&lat, 95.0),
            p99_ms: percentile(&lat, 99.0),
            mean_service_ms: crate::util::mean(&svc),
            max_queue_depth: max_depth,
        }
    }

    pub fn print(&self, label: &str) {
        println!(
            "[serve:{label}] {} reqs in {:.2}s → {:.1} rps | acc {:.1}% | \
             lat p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms | svc {:.2} ms | maxq {}",
            self.completed,
            self.duration_s,
            self.throughput_rps,
            self.accuracy * 100.0,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.mean_service_ms,
            self.max_queue_depth
        );
    }
}

/// Serve a pre-generated request list, replaying arrival times.
///
/// `time_scale` compresses the virtual arrival clock (e.g. 0.1 replays a
/// 10 s trace in 1 s) — useful for tests; 1.0 is real time.
///
/// `intra_threads` is the per-sample row-tile parallelism of the tiled
/// engine (see [`RunOpts::threads`]): keep it at 1 when `workers` already
/// saturates the machine (throughput serving), raise it for
/// latency-critical low-concurrency streams.
pub fn serve(
    arts: &Artifacts,
    policy: Option<MorPolicy>,
    backend: Backend,
    workers: usize,
    requests: Vec<Request>,
    artifacts_dir: &str,
    time_scale: f64,
    intra_threads: usize,
) -> Result<ServeReport> {
    #[cfg(not(feature = "pjrt"))]
    {
        anyhow::ensure!(
            backend != Backend::Pjrt,
            "the Pjrt backend needs a build with `--features pjrt`"
        );
        let _ = artifacts_dir;
    }
    if requests.is_empty() {
        return Ok(ServeReport::default());
    }
    let n_req = requests.len();

    let queue: Arc<Mutex<std::collections::VecDeque<(Request, Instant)>>> =
        Arc::new(Mutex::new(std::collections::VecDeque::new()));
    let depth_hwm = Arc::new(AtomicUsize::new(0));
    let (done_tx, done_rx) = mpsc::channel::<Served>();
    let stop = Arc::new(AtomicUsize::new(0)); // 1 = dispatcher finished

    // shared read-only state for Engine workers
    let model = Arc::new(arts.model.clone());
    let policy = Arc::new(policy);
    let data = Arc::new((
        arts.data.test_x.clone(),
        arts.data.test_y.clone(),
        arts.data.sample_len(),
    ));

    let t0 = Instant::now();

    // dispatcher: replay arrivals
    let disp = {
        let queue = Arc::clone(&queue);
        let depth_hwm = Arc::clone(&depth_hwm);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            for req in requests {
                let due = Duration::from_micros((req.arrival_us as f64 * time_scale) as u64);
                let now = t0.elapsed();
                if due > now {
                    std::thread::sleep(due - now);
                }
                let mut q = queue.lock().unwrap();
                q.push_back((req, Instant::now()));
                let d = q.len();
                drop(q);
                depth_hwm.fetch_max(d, Ordering::Relaxed);
            }
            stop.store(1, Ordering::SeqCst);
        })
    };

    let n_workers = match backend {
        Backend::Engine => workers.max(1),
        Backend::Pjrt => 1, // PJRT handles live on one thread
    };
    #[cfg(feature = "pjrt")]
    let hlo_path = Artifacts::hlo_path(artifacts_dir, &arts.meta.name);
    #[cfg(feature = "pjrt")]
    let input_shape = arts.meta.input_shape;
    let run_opts = RunOpts {
        oracle: false,
        collect_trace: false,
        threads: intra_threads.max(1),
        ..Default::default()
    };

    let mut handles = Vec::new();
    for _ in 0..n_workers {
        let queue = Arc::clone(&queue);
        let stop = Arc::clone(&stop);
        let done_tx = done_tx.clone();
        let model = Arc::clone(&model);
        let policy = Arc::clone(&policy);
        let data = Arc::clone(&data);
        #[cfg(feature = "pjrt")]
        let hlo_path = hlo_path.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            // PJRT backend: compile once inside the owner thread
            #[cfg(feature = "pjrt")]
            let pjrt_exe = match backend {
                Backend::Pjrt => {
                    let rt = crate::runtime::Runtime::cpu()?;
                    Some(rt.load_hlo(&hlo_path, input_shape)?)
                }
                Backend::Engine => None,
            };
            loop {
                let item = queue.lock().unwrap().pop_front();
                let Some((req, enqueued)) = item else {
                    if stop.load(Ordering::SeqCst) == 1 && queue.lock().unwrap().is_empty() {
                        return Ok(());
                    }
                    std::thread::sleep(Duration::from_micros(50));
                    continue;
                };
                let queue_us = enqueued.elapsed().as_micros() as u64;
                let svc_t = Instant::now();
                let (x, y, sample_len) = (&data.0, &data.1, data.2);
                let sample = &x[req.sample_idx * sample_len..(req.sample_idx + 1) * sample_len];
                #[cfg(feature = "pjrt")]
                let logits = match &pjrt_exe {
                    Some(exe) => exe.forward(sample)?,
                    None => {
                        exec::run_sample(&model, policy.as_ref().as_ref(), sample, run_opts)
                            .logits
                    }
                };
                #[cfg(not(feature = "pjrt"))]
                let logits =
                    exec::run_sample(&model, policy.as_ref().as_ref(), sample, run_opts).logits;
                let correct =
                    crate::predictor::argmax(&logits) == y[req.sample_idx] as usize;
                done_tx
                    .send(Served {
                        id: req.id,
                        queue_us,
                        service_us: svc_t.elapsed().as_micros() as u64,
                        correct,
                    })
                    .ok();
            }
        }));
    }
    drop(done_tx);

    let mut records = Vec::with_capacity(n_req);
    for served in done_rx {
        records.push(served);
    }
    disp.join().expect("dispatcher panicked");
    for h in handles {
        h.join().expect("worker panicked")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(ServeReport::from_records(
        &records,
        wall,
        depth_hwm.load(Ordering::Relaxed),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-backend serving is exercised end-to-end in rust/tests (needs
    // artifacts); here we unit-test the report math.

    #[test]
    fn report_percentiles() {
        let recs: Vec<Served> = (0..100)
            .map(|i| Served {
                id: i,
                queue_us: 0,
                service_us: (i + 1) * 1000,
                correct: i % 2 == 0,
            })
            .collect();
        let r = ServeReport::from_records(&recs, 2.0, 7);
        assert_eq!(r.completed, 100);
        assert!((r.throughput_rps - 50.0).abs() < 1e-9);
        assert!((r.accuracy - 0.5).abs() < 1e-9);
        assert!(r.p50_ms > 49.0 && r.p50_ms < 52.0);
        assert!(r.p99_ms > 98.0);
        assert_eq!(r.max_queue_depth, 7);
    }

    #[test]
    fn empty_request_list_gives_empty_report() {
        let r = ServeReport::default();
        assert_eq!(r.completed, 0);
        assert_eq!(r.throughput_rps, 0.0);
    }
}
