//! The dispatcher→worker request queues, extracted so their concurrency
//! contracts are units: a condvar-backed micro-batching MPMC queue
//! ([`SharedQueue`], the single-model coordinator's) and a per-tenant
//! weighted-fair queue with non-blocking pop/steal ([`TierQueue`], one
//! per registered model in the serving tier) plus the group-wide
//! [`Notifier`] idle workers park on between steal scans.
//!
//! Contract (what the loom models in `rust/tests/loom_models.rs` check
//! exhaustively, and the unit tests below check on real threads):
//!
//! * **No lost wakeups** — every [`SharedQueue::push`] is observed by
//!   some [`SharedQueue::next_batch`] caller; requests never stall in
//!   the queue while a worker sleeps forever. For the tier: a stealer
//!   that reads [`Notifier::epoch`] *before* scanning and then parks
//!   with [`Notifier::wait_past`] cannot miss a push or close that
//!   lands between the scan and the park.
//! * **No deadlock on close** — [`SharedQueue::close`] wakes every
//!   blocked worker; after the queue is closed *and drained*,
//!   `next_batch` returns `None` (worker shutdown), never blocks.
//!   [`TierQueue::close`] bumps the group notifier, so parked stealers
//!   re-scan and observe [`Poll::Closed`].
//! * **Exact accounting** — each pushed request is handed out exactly
//!   once across all workers (the coordinator's dropped-request
//!   arithmetic depends on this: `completed + dropped == pushed`, and
//!   the tier's `completed + dropped + shed == submitted`). Racing
//!   [`TierQueue::try_pop`] calls — a home worker and a stealer — can
//!   never hand the same request out twice.
//!
//! The synchronization types come from [`crate::util::sync`] so
//! `--cfg loom` builds swap in the model checker's instrumented
//! versions; production builds are plain `std::sync`.

use crate::util::sync::{Condvar, Mutex};
use crate::workload::Request;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Request queue shared between dispatcher and workers. The condvar
/// replaces the previous 50 µs pop-and-sleep busy-poll: workers sleep
/// until a push (or shutdown) actually happens, and the batcher's linger
/// wait is a timed wait on the same condvar.
pub struct SharedQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    q: VecDeque<(Request, Instant)>,
    /// Dispatcher finished: no more pushes will ever happen.
    closed: bool,
    depth_hwm: usize,
    first_arrival: Option<Instant>,
}

impl SharedQueue {
    pub fn new() -> SharedQueue {
        SharedQueue {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                closed: false,
                depth_hwm: 0,
                first_arrival: None,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn push(&self, req: Request) {
        let now = Instant::now();
        let mut st = self.state.lock().unwrap();
        st.q.push_back((req, now));
        st.depth_hwm = st.depth_hwm.max(st.q.len());
        st.first_arrival.get_or_insert(now);
        drop(st);
        self.cv.notify_one();
    }

    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Pop the next micro-batch: blocks for the first request, then
    /// lingers up to `batch_wait` for up to `max_batch` requests. Returns
    /// None when the queue is closed and drained (worker shutdown).
    pub fn next_batch(
        &self,
        max_batch: usize,
        batch_wait: Duration,
    ) -> Option<Vec<(Request, Instant)>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.q.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
        if max_batch > 1 && !batch_wait.is_zero() {
            let deadline = Instant::now() + batch_wait;
            while st.q.len() < max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                st = guard;
            }
        }
        let n = st.q.len().min(max_batch.max(1));
        Some(st.q.drain(..n).collect())
    }

    /// Peak queue depth observed so far (`ServeReport::max_queue_depth`).
    pub fn depth_hwm(&self) -> usize {
        self.state.lock().unwrap().depth_hwm
    }

    /// When the first request was pushed — the start of the busy window
    /// throughput is measured over.
    pub fn first_arrival(&self) -> Option<Instant> {
        self.state.lock().unwrap().first_arrival
    }
}

impl Default for SharedQueue {
    fn default() -> SharedQueue {
        SharedQueue::new()
    }
}

// ---- serving-tier queue ----------------------------------------------------

/// Group-wide wakeup channel for the tier's work-stealing workers.
///
/// An idle worker scans every model queue non-blockingly; between scans
/// it parks here instead of busy-polling. The epoch counter closes the
/// classic lost-wakeup window: read [`Notifier::epoch`] **before** the
/// scan, and [`Notifier::wait_past`] returns immediately if any push or
/// close bumped the epoch while the scan was running.
pub struct Notifier {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl Notifier {
    pub fn new() -> Notifier {
        Notifier { epoch: Mutex::new(0), cv: Condvar::new() }
    }

    /// Current epoch. Sample this before scanning the queues.
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    /// Bump the epoch and wake every parked worker (every push and
    /// every close calls this).
    pub fn notify_all(&self) {
        let mut e = self.epoch.lock().unwrap();
        *e = e.wrapping_add(1);
        drop(e);
        self.cv.notify_all();
    }

    /// Park until the epoch moves past `seen` or `timeout` elapses;
    /// returns the epoch observed on wakeup. If the epoch already moved
    /// (a push/close landed after `seen` was sampled), returns at once —
    /// the no-lost-wakeup half of the stealing contract.
    pub fn wait_past(&self, seen: u64, timeout: Duration) -> u64 {
        let mut e = self.epoch.lock().unwrap();
        while *e == seen {
            let (guard, to) = self.cv.wait_timeout(e, timeout).unwrap();
            e = guard;
            if to.timed_out() {
                break;
            }
        }
        *e
    }
}

impl Default for Notifier {
    fn default() -> Notifier {
        Notifier::new()
    }
}

/// A queued tier request: the request plus its enqueue timestamp on the
/// driver's clock — real elapsed µs in the threaded driver, virtual µs
/// in the deterministic simulator. Deadline/expiry math happens on this
/// timestamp, so the same policy code runs under both clocks.
#[derive(Clone, Debug)]
pub struct Queued {
    pub req: Request,
    pub enq_us: u64,
    /// WFQ virtual finish tag, assigned at push.
    tag: u64,
}

/// Result of a non-blocking [`TierQueue::try_pop`].
#[derive(Debug)]
pub enum Poll {
    Item(Queued),
    /// Nothing queued right now, but the queue may still receive pushes.
    Empty,
    /// Closed *and* drained: this queue will never yield again.
    Closed,
}

/// Virtual-finish-tag granularity: a weight-1 request advances a lane's
/// tag by this much, a weight-w request by `WFQ_SCALE / w`. Weights are
/// expected to be small integers (≪ 2^20).
const WFQ_SCALE: u64 = 1 << 20;

/// Per-model request queue of the serving tier: one FIFO lane per
/// tenant, dequeued in weighted-fair order (start-time fair queueing
/// with unit request cost: each lane's next virtual finish tag is
/// `max(vtime, lane.last_finish) + WFQ_SCALE/weight`; [`TierQueue::try_pop`]
/// hands out the lowest head tag, ties to the lowest lane index). With
/// every lane backlogged, tenant service rates converge to the weight
/// ratio — the 2:1 goodput contract the fairness suite asserts.
///
/// All operations are non-blocking; workers park on the shared
/// [`Notifier`] between scans, which is what makes cross-queue work
/// stealing race-free: stealing *is* `try_pop` on a foreign queue, and
/// the per-queue mutex makes hand-out exactly-once.
pub struct TierQueue {
    state: Mutex<TierState>,
    notifier: Arc<Notifier>,
}

struct TierState {
    lanes: Vec<Lane>,
    /// WFQ virtual time: the largest finish tag ever handed out.
    vtime: u64,
    len: usize,
    closed: bool,
    depth_hwm: usize,
}

struct Lane {
    q: VecDeque<Queued>,
    weight: u64,
    last_finish: u64,
}

impl TierQueue {
    /// One lane per entry of `weights` (all weights ≥ 1). Requests whose
    /// `tenant` is out of range are clamped to the last lane.
    pub fn new(weights: &[u64], notifier: Arc<Notifier>) -> TierQueue {
        assert!(!weights.is_empty(), "a TierQueue needs at least one tenant lane");
        assert!(weights.iter().all(|&w| w >= 1), "tenant weights must be >= 1");
        TierQueue {
            state: Mutex::new(TierState {
                lanes: weights
                    .iter()
                    .map(|&weight| Lane { q: VecDeque::new(), weight, last_finish: 0 })
                    .collect(),
                vtime: 0,
                len: 0,
                closed: false,
                depth_hwm: 0,
            }),
            notifier,
        }
    }

    /// Enqueue at `enq_us` on the driver's clock. Wakes parked workers
    /// through the group notifier.
    pub fn push(&self, req: Request, enq_us: u64) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(!st.closed, "push after close");
        let lane_idx = req.tenant.min(st.lanes.len() - 1);
        let vtime = st.vtime;
        let lane = &mut st.lanes[lane_idx];
        let tag = vtime.max(lane.last_finish) + WFQ_SCALE / lane.weight;
        lane.last_finish = tag;
        lane.q.push_back(Queued { req, enq_us, tag });
        st.len += 1;
        st.depth_hwm = st.depth_hwm.max(st.len);
        drop(st);
        self.notifier.notify_all();
    }

    /// Dequeue the weighted-fair next request, without blocking. Both
    /// the home worker and stealers call this; the mutex guarantees a
    /// request is handed out exactly once. A closed queue keeps
    /// yielding until drained, then reports [`Poll::Closed`].
    pub fn try_pop(&self) -> Poll {
        let mut st = self.state.lock().unwrap();
        if st.len == 0 {
            return if st.closed { Poll::Closed } else { Poll::Empty };
        }
        let lane = (0..st.lanes.len())
            .filter(|&i| !st.lanes[i].q.is_empty())
            .min_by_key(|&i| st.lanes[i].q.front().expect("non-empty").tag)
            .expect("len > 0 implies a non-empty lane");
        let item = st.lanes[lane].q.pop_front().expect("chosen lane non-empty");
        st.vtime = st.vtime.max(item.tag);
        st.len -= 1;
        Poll::Item(item)
    }

    /// No more pushes will ever happen; parked workers are woken so
    /// they can observe the drain-then-[`Poll::Closed`] state.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.notifier.notify_all();
    }

    /// Queued requests right now (the steal scan's size signal).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// Queued requests in one tenant's lane (admission control's depth
    /// input: a tenant's projected wait depends on its own backlog and
    /// its weighted share, not on other tenants' backlogs). Out-of-range
    /// tenants clamp to the last lane, mirroring [`TierQueue::push`].
    pub fn lane_len(&self, tenant: usize) -> usize {
        let st = self.state.lock().unwrap();
        st.lanes[tenant.min(st.lanes.len() - 1)].q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Peak depth observed (`ServeReport::max_queue_depth` per model).
    pub fn depth_hwm(&self) -> usize {
        self.state.lock().unwrap().depth_hwm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request { id, sample_idx: 0, arrival_us: 0, tenant: 0 }
    }

    fn treq(id: u64, tenant: usize) -> Request {
        Request { id, sample_idx: 0, arrival_us: 0, tenant }
    }

    #[test]
    fn batcher_coalesces_and_drains_on_close() {
        let q = SharedQueue::new();
        for i in 0..5 {
            q.push(req(i));
        }
        let b = q.next_batch(4, Duration::ZERO).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].0.id, 0);
        q.close();
        // remainder drains even after close
        let b = q.next_batch(4, Duration::from_micros(500)).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].0.id, 4);
        // then shutdown
        assert!(q.next_batch(4, Duration::ZERO).is_none());
    }

    #[test]
    fn batcher_lingers_for_late_arrivals() {
        let q = Arc::new(SharedQueue::new());
        q.push(req(0));
        let pusher = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(2));
                q.push(req(1));
                q.close();
            })
        };
        // linger long enough for the second request to join the batch
        let b = q.next_batch(2, Duration::from_millis(200)).unwrap();
        pusher.join().unwrap();
        assert_eq!(b.len(), 2, "linger should have picked up the late request");
    }

    #[test]
    fn blocked_worker_wakes_on_close() {
        let q = Arc::new(SharedQueue::new());
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.next_batch(8, Duration::from_millis(50)))
        };
        std::thread::sleep(Duration::from_millis(2));
        q.close();
        assert!(waiter.join().unwrap().is_none());
    }

    #[test]
    fn depth_and_arrival_accessors() {
        let q = SharedQueue::new();
        assert_eq!(q.depth_hwm(), 0);
        assert!(q.first_arrival().is_none());
        for i in 0..3 {
            q.push(req(i));
        }
        assert_eq!(q.depth_hwm(), 3);
        assert!(q.first_arrival().is_some());
        let _ = q.next_batch(3, Duration::ZERO);
        // the high-water mark is a peak, not the current depth
        assert_eq!(q.depth_hwm(), 3);
    }

    // ---- TierQueue -------------------------------------------------------

    fn pop_id(q: &TierQueue) -> u64 {
        match q.try_pop() {
            Poll::Item(item) => item.req.id,
            other => panic!("expected an item, got {other:?}"),
        }
    }

    #[test]
    fn tier_queue_wfq_serves_weights_2_to_1() {
        let q = TierQueue::new(&[2, 1], Arc::new(Notifier::new()));
        // tenant 0 requests have even ids, tenant 1 odd ids
        for i in 0..6 {
            q.push(treq(2 * i, 0), 0);
            q.push(treq(2 * i + 1, 1), 0);
        }
        // weight 2:1 → the service pattern is A A B repeating
        let tenants: Vec<u64> = (0..9).map(|_| pop_id(&q) % 2).collect();
        assert_eq!(tenants, vec![0, 0, 1, 0, 0, 1, 0, 0, 1], "not a 2:1 pattern");
        // within a lane, FIFO order holds
        let q = TierQueue::new(&[1], Arc::new(Notifier::new()));
        for i in 0..4 {
            q.push(treq(i, 0), 0);
        }
        let ids: Vec<u64> = (0..4).map(|_| pop_id(&q)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn tier_queue_idle_lane_does_not_starve_the_other() {
        // only tenant 1 (weight 1 of a 3:1 split) is active: it gets
        // every slot — WFQ shares capacity, it doesn't reserve it
        let q = TierQueue::new(&[3, 1], Arc::new(Notifier::new()));
        for i in 0..3 {
            q.push(treq(i, 1), 0);
        }
        let ids: Vec<u64> = (0..3).map(|_| pop_id(&q)).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn tier_queue_out_of_range_tenant_clamps_to_last_lane() {
        let q = TierQueue::new(&[1, 1], Arc::new(Notifier::new()));
        q.push(treq(0, 7), 0); // no lane 7: lands in lane 1
        q.push(treq(1, 1), 0);
        assert_eq!(q.len(), 2);
        assert_eq!(q.lane_len(0), 0);
        assert_eq!(q.lane_len(1), 2);
        assert_eq!(q.lane_len(9), 2); // lane_len clamps like push
        assert_eq!(pop_id(&q), 0);
        assert_eq!(pop_id(&q), 1);
    }

    #[test]
    fn tier_queue_drains_after_close_then_reports_closed() {
        let q = TierQueue::new(&[1], Arc::new(Notifier::new()));
        q.push(treq(0, 0), 10);
        q.close();
        match q.try_pop() {
            Poll::Item(item) => {
                assert_eq!(item.req.id, 0);
                assert_eq!(item.enq_us, 10);
            }
            other => panic!("closed queue must drain first, got {other:?}"),
        }
        assert!(matches!(q.try_pop(), Poll::Closed));
        // empty-but-open reports Empty, not Closed
        let open = TierQueue::new(&[1], Arc::new(Notifier::new()));
        assert!(matches!(open.try_pop(), Poll::Empty));
    }

    #[test]
    fn tier_queue_depth_hwm_is_a_peak() {
        let q = TierQueue::new(&[1, 1], Arc::new(Notifier::new()));
        assert_eq!(q.depth_hwm(), 0);
        for i in 0..5 {
            q.push(treq(i, (i % 2) as usize), 0);
        }
        assert_eq!(q.depth_hwm(), 5);
        for _ in 0..5 {
            pop_id(&q);
        }
        assert!(q.is_empty());
        assert_eq!(q.depth_hwm(), 5);
    }

    #[test]
    fn notifier_epoch_advances_on_notify_and_unparks() {
        let n = Arc::new(Notifier::new());
        let e0 = n.epoch();
        n.notify_all();
        assert_ne!(n.epoch(), e0);
        // a stale `seen` returns immediately even with a long timeout
        let t = std::time::Instant::now();
        n.wait_past(e0, Duration::from_secs(10));
        assert!(t.elapsed() < Duration::from_secs(1), "missed-wakeup stall");
        // cross-thread: a parked waiter is woken by a push through the
        // queue (push → notify_all)
        let q = Arc::new(TierQueue::new(&[1], Arc::clone(&n)));
        let waiter = {
            let (n, q) = (Arc::clone(&n), Arc::clone(&q));
            std::thread::spawn(move || {
                loop {
                    let seen = n.epoch();
                    match q.try_pop() {
                        Poll::Item(item) => return item.req.id,
                        Poll::Closed => panic!("queue closed unexpectedly"),
                        Poll::Empty => {
                            n.wait_past(seen, Duration::from_secs(10));
                        }
                    }
                }
            })
        };
        std::thread::sleep(Duration::from_millis(2));
        q.push(treq(42, 0), 0);
        assert_eq!(waiter.join().unwrap(), 42);
    }
}
