//! The dispatcher→worker request queue, extracted so its concurrency
//! contract is a unit: a condvar-backed micro-batching MPMC queue.
//!
//! Contract (what the loom models in `rust/tests/loom_models.rs` check
//! exhaustively, and the unit tests below check on real threads):
//!
//! * **No lost wakeups** — every [`SharedQueue::push`] is observed by
//!   some [`SharedQueue::next_batch`] caller; requests never stall in
//!   the queue while a worker sleeps forever.
//! * **No deadlock on close** — [`SharedQueue::close`] wakes every
//!   blocked worker; after the queue is closed *and drained*,
//!   `next_batch` returns `None` (worker shutdown), never blocks.
//! * **Exact accounting** — each pushed request is handed out exactly
//!   once across all workers (the coordinator's dropped-request
//!   arithmetic depends on this: `completed + dropped == pushed`).
//!
//! The synchronization types come from [`crate::util::sync`] so
//! `--cfg loom` builds swap in the model checker's instrumented
//! versions; production builds are plain `std::sync`.

use crate::util::sync::{Condvar, Mutex};
use crate::workload::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Request queue shared between dispatcher and workers. The condvar
/// replaces the previous 50 µs pop-and-sleep busy-poll: workers sleep
/// until a push (or shutdown) actually happens, and the batcher's linger
/// wait is a timed wait on the same condvar.
pub struct SharedQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    q: VecDeque<(Request, Instant)>,
    /// Dispatcher finished: no more pushes will ever happen.
    closed: bool,
    depth_hwm: usize,
    first_arrival: Option<Instant>,
}

impl SharedQueue {
    pub fn new() -> SharedQueue {
        SharedQueue {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                closed: false,
                depth_hwm: 0,
                first_arrival: None,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn push(&self, req: Request) {
        let now = Instant::now();
        let mut st = self.state.lock().unwrap();
        st.q.push_back((req, now));
        st.depth_hwm = st.depth_hwm.max(st.q.len());
        st.first_arrival.get_or_insert(now);
        drop(st);
        self.cv.notify_one();
    }

    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Pop the next micro-batch: blocks for the first request, then
    /// lingers up to `batch_wait` for up to `max_batch` requests. Returns
    /// None when the queue is closed and drained (worker shutdown).
    pub fn next_batch(
        &self,
        max_batch: usize,
        batch_wait: Duration,
    ) -> Option<Vec<(Request, Instant)>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.q.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
        if max_batch > 1 && !batch_wait.is_zero() {
            let deadline = Instant::now() + batch_wait;
            while st.q.len() < max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                st = guard;
            }
        }
        let n = st.q.len().min(max_batch.max(1));
        Some(st.q.drain(..n).collect())
    }

    /// Peak queue depth observed so far (`ServeReport::max_queue_depth`).
    pub fn depth_hwm(&self) -> usize {
        self.state.lock().unwrap().depth_hwm
    }

    /// When the first request was pushed — the start of the busy window
    /// throughput is measured over.
    pub fn first_arrival(&self) -> Option<Instant> {
        self.state.lock().unwrap().first_arrival
    }
}

impl Default for SharedQueue {
    fn default() -> SharedQueue {
        SharedQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request { id, sample_idx: 0, arrival_us: 0 }
    }

    #[test]
    fn batcher_coalesces_and_drains_on_close() {
        let q = SharedQueue::new();
        for i in 0..5 {
            q.push(req(i));
        }
        let b = q.next_batch(4, Duration::ZERO).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].0.id, 0);
        q.close();
        // remainder drains even after close
        let b = q.next_batch(4, Duration::from_micros(500)).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].0.id, 4);
        // then shutdown
        assert!(q.next_batch(4, Duration::ZERO).is_none());
    }

    #[test]
    fn batcher_lingers_for_late_arrivals() {
        let q = Arc::new(SharedQueue::new());
        q.push(req(0));
        let pusher = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(2));
                q.push(req(1));
                q.close();
            })
        };
        // linger long enough for the second request to join the batch
        let b = q.next_batch(2, Duration::from_millis(200)).unwrap();
        pusher.join().unwrap();
        assert_eq!(b.len(), 2, "linger should have picked up the late request");
    }

    #[test]
    fn blocked_worker_wakes_on_close() {
        let q = Arc::new(SharedQueue::new());
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.next_batch(8, Duration::from_millis(50)))
        };
        std::thread::sleep(Duration::from_millis(2));
        q.close();
        assert!(waiter.join().unwrap().is_none());
    }

    #[test]
    fn depth_and_arrival_accessors() {
        let q = SharedQueue::new();
        assert_eq!(q.depth_hwm(), 0);
        assert!(q.first_arrival().is_none());
        for i in 0..3 {
            q.push(req(i));
        }
        assert_eq!(q.depth_hwm(), 3);
        assert!(q.first_arrival().is_some());
        let _ = q.next_batch(3, Duration::ZERO);
        // the high-water mark is a peak, not the current depth
        assert_eq!(q.depth_hwm(), 3);
    }
}
