//! The sharded serving tier: many models, replicas, tenants — one
//! process.
//!
//! [`super::serve`] drives one queue + N workers over one [`Session`].
//! A [`ServingTier`] scales that shape out:
//!
//! * **models** — each registered model owns its own serve-configured
//!   [`Session`] (its compiled plan + workspace pool), a replica count,
//!   and a per-model [`TierQueue`];
//! * **tenants** — every queue is laned per tenant and dequeued in
//!   weighted-fair order (see [`TierQueue`]), so a 2:1 weight split
//!   yields a 2:1 service split whenever both lanes are backlogged;
//! * **work stealing** — an idle replica first drains its home queue,
//!   then steals single requests from the deepest foreign queue, and
//!   only then parks on the tier's shared [`Notifier`];
//! * **admission control + load shedding** — with a deadline set, an
//!   arrival whose projected wait already exceeds the deadline is
//!   rejected up front ([`admit`]), and a dequeued request that can no
//!   longer finish in time is dropped instead of executed
//!   ([`expired`]). Both are *shed* (never-executed) requests, counted
//!   separately from error drops in [`ServeReport::shed`].
//!
//! Two drivers share all of that policy code:
//!
//! * [`ServingTier::serve`] — real threads, scoped: a dispatcher
//!   replays the merged arrival timeline, per-replica workers pull
//!   micro-batches and steal, a collector aggregates. Timing comes
//!   from the wall clock, so its assertions are smoke-level.
//! * [`ServingTier::simulate`] — a single-threaded discrete-event
//!   simulator on a **virtual clock**: arrivals and completions are
//!   processed in deterministic timestamp order and service times are
//!   supplied by the caller ([`VirtualService`]). Same queues, same
//!   admission, same expiry, same report — but bit-reproducible, which
//!   is what lets `rust/tests/serving_pipeline.rs` assert overload
//!   behavior (shedding engages, accepted p99 bounded, 2:1 goodput)
//!   instead of eyeballing it.
//!
//! The conservation invariant both drivers maintain is
//! [`ServeReport::conserved`]: `completed + dropped + shed ==
//! submitted` — on the tier's Engine-only path `dropped` is always 0
//! ([`Session::run_batch_into`] is infallible), so every request is
//! either served or accounted shed.

use super::queue::{Notifier, Poll, Queued, TierQueue};
use super::{ServeReport, Served, Shed, Tally};
use crate::model::Artifacts;
use crate::plan::Workspace;
use crate::predictor::{argmax, RunOpts, RunResult};
use crate::session::Session;
use crate::workload::Request;
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Tier-wide serving knobs.
#[derive(Clone, Copy, Debug)]
pub struct TierOpts {
    /// Per-request deadline on the driver clock, µs (0 = no deadline:
    /// admission and expiry are both disabled).
    pub deadline_us: u64,
    /// Reject-on-admission when the projected wait exceeds the
    /// deadline. Off leaves expiry-at-dequeue as the only shedding
    /// mechanism (useful for exercising it in isolation).
    pub admission: bool,
    /// Idle replicas steal from foreign model queues.
    pub steal: bool,
    /// Requests coalesced per home-queue execution (stolen requests
    /// always execute singly). The virtual simulator serves requests
    /// one per replica regardless — micro-batching is a real-driver
    /// throughput optimization, not a policy.
    pub max_batch: usize,
    /// Compresses the arrival clock in the threaded driver (ignored by
    /// the simulator, whose clock is already virtual).
    pub time_scale: f64,
}

impl Default for TierOpts {
    fn default() -> TierOpts {
        TierOpts {
            deadline_us: 0,
            admission: true,
            steal: true,
            max_batch: 1,
            time_scale: 1.0,
        }
    }
}

/// Deadline-aware admission: admit iff the arrival's projected
/// completion fits the deadline. Under weighted-fair service a
/// backlogged lane with weight `w` drains at `replicas * w / w_sum`
/// requests per `svc_us`, so the arrival's projected wait is its lane
/// depth over that rate; `2 * svc_us` adds its own service plus one
/// service of margin for residual in-flight work. Conservative when
/// other lanes are idle (the lane only drains faster than projected).
/// `deadline_us == 0` (no deadline) and `svc_us == 0` (no estimate
/// yet) admit everything.
pub(crate) fn admit(
    lane_depth: usize,
    svc_us: u64,
    replicas: usize,
    w: u64,
    w_sum: u64,
    deadline_us: u64,
) -> bool {
    if deadline_us == 0 || svc_us == 0 {
        return true;
    }
    let wait = lane_depth as u64 * svc_us * w_sum / (w * replicas.max(1) as u64) + 2 * svc_us;
    wait <= deadline_us
}

/// Expiry at dequeue: even immediate service cannot finish the request
/// inside its deadline, so executing it would waste a replica on a
/// guaranteed SLO miss. This is also what makes "every completed
/// request met its deadline" a theorem under the virtual clock (exact
/// `svc_us`), not a tuning outcome.
pub(crate) fn expired(now_us: u64, enq_us: u64, svc_us: u64, deadline_us: u64) -> bool {
    deadline_us != 0 && now_us + svc_us > enq_us + deadline_us
}

struct Tenant {
    name: String,
    weight: u64,
}

/// One registered model: its serve-configured session plus the test
/// split its requests index into.
struct TierModel {
    name: String,
    sess: Session,
    x: Vec<f32>,
    y: Vec<u16>,
    sample_len: usize,
    replicas: usize,
    /// EWMA per-request service time, µs — the threaded driver's
    /// admission/expiry input (the simulator uses exact virtual
    /// times). 0 until the first completion, which admits everything.
    svc_est_us: AtomicU64,
}

enum TierEvent {
    Done(Served),
    Shed(Shed),
}

/// Multi-model, multi-tenant serving tier. Build with
/// [`ServingTier::builder`]; drive with [`ServingTier::serve`] (real
/// threads) or [`ServingTier::simulate`] (deterministic virtual clock).
pub struct ServingTier {
    tenants: Vec<Tenant>,
    models: Vec<TierModel>,
    opts: TierOpts,
    notifier: Arc<Notifier>,
}

/// Caller-supplied service model for [`ServingTier::simulate`].
pub struct VirtualService {
    /// Per-model per-request service time on the virtual clock, µs
    /// (index-aligned with model registration order; all > 0).
    pub svc_us: Vec<u64>,
    /// Also run real inference for each completed request so the
    /// report's `accuracy` is meaningful; timing stays virtual. Keep
    /// off for large synthetic overload traces.
    pub execute: bool,
}

/// Builder for [`ServingTier`].
pub struct TierBuilder {
    tenants: Vec<Tenant>,
    models: Vec<TierModel>,
    opts: TierOpts,
}

impl ServingTier {
    pub fn builder() -> TierBuilder {
        TierBuilder { tenants: Vec::new(), models: Vec::new(), opts: TierOpts::default() }
    }

    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.iter().map(|t| t.name.clone()).collect()
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }

    fn weights(&self) -> Vec<u64> {
        self.tenants.iter().map(|t| t.weight).collect()
    }

    /// Serve one pre-generated trace per registered model
    /// (index-aligned) on real threads: a dispatcher replays the merged
    /// arrival timeline with admission control, `Σ replicas` workers
    /// pull home micro-batches / steal foreign singles / park on the
    /// tier notifier, and a collector aggregates the report.
    pub fn serve(&self, traces: Vec<Vec<Request>>) -> Result<ServeReport> {
        anyhow::ensure!(
            traces.len() == self.models.len(),
            "got {} traces for {} models (one per registered model, in order)",
            traces.len(),
            self.models.len()
        );
        let submitted: usize = traces.iter().map(|t| t.len()).sum();
        let predictor = self.models[0].sess.predictor_name().to_string();
        let tenant_names = self.tenant_names();
        let model_names = self.model_names();
        if submitted == 0 {
            return Ok(ServeReport { predictor, ..Default::default() });
        }
        let weights = self.weights();
        let w_sum: u64 = weights.iter().sum();
        let deadline = self.opts.deadline_us;

        // one merged dispatch timeline across models, arrival-ordered
        let mut merged: Vec<(usize, Request)> = Vec::with_capacity(submitted);
        for (m, trace) in traces.into_iter().enumerate() {
            merged.extend(trace.into_iter().map(|r| (m, r)));
        }
        merged.sort_by_key(|&(m, ref r)| (r.arrival_us, m, r.id));

        let queues: Vec<TierQueue> = self
            .models
            .iter()
            .map(|_| TierQueue::new(&weights, Arc::clone(&self.notifier)))
            .collect();
        let batches = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<TierEvent>();
        let t0 = Instant::now();

        let mut tally = Tally { submitted, ..Default::default() };
        let mut last_done: Option<Instant> = None;
        std::thread::scope(|s| {
            let queues = &queues;
            let weights = &weights;
            let batches = &batches;
            // dispatcher: replay arrivals, shedding at admission
            let disp_tx = tx.clone();
            let time_scale = self.opts.time_scale;
            s.spawn(move || {
                for (m, req) in merged {
                    let due =
                        Duration::from_micros((req.arrival_us as f64 * time_scale) as u64);
                    let now = t0.elapsed();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    let lane = req.tenant.min(weights.len() - 1);
                    let svc = self.models[m].svc_est_us.load(Ordering::Relaxed);
                    let ok = !self.opts.admission
                        || admit(
                            queues[m].lane_len(lane),
                            svc,
                            self.models[m].replicas,
                            weights[lane],
                            w_sum,
                            deadline,
                        );
                    if ok {
                        queues[m].push(req, t0.elapsed().as_micros() as u64);
                    } else {
                        disp_tx
                            .send(TierEvent::Shed(Shed {
                                tenant: req.tenant,
                                model: m,
                                expired: false,
                            }))
                            .ok();
                    }
                }
                for q in queues.iter() {
                    q.close();
                }
            });
            for (home, model) in self.models.iter().enumerate() {
                for _ in 0..model.replicas {
                    let tx = tx.clone();
                    s.spawn(move || self.run_worker(home, queues, &tx, t0, batches));
                }
            }
            drop(tx);
            // collector (this thread): aggregate until every sender hung up
            for ev in rx {
                match ev {
                    TierEvent::Done(rec) => {
                        tally.records.push(rec);
                        last_done = Some(Instant::now());
                    }
                    TierEvent::Shed(shd) => tally.shed.push(shd),
                }
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        // busy window: serve start → last completion (the threaded
        // driver's arrival lead-in is part of the window; precise
        // windows come from the simulator)
        let busy = last_done.map(|d| d.duration_since(t0).as_secs_f64()).unwrap_or(0.0);
        tally.batches = batches.load(Ordering::Relaxed);
        tally.max_depth = queues.iter().map(|q| q.depth_hwm()).max().unwrap_or(0);
        Ok(ServeReport::from_records(predictor, tally, wall, busy, &tenant_names, &model_names))
    }

    /// One replica's loop: drain the home queue in micro-batches, then
    /// steal a single request from the deepest foreign queue, then park
    /// on the tier notifier (epoch sampled *before* the scan, so a push
    /// landing mid-scan is never missed). Exits when the home queue —
    /// and, with stealing on, every queue — is closed and drained.
    fn run_worker(
        &self,
        home: usize,
        queues: &[TierQueue],
        tx: &mpsc::Sender<TierEvent>,
        t0: Instant,
        batches: &AtomicUsize,
    ) {
        let max_batch = self.opts.max_batch.max(1);
        let mut ws = self.models[home].sess.checkout_workspace();
        let mut results: Vec<RunResult> = Vec::new();
        let mut samples: Vec<&[f32]> = Vec::new();
        let mut batch: Vec<Queued> = Vec::new();
        loop {
            let seen = self.notifier.epoch();
            batch.clear();
            let mut home_closed = false;
            while batch.len() < max_batch {
                match queues[home].try_pop() {
                    Poll::Item(it) => {
                        if let Some(it) = self.vet(home, it, tx, t0) {
                            batch.push(it);
                        }
                    }
                    Poll::Empty => break,
                    Poll::Closed => {
                        home_closed = true;
                        break;
                    }
                }
            }
            if !batch.is_empty() {
                self.execute(home, &batch, &mut ws, &mut samples, &mut results, tx, t0, batches);
                continue;
            }
            let mut saw_open_foreign = false;
            if self.opts.steal && queues.len() > 1 {
                let mut order: Vec<usize> =
                    (0..queues.len()).filter(|&i| i != home).collect();
                order.sort_by_key(|&i| (Reverse(queues[i].len()), i));
                let mut stole = false;
                for &f in &order {
                    match queues[f].try_pop() {
                        Poll::Item(it) => {
                            if let Some(it) = self.vet(f, it, tx, t0) {
                                // a stolen request runs the *owning*
                                // model: borrow a workspace from its pool
                                let mut fws = self.models[f].sess.checkout_workspace();
                                let one = [it];
                                self.execute(
                                    f, &one, &mut fws, &mut samples, &mut results, tx, t0,
                                    batches,
                                );
                            }
                            stole = true;
                            break;
                        }
                        Poll::Empty => saw_open_foreign = true,
                        Poll::Closed => {}
                    }
                }
                if stole {
                    continue;
                }
            }
            if home_closed && !(self.opts.steal && saw_open_foreign) {
                return;
            }
            self.notifier.wait_past(seen, Duration::from_millis(1));
        }
    }

    /// Expiry-at-dequeue on the threaded driver's clock: shed (and
    /// report) a request that can no longer finish inside its deadline.
    fn vet(
        &self,
        m: usize,
        it: Queued,
        tx: &mpsc::Sender<TierEvent>,
        t0: Instant,
    ) -> Option<Queued> {
        let svc = self.models[m].svc_est_us.load(Ordering::Relaxed);
        let now = t0.elapsed().as_micros() as u64;
        if expired(now, it.enq_us, svc, self.opts.deadline_us) {
            tx.send(TierEvent::Shed(Shed { tenant: it.req.tenant, model: m, expired: true }))
                .ok();
            None
        } else {
            Some(it)
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn execute(
        &self,
        m: usize,
        batch: &[Queued],
        ws: &mut Workspace,
        samples: &mut Vec<&[f32]>,
        results: &mut Vec<RunResult>,
        tx: &mpsc::Sender<TierEvent>,
        t0: Instant,
        batches: &AtomicUsize,
    ) {
        let model = &self.models[m];
        batches.fetch_add(1, Ordering::Relaxed);
        let start_us = t0.elapsed().as_micros() as u64;
        let svc_t = Instant::now();
        samples.clear();
        samples.extend(batch.iter().map(|q| {
            let s = q.req.sample_idx * model.sample_len;
            &model.x[s..s + model.sample_len]
        }));
        model.sess.run_batch_into(ws, samples, results);
        let service_us = (svc_t.elapsed().as_micros() as u64).max(1);
        // EWMA per-request estimate feeds admission/expiry; the racy
        // read-modify-write can lose an update under contention, which
        // only smooths the estimate further
        let per_req = (service_us / batch.len() as u64).max(1);
        let old = model.svc_est_us.load(Ordering::Relaxed);
        let est = if old == 0 { per_req } else { (7 * old + per_req) / 8 };
        model.svc_est_us.store(est, Ordering::Relaxed);
        let finish_us = start_us + service_us;
        let deadline = self.opts.deadline_us;
        for (q, r) in batch.iter().zip(results.iter()) {
            tx.send(TierEvent::Done(Served {
                id: q.req.id,
                tenant: q.req.tenant,
                model: m,
                queue_us: start_us.saturating_sub(q.enq_us),
                service_us,
                correct: argmax(&r.logits) == model.y[q.req.sample_idx] as usize,
                deadline_ok: deadline == 0
                    || finish_us.saturating_sub(q.enq_us) <= deadline,
            }))
            .ok();
        }
    }

    /// Deterministic discrete-event run of the same serving policy on a
    /// virtual clock: one trace per model (index-aligned), service
    /// times from `vs`. Events are processed in strict timestamp order
    /// (completions before arrivals at equal times, so freed replicas
    /// are visible to admission), each idle replica serves one request
    /// at a time, and stealing targets the deepest foreign queue (ties
    /// to the lowest model index). Same seed + same knobs ⇒ identical
    /// report, independent of wall-clock and thread scheduling.
    pub fn simulate(&self, traces: Vec<Vec<Request>>, vs: &VirtualService) -> Result<ServeReport> {
        anyhow::ensure!(
            traces.len() == self.models.len(),
            "got {} traces for {} models (one per registered model, in order)",
            traces.len(),
            self.models.len()
        );
        anyhow::ensure!(
            vs.svc_us.len() == self.models.len() && vs.svc_us.iter().all(|&s| s > 0),
            "VirtualService needs one positive svc_us per model"
        );
        let submitted: usize = traces.iter().map(|t| t.len()).sum();
        let predictor = self.models[0].sess.predictor_name().to_string();
        let tenant_names = self.tenant_names();
        let model_names = self.model_names();
        if submitted == 0 {
            return Ok(ServeReport { predictor, ..Default::default() });
        }
        let weights = self.weights();
        let w_sum: u64 = weights.iter().sum();
        let deadline = self.opts.deadline_us;
        let n_models = self.models.len();

        let mut arrivals: Vec<(u64, usize, Request)> = Vec::with_capacity(submitted);
        for (m, trace) in traces.into_iter().enumerate() {
            arrivals.extend(trace.into_iter().map(|r| (r.arrival_us, m, r)));
        }
        arrivals.sort_by_key(|&(t, m, ref r)| (t, m, r.id));

        /// A replica busy until `finish_us` serving `item` of model
        /// `owner` (popped at `start_us`, freeing replica pool `home`).
        struct Completion {
            finish_us: u64,
            seq: u64,
            owner: usize,
            home: usize,
            start_us: u64,
            item: Queued,
        }
        impl PartialEq for Completion {
            fn eq(&self, o: &Self) -> bool {
                (self.finish_us, self.seq) == (o.finish_us, o.seq)
            }
        }
        impl Eq for Completion {}
        impl PartialOrd for Completion {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Completion {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                (self.finish_us, self.seq).cmp(&(o.finish_us, o.seq))
            }
        }

        let queues: Vec<TierQueue> = self
            .models
            .iter()
            .map(|_| TierQueue::new(&weights, Arc::clone(&self.notifier)))
            .collect();
        let mut idle: Vec<usize> = self.models.iter().map(|m| m.replicas).collect();
        let mut heap: BinaryHeap<Reverse<Completion>> = BinaryHeap::new();
        let mut tally = Tally { submitted, ..Default::default() };
        let mut first_arrival: Option<u64> = None;
        let mut last_finish = 0u64;
        let mut last_arrival = 0u64;
        let mut seq = 0u64;
        let mut ai = 0usize;

        // pop the next runnable request for an idle replica of `m`,
        // shedding expired items along the way: home queue first, then
        // (with stealing) the deepest foreign queue
        let take = |m: usize,
                    now: u64,
                    queues: &[TierQueue],
                    shed: &mut Vec<Shed>|
         -> Option<(usize, Queued)> {
            let from = |q: usize, shed: &mut Vec<Shed>| -> Option<Queued> {
                while let Poll::Item(it) = queues[q].try_pop() {
                    if expired(now, it.enq_us, vs.svc_us[q], deadline) {
                        shed.push(Shed { tenant: it.req.tenant, model: q, expired: true });
                        continue;
                    }
                    return Some(it);
                }
                None
            };
            if let Some(it) = from(m, shed) {
                return Some((m, it));
            }
            if self.opts.steal {
                while let Some(f) = (0..n_models)
                    .filter(|&i| i != m && !queues[i].is_empty())
                    .min_by_key(|&i| (Reverse(queues[i].len()), i))
                {
                    if let Some(it) = from(f, shed) {
                        return Some((f, it));
                    }
                }
            }
            None
        };

        loop {
            let next_arrival = arrivals.get(ai).map(|a| a.0);
            let next_finish = heap.peek().map(|Reverse(c)| c.finish_us);
            let now = match (next_finish, next_arrival) {
                (None, None) => break,
                // completions first at equal timestamps: the freed
                // replica and shorter queue are visible to admission
                (Some(f), Some(a)) if f <= a => f,
                (Some(f), None) => f,
                (_, Some(a)) => a,
            };
            if next_finish == Some(now) {
                let Reverse(c) = heap.pop().expect("peeked above");
                last_finish = now;
                let model = &self.models[c.owner];
                let correct = if vs.execute {
                    let s = c.item.req.sample_idx * model.sample_len;
                    let r = model.sess.run_sample(&model.x[s..s + model.sample_len]);
                    argmax(&r.logits) == model.y[c.item.req.sample_idx] as usize
                } else {
                    true
                };
                tally.records.push(Served {
                    id: c.item.req.id,
                    tenant: c.item.req.tenant,
                    model: c.owner,
                    queue_us: c.start_us - c.item.enq_us,
                    service_us: vs.svc_us[c.owner],
                    correct,
                    deadline_ok: deadline == 0 || now - c.item.enq_us <= deadline,
                });
                idle[c.home] += 1;
            } else {
                let (t, m, req) = arrivals[ai].clone();
                ai += 1;
                first_arrival.get_or_insert(t);
                last_arrival = t;
                let lane = req.tenant.min(weights.len() - 1);
                let ok = !self.opts.admission
                    || admit(
                        queues[m].lane_len(lane),
                        vs.svc_us[m],
                        self.models[m].replicas,
                        weights[lane],
                        w_sum,
                        deadline,
                    );
                if ok {
                    queues[m].push(req, t);
                } else {
                    tally.shed.push(Shed { tenant: req.tenant, model: m, expired: false });
                }
            }
            // assign freed/idle replicas until no runnable work remains
            loop {
                let mut assigned = false;
                for m in 0..n_models {
                    while idle[m] > 0 {
                        match take(m, now, &queues, &mut tally.shed) {
                            Some((owner, item)) => {
                                idle[m] -= 1;
                                seq += 1;
                                tally.batches += 1;
                                heap.push(Reverse(Completion {
                                    finish_us: now + vs.svc_us[owner],
                                    seq,
                                    owner,
                                    home: m,
                                    start_us: now,
                                    item,
                                }));
                                assigned = true;
                            }
                            None => break,
                        }
                    }
                }
                if !assigned {
                    break;
                }
            }
        }
        debug_assert!(queues.iter().all(|q| q.is_empty()), "simulate left work queued");
        tally.max_depth = queues.iter().map(|q| q.depth_hwm()).max().unwrap_or(0);
        let wall = last_finish.max(last_arrival) as f64 / 1e6;
        let busy = match first_arrival {
            Some(a) if !tally.records.is_empty() => (last_finish - a) as f64 / 1e6,
            _ => 0.0,
        };
        Ok(ServeReport::from_records(predictor, tally, wall, busy, &tenant_names, &model_names))
    }
}

impl TierBuilder {
    /// Register a tenant class. Requests route to lanes by their
    /// `tenant` index, in registration order; weights set the fair
    /// share (2:1 weights ⇒ 2:1 service under saturation). With no
    /// tenants registered, `finish` installs a single weight-1 "all".
    pub fn tenant(mut self, name: &str, weight: u64) -> Self {
        assert!(weight >= 1, "tenant weights must be >= 1");
        self.tenants.push(Tenant { name: name.to_string(), weight });
        self
    }

    /// Register a model: its artifact bundle (for the request sample
    /// pool), a prepared session (re-derived with serve options: no
    /// oracle, no tracing), and its replica count.
    pub fn model(
        mut self,
        name: &str,
        arts: &Artifacts,
        session: &Session,
        replicas: usize,
    ) -> Self {
        assert!(replicas >= 1, "a model needs at least one replica");
        let sess = session.with_opts(RunOpts {
            oracle: false,
            collect_trace: false,
            threads: session.opts().threads.max(1),
            engine: session.opts().engine,
            input_sparsity: session.opts().input_sparsity,
            weight_sparsity: session.opts().weight_sparsity,
        });
        self.models.push(TierModel {
            name: name.to_string(),
            sess,
            x: arts.data.test_x.clone(),
            y: arts.data.test_y.clone(),
            sample_len: arts.data.sample_len(),
            replicas,
            svc_est_us: AtomicU64::new(0),
        });
        self
    }

    /// Per-request deadline in milliseconds (0 disables deadlines).
    pub fn deadline_ms(mut self, ms: f64) -> Self {
        self.opts.deadline_us = (ms * 1000.0) as u64;
        self
    }

    pub fn admission(mut self, on: bool) -> Self {
        self.opts.admission = on;
        self
    }

    pub fn steal(mut self, on: bool) -> Self {
        self.opts.steal = on;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.opts.max_batch = n.max(1);
        self
    }

    pub fn time_scale(mut self, s: f64) -> Self {
        self.opts.time_scale = s;
        self
    }

    pub fn finish(mut self) -> ServingTier {
        assert!(!self.models.is_empty(), "register at least one model");
        if self.tenants.is_empty() {
            self.tenants.push(Tenant { name: "all".to_string(), weight: 1 });
        }
        ServingTier {
            tenants: self.tenants,
            models: self.models,
            opts: self.opts,
            notifier: Arc::new(Notifier::new()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth;

    // End-to-end tier behavior (overload, fairness, isolation,
    // reproducibility) lives in rust/tests/serving_pipeline.rs; here:
    // the policy math and the simulator's basic clockwork.

    #[test]
    fn admission_math_caps_lane_depth_by_weight() {
        // deadline 20 ms, svc 1 ms, 2 replicas, weights 2:1 (sum 3):
        // lane A admits to depth 24, lane B to depth 12 — the 2:1
        // backlog split behind the 2:1 goodput contract
        assert!(admit(24, 1000, 2, 2, 3, 20_000));
        assert!(!admit(25, 1000, 2, 2, 3, 20_000));
        assert!(admit(12, 1000, 2, 1, 3, 20_000));
        assert!(!admit(13, 1000, 2, 1, 3, 20_000));
        // no deadline / no estimate yet → admit everything
        assert!(admit(10_000, 1000, 1, 1, 1, 0));
        assert!(admit(10_000, 0, 1, 1, 1, 5));
    }

    #[test]
    fn expiry_is_deadline_relative() {
        assert!(!expired(0, 0, 1000, 2000));
        assert!(!expired(1000, 0, 1000, 2000)); // exactly fits
        assert!(expired(1001, 0, 1000, 2000)); // one µs too late
        assert!(expired(1500, 200, 1000, 2000));
        assert!(!expired(999_999, 0, 1000, 0)); // no deadline → never
    }

    fn tiny_tier(replicas: usize) -> ServingTier {
        let arts = synth::artifacts_for(synth::tiny_serving_model(1), 2, 4, 4);
        let sess = Session::from_artifacts(&arts, Default::default());
        ServingTier::builder().model("tiny", &arts, &sess, replicas).finish()
    }

    fn req(id: u64, arrival_us: u64) -> Request {
        Request { id, sample_idx: (id % 4) as usize, arrival_us, tenant: 0 }
    }

    #[test]
    fn simulate_single_replica_queues_deterministically() {
        // 3 requests at t=0, svc 1 ms, 1 replica: latencies 1/2/3 ms
        let tier = tiny_tier(1);
        let r = tier
            .simulate(
                vec![vec![req(0, 0), req(1, 0), req(2, 0)]],
                &VirtualService { svc_us: vec![1000], execute: false },
            )
            .unwrap();
        assert_eq!(r.completed, 3);
        assert_eq!((r.shed, r.dropped), (0, 0));
        assert!(r.conserved());
        assert!((r.p99_ms - 3.0).abs() < 1e-9);
        assert!((r.busy_s - 0.003).abs() < 1e-12);
        assert!((r.throughput_rps - 1000.0).abs() < 1e-6);
        assert_eq!(r.max_queue_depth, 3);
    }

    #[test]
    fn simulate_two_replicas_halve_the_backlog() {
        let tier = tiny_tier(2);
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 0)).collect();
        let r = tier
            .simulate(vec![reqs], &VirtualService { svc_us: vec![1000], execute: false })
            .unwrap();
        assert_eq!(r.completed, 4);
        // two in service at once: finishes at 1,1,2,2 ms
        assert!((r.p99_ms - 2.0).abs() < 1e-9);
        assert!((r.busy_s - 0.002).abs() < 1e-12);
    }

    #[test]
    fn simulate_executes_real_inference_when_asked() {
        let tier = tiny_tier(1);
        let r = tier
            .simulate(
                vec![vec![req(0, 0), req(1, 500)]],
                &VirtualService { svc_us: vec![1000], execute: true },
            )
            .unwrap();
        assert_eq!(r.completed, 2);
        // accuracy is whatever the model actually scores — the point
        // is that it is computed (not defaulted) and stays in [0, 1]
        assert!((0.0..=1.0).contains(&r.accuracy));
    }

    #[test]
    fn trace_count_must_match_model_count() {
        let tier = tiny_tier(1);
        assert!(tier.simulate(vec![], &VirtualService { svc_us: vec![1000], execute: false }).is_err());
        assert!(tier
            .serve(vec![vec![], vec![]])
            .is_err());
    }
}
