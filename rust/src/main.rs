//! `mor` — leader binary for the Mixture-of-Rookies reproduction.
//!
//! See `mor help` (cli::USAGE) for commands. Python is only needed once,
//! at `make artifacts`; this binary is self-contained afterwards.

use anyhow::{bail, Result};
use mor::cli::{Args, USAGE};
use mor::config::Config;
use mor::coordinator::tier::ServingTier;
use mor::coordinator::{self, Backend, ServeOpts};
use mor::engine::tune::TuneProfile;
use mor::engine::isa::{self, Isa};
use mor::engine::{InputSparsity, WeightSparsity};
use mor::figures;
use mor::model::Artifacts;
use mor::predictor::strategies::{Strategy, ZeroPredictor};
use mor::predictor::MorRun;
use mor::session::Session;
use mor::workload::{Arrival, RequestStream};

fn main() {
    let args = match Args::parse(std::env::args()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "run" => cmd_run(args),
        "simulate" => cmd_simulate(args),
        "figures" => cmd_figures(args),
        "serve" => cmd_serve(args),
        "lint" => cmd_lint(args),
        "info" => cmd_info(args),
        "predictors" => cmd_predictors(),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn models_arg(args: &Args) -> Vec<String> {
    match args.opt("model") {
        Some(m) => m.split(',').map(|s| s.trim().to_string()).collect(),
        None => mor::MODELS.iter().map(|s| s.to_string()).collect(),
    }
}

fn config_from(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    cfg.predictor.threshold = args.opt_f64("threshold", cfg.predictor.threshold as f64)? as f32;
    if let Some(mode) = args.opt("input-sparsity") {
        cfg.engine.input_sparsity = InputSparsity::parse(mode)?;
    }
    if let Some(mode) = args.opt("weight-sparsity") {
        cfg.engine.weight_sparsity = WeightSparsity::parse(mode)?;
    }
    if let Some(name) = args.opt("predictor") {
        cfg.predictor.strategy = Strategy::parse(name)?;
    } else if args.flag("no-clusters") || args.flag("no-binary") {
        // legacy component toggles, kept as aliases for the strategies
        // they used to describe
        let s = cfg.predictor.strategy;
        cfg.predictor.strategy = Strategy::from_components(
            s.uses_clusters() && !args.flag("no-clusters"),
            s.uses_binary() && !args.flag("no-binary"),
        );
    }
    Ok(cfg)
}

/// Resolve the tuning surface shared by `run` and `serve`:
/// `--autotune` (or `[engine] autotune`) calibrates once per process
/// and, with `--tune-profile <f>`, saves the measured profile to `<f>`;
/// `--tune-profile` alone loads a saved profile. `None` = host default.
fn tune_from(args: &Args, cfg: &Config) -> Result<Option<TuneProfile>> {
    let autotune = args.flag("autotune") || cfg.engine.autotune;
    let path = args.opt("tune-profile");
    if autotune {
        let p = mor::engine::tune::calibrate();
        eprintln!(
            "[tune] calibrated for {}: input_cutoff {:.3} weight_cutoff {:.3} \
             tile_rows {} threads {} (hash {:016x})",
            p.isa.name(),
            p.input_cutoff,
            p.weight_cutoff,
            p.tile_rows,
            p.threads,
            p.hash()
        );
        if let Some(path) = path {
            p.save(path)?;
            eprintln!("[tune] profile saved to {path}");
        }
        return Ok(Some(p));
    }
    match path {
        Some(path) => {
            let p = TuneProfile::load(path)?;
            Ok(Some(p))
        }
        None => Ok(None),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", mor::DEFAULT_ARTIFACTS_DIR);
    let samples = args.opt_usize("samples", 128)?;
    let cfg = config_from(args)?;
    let tune = tune_from(args, &cfg)?;
    let auto_thr = args.opt("threshold").is_none() && cfg.predictor.strategy.uses_binary();
    for name in models_arg(args) {
        let arts = Artifacts::load(dir, &name)?;
        let mut pcfg = cfg.predictor.clone();
        if auto_thr {
            // paper (Sec 3.2.1): T is set per DNN using training data
            pcfg.threshold = mor::predictor::choose_threshold(&arts, &pcfg, 3.2, 32);
        }
        // one session carries both runs: the dense baseline shares the
        // model (and prepacked weights) with the policied evaluation
        let mut builder = Session::build(&arts.model)
            .params(&arts.predictor)
            .config(pcfg.clone())
            .input_sparsity(cfg.engine.input_sparsity)
            .weight_sparsity(cfg.engine.weight_sparsity);
        if let Some(p) = tune {
            builder = builder.tune_profile(p);
        }
        let session = builder.finish();
        let base = MorRun::evaluate(&arts, &session.with_policy(None), samples);
        let s = MorRun::evaluate(&arts, &session, samples);
        let p = &s.pred;
        println!(
            "[{name}] predictor={} T={:.2}{} | acc {:.2}% (baseline {:.2}%, Δ {:+.2}%) | \
             MACs elided: output-pred {:.1}% | input-zero {:.1}% | weight-zero {:.1}% of done | \
             DRAM wt saved {:.1}%",
            session.predictor_name(),
            pcfg.threshold,
            if auto_thr { " (auto)" } else { "" },
            s.accuracy * 100.0,
            base.accuracy * 100.0,
            (s.accuracy - base.accuracy) * 100.0,
            s.ops.macs_saved_frac() * 100.0,
            s.ops.input_zero_frac() * 100.0,
            s.ops.weight_zero_frac() * 100.0,
            s.ops.weight_bytes_saved as f64
                / (s.ops.weight_bytes_fetched + s.ops.weight_bytes_saved).max(1) as f64
                * 100.0,
        );
        if let WeightSparsity::Threshold(t) = cfg.engine.weight_sparsity {
            // the lossy mode: quantify what pruning itself cost by
            // re-running the *unpruned* dense model (both runs above
            // share the pruned clone, so their Δ is predictor-only)
            let unpruned = Session::build(&arts.model)
                .input_sparsity(cfg.engine.input_sparsity)
                .finish();
            let u = MorRun::evaluate(&arts, &unpruned, samples);
            println!(
                "       weight pruning: t={t} zeroed {:.1}% of weights | dense acc \
                 {:.2}% pruned vs {:.2}% unpruned (Δ {:+.2}%)",
                session.model().weight_zero_fraction() * 100.0,
                base.accuracy * 100.0,
                u.accuracy * 100.0,
                (base.accuracy - u.accuracy) * 100.0,
            );
        }
        println!(
            "       outcomes: correct-zero {:.2}% | incorrect-zero {:.2}% | \
             correct-nonzero {:.2}% | incorrect-nonzero {:.2}% | not-applied {:.2}%",
            p.frac(p.correct_zero) * 100.0,
            p.frac(p.incorrect_zero) * 100.0,
            p.frac(p.correct_nonzero) * 100.0,
            p.frac(p.incorrect_nonzero) * 100.0,
            p.frac(p.not_applied) * 100.0,
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", mor::DEFAULT_ARTIFACTS_DIR);
    let samples = args.opt_usize("samples", figures::SIM_SAMPLES)?;
    let cfg = config_from(args)?;
    let artifacts: Vec<Artifacts> = models_arg(args)
        .iter()
        .map(|m| Artifacts::load(dir, m))
        .collect::<Result<_>>()?;
    let (table, _) = figures::fig13(&artifacts, samples, &cfg);
    table.print();
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", mor::DEFAULT_ARTIFACTS_DIR);
    let out = args.opt_or("out", "figures_out");
    let samples = args.opt_usize("samples", figures::EVAL_SAMPLES)?;
    let sim_samples = args.opt_usize("sim-samples", figures::SIM_SAMPLES)?;
    let cfg = config_from(args)?;
    let all = args.flag("all") || args.positional.is_empty();
    let want = |id: &str| all || args.positional.iter().any(|p| p == id);

    let artifacts = figures::load_all(dir)?;
    let emit = |name: &str, t: mor::util::bench::Table| -> Result<()> {
        t.print();
        t.write_csv(out, name)?;
        Ok(())
    };

    if want("fig1") {
        emit("fig01_neg_relu", figures::fig01(&artifacts, samples))?;
    }
    if want("fig3") {
        emit("fig03_mac_breakdown", figures::fig03(&artifacts))?;
    }
    if want("fig4") {
        let tds = artifacts
            .iter()
            .find(|a| a.meta.name == "tds")
            .unwrap_or(&artifacts[0]);
        emit("fig04_scatter", figures::fig04(tds, 8))?;
    }
    if want("fig5") {
        emit("fig05_corr_hist", figures::fig05(&artifacts))?;
    }
    if want("fig6") {
        emit(
            "fig06_threshold_sweep",
            figures::threshold_sweep(&artifacts, samples, Strategy::Binary),
        )?;
    }
    if want("fig8") {
        emit("fig08_angle_hist", figures::fig08(&artifacts))?;
    }
    if want("fig9") {
        emit(
            "fig09_hybrid_sweep",
            figures::threshold_sweep(&artifacts, samples, Strategy::Mor),
        )?;
    }
    if want("ablation") {
        emit("ablation_strategies", figures::strategy_ablation(&artifacts, samples))?;
    }
    if want("sparsity") {
        emit("sparsity_triple_sided", figures::sparsity_table(&artifacts, samples))?;
    }
    if want("fig12") {
        let (t, _) = figures::fig12(&artifacts, samples);
        emit("fig12_pred_breakdown", t)?;
    }
    if want("fig13") {
        let (t, _) = figures::fig13(&artifacts, sim_samples, &cfg);
        emit("fig13_speedup_energy", t)?;
    }
    if want("table1") {
        emit("table1_config", figures::table1(&cfg))?;
    }
    if want("area") {
        emit("area_overhead", figures::area_table(&cfg))?;
    }
    if want("montecarlo") {
        emit("montecarlo_angles", figures::montecarlo_table(100_000))?;
    }
    println!("\nCSV series written to {out}/");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // the multi-model/multi-tenant/deadline surface routes to the
    // sharded serving tier; the single-model path below is untouched
    if args.opt("models").is_some()
        || args.opt("tenants").is_some()
        || args.opt("deadline-ms").is_some()
    {
        return cmd_serve_tier(args);
    }
    let dir = args.opt_or("artifacts", mor::DEFAULT_ARTIFACTS_DIR);
    let model = args.opt_or("model", "tds");
    let rps = args.opt_f64("rps", 200.0)?;
    let duration = args.opt_f64("duration", 5.0)?;
    let workers = args.opt_usize("workers", 4)?;
    let intra_threads = args.opt_usize("intra-threads", 1)?;
    let max_batch = args.opt_usize("max-batch", 1)?;
    let batch_wait_us = args.opt_usize("batch-wait-us", 200)? as u64;
    let arrival_kind = args.opt_or("arrival", "poisson");
    let concurrency = args.opt_usize("concurrency", 0)?;
    let backend = match args.opt_or("runtime", "engine") {
        "pjrt" => Backend::Pjrt,
        "engine" => Backend::Engine,
        other => bail!("--runtime must be 'engine' or 'pjrt', got '{other}'"),
    };
    let mut cfg = config_from(args)?;
    if args.flag("no-predictor") {
        cfg.predictor.strategy = Strategy::None;
    }
    let tune = tune_from(args, &cfg)?;

    let arts = Artifacts::load(dir, model)?;
    let mut builder = Session::build(&arts.model)
        .params(&arts.predictor)
        .config(cfg.predictor.clone())
        .threads(intra_threads)
        .input_sparsity(cfg.engine.input_sparsity)
        .weight_sparsity(cfg.engine.weight_sparsity);
    if let Some(p) = tune {
        builder = builder.tune_profile(p);
    }
    let session = builder.finish();
    let arrival = Arrival::from_cli(arrival_kind, rps)?;
    let mut stream = RequestStream::with_arrival(arrival, arts.data.n_test(), 42);
    let requests = stream.generate(duration);
    println!(
        "[serve] model={model} predictor={} backend={backend:?} workers={workers} \
         arrival={arrival_kind} rps={rps} duration={duration}s \
         max_batch={max_batch} → {} requests",
        session.predictor_name(),
        requests.len()
    );
    let report = coordinator::serve(
        &arts,
        &session,
        backend,
        requests,
        dir,
        ServeOpts {
            workers,
            time_scale: 1.0,
            max_batch,
            batch_wait_us,
            closed_loop: arrival_kind == "closed",
            concurrency,
        },
    )?;
    report.print(model);
    Ok(())
}

/// `mor serve --models a,b --tenants gold:2,free:1 --deadline-ms 20`:
/// the sharded serving tier — one session + queue + replica pool per
/// model, weighted-fair tenant lanes, deadline admission control and
/// load shedding, work stealing between idle replicas.
fn cmd_serve_tier(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", mor::DEFAULT_ARTIFACTS_DIR);
    let model_list = args.opt_or("models", args.opt_or("model", "tds")).to_string();
    let replicas = args.opt_usize("replicas", 2)?;
    let rps = args.opt_f64("rps", 200.0)?;
    let duration = args.opt_f64("duration", 5.0)?;
    let intra_threads = args.opt_usize("intra-threads", 1)?;
    let max_batch = args.opt_usize("max-batch", 1)?;
    let deadline_ms = args.opt_f64("deadline-ms", 0.0)?;
    let arrival_kind = args.opt_or("arrival", "poisson");
    let mut cfg = config_from(args)?;
    if args.flag("no-predictor") {
        cfg.predictor.strategy = Strategy::None;
    }
    let tune = tune_from(args, &cfg)?;

    // --tenants name:weight,... (weight defaults to 1)
    let mut builder = ServingTier::builder()
        .deadline_ms(deadline_ms)
        .max_batch(max_batch)
        .steal(!args.flag("no-steal"));
    let mut tenants = Vec::new();
    for part in args.opt_or("tenants", "all:1").split(',').filter(|s| !s.is_empty()) {
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => (
                n,
                w.parse::<u64>().map_err(|_| {
                    anyhow::anyhow!("--tenants expects name:weight entries, got '{part}'")
                })?,
            ),
            None => (part, 1),
        };
        builder = builder.tenant(name, weight);
        tenants.push(name.to_string());
    }
    anyhow::ensure!(!tenants.is_empty(), "--tenants must name at least one tenant");

    let mut bundles = Vec::new();
    for name in model_list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        bundles.push(Artifacts::load(dir, name)?);
    }
    anyhow::ensure!(!bundles.is_empty(), "--models must name at least one model");
    for arts in &bundles {
        let mut sb = Session::build(&arts.model)
            .params(&arts.predictor)
            .config(cfg.predictor.clone())
            .threads(intra_threads)
            .input_sparsity(cfg.engine.input_sparsity)
            .weight_sparsity(cfg.engine.weight_sparsity);
        if let Some(p) = tune {
            sb = sb.tune_profile(p);
        }
        let session = sb.finish();
        builder = builder.model(&arts.meta.name, arts, &session, replicas);
    }
    let tier = builder.finish();

    // per-model traces: each tenant gets an equal slice of --rps on its
    // own seeded stream; merge interleaves them arrival-ordered
    let arrival = Arrival::from_cli(arrival_kind, rps / tenants.len() as f64)?;
    let traces: Vec<Vec<mor::workload::Request>> = bundles
        .iter()
        .enumerate()
        .map(|(mi, arts)| {
            mor::workload::merge(
                (0..tenants.len())
                    .map(|ti| {
                        RequestStream::with_arrival(
                            arrival,
                            arts.data.n_test(),
                            42 + (mi * 101 + ti) as u64,
                        )
                        .for_tenant(ti)
                        .generate(duration)
                    })
                    .collect(),
            )
        })
        .collect();
    println!(
        "[serve] tier: {} model(s) x {replicas} replica(s), tenants [{}], \
         deadline {deadline_ms}ms, arrival={arrival_kind} rps={rps} duration={duration}s \
         → {} requests",
        bundles.len(),
        tenants.join(","),
        traces.iter().map(|t| t.len()).sum::<usize>()
    );
    let report = tier.serve(traces)?;
    report.print("tier");
    anyhow::ensure!(report.conserved(), "serving tier lost requests (accounting bug)");
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use mor::config::PredictorConfig;
    use mor::model::{synth, Model};
    use mor::plan;
    use mor::predictor::{MorPolicy, RunOpts};
    use mor::util::json::{obj, Json};
    use mor::util::rng::Rng;

    let seed = args.opt_usize("seed", 7)? as u64;
    let n_random = args.opt_usize("random-models", 8)?;
    let numeric = args.flag("numeric");
    // --acc-bits narrows the *claimed* accumulator the numeric pass
    // proves against (num.width / num.vnni); 32 is the real i32.
    let acc_bits = args.opt_usize("acc-bits", 32)? as u32;
    // --tune-profile: freeze every plan from the saved profile and then
    // audit the frozen decisions against that same profile — a clean
    // report proves the compile/verify round-trip agrees with the file.
    let tprof = match args.opt("tune-profile") {
        Some(path) => Some(mor::engine::tune::TuneProfile::load(path)?),
        None => None,
    };

    // Models to lint: one real artifact model under --model, otherwise
    // the synthetic zoo (the same generators the plan test suites use).
    let models: Vec<Model> = match args.opt("model") {
        Some(name) => {
            let dir = args.opt_or("artifacts", mor::DEFAULT_ARTIFACTS_DIR);
            vec![Artifacts::load(dir, name)?.model]
        }
        None => {
            let mut zoo = vec![synth::cnn10_like(seed), synth::tiny_serving_model(seed)];
            let mut sparse = synth::tiny_serving_model(seed);
            synth::sparsify_weights(&mut sparse, seed, 90);
            sparse.name = format!("{}_sparse90", sparse.name);
            zoo.push(sparse);
            let mut rng = Rng::new(seed);
            zoo.extend((0..n_random).map(|_| synth::random_model(&mut rng)));
            zoo
        }
    };

    // Each model is compiled and verified under every frozen-decision
    // axis: input-sparsity mode × exact weight-sparsity mode × with and
    // without a MoR policy. One clean bill per configuration.
    let mut configs = 0usize;
    let (mut errors, mut warnings) = (0usize, 0usize);
    let mut json_models = Vec::new();
    for model in &models {
        let params = synth::predictor_for(model, seed);
        let policy = MorPolicy::new(model, &params, PredictorConfig::default());
        let mut json_configs = Vec::new();
        let mut model_errors = 0usize;
        let mut model_warnings = 0usize;
        // worst-case accumulator width proven across all configs (0
        // until --numeric runs; 32 means the full i32 is needed)
        let mut model_acc_bits = 0u32;
        for is in InputSparsity::ALL {
            for ws in WeightSparsity::EXACT_MODES {
                for pol in [None, Some(&policy)] {
                    let opts = RunOpts {
                        input_sparsity: is,
                        weight_sparsity: ws,
                        tune: tprof.unwrap_or_default(),
                        ..Default::default()
                    };
                    let compiled = plan::compile(model, pol, opts);
                    let report = plan::verify_with(&compiled, model, pol, tprof.as_ref());
                    configs += 1;
                    model_errors += report.errors();
                    model_warnings += report.warnings();
                    // --numeric: run the abstract interpreter on the
                    // same frozen plan and fold its findings into the
                    // per-model and exit-status accounting.
                    let num = numeric.then(|| {
                        plan::ranges::analyze_with(
                            &compiled,
                            model,
                            pol,
                            &plan::ranges::NumericOpts { acc_bits },
                        )
                    });
                    if let Some(num) = &num {
                        model_errors += num.lint.errors();
                        model_warnings += num.lint.warnings();
                        model_acc_bits = model_acc_bits.max(num.max_acc_bits());
                    }
                    if args.flag("json") {
                        let mut pairs = vec![
                            ("input_sparsity", Json::Str(is.name().to_string())),
                            ("weight_sparsity", Json::Str(ws.name())),
                            ("policy", Json::Bool(pol.is_some())),
                            ("findings", report.to_json()),
                        ];
                        if let Some(num) = &num {
                            pairs.push(("numeric", num.to_json()));
                        }
                        json_configs.push(obj(pairs));
                    } else {
                        let num_dirty = num.as_ref().is_some_and(|n| !n.is_clean());
                        if !report.is_clean() || num_dirty {
                            println!(
                                "[{}] input-sparsity={} weight-sparsity={} policy={}",
                                model.name,
                                is.name(),
                                ws.name(),
                                pol.is_some()
                            );
                            for line in report.to_string().lines() {
                                println!("    {line}");
                            }
                            if let Some(num) = &num {
                                for f in &num.lint.findings {
                                    println!("    {f}");
                                }
                            }
                        }
                    }
                }
            }
        }
        errors += model_errors;
        warnings += model_warnings;
        if args.flag("json") {
            let mut pairs = vec![
                ("model", Json::Str(model.name.clone())),
                ("errors", Json::Num(model_errors as f64)),
                ("warnings", Json::Num(model_warnings as f64)),
            ];
            if numeric {
                pairs.push(("acc_bits", Json::Num(model_acc_bits as f64)));
            }
            pairs.push(("configs", Json::Arr(json_configs)));
            json_models.push(obj(pairs));
        } else {
            println!(
                "[{}] {} plan configuration(s): {}{}",
                model.name,
                InputSparsity::ALL.len() * WeightSparsity::EXACT_MODES.len() * 2,
                if model_errors == 0 && model_warnings == 0 {
                    "clean".to_string()
                } else {
                    format!("{model_errors} error(s), {model_warnings} warning(s)")
                },
                if numeric {
                    format!(" | widest accumulator {model_acc_bits} bit(s)")
                } else {
                    String::new()
                }
            );
        }
    }

    if args.flag("json") {
        let doc = obj(vec![
            ("models", Json::Arr(json_models)),
            ("configs", Json::Num(configs as f64)),
            ("errors", Json::Num(errors as f64)),
            ("warnings", Json::Num(warnings as f64)),
        ]);
        println!("{doc}");
    } else {
        println!(
            "mor lint{}: {} model(s) × plan configs = {configs} verified | \
             {errors} error(s), {warnings} warning(s)",
            if numeric { " --numeric" } else { "" },
            models.len()
        );
    }
    if errors > 0 {
        bail!("mor lint found {errors} error-severity finding(s)");
    }
    Ok(())
}

fn cmd_predictors() -> Result<()> {
    println!("available zero-predictor strategies (--predictor <name>):\n");
    for s in Strategy::ALL {
        println!("  {:<8} {}", s.name(), s.describe());
    }
    println!(
        "\nselect via `--predictor <name>` (run/simulate/figures/serve), the\n\
         `[predictor] strategy = \"<name>\"` config key, or Session::predictor(name)\n\
         in code. See EXPERIMENTS.md §Predictor API for the contract."
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    if args.flag("config") {
        println!("{}", cfg.table1());
        return Ok(());
    }

    // Host ISA report: what the CPU offers, what the dispatcher will
    // actually use (after any MOR_ISA cap), and the kernels that implies.
    let tiers: Vec<&str> = isa::available().into_iter().map(Isa::name).collect();
    println!("isa:");
    println!("  detected   {}", isa::detected().name());
    println!("  active     {} (cap via MOR_ISA=<tier>)", isa::active().name());
    println!("  available  [{}]", tiers.join(", "));
    println!(
        "  kernels    dot={} gemm={}",
        if isa::vnni_enabled() {
            "avx512-vnni vpdpbusd"
        } else if isa::avx2_enabled() {
            "avx2 maddubs/madd"
        } else if isa::neon_enabled() {
            "neon smlal"
        } else {
            "scalar"
        },
        if isa::active() > Isa::Scalar { "simd-tiled" } else { "scalar-tiled" },
    );

    // Tune profile: the saved one under --tune-profile, else the
    // compiled-in host default every non-autotuned plan freezes.
    let (p, src) = match args.opt("tune-profile") {
        Some(path) => (TuneProfile::load(path)?, format!("loaded from {path}")),
        None => (TuneProfile::host_default(), "host default".to_string()),
    };
    println!("tune profile ({src}):");
    println!("  isa {} | input_cutoff {:.3} | weight_cutoff {:.3} | tile_rows {} | threads {} | hash {:016x}",
        p.isa.name(), p.input_cutoff, p.weight_cutoff, p.tile_rows, p.threads, p.hash());

    let dir = args.opt_or("artifacts", mor::DEFAULT_ARTIFACTS_DIR);
    match mor::model::load_meta(dir) {
        Ok(metas) => {
            println!("artifacts in {dir}:");
            for m in metas {
                println!(
                    "  {:<12} input {:?} | {:.1}M MACs/sample | fp32 {:.1}% | int8 {:.1}% | {} relu layers",
                    m.name,
                    m.input_shape,
                    m.macs_per_sample as f64 / 1e6,
                    m.fp32_accuracy * 100.0,
                    m.int8_accuracy * 100.0,
                    m.relu_layers.len()
                );
            }
        }
        Err(e) => println!("artifacts in {dir}: none ({e})"),
    }
    Ok(())
}
