//! Synthetic model / predictor-parameter generators.
//!
//! Benches and the engine-equivalence property suite need models without
//! `make artifacts` having run: the perf benches need a cnn10-scale conv
//! stack with a plausible MoR policy, and the property tests need random
//! graphs that cover the geometry corners (stride > kernel, 1×1 SAME,
//! non-square inputs, VALID/SAME, BN on/off, FC heads).

use super::{Artifacts, Dataset, LayerPredictor, Model, ModelMeta, Node, PredictorParams};
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Uniform random int8 weights.
pub fn rand_weights(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.int8()).collect()
}

/// A single FC node with random weights — the unit the GEMV-vs-GEMM
/// micro-bench operates on.
pub fn dense_node(cin: usize, cout: usize, seed: u64) -> Node {
    let mut rng = Rng::new(seed);
    Node::Fc {
        cin,
        cout,
        sw: 0.01,
        sx: 1.0 / 127.0,
        w: rand_weights(&mut rng, cin * cout),
        bn: None,
        relu: false,
        res_from: None,
        consumes: -1,
    }
}

fn rand_bn(rng: &mut Rng, cout: usize) -> (Vec<f32>, Vec<f32>) {
    (
        (0..cout).map(|_| rng.uniform(0.6, 1.4) as f32).collect(),
        (0..cout).map(|_| rng.uniform(-0.1, 0.1) as f32).collect(),
    )
}

/// A cnn10-like stack (8 convs + GAP + FC head, 16×16×16 input) for the
/// forward-pass benches when the real artifacts are absent. Deterministic
/// for a given seed.
pub fn cnn10_like(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let mut nodes: Vec<Node> = Vec::new();
    let conv = |rng: &mut Rng, cin: usize, cout: usize, stride: usize, consumes: i32, sx: f32| {
        Node::Conv {
            kh: 3,
            kw: 3,
            cin,
            cout,
            stride,
            pad_same: true,
            sw: 0.01,
            sx,
            w: rand_weights(rng, 3 * 3 * cin * cout),
            bn: Some(rand_bn(rng, cout)),
            relu: true,
            res_from: None,
            consumes,
        }
    };
    nodes.push(conv(&mut rng, 16, 32, 1, -1, 1.0 / 127.0));
    nodes.push(conv(&mut rng, 32, 32, 1, 0, 0.05));
    nodes.push(conv(&mut rng, 32, 64, 2, 1, 0.05));
    for i in 0..5 {
        nodes.push(conv(&mut rng, 64, 64, 1, 2 + i, 0.05));
    }
    nodes.push(Node::Gap { consumes: 7 });
    nodes.push(Node::Fc {
        cin: 64,
        cout: 10,
        sw: 0.02,
        sx: 0.05,
        w: rand_weights(&mut rng, 64 * 10),
        bn: None,
        relu: false,
        res_from: None,
        consumes: 8,
    });
    Model::new(format!("cnn10_synth_{seed}"), 1.0 / 127.0, (16, 16, 16), nodes)
}

/// A random small model: 1–3 conv layers with random kernel/stride
/// (including stride > kernel), SAME or VALID padding, optional BN and
/// ReLU, on a random (possibly non-square, possibly W=1) input; optionally
/// a 2×2 max-pool; and an FC head. Shapes are kept consistent so every
/// generated graph runs.
pub fn random_model(rng: &mut Rng) -> Model {
    let mut h = rng.int_in(3, 10) as usize;
    let mut w = rng.int_in(1, 9) as usize;
    let mut c = rng.int_in(1, 6) as usize;
    let input_shape = (h, w, c);
    let mut nodes: Vec<Node> = Vec::new();

    let n_conv = rng.int_in(1, 3);
    for li in 0..n_conv {
        let kh = rng.int_in(1, 3.min(h as i64)) as usize;
        let kw = rng.int_in(1, 3.min(w as i64)) as usize;
        let stride = rng.int_in(1, 4) as usize; // may exceed the kernel
        let pad_same = rng.chance(0.5);
        let cout = rng.int_in(1, 12) as usize;
        let relu = rng.chance(0.7);
        let bn = rng.chance(0.5).then(|| rand_bn(rng, cout));
        nodes.push(Node::Conv {
            kh,
            kw,
            cin: c,
            cout,
            stride,
            pad_same,
            sw: rng.uniform(0.005, 0.03) as f32,
            sx: if li == 0 { 1.0 / 127.0 } else { rng.uniform(0.02, 0.1) as f32 },
            w: rand_weights(rng, kh * kw * c * cout),
            bn,
            relu,
            res_from: None,
            consumes: li as i32 - 1,
        });
        if pad_same {
            h = h.div_ceil(stride);
            w = w.div_ceil(stride);
        } else {
            h = (h - kh) / stride + 1;
            w = (w - kw) / stride + 1;
        }
        c = cout;
    }

    if rng.chance(0.3) && h >= 2 {
        nodes.push(Node::MaxPool {
            size: 2,
            consumes: nodes.len() as i32 - 1,
        });
        h /= 2;
        w = (w / 2).max(1);
    }

    let classes = rng.int_in(2, 6) as usize;
    nodes.push(Node::Fc {
        cin: c,
        cout: classes,
        sw: 0.02,
        sx: rng.uniform(0.02, 0.1) as f32,
        w: rand_weights(rng, c * classes),
        bn: None,
        relu: false,
        res_from: None,
        consumes: nodes.len() as i32 - 1,
    });

    Model::new("synth_random".into(), 1.0 / 127.0, input_shape, nodes)
}

/// Random-but-plausible offline predictor parameters for every predictable
/// (ReLU) layer of `model`: shuffled clusters of 1–4 neurons, fitted lines
/// with small slopes and mixed-sign intercepts, correlations spanning the
/// whole [0, 1) range so thresholding enables a random subset.
pub fn predictor_for(model: &Model, seed: u64) -> PredictorParams {
    let mut rng = Rng::new(seed ^ 0x5EED_CAFE);
    let mut layers = BTreeMap::new();
    for &li in &model.relu_layers() {
        let n = model.nodes[li].cout();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut clusters: Vec<Vec<usize>> = Vec::new();
        let mut i = 0;
        while i < n {
            let sz = (rng.int_in(1, 4) as usize).min(n - i);
            clusters.push(order[i..i + sz].to_vec());
            i += sz;
        }
        let mut proxy_of = vec![0usize; n];
        for cl in &clusters {
            for &m in cl {
                proxy_of[m] = cl[0];
            }
        }
        layers.insert(
            li,
            LayerPredictor {
                layer: li,
                c: (0..n).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
                m: (0..n).map(|_| rng.uniform(0.0, 0.02) as f32).collect(),
                b: (0..n).map(|_| rng.uniform(-0.5, 0.5) as f32).collect(),
                s: (0..n).map(|_| rng.uniform(0.0, 0.3) as f32).collect(),
                clusters,
                closest_angle_deg: (0..n).map(|_| rng.uniform(0.0, 90.0) as f32).collect(),
                proxy_of,
            },
        );
    }
    PredictorParams {
        model: model.name.clone(),
        default_threshold: 0.85,
        layers,
    }
}

/// A small conv+fc stack (8×8×4 input, two ReLU convs, GAP, 4-class head)
/// — fast enough that serving tests can push hundreds of requests through
/// it without `make artifacts`.
pub fn tiny_serving_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let conv = |rng: &mut Rng, cin: usize, cout: usize, stride: usize, consumes: i32, sx: f32| {
        Node::Conv {
            kh: 3,
            kw: 3,
            cin,
            cout,
            stride,
            pad_same: true,
            sw: 0.01,
            sx,
            w: rand_weights(rng, 3 * 3 * cin * cout),
            bn: Some(rand_bn(rng, cout)),
            relu: true,
            res_from: None,
            consumes,
        }
    };
    let nodes = vec![
        conv(&mut rng, 4, 8, 1, -1, 1.0 / 127.0),
        conv(&mut rng, 8, 8, 2, 0, 0.05),
        Node::Gap { consumes: 1 },
        Node::Fc {
            cin: 8,
            cout: 4,
            sw: 0.02,
            sx: 0.05,
            w: rand_weights(&mut rng, 8 * 4),
            bn: None,
            relu: false,
            res_from: None,
            consumes: 2,
        },
    ];
    Model::new(format!("tiny_serve_{seed}"), 1.0 / 127.0, (8, 8, 4), nodes)
}

/// Zero roughly `zero_pct` percent of every compute node's weight
/// lanes, in place — the weight-sparsity suites and benches need models
/// whose prepacked density is controlled rather than the ~0.4% natural
/// zero rate of uniform int8 weights. Deterministic for a given seed;
/// resets the prepack cache so the compressed lane lists are rebuilt
/// from the new weights.
pub fn sparsify_weights(model: &mut Model, seed: u64, zero_pct: u32) {
    let mut rng = Rng::new(seed ^ 0x5A12_51F7);
    for node in &mut model.nodes {
        if let Node::Conv { w, .. } | Node::Fc { w, .. } = node {
            for v in w.iter_mut() {
                if rng.chance(zero_pct as f64 / 100.0) {
                    *v = 0;
                }
            }
        }
    }
    model.prepacked = std::sync::OnceLock::new();
}

/// Wrap a synthetic model into a full [`Artifacts`] bundle (predictor
/// params, random evaluation data, meta) so the serving coordinator and
/// its benches/tests run without `make artifacts`.
///
/// Test labels are **self-consistent**: the dense forward's own argmax,
/// so serving accuracy measures predictor-induced divergence (1.0 without
/// a policy), not label noise.
pub fn artifacts_for(model: Model, seed: u64, n_test: usize, n_calib: usize) -> Artifacts {
    let predictor = predictor_for(&model, seed ^ 0x0517);
    let (h, w, c) = model.input_shape;
    let sample = h * w * c;
    let mut rng = Rng::new(seed ^ 0xDA7A);
    let test_x: Vec<f32> = (0..n_test * sample)
        .map(|_| rng.uniform(-1.0, 1.0) as f32)
        .collect();
    let label_of = |x: &[f32]| {
        let opts = crate::predictor::RunOpts {
            oracle: false,
            ..Default::default()
        };
        let r = crate::predictor::exec::run_sample(&model, None, x, opts);
        crate::predictor::argmax(&r.logits) as u16
    };
    let test_y: Vec<u16> = (0..n_test)
        .map(|i| label_of(&test_x[i * sample..(i + 1) * sample]))
        .collect();
    let calib_x: Vec<f32> = (0..n_calib * sample)
        .map(|_| rng.uniform(-1.0, 1.0) as f32)
        .collect();
    let calib_y: Vec<u16> = (0..n_calib)
        .map(|i| label_of(&calib_x[i * sample..(i + 1) * sample]))
        .collect();
    let meta = ModelMeta {
        name: model.name.clone(),
        input_shape: model.input_shape,
        macs_per_sample: model.mac_counts().iter().sum(),
        fp32_accuracy: 1.0,
        int8_accuracy: 1.0,
        relu_layers: model.relu_layers(),
    };
    Artifacts {
        meta,
        model,
        predictor,
        data: Dataset {
            shape: (h, w, c),
            test_x,
            test_y,
            calib_x,
            calib_y,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_serving_artifacts_are_consistent() {
        let arts = artifacts_for(tiny_serving_model(3), 4, 6, 2);
        assert_eq!(arts.data.n_test(), 6);
        assert_eq!(arts.data.n_calib(), 2);
        assert_eq!(arts.data.shape, arts.meta.input_shape);
        assert!(!arts.predictor.layers.is_empty());
        // labels are the dense forward's argmax → dense accuracy is 1.0
        let dense = crate::session::Session::build(&arts.model).finish();
        let s = crate::predictor::MorRun::evaluate(&arts, &dense, 6);
        assert_eq!(s.accuracy, 1.0);
    }

    #[test]
    fn cnn10_like_is_well_formed() {
        let m = cnn10_like(3);
        assert_eq!(m.input_shape, (16, 16, 16));
        let shapes = m.node_shapes();
        assert_eq!(shapes[0], (16, 16, 32));
        assert_eq!(shapes[2], (8, 8, 64));
        assert_eq!(*shapes.last().unwrap(), (1, 1, 10));
        assert!(m.mac_counts().iter().sum::<u64>() > 10_000_000);
        // every conv is predictable (relu), so the synthetic predictor
        // covers them all
        let p = predictor_for(&m, 4);
        assert_eq!(p.layers.len(), m.relu_layers().len());
    }

    #[test]
    fn sparsify_weights_hits_the_requested_density() {
        let mut m = cnn10_like(1);
        sparsify_weights(&mut m, 9, 70);
        // ~30% of the lanes survive; the prepack cache was rebuilt
        let d = m.prepacked().layer(0).density();
        assert!(d > 0.2 && d < 0.4, "density {d}");
    }

    #[test]
    fn random_models_run_shape_math() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let m = random_model(&mut rng);
            // node_shapes must not panic and every dim stays positive
            for (h, w, c) in m.node_shapes() {
                assert!(h >= 1 && w >= 1 && c >= 1);
            }
            let p = predictor_for(&m, 7);
            for (&l, lp) in &p.layers {
                assert_eq!(lp.neurons(), m.nodes[l].cout());
                // clusters partition the neurons
                let mut seen: Vec<usize> = lp.clusters.iter().flatten().copied().collect();
                seen.sort();
                assert_eq!(seen, (0..lp.neurons()).collect::<Vec<_>>());
            }
        }
    }
}
