//! MORW v1 parser — the quantized model format written by
//! python/compile/artifacts_io.py (see its docstring for the byte layout).

use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::OnceLock;

/// One node of the layer graph. Conv weights are re-laid-out at load time
/// to filter-major `[cout][kh*kw*cin]` (the dot-product hot path wants each
/// filter contiguous); FC weights to `[cout][cin]`.
#[derive(Clone, Debug)]
pub enum Node {
    Conv {
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        pad_same: bool,
        sw: f32,
        sx: f32,
        /// filter-major: w[f * k_len + k], k in (kh,kw,cin) row-major order
        w: Vec<i8>,
        /// folded batch-norm (scale, shift), if present
        bn: Option<(Vec<f32>, Vec<f32>)>,
        relu: bool,
        res_from: Option<usize>,
        /// index of the node whose output this consumes (-1 = model input)
        consumes: i32,
    },
    Fc {
        cin: usize,
        cout: usize,
        sw: f32,
        sx: f32,
        /// filter-major: w[f * cin + k]
        w: Vec<i8>,
        bn: Option<(Vec<f32>, Vec<f32>)>,
        relu: bool,
        res_from: Option<usize>,
        consumes: i32,
    },
    MaxPool {
        size: usize,
        consumes: i32,
    },
    Gap {
        consumes: i32,
    },
    Relu {
        consumes: i32,
    },
}

impl Node {
    pub fn consumes(&self) -> i32 {
        match self {
            Node::Conv { consumes, .. }
            | Node::Fc { consumes, .. }
            | Node::MaxPool { consumes, .. }
            | Node::Gap { consumes }
            | Node::Relu { consumes } => *consumes,
        }
    }

    pub fn is_compute(&self) -> bool {
        matches!(self, Node::Conv { .. } | Node::Fc { .. })
    }

    /// Dot-product length (weights per neuron).
    pub fn k_len(&self) -> usize {
        match self {
            Node::Conv { kh, kw, cin, .. } => kh * kw * cin,
            Node::Fc { cin, .. } => *cin,
            _ => 0,
        }
    }

    pub fn cout(&self) -> usize {
        match self {
            Node::Conv { cout, .. } | Node::Fc { cout, .. } => *cout,
            _ => 0,
        }
    }

    pub fn relu(&self) -> bool {
        match self {
            Node::Conv { relu, .. } | Node::Fc { relu, .. } => *relu,
            _ => false,
        }
    }

    /// Weight slice for filter `f` (compute nodes only).
    pub fn filter(&self, f: usize) -> &[i8] {
        let (w, k) = match self {
            Node::Conv { w, .. } => (w, self.k_len()),
            Node::Fc { w, cin, .. } => (w, *cin),
            _ => panic!("filter() on non-compute node"),
        };
        &w[f * k..(f + 1) * k]
    }
}

/// A loaded quantized model.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub sx0: f32,
    /// (H, W, C) — provided by meta/data (MORW itself carries no shape).
    pub input_shape: (usize, usize, usize),
    pub nodes: Vec<Node>,
    /// Lazily-built prepacked weight blocks for the tiled GEMM engine —
    /// built once per model on first forward, shared read-only by every
    /// worker thread (cloning a Model clones the cache).
    pub(crate) prepacked: OnceLock<crate::engine::gemm::PrepackedModel>,
}

impl Model {
    /// Assemble a model from parts (artifact loading uses [`Model::load`];
    /// this is for synthetic models — benches, property tests).
    pub fn new(
        name: String,
        sx0: f32,
        input_shape: (usize, usize, usize),
        nodes: Vec<Node>,
    ) -> Model {
        Model {
            name,
            sx0,
            input_shape,
            nodes,
            prepacked: OnceLock::new(),
        }
    }

    /// Filter-major, alignment-padded weight blocks for the tiled engine.
    pub fn prepacked(&self) -> &crate::engine::gemm::PrepackedModel {
        self.prepacked
            .get_or_init(|| crate::engine::gemm::PrepackedModel::new(self))
    }

    /// Zero every weight lane whose dequantized magnitude `|w| * sw` is
    /// below `t` (the `WeightSparsity::Threshold` magnitude pruning,
    /// applied to a model clone at session build — see
    /// [`crate::session::SessionBuilder::weight_sparsity`]). Returns the
    /// number of lanes newly zeroed, and resets the prepack cache so the
    /// compressed weight lanes are rebuilt from the pruned tensors.
    pub fn prune_weights_below(&mut self, t: f32) -> u64 {
        let mut zeroed = 0u64;
        for node in &mut self.nodes {
            if let Node::Conv { w, sw, .. } | Node::Fc { w, sw, .. } = node {
                let sw = *sw;
                for v in w.iter_mut() {
                    if *v != 0 && (*v as f32).abs() * sw < t {
                        *v = 0;
                        zeroed += 1;
                    }
                }
            }
        }
        self.prepacked = OnceLock::new();
        zeroed
    }

    /// Fraction of weight lanes that are exactly zero across all compute
    /// nodes (`0.0` for a weightless model) — what `mor run` reports
    /// alongside a threshold-pruned forward.
    pub fn weight_zero_fraction(&self) -> f64 {
        let (mut zeros, mut total) = (0u64, 0u64);
        for node in &self.nodes {
            if let Node::Conv { w, .. } | Node::Fc { w, .. } = node {
                total += w.len() as u64;
                zeros += w.iter().filter(|&&v| v == 0).count() as u64;
            }
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }
    pub fn load<P: AsRef<Path>>(path: P, name: &str) -> Result<Model> {
        let buf = std::fs::read(&path)
            .with_context(|| format!("reading {} — run `make artifacts`", path.as_ref().display()))?;
        let mut r = Reader { buf: &buf, pos: 0 };
        ensure!(r.bytes(4)? == b"MORW", "bad magic in {}", path.as_ref().display());
        let version = r.u32()?;
        ensure!(version == 1, "unsupported MORW version {version}");
        let n_nodes = r.u32()? as usize;
        let sx0 = r.f32()?;
        let mut nodes = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            nodes.push(parse_node(&mut r)?);
        }
        ensure!(r.pos == buf.len(), "trailing bytes in MORW file");
        Ok(Model::new(
            name.to_string(),
            sx0,
            (0, 0, 0), // input_shape filled by Artifacts::load via Dataset
            nodes,
        ))
    }

    /// Node output (H,W,C) shapes, given the input shape.
    pub fn node_shapes(&self) -> Vec<(usize, usize, usize)> {
        let mut shapes: Vec<(usize, usize, usize)> = Vec::with_capacity(self.nodes.len());
        for nd in &self.nodes {
            let (h, w, c) = self.input_shape_of(nd.consumes(), &shapes);
            let s = match nd {
                Node::Conv {
                    kh,
                    kw,
                    cout,
                    stride,
                    pad_same,
                    ..
                } => {
                    if *pad_same {
                        (h.div_ceil(*stride), w.div_ceil(*stride), *cout)
                    } else {
                        ((h - kh) / stride + 1, (w - kw) / stride + 1, *cout)
                    }
                }
                Node::Fc { cout, .. } => (h, w, *cout),
                Node::MaxPool { size, .. } => (h / size, (w / size).max(1), c),
                Node::Gap { .. } => (1, 1, c),
                Node::Relu { .. } => (h, w, c),
            };
            shapes.push(s);
        }
        shapes
    }

    fn input_shape_of(
        &self,
        consumes: i32,
        shapes: &[(usize, usize, usize)],
    ) -> (usize, usize, usize) {
        if consumes < 0 {
            self.input_shape
        } else {
            shapes[consumes as usize]
        }
    }

    /// MACs per node for one sample (Fig 1 / Fig 3 / simulator workloads).
    pub fn mac_counts(&self) -> Vec<u64> {
        let shapes = self.node_shapes();
        self.nodes
            .iter()
            .enumerate()
            .map(|(i, nd)| match nd {
                Node::Conv { .. } | Node::Fc { .. } => {
                    let (oh, ow, _) = shapes[i];
                    (oh * ow * nd.cout() * nd.k_len()) as u64
                }
                _ => 0,
            })
            .collect()
    }

    /// Indices of compute nodes whose output feeds a ReLU (directly or via
    /// a standalone Relu node) — the predictable layers.
    pub fn relu_layers(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(i, nd)| {
                nd.is_compute()
                    && (nd.relu()
                        || matches!(self.nodes.get(i + 1), Some(Node::Relu { .. })))
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Total weight bytes a full evaluation must fetch (8-bit weights).
    pub fn weight_bytes(&self) -> u64 {
        self.nodes
            .iter()
            .map(|nd| match nd {
                Node::Conv { w, .. } | Node::Fc { w, .. } => w.len() as u64,
                _ => 0,
            })
            .sum()
    }
}

// ---------------------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "truncated MORW file");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.bytes(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i8_vec(&mut self, n: usize) -> Result<Vec<i8>> {
        let raw = self.bytes(n)?;
        Ok(raw.iter().map(|&b| b as i8).collect())
    }
}

fn parse_node(r: &mut Reader) -> Result<Node> {
    let kind = r.u8()?;
    let flags = r.u8()?;
    let res_from_raw = r.i32()?;
    let consumes = r.i32()?;
    let relu = flags & 1 != 0;
    let has_bn = flags & 2 != 0;
    let res_from = if res_from_raw < 0 {
        None
    } else {
        Some(res_from_raw as usize)
    };
    match kind {
        0 => {
            let kh = r.u32()? as usize;
            let kw = r.u32()? as usize;
            let cin = r.u32()? as usize;
            let cout = r.u32()? as usize;
            let stride = r.u32()? as usize;
            let pad_same = r.u8()? == 1;
            let sw = r.f32()?;
            let sx = r.f32()?;
            // file order: (KH, KW, CIN, COUT) row-major → filter-major
            let raw = r.i8_vec(kh * kw * cin * cout)?;
            let k_len = kh * kw * cin;
            let mut w = vec![0i8; cout * k_len];
            for k in 0..k_len {
                for f in 0..cout {
                    w[f * k_len + k] = raw[k * cout + f];
                }
            }
            let bn = if has_bn {
                Some((r.f32_vec(cout)?, r.f32_vec(cout)?))
            } else {
                None
            };
            Ok(Node::Conv {
                kh, kw, cin, cout, stride, pad_same, sw, sx, w, bn, relu, res_from, consumes,
            })
        }
        1 => {
            let cin = r.u32()? as usize;
            let cout = r.u32()? as usize;
            let sw = r.f32()?;
            let sx = r.f32()?;
            let raw = r.i8_vec(cin * cout)?; // (CIN, COUT) row-major
            let mut w = vec![0i8; cout * cin];
            for k in 0..cin {
                for f in 0..cout {
                    w[f * cin + k] = raw[k * cout + f];
                }
            }
            let bn = if has_bn {
                Some((r.f32_vec(cout)?, r.f32_vec(cout)?))
            } else {
                None
            };
            Ok(Node::Fc {
                cin, cout, sw, sx, w, bn, relu, res_from, consumes,
            })
        }
        2 => Ok(Node::MaxPool {
            size: r.u32()? as usize,
            consumes,
        }),
        3 => Ok(Node::Gap { consumes }),
        4 => Ok(Node::Relu { consumes }),
        k => bail!("unknown node kind {k}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build a MORW byte stream and parse it back.
    fn tiny_morw() -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        b.extend(b"MORW");
        b.extend(1u32.to_le_bytes());
        b.extend(2u32.to_le_bytes()); // 2 nodes
        b.extend(0.5f32.to_le_bytes()); // sx0
        // node 0: fc 3 -> 2, relu, no bn
        b.push(1); // kind fc
        b.push(1); // flags: relu
        b.extend((-1i32).to_le_bytes()); // res_from
        b.extend((-1i32).to_le_bytes()); // consumes
        b.extend(3u32.to_le_bytes());
        b.extend(2u32.to_le_bytes());
        b.extend(0.1f32.to_le_bytes());
        b.extend(0.2f32.to_le_bytes());
        // weights (CIN=3, COUT=2) row-major: [[1,2],[3,4],[5,-6]]
        for v in [1i8, 2, 3, 4, 5, -6] {
            b.push(v as u8);
        }
        // node 1: gap
        b.push(3);
        b.push(0);
        b.extend((-1i32).to_le_bytes());
        b.extend(0i32.to_le_bytes());
        b
    }

    #[test]
    fn parses_tiny_morw() {
        let dir = std::env::temp_dir().join(format!("mor_w_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.w.bin");
        std::fs::write(&p, tiny_morw()).unwrap();
        let m = Model::load(&p, "t").unwrap();
        assert_eq!(m.sx0, 0.5);
        assert_eq!(m.nodes.len(), 2);
        match &m.nodes[0] {
            Node::Fc { cin, cout, w, relu, .. } => {
                assert_eq!((*cin, *cout), (3, 2));
                assert!(relu);
                // filter-major: filter 0 = [1,3,5], filter 1 = [2,4,-6]
                assert_eq!(&w[0..3], &[1, 3, 5]);
                assert_eq!(&w[3..6], &[2, 4, -6]);
            }
            _ => panic!("expected fc"),
        }
        assert!(matches!(m.nodes[1], Node::Gap { consumes: 0 }));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_file_errors() {
        let dir = std::env::temp_dir().join(format!("mor_wt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.w.bin");
        let mut bytes = tiny_morw();
        bytes.truncate(bytes.len() - 3);
        std::fs::write(&p, bytes).unwrap();
        assert!(Model::load(&p, "bad").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shapes_and_macs_tiny_conv() {
        let mut m = super::super::testutil::tiny_conv(1);
        m.input_shape = (6, 6, 2);
        let shapes = m.node_shapes();
        assert_eq!(shapes[0], (6, 6, 4)); // SAME conv
        assert_eq!(shapes[1], (6, 6, 4)); // projection
        assert_eq!(shapes[4], (6, 6, 4)); // relu keeps shape
        assert_eq!(shapes[5], (3, 3, 4)); // maxpool 2
        assert_eq!(shapes[6], (1, 1, 4)); // gap
        let macs = m.mac_counts();
        assert_eq!(macs[0], 6 * 6 * 4 * (3 * 3 * 2));
        assert_eq!(macs[4], 0);
    }

    #[test]
    fn prune_weights_below_zeroes_small_lanes_and_rebuilds_prepack() {
        let m0 = Node::Fc {
            cin: 3,
            cout: 2,
            sw: 0.1,
            sx: 0.5,
            w: vec![1, 3, 5, 2, 4, -6],
            bn: None,
            relu: false,
            res_from: None,
            consumes: -1,
        };
        let mut m = Model::new("p".into(), 0.5, (1, 1, 3), vec![m0]);
        assert_eq!(m.prepacked().layer(0).density(), 1.0); // cache forced
        // |w| * 0.1 < 0.25 → lanes 1 and 2 go, everything else stays
        let zeroed = m.prune_weights_below(0.25);
        assert_eq!(zeroed, 2);
        assert_eq!(m.nodes[0].filter(0), &[0, 3, 5]);
        assert_eq!(m.nodes[0].filter(1), &[0, 4, -6]);
        assert_eq!(m.weight_zero_fraction(), 2.0 / 6.0);
        // the prepack cache was reset, so the rebuilt density sees them
        assert_eq!(m.prepacked().layer(0).density(), 4.0 / 6.0);
        // idempotent: already-zero lanes are not re-counted
        assert_eq!(m.prune_weights_below(0.25), 0);
    }

    #[test]
    fn relu_layers_include_standalone_relu() {
        let mut m = super::super::testutil::tiny_conv(2);
        m.input_shape = (6, 6, 2);
        // node 0 (relu=true), node 2 (relu=true), node 3 (followed by Relu)
        assert_eq!(m.relu_layers(), vec![0, 2, 3]);
    }
}
