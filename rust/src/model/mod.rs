//! Artifact loaders: quantized models (MORW), predictor parameters (JSON),
//! evaluation data (MORD) and the bundle index (meta.json).
//!
//! Formats are defined in python/compile/artifacts_io.py; the loaders here
//! parse the exact bytes that file writes.

mod data;
mod predictor_params;
pub mod synth;
mod weights;

pub use data::Dataset;
pub use predictor_params::{LayerPredictor, PredictorParams};
pub use weights::{Model, Node};

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Metadata for one model from meta.json.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub input_shape: (usize, usize, usize),
    pub macs_per_sample: u64,
    pub fp32_accuracy: f64,
    pub int8_accuracy: f64,
    pub relu_layers: Vec<usize>,
}

/// A fully loaded model bundle.
pub struct Artifacts {
    pub meta: ModelMeta,
    pub model: Model,
    pub predictor: PredictorParams,
    pub data: Dataset,
}

impl Artifacts {
    /// Load `<dir>/<name>.{w.bin,predictor.json,data.bin}` + meta.json.
    pub fn load<P: AsRef<Path>>(dir: P, name: &str) -> Result<Artifacts> {
        let dir = dir.as_ref();
        let meta = load_meta(dir)?
            .into_iter()
            .find(|m| m.name == name)
            .with_context(|| format!("model '{name}' not in {}/meta.json", dir.display()))?;
        let mut model = Model::load(dir.join(format!("{name}.w.bin")), name)?;
        model.input_shape = meta.input_shape;
        let predictor = PredictorParams::load(dir.join(format!("{name}.predictor.json")))?;
        let data = Dataset::load(dir.join(format!("{name}.data.bin")))?;
        anyhow::ensure!(
            data.shape == meta.input_shape,
            "data shape {:?} != meta input_shape {:?}",
            data.shape,
            meta.input_shape
        );
        Ok(Artifacts {
            meta,
            model,
            predictor,
            data,
        })
    }

    pub fn hlo_path<P: AsRef<Path>>(dir: P, name: &str) -> PathBuf {
        dir.as_ref().join(format!("{name}_fwd.hlo.txt"))
    }
}

/// Parse meta.json into per-model metadata.
pub fn load_meta<P: AsRef<Path>>(dir: P) -> Result<Vec<ModelMeta>> {
    let path = dir.as_ref().join("meta.json");
    let src = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
    let j = Json::parse(&src).context("parsing meta.json")?;
    let models = j
        .get("models")
        .and_then(|m| m.as_arr())
        .context("meta.json: missing 'models'")?;
    models
        .iter()
        .map(|m| {
            let shape = m
                .get("input_shape")
                .and_then(|s| s.as_usize_vec())
                .context("meta.json: input_shape")?;
            anyhow::ensure!(shape.len() == 3, "input_shape must be rank 3");
            Ok(ModelMeta {
                name: m
                    .get("name")
                    .and_then(|v| v.as_str())
                    .context("meta.json: name")?
                    .to_string(),
                input_shape: (shape[0], shape[1], shape[2]),
                macs_per_sample: m
                    .get("macs_per_sample")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64,
                fp32_accuracy: m.get("fp32_accuracy").and_then(|v| v.as_f64()).unwrap_or(0.0),
                int8_accuracy: m.get("int8_accuracy").and_then(|v| v.as_f64()).unwrap_or(0.0),
                relu_layers: m
                    .get("relu_layers")
                    .and_then(|v| v.as_usize_vec())
                    .unwrap_or_default(),
            })
        })
        .collect()
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Synthetic in-memory models for unit tests that must not depend on
    //! `make artifacts` having run.
    use super::weights::{Model, Node};
    use crate::util::rng::Rng;

    /// Tiny 2-layer FC model: 8 -> 6 (relu) -> 4, no BN.
    pub fn tiny_fc(seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        let mut w1 = vec![0i8; 8 * 6];
        let mut w2 = vec![0i8; 6 * 4];
        for v in w1.iter_mut().chain(w2.iter_mut()) {
            *v = rng.int8();
        }
        Model::new(
            "tiny_fc".into(),
            1.0 / 127.0,
            (1, 1, 8),
            vec![
                Node::Fc {
                    cin: 8,
                    cout: 6,
                    sw: 0.01,
                    sx: 1.0 / 127.0,
                    w: w1,
                    bn: None,
                    relu: true,
                    res_from: None,
                    consumes: -1,
                },
                Node::Fc {
                    cin: 6,
                    cout: 4,
                    sw: 0.02,
                    sx: 0.05,
                    w: w2,
                    bn: None,
                    relu: false,
                    res_from: None,
                    consumes: 0,
                },
            ],
        )
    }

    /// Tiny conv model with BN + residual + pooling, 6x6x2 input.
    pub fn tiny_conv(seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        let mut mk = |n: usize| -> Vec<i8> { (0..n).map(|_| rng.int8()).collect() };
        let c1 = mk(3 * 3 * 2 * 4);
        let proj = mk(1 * 1 * 4 * 4);
        let c2 = mk(3 * 3 * 4 * 4);
        let c3 = mk(3 * 3 * 4 * 4);
        Model::new(
            "tiny_conv".into(),
            1.0 / 127.0,
            (6, 6, 2),
            vec![
                // 0: stem conv + bn + relu
                Node::Conv {
                    kh: 3, kw: 3, cin: 2, cout: 4, stride: 1, pad_same: true,
                    sw: 0.01, sx: 1.0 / 127.0, w: c1,
                    bn: Some((vec![1.0; 4], vec![0.05; 4])),
                    relu: true, res_from: None, consumes: -1,
                },
                // 1: projection (no relu) — side branch reading node 0
                Node::Conv {
                    kh: 1, kw: 1, cin: 4, cout: 4, stride: 1, pad_same: true,
                    sw: 0.02, sx: 0.04, w: proj,
                    bn: Some((vec![1.0; 4], vec![0.0; 4])),
                    relu: false, res_from: None, consumes: 0,
                },
                // 2: conv + bn + relu reading node 0
                Node::Conv {
                    kh: 3, kw: 3, cin: 4, cout: 4, stride: 1, pad_same: true,
                    sw: 0.015, sx: 0.04, w: c2,
                    bn: Some((vec![0.9; 4], vec![-0.02; 4])),
                    relu: true, res_from: None, consumes: 0,
                },
                // 3: conv + bn + residual(node 1), no relu
                Node::Conv {
                    kh: 3, kw: 3, cin: 4, cout: 4, stride: 1, pad_same: true,
                    sw: 0.015, sx: 0.03, w: c3,
                    bn: Some((vec![1.1; 4], vec![0.01; 4])),
                    relu: false, res_from: Some(1), consumes: 2,
                },
                // 4: standalone relu
                Node::Relu { consumes: 3 },
                // 5: maxpool
                Node::MaxPool { size: 2, consumes: 4 },
                // 6: gap
                Node::Gap { consumes: 5 },
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_minimal() {
        let dir = std::env::temp_dir().join(format!("mor_meta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"version":1,"models":[{"name":"toy","input_shape":[4,1,3],
                "macs_per_sample":123,"fp32_accuracy":0.9,"int8_accuracy":0.88,
                "relu_layers":[0,2]}]}"#,
        )
        .unwrap();
        let metas = load_meta(&dir).unwrap();
        assert_eq!(metas.len(), 1);
        assert_eq!(metas[0].name, "toy");
        assert_eq!(metas[0].input_shape, (4, 1, 3));
        assert_eq!(metas[0].macs_per_sample, 123);
        assert_eq!(metas[0].relu_layers, vec![0, 2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_meta_is_error() {
        assert!(load_meta("/nonexistent/dir").is_err());
    }
}
