//! Predictor parameter loader — `<model>.predictor.json` written by
//! python/compile/calibrate.py (offline stage, Section 3.2 of the paper).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Offline parameters for one predictable (ReLU) layer.
#[derive(Clone, Debug)]
pub struct LayerPredictor {
    pub layer: usize,
    /// Per-neuron Pearson correlation between binary and base dot products.
    pub c: Vec<f32>,
    /// Per-neuron fitted line slope (dequant units per binary count).
    pub m: Vec<f32>,
    /// Per-neuron fitted line intercept.
    pub b: Vec<f32>,
    /// Per-neuron regression residual std (skip-confidence margin unit);
    /// zeros when the artifact predates the field.
    pub s: Vec<f32>,
    /// Clusters: `[proxy, member, member, ...]`, a partition of all neurons.
    pub clusters: Vec<Vec<usize>>,
    /// Angle (degrees) to each neuron's closest peer (Fig 8 data).
    pub closest_angle_deg: Vec<f32>,
    /// Derived: for each neuron, its proxy (proxy of a singleton = itself).
    pub proxy_of: Vec<usize>,
}

impl LayerPredictor {
    pub fn neurons(&self) -> usize {
        self.c.len()
    }

    pub fn is_proxy(&self, n: usize) -> bool {
        self.proxy_of[n] == n
    }
}

/// All layers' offline parameters for one model.
#[derive(Clone, Debug)]
pub struct PredictorParams {
    pub model: String,
    pub default_threshold: f32,
    pub layers: BTreeMap<usize, LayerPredictor>,
}

impl PredictorParams {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<PredictorParams> {
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts`", path.as_ref().display()))?;
        let j = Json::parse(&src).context("parsing predictor.json")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<PredictorParams> {
        let model = j
            .get("model")
            .and_then(|v| v.as_str())
            .context("predictor.json: model")?
            .to_string();
        let default_threshold = j
            .get("default_threshold")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.85) as f32;
        let mut layers = BTreeMap::new();
        for l in j
            .get("layers")
            .and_then(|v| v.as_arr())
            .context("predictor.json: layers")?
        {
            let layer = l
                .get("layer")
                .and_then(|v| v.as_usize())
                .context("layer id")?;
            let c = l.get("c").and_then(|v| v.as_f32_vec()).context("c")?;
            let m = l.get("m").and_then(|v| v.as_f32_vec()).context("m")?;
            let b = l.get("b").and_then(|v| v.as_f32_vec()).context("b")?;
            let s = l
                .get("s")
                .and_then(|v| v.as_f32_vec())
                .unwrap_or_else(|| vec![0.0; c.len()]);
            let closest_angle_deg = l
                .get("closest_angle_deg")
                .and_then(|v| v.as_f32_vec())
                .unwrap_or_default();
            let clusters: Vec<Vec<usize>> = l
                .get("clusters")
                .and_then(|v| v.as_arr())
                .context("clusters")?
                .iter()
                .map(|cl| cl.as_usize_vec().context("cluster entry"))
                .collect::<Result<_>>()?;
            let n = c.len();
            anyhow::ensure!(
                m.len() == n && b.len() == n,
                "predictor layer {layer}: c/m/b length mismatch"
            );
            let mut proxy_of = vec![usize::MAX; n];
            for cl in &clusters {
                anyhow::ensure!(!cl.is_empty(), "empty cluster in layer {layer}");
                let proxy = cl[0];
                for &member in cl {
                    anyhow::ensure!(
                        member < n,
                        "cluster member {member} out of range in layer {layer}"
                    );
                    anyhow::ensure!(
                        proxy_of[member] == usize::MAX,
                        "neuron {member} appears in two clusters (layer {layer})"
                    );
                    proxy_of[member] = proxy;
                }
            }
            anyhow::ensure!(
                proxy_of.iter().all(|&p| p != usize::MAX),
                "clusters do not cover all neurons in layer {layer}"
            );
            layers.insert(
                layer,
                LayerPredictor {
                    layer,
                    c,
                    m,
                    b,
                    s,
                    clusters,
                    closest_angle_deg,
                    proxy_of,
                },
            );
        }
        Ok(PredictorParams {
            model,
            default_threshold,
            layers,
        })
    }
}

#[cfg(test)]
pub(crate) fn toy_layer(n: usize, clusters: Vec<Vec<usize>>) -> LayerPredictor {
    let mut proxy_of = vec![usize::MAX; n];
    for cl in &clusters {
        for &m in cl {
            proxy_of[m] = cl[0];
        }
    }
    LayerPredictor {
        layer: 0,
        c: vec![1.0; n],
        m: vec![1.0; n],
        b: vec![0.0; n],
        s: vec![0.0; n],
        clusters,
        closest_angle_deg: vec![45.0; n],
        proxy_of,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": "toy", "default_threshold": 0.8,
      "layers": [{
         "layer": 2, "neurons": 4,
         "c": [0.9, 0.2, 0.95, 0.5],
         "m": [1.5, 0.0, 2.0, 1.0],
         "b": [0.1, 0.0, -0.2, 0.0],
         "clusters": [[2, 0, 3], [1]],
         "closest_angle_deg": [70.0, 85.0, 70.0, 76.0]
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let p = PredictorParams::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(p.model, "toy");
        assert_eq!(p.default_threshold, 0.8);
        let l = &p.layers[&2];
        assert_eq!(l.neurons(), 4);
        assert_eq!(l.proxy_of, vec![2, 1, 2, 2]);
        assert!(l.is_proxy(2) && l.is_proxy(1));
        assert!(!l.is_proxy(0) && !l.is_proxy(3));
    }

    #[test]
    fn rejects_overlapping_clusters() {
        let bad = SAMPLE.replace("[[2, 0, 3], [1]]", "[[2, 0, 3], [1, 0]]");
        assert!(PredictorParams::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn rejects_partial_cover() {
        let bad = SAMPLE.replace("[[2, 0, 3], [1]]", "[[2, 0], [1]]");
        assert!(PredictorParams::from_json(&Json::parse(&bad).unwrap()).is_err());
    }
}
