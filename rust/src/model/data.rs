//! MORD v1 parser — evaluation data written by python/compile/artifacts_io.py.

use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Test + calibration splits for one model, stored as float32 NHWC.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub shape: (usize, usize, usize), // (H, W, C) per sample
    pub test_x: Vec<f32>,             // n_test * H*W*C
    pub test_y: Vec<u16>,
    pub calib_x: Vec<f32>,
    pub calib_y: Vec<u16>,
}

impl Dataset {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Dataset> {
        let buf = std::fs::read(&path)
            .with_context(|| format!("reading {} — run `make artifacts`", path.as_ref().display()))?;
        ensure!(buf.len() >= 28 && &buf[..4] == b"MORD", "bad MORD magic");
        let u = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().unwrap()) as usize;
        let version = u(4);
        ensure!(version == 1, "unsupported MORD version {version}");
        let (n_test, n_calib, h, w, c) = (u(8), u(12), u(16), u(20), u(24));
        let sample = h * w * c;
        let mut off = 28;
        let take_f32 = |off: &mut usize, n: usize| -> Result<Vec<f32>> {
            ensure!(*off + 4 * n <= buf.len(), "truncated MORD file");
            let v = buf[*off..*off + 4 * n]
                .chunks_exact(4)
                .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
                .collect();
            *off += 4 * n;
            Ok(v)
        };
        let take_u16 = |off: &mut usize, n: usize| -> Result<Vec<u16>> {
            ensure!(*off + 2 * n <= buf.len(), "truncated MORD file");
            let v = buf[*off..*off + 2 * n]
                .chunks_exact(2)
                .map(|ch| u16::from_le_bytes(ch.try_into().unwrap()))
                .collect();
            *off += 2 * n;
            Ok(v)
        };
        let test_x = take_f32(&mut off, n_test * sample)?;
        let test_y = take_u16(&mut off, n_test)?;
        let calib_x = take_f32(&mut off, n_calib * sample)?;
        let calib_y = take_u16(&mut off, n_calib)?;
        ensure!(off == buf.len(), "trailing bytes in MORD file");
        Ok(Dataset {
            shape: (h, w, c),
            test_x,
            test_y,
            calib_x,
            calib_y,
        })
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    pub fn n_calib(&self) -> usize {
        self.calib_y.len()
    }

    pub fn sample_len(&self) -> usize {
        self.shape.0 * self.shape.1 * self.shape.2
    }

    /// The i-th test sample as a (H*W*C) float slice.
    pub fn test_sample(&self, i: usize) -> &[f32] {
        let n = self.sample_len();
        &self.test_x[i * n..(i + 1) * n]
    }

    pub fn calib_sample(&self, i: usize) -> &[f32] {
        let n = self.sample_len();
        &self.calib_x[i * n..(i + 1) * n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_mord(n_test: usize, n_calib: usize, h: usize, w: usize, c: usize) -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        b.extend(b"MORD");
        for v in [1u32, n_test as u32, n_calib as u32, h as u32, w as u32, c as u32] {
            b.extend(v.to_le_bytes());
        }
        let sample = h * w * c;
        for i in 0..n_test * sample {
            b.extend((i as f32 * 0.25).to_le_bytes());
        }
        for i in 0..n_test {
            b.extend((i as u16).to_le_bytes());
        }
        for i in 0..n_calib * sample {
            b.extend((-(i as f32)).to_le_bytes());
        }
        for _ in 0..n_calib {
            b.extend(9u16.to_le_bytes());
        }
        b
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("mor_d_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.data.bin");
        std::fs::write(&p, mk_mord(3, 2, 2, 1, 4)).unwrap();
        let d = Dataset::load(&p).unwrap();
        assert_eq!(d.shape, (2, 1, 4));
        assert_eq!(d.n_test(), 3);
        assert_eq!(d.n_calib(), 2);
        assert_eq!(d.test_sample(1)[0], 8.0 * 0.25);
        assert_eq!(d.test_y, vec![0, 1, 2]);
        assert_eq!(d.calib_y, vec![9, 9]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncation_detected() {
        let dir = std::env::temp_dir().join(format!("mor_dt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.data.bin");
        let mut bytes = mk_mord(2, 1, 2, 1, 2);
        bytes.pop();
        std::fs::write(&p, bytes).unwrap();
        assert!(Dataset::load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
