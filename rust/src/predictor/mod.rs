//! Online zero-output prediction (paper Section 3.2) and the
//! prediction-aware forward pass.
//!
//! * [`strategies`] — the pluggable [`strategies::ZeroPredictor`] API:
//!   named skip strategies (`mor`, `binary`, `cluster`, `oracle`,
//!   `none`) behind one trait with enum-based static dispatch.
//! * [`MorPolicy`] — the prepared per-layer decision state: one
//!   [`strategies::LayerState`] per predictable layer, built by the
//!   configured strategy from the offline artifacts (fitted lines,
//!   clusters) and a [`crate::config::PredictorConfig`].
//! * [`exec::run_sample`] — one forward pass with optional prediction,
//!   producing logits, prediction-outcome stats (Fig 12), operation
//!   accounting (Fig 1/6/9/13) and an optional skip trace for the
//!   cycle-level simulator.
//! * [`exec::run_batch`] — the batch-native form: advances B samples
//!   layer-by-layer so GEMM row tiles fill across request boundaries
//!   (the serving coordinator's micro-batch path); bit-identical to
//!   per-sample execution.
//! * [`MorRun`] — dataset-level evaluation driver over a
//!   [`crate::session::Session`].

pub mod exec;
pub mod strategies;

use crate::config::PredictorConfig;
use crate::model::{Model, PredictorParams};
use crate::session::Session;
use std::collections::BTreeMap;
use strategies::{LayerState, Strategy, ZeroPredictor};

pub use crate::engine::{InputSparsity, WeightSparsity};

/// The full prepared policy for a model: the configured strategy plus
/// the per-layer state it built. Shared read-only across worker
/// threads; re-threshold a cached policy with [`MorPolicy::with_threshold`]
/// instead of rebuilding it (the packed sign bits are shared).
pub struct MorPolicy {
    pub cfg: PredictorConfig,
    pub layers: BTreeMap<usize, LayerState>,
}

impl MorPolicy {
    pub fn new(model: &Model, params: &PredictorParams, cfg: PredictorConfig) -> MorPolicy {
        let mut layers = BTreeMap::new();
        for (&layer, lp) in &params.layers {
            let node = &model.nodes[layer];
            debug_assert_eq!(node.cout(), lp.neurons());
            layers.insert(layer, cfg.strategy.prepare(lp, node, &cfg));
        }
        MorPolicy { cfg, layers }
    }

    /// The active strategy.
    pub fn strategy(&self) -> Strategy {
        self.cfg.strategy
    }

    /// A candidate-threshold variant of this policy. Only the per-layer
    /// `enabled` sets are recomputed; clusters and packed rookie
    /// operands are shared with `self` — this is what makes
    /// [`choose_threshold`]'s sweep cheap.
    pub fn with_threshold(&self, t: f32) -> MorPolicy {
        MorPolicy {
            cfg: PredictorConfig { threshold: t, ..self.cfg.clone() },
            layers: self
                .layers
                .iter()
                .map(|(&l, st)| (l, st.with_threshold(t)))
                .collect(),
        }
    }
}

/// Prediction-outcome counters (paper Fig 12 categories).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PredStats {
    /// Predicted zero, truly zero — savings, no accuracy impact.
    pub correct_zero: u64,
    /// Predicted zero, truly non-zero — savings, *introduces errors*.
    pub incorrect_zero: u64,
    /// Predicted non-zero, truly non-zero.
    pub correct_nonzero: u64,
    /// Predicted non-zero, truly zero — missed opportunity.
    pub incorrect_nonzero: u64,
    /// Outputs where the predictor was not applied (proxies, c < T,
    /// non-ReLU layers' outputs are not even counted here).
    pub not_applied: u64,
    /// All outputs of predictable (ReLU) layers.
    pub relu_outputs: u64,
}

impl PredStats {
    pub fn add(&mut self, o: &PredStats) {
        self.correct_zero += o.correct_zero;
        self.incorrect_zero += o.incorrect_zero;
        self.correct_nonzero += o.correct_nonzero;
        self.incorrect_nonzero += o.incorrect_nonzero;
        self.not_applied += o.not_applied;
        self.relu_outputs += o.relu_outputs;
    }

    pub fn applied(&self) -> u64 {
        self.correct_zero + self.incorrect_zero + self.correct_nonzero + self.incorrect_nonzero
    }

    pub fn frac(&self, v: u64) -> f64 {
        if self.relu_outputs == 0 {
            0.0
        } else {
            v as f64 / self.relu_outputs as f64
        }
    }
}

/// Operation/traffic accounting for one run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpsStats {
    /// MACs a dense evaluation would perform.
    pub macs_total: u64,
    /// MACs actually performed.
    pub macs_done: u64,
    /// 1-bit (binCU) operations performed.
    pub bin_ops: u64,
    /// Weight bytes fetched from DRAM (8-bit weights).
    pub weight_bytes_fetched: u64,
    /// Weight bytes *not* fetched thanks to skipped neurons.
    pub weight_bytes_saved: u64,
    /// MACs spent on outputs whose true ReLU input was negative (Fig 1).
    pub neg_relu_macs: u64,
    /// MACs in predictable (ReLU) layers.
    pub relu_macs: u64,
    /// True zero outputs among ReLU-layer outputs.
    pub true_zero_outputs: u64,
    /// Among [`OpsStats::macs_done`]: MACs whose *input* activation lane
    /// is exactly zero (ineffectual — they contribute nothing to the
    /// integer dot). This is the input-side savings pool the triple-sided
    /// engine elides via the compressed-lane kernels, complementary to
    /// the output-prediction savings (`macs_total - macs_done`).
    ///
    /// A property of the data, not of the kernel that ran: it is
    /// counted identically whatever [`InputSparsity`] mode executes, so
    /// the equivalence suites can demand `OpsStats` bit-equality across
    /// sparse/dense runs.
    pub macs_skipped_input_zero: u64,
    /// Among [`OpsStats::macs_done`]: MACs whose *weight* lane is
    /// exactly zero while the input lane is nonzero — the weight-side
    /// ineffectual pool (Cnvlutin2's weight-lane elision), disjoint
    /// from [`OpsStats::macs_skipped_input_zero`] by construction
    /// (input-zero lanes are counted there regardless of the weight).
    /// The three savings sources therefore partition `macs_total`
    /// exactly: skipped-output MACs (`macs_total - macs_done`) +
    /// input-zero + weight-zero + [`OpsStats::effectual_macs`].
    ///
    /// Like the input counter, a property of the data: counted
    /// identically in every [`WeightSparsity`] mode and both engines
    /// (scalar lane scan vs prepacked-bitmask popcount — same
    /// definition, proven equal in `rust/tests/weight_sparsity.rs`).
    pub macs_skipped_weight_zero: u64,
}

impl OpsStats {
    pub fn add(&mut self, o: &OpsStats) {
        self.macs_total += o.macs_total;
        self.macs_done += o.macs_done;
        self.bin_ops += o.bin_ops;
        self.weight_bytes_fetched += o.weight_bytes_fetched;
        self.weight_bytes_saved += o.weight_bytes_saved;
        self.neg_relu_macs += o.neg_relu_macs;
        self.relu_macs += o.relu_macs;
        self.true_zero_outputs += o.true_zero_outputs;
        self.macs_skipped_input_zero += o.macs_skipped_input_zero;
        self.macs_skipped_weight_zero += o.macs_skipped_weight_zero;
    }

    /// Fraction of all MACs avoided (the paper's "computations avoided").
    pub fn macs_saved_frac(&self) -> f64 {
        if self.macs_total == 0 {
            0.0
        } else {
            (self.macs_total - self.macs_done) as f64 / self.macs_total as f64
        }
    }

    /// Fraction of the *performed* MACs that were ineffectual
    /// (zero-valued input lane) — the engine's input-side savings pool.
    pub fn input_zero_frac(&self) -> f64 {
        if self.macs_done == 0 {
            0.0
        } else {
            self.macs_skipped_input_zero as f64 / self.macs_done as f64
        }
    }

    /// Fraction of the *performed* MACs whose weight lane is zero (and
    /// input lane nonzero) — the weight-side savings pool, same
    /// denominator as [`OpsStats::input_zero_frac`].
    pub fn weight_zero_frac(&self) -> f64 {
        if self.macs_done == 0 {
            0.0
        } else {
            self.macs_skipped_weight_zero as f64 / self.macs_done as f64
        }
    }

    /// MACs that survived output prediction *and* had a nonzero input
    /// lane *and* a nonzero weight lane — the work a triple-sided
    /// accelerator actually performs. Together with the three elidable
    /// pools this partitions `macs_total` exactly:
    /// `effectual + input_zero + weight_zero + (total - done) == total`.
    pub fn effectual_macs(&self) -> u64 {
        self.macs_done - self.macs_skipped_input_zero - self.macs_skipped_weight_zero
    }
}

/// Per-layer skip trace consumed by the cycle-level simulator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerTrace {
    pub node: usize,
    pub rows: usize,
    pub cout: usize,
    /// row-major (rows x cout): output was skipped (predicted zero).
    pub skipped: Vec<bool>,
    /// row-major (rows x cout): binCU evaluated this output.
    pub bin_eval: Vec<bool>,
}

/// Result of one sample's forward pass.
#[derive(Debug)]
pub struct RunResult {
    pub logits: Vec<f32>,
    pub pred: PredStats,
    pub ops: OpsStats,
    pub traces: Vec<LayerTrace>,
}

/// Which compute-layer implementation [`exec::run_sample`] uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSel {
    /// Tiled row-batched GEMM with prepacked weights (the default).
    Tiled,
    /// The original per-neuron GEMV path, retained as the bit-exact
    /// reference oracle (see `rust/tests/engine_equivalence.rs`) and as
    /// the baseline the perf benches compare against.
    ScalarRef,
}

/// Options for [`exec::run_sample`].
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    /// Compute the true value of skipped outputs too (needed for Fig 12
    /// categories and accuracy-loss accounting; costs extra host time but
    /// does not affect the modelled hardware).
    pub oracle: bool,
    /// Collect per-layer skip traces for the simulator.
    pub collect_trace: bool,
    /// Worker threads for row-tile parallelism within one sample
    /// (`<= 1` runs inline). Stats and traces merge deterministically,
    /// so results are identical for any thread count.
    pub threads: usize,
    /// Engine implementation (tiled GEMM vs scalar reference).
    pub engine: EngineSel,
    /// Input-side sparsity mode for the tiled engine: skip zero-valued
    /// input activation lanes via the compressed-lane kernels. All
    /// modes are bit-identical (see [`InputSparsity`]); `Auto` picks
    /// sparse vs dense per tile row on a density crossover.
    pub input_sparsity: InputSparsity,
    /// Weight-side sparsity mode for the tiled engine: elide zero
    /// weight lanes via the prepack-time compressed filter lists.
    /// `Off` and `Exact` are bit-identical (see [`WeightSparsity`]);
    /// `Threshold` prunes at session build and is the one
    /// accuracy-affecting knob. NOTE the free-function
    /// [`exec::run_batch`] path borrows its `Model` and therefore
    /// cannot prune — `Threshold` pruning is applied by
    /// [`crate::session::SessionBuilder::finish`] (the Session/CLI
    /// layer); below that it selects kernels exactly like `Exact`.
    pub weight_sparsity: WeightSparsity,
    /// Kernel-choice calibration profile: the density crossovers, tile
    /// height and thread suggestion that plan compilation freezes into
    /// each `ComputeStep`. Defaults to the deterministic compiled-in
    /// profile for the active ISA
    /// ([`crate::engine::tune::TuneProfile::host_default`]);
    /// `SessionBuilder::autotune` replaces it with a measured one and
    /// `--tune-profile` loads a shipped one. Host-performance only:
    /// every kernel the profile chooses between is bit-identical.
    pub tune: crate::engine::tune::TuneProfile,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            oracle: true,
            collect_trace: false,
            threads: 1,
            engine: EngineSel::Tiled,
            input_sparsity: InputSparsity::Auto,
            weight_sparsity: WeightSparsity::Off,
            tune: crate::engine::tune::TuneProfile::host_default(),
        }
    }
}

impl RunOpts {
    /// Use every available core for one sample (latency-optimal forward).
    pub fn parallel(self) -> RunOpts {
        RunOpts {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            ..self
        }
    }

    /// Select the per-neuron scalar reference engine.
    pub fn scalar_ref(self) -> RunOpts {
        RunOpts {
            engine: EngineSel::ScalarRef,
            ..self
        }
    }
}

/// Dataset-level evaluation summary.
#[derive(Clone, Debug, Default)]
pub struct EvalSummary {
    pub samples: usize,
    pub accuracy: f64,
    pub pred: PredStats,
    pub ops: OpsStats,
}

/// Evaluate `n` test samples through a prepared [`Session`].
pub struct MorRun;

impl MorRun {
    pub fn evaluate(arts: &crate::model::Artifacts, session: &Session, n: usize) -> EvalSummary {
        Self::eval_split(arts, session, n, false)
    }

    /// Like [`MorRun::evaluate`] but over the *calibration* split
    /// (training data) — used by [`choose_threshold`], exactly as the
    /// paper sets T "using the training data ... and verify its
    /// correctness using the unseen test data set" (Section 3.2.1).
    pub fn evaluate_calib(
        arts: &crate::model::Artifacts,
        session: &Session,
        n: usize,
    ) -> EvalSummary {
        Self::eval_split(arts, session, n, true)
    }

    fn eval_split(
        arts: &crate::model::Artifacts,
        session: &Session,
        n: usize,
        calib: bool,
    ) -> EvalSummary {
        let avail = if calib {
            arts.data.n_calib()
        } else {
            arts.data.n_test()
        };
        let n = n.min(avail);
        let mut pred = PredStats::default();
        let mut ops = OpsStats::default();
        let mut hits = 0usize;
        for i in 0..n {
            // calibration split: iterate from the END — aot.py fits the
            // regressions on the first 96 samples, so the tail is a clean
            // holdout for threshold selection
            let (sample, label) = if calib {
                let j = avail - 1 - i;
                (arts.data.calib_sample(j), arts.data.calib_y[j])
            } else {
                (arts.data.test_sample(i), arts.data.test_y[i])
            };
            let r = session.run_sample(sample);
            if argmax(&r.logits) == label as usize {
                hits += 1;
            }
            pred.add(&r.pred);
            ops.add(&r.ops);
        }
        EvalSummary {
            samples: n,
            accuracy: hits as f64 / n.max(1) as f64,
            pred,
            ops,
        }
    }
}

/// Per-DNN threshold selection (paper Section 3.2.1): sweep T from high to
/// low on the *training* (calibration) split and keep the lowest T whose
/// accuracy loss stays within `max_loss_pp` percentage points — i.e. the
/// most aggressive operating point that is still accuracy-safe.
/// Default holdout size for threshold selection (the tail of the
/// calibration split that aot.py leaves out of the regression fit).
pub const THRESHOLD_HOLDOUT: usize = 32;

pub fn choose_threshold(
    arts: &crate::model::Artifacts,
    cfg_base: &crate::config::PredictorConfig,
    max_loss_pp: f64,
    samples: usize,
) -> f32 {
    // strategies that never consult the rookie ignore the T gate — the
    // sweep would measure noise
    if !cfg_base.strategy.uses_binary() {
        return cfg_base.threshold;
    }
    let samples = samples.min(THRESHOLD_HOLDOUT);
    // one Session carries the whole sweep: the model (and its prepacked
    // weights) is cloned once, the policy is prepared once, and each
    // candidate T only recomputes the per-layer enabled sets — the
    // packed filter sign bits are shared, never re-packed
    let sess = Session::build(&arts.model)
        .params(&arts.predictor)
        .config(cfg_base.clone())
        .finish();
    let base = MorRun::evaluate_calib(arts, &sess.with_policy(None), samples);
    let mut best = 1.0f32;
    for &t in &[0.9f32, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2] {
        let s = MorRun::evaluate_calib(arts, &sess.with_threshold(t), samples);
        // two gates: holdout accuracy loss AND the (much smoother) wrong-skip
        // rate per output — the latter transfers almost exactly to the test
        // split, the former catches model-specific fragility
        let loss_ok = (base.accuracy - s.accuracy) * 100.0 <= max_loss_pp;
        let iz_ok = s.pred.frac(s.pred.incorrect_zero) <= 0.010;
        if loss_ok && iz_ok {
            best = t;
        } else {
            break;
        }
    }
    best
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    let _ = xs;
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // first wins ties
    }

    #[test]
    fn predstats_fractions() {
        let s = PredStats {
            correct_zero: 10,
            incorrect_zero: 2,
            correct_nonzero: 8,
            incorrect_nonzero: 4,
            not_applied: 76,
            relu_outputs: 100,
        };
        assert_eq!(s.applied(), 24);
        assert!((s.frac(s.correct_zero) - 0.10).abs() < 1e-12);
    }

    #[test]
    fn opsstats_saved_frac() {
        let o = OpsStats {
            macs_total: 100,
            macs_done: 80,
            ..Default::default()
        };
        assert!((o.macs_saved_frac() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn opsstats_triple_sided_partition() {
        let o = OpsStats {
            macs_total: 100,
            macs_done: 80,
            macs_skipped_input_zero: 25,
            macs_skipped_weight_zero: 15,
            ..Default::default()
        };
        assert_eq!(o.effectual_macs(), 40);
        // the three elidable pools + effectual work partition the total
        assert_eq!(
            (o.macs_total - o.macs_done)
                + o.macs_skipped_input_zero
                + o.macs_skipped_weight_zero
                + o.effectual_macs(),
            o.macs_total
        );
        assert!((o.weight_zero_frac() - 15.0 / 80.0).abs() < 1e-12);
        assert!((o.input_zero_frac() - 25.0 / 80.0).abs() < 1e-12);
        let zero = OpsStats::default();
        assert_eq!(zero.weight_zero_frac(), 0.0);
    }
}
