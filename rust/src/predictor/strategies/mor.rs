//! `mor` — the paper's hybrid Mixture-of-Rookies predictor (§3.2).
//!
//! A member neuron is skipped only when **both** components agree on a
//! zero output: its cluster proxy produced a zero ReLU output (spatial
//! correlation, Eq. 7) *and* the binarized dot-product rookie estimates
//! a negative pre-activation (self correlation, Eq. 1–2). Cluster
//! proxies themselves are always evaluated by the engine before this
//! mask fills — their ReLU inputs arrive via [`RowCtx::proxy_ri`].
//!
//! This strategy is bit-exact with the pre-strategy implementation:
//! same decision order (enabled gate → proxy gate → rookie consult),
//! same accounting (the rookie is only charged when actually consulted).

use super::{binary_says_skip, LayerState, RowCtx, SkipMask, ZeroPredictor};
use crate::config::PredictorConfig;
use crate::model::{LayerPredictor, Node};
use crate::predictor::OpsStats;

pub struct MorStrategy;

impl ZeroPredictor for MorStrategy {
    fn name(&self) -> &'static str {
        "mor"
    }

    fn describe(&self) -> &'static str {
        "hybrid: skip when the cluster proxy is zero AND the binary rookie agrees (paper default)"
    }

    fn prepare(&self, lp: &LayerPredictor, node: &Node, cfg: &PredictorConfig) -> LayerState {
        LayerState::build(lp, node, cfg, true, true)
    }

    #[inline]
    fn fill_skip_mask(
        &self,
        ctx: &RowCtx,
        mask: &mut SkipMask,
        bin_eval: &mut Option<&mut [bool]>,
        ops: &mut OpsStats,
    ) {
        for cl in &ctx.lp.clusters {
            let proxy_zero = ctx.proxy_ri[cl[0]] <= 0.0;
            for &f in &cl[1..] {
                // both components must agree; the rookie is only
                // consulted (and only accounted) when the proxy says
                // zero and the neuron's correlation passed the T gate
                let ap = ctx.lp.enabled[f];
                let sk = ap && proxy_zero && binary_says_skip(ctx, f, bin_eval, ops);
                mask.skip[f] = sk;
                mask.applied[f] = ap;
                if !sk {
                    mask.survivors.push(f);
                }
            }
        }
    }
}
