//! `binary` — the binarized dot-product rookie in isolation (paper
//! Fig 6): every neuron whose correlation passes the T gate is
//! predicted from the 1-bit dot product alone; no cluster structure,
//! no proxies.

use super::{binary_says_skip, LayerState, RowCtx, SkipMask, ZeroPredictor};
use crate::config::PredictorConfig;
use crate::model::{LayerPredictor, Node};
use crate::predictor::OpsStats;

pub struct BinaryStrategy;

impl ZeroPredictor for BinaryStrategy {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn describe(&self) -> &'static str {
        "binarized dot-product rookie alone (paper Fig 6 ablation)"
    }

    fn prepare(&self, lp: &LayerPredictor, node: &Node, cfg: &PredictorConfig) -> LayerState {
        LayerState::build(lp, node, cfg, false, true)
    }

    #[inline]
    fn fill_skip_mask(
        &self,
        ctx: &RowCtx,
        mask: &mut SkipMask,
        bin_eval: &mut Option<&mut [bool]>,
        ops: &mut OpsStats,
    ) {
        for f in 0..ctx.cout {
            let ap = ctx.lp.enabled[f];
            let sk = ap && binary_says_skip(ctx, f, bin_eval, ops);
            mask.skip[f] = sk;
            mask.applied[f] = ap;
            if !sk {
                mask.survivors.push(f);
            }
        }
    }
}
