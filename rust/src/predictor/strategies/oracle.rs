//! `oracle` — the perfect zero predictor: computes every true
//! pre-activation and skips exactly the outputs whose ReLU input is
//! non-positive. Not realizable in hardware (the decision *is* the
//! computation it saves), but the upper bound every realizable
//! strategy is measured against: maximal savings on predictable
//! layers, `incorrect_zero == 0` by construction, and logits that are
//! bit-identical to the dense forward (a skipped output's true ReLU
//! value is 0).
//!
//! The engines force ground-truth accounting for this strategy
//! regardless of `RunOpts::oracle`, so its Fig-12 categories are always
//! populated.

use super::{LayerState, RowCtx, SkipMask, ZeroPredictor};
use crate::config::PredictorConfig;
use crate::engine::{dot::dot_i8, relu_input};
use crate::model::{LayerPredictor, Node};
use crate::predictor::OpsStats;

pub struct OracleStrategy;

impl ZeroPredictor for OracleStrategy {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn describe(&self) -> &'static str {
        "perfect predictor: skips exactly the true zeros (upper bound; incorrect_zero == 0)"
    }

    fn prepare(&self, lp: &LayerPredictor, node: &Node, cfg: &PredictorConfig) -> LayerState {
        // needs neither the cluster structure nor the packed rookie
        // operands — the ground truth is the patch itself
        LayerState::build(lp, node, cfg, false, false)
    }

    #[inline]
    fn fill_skip_mask(
        &self,
        ctx: &RowCtx,
        mask: &mut SkipMask,
        _bin_eval: &mut Option<&mut [bool]>,
        _ops: &mut OpsStats,
    ) {
        for f in 0..ctx.cout {
            // the true dot product decides; this host-side work models
            // no hardware and is charged to no counter
            let d = dot_i8(ctx.patch, ctx.pf.filter(f));
            let ri = relu_input(d, ctx.dq, ctx.bn, f, ctx.res(f));
            let sk = ri <= 0.0;
            mask.skip[f] = sk;
            mask.applied[f] = true;
            if !sk {
                mask.survivors.push(f);
            }
        }
    }
}
