//! `none` — the dense baseline: never skips, never consults a
//! predictor component. Every output survives, every output is counted
//! `not_applied`; identical results (and identical accounting) to
//! running with no policy at all.

use super::{LayerState, RowCtx, SkipMask, ZeroPredictor};
use crate::config::PredictorConfig;
use crate::model::{LayerPredictor, Node};
use crate::predictor::OpsStats;

pub struct NoneStrategy;

impl ZeroPredictor for NoneStrategy {
    fn name(&self) -> &'static str {
        "none"
    }

    fn describe(&self) -> &'static str {
        "dense baseline: never skip (no predictor datapath)"
    }

    fn prepare(&self, lp: &LayerPredictor, node: &Node, cfg: &PredictorConfig) -> LayerState {
        LayerState::build(lp, node, cfg, false, false)
    }

    #[inline]
    fn fill_skip_mask(
        &self,
        ctx: &RowCtx,
        mask: &mut SkipMask,
        _bin_eval: &mut Option<&mut [bool]>,
        _ops: &mut OpsStats,
    ) {
        for f in 0..ctx.cout {
            mask.skip[f] = false;
            mask.applied[f] = false;
            mask.survivors.push(f);
        }
    }
}
