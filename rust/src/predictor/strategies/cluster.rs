//! `cluster` — the angle-cluster proxy in isolation (paper Fig 9
//! ablation): a member is skipped whenever its cluster proxy produced a
//! zero ReLU output, with no binary confirmation. Aggressive: highest
//! savings of the realizable strategies, highest wrong-skip rate.

use super::{LayerState, RowCtx, SkipMask, ZeroPredictor};
use crate::config::PredictorConfig;
use crate::model::{LayerPredictor, Node};
use crate::predictor::OpsStats;

pub struct ClusterStrategy;

impl ZeroPredictor for ClusterStrategy {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn describe(&self) -> &'static str {
        "angle-cluster proxy alone: skip whenever the proxy output is zero (paper Fig 9 ablation)"
    }

    fn prepare(&self, lp: &LayerPredictor, node: &Node, cfg: &PredictorConfig) -> LayerState {
        LayerState::build(lp, node, cfg, true, false)
    }

    #[inline]
    fn fill_skip_mask(
        &self,
        ctx: &RowCtx,
        mask: &mut SkipMask,
        _bin_eval: &mut Option<&mut [bool]>,
        _ops: &mut OpsStats,
    ) {
        for cl in &ctx.lp.clusters {
            let proxy_zero = ctx.proxy_ri[cl[0]] <= 0.0;
            for &f in &cl[1..] {
                mask.skip[f] = proxy_zero;
                mask.applied[f] = true;
                if !proxy_zero {
                    mask.survivors.push(f);
                }
            }
        }
    }
}
