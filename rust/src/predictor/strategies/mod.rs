//! Pluggable zero-output predictors ([`ZeroPredictor`]).
//!
//! The paper's contribution is a *predictor* — a policy that declares,
//! per (output row, filter) pair, "this ReLU output will be zero, skip
//! the dot product". This module turns that decision into a strategy
//! interface so alternative predictors (the paper's hybrid, its two
//! components in isolation, a perfect oracle, related work such as
//! Shomron et al.'s *Thanks for Nothing* or Zhu et al.'s *SparseNN*)
//! plug into the same execution engine without touching the tile loop.
//!
//! The contract has two halves:
//!
//! * [`ZeroPredictor::prepare`] — run once per (model layer, offline
//!   params, config); produces the [`LayerState`] the online decision
//!   reads (enabled set, clusters, fitted lines, packed sign bits).
//! * [`ZeroPredictor::fill_skip_mask`] — the hot-path half: called by
//!   both engines for every output row of a predictable layer, fills a
//!   [`SkipMask`] (skip / applied / survivors) from a read-only
//!   [`RowCtx`]. Side accounting (binCU op counts, `bin_eval` trace
//!   bits) goes through the `bin_eval`/`ops` out-params so the engine's
//!   stats stay bit-exact with the pre-strategy implementation.
//!
//! Dispatch is **enum-based and static** ([`Strategy`] implements the
//! trait by delegating to the per-strategy unit structs): the tile loop
//! never pays a vtable indirection, and the optimizer sees through the
//! match.
//!
//! ## Named strategies
//!
//! | name      | decision rule                                                | accuracy risk |
//! |-----------|--------------------------------------------------------------|---------------|
//! | `mor`     | hybrid (paper §3.2): proxy zero **and** binary rookie agree  | bounded, low  |
//! | `binary`  | binarized dot-product rookie alone (paper Fig 6)             | medium        |
//! | `cluster` | angle-cluster proxy alone (paper Fig 9 ablation)             | high          |
//! | `oracle`  | skips exactly the true zeros (upper bound, not realizable)   | none          |
//! | `none`    | never skips (dense baseline)                                 | none          |
//!
//! `oracle` reports `incorrect_zero == 0` by construction; `none`
//! reports `applied() == 0`. Both bracket the realizable strategies.
//!
//! ## Adding a strategy
//!
//! 1. Add a unit struct + `ZeroPredictor` impl in a new file here.
//! 2. Add a [`Strategy`] variant and extend [`Strategy::ALL`], the
//!    delegation match arms, and [`Strategy::parse`].
//! 3. `rust/tests/strategy_contracts.rs` picks it up via
//!    `Strategy::ALL`; add a contract test asserting its invariant.

mod binary;
mod cluster;
mod mor;
mod none;
mod oracle;

pub use binary::BinaryStrategy;
pub use cluster::ClusterStrategy;
pub use mor::MorStrategy;
pub use none::NoneStrategy;
pub use oracle::OracleStrategy;

use crate::config::PredictorConfig;
use crate::engine::gemm::PrepackedFilters;
use crate::model::{LayerPredictor, Node};
use crate::predictor::OpsStats;
use crate::util::bits::PackedVec;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Per-layer online decision state, built by [`ZeroPredictor::prepare`]
/// once per (model, params, config) and shared read-only by every
/// worker thread afterwards.
#[derive(Clone)]
pub struct LayerState {
    /// Pearson correlation per neuron (kept so a cached policy can be
    /// re-thresholded without re-reading the offline params).
    pub c: Vec<f32>,
    /// Binary component enabled per neuron: `c >= T`.
    pub enabled: Vec<bool>,
    /// Proxy of each neuron (proxy of a singleton = itself).
    pub proxy_of: Vec<usize>,
    /// Clusters `[proxy, members...]` after the angle gate.
    pub clusters: Vec<Vec<usize>>,
    /// Cluster heads, hoisted for the engines' always-evaluate phase.
    /// Empty for strategies that do not use the spatial component.
    pub proxies: Vec<usize>,
    /// Fitted line per neuron.
    pub m: Vec<f32>,
    pub b: Vec<f32>,
    /// Regression residual std per neuron (margin unit).
    pub s: Vec<f32>,
    /// Packed weight sign bits per filter (binCU operands). Behind an
    /// `Arc` so threshold sweeps share one packing across candidate
    /// policies; empty for strategies that never consult the rookie.
    pub packed_w: Arc<Vec<PackedVec>>,
}

impl LayerState {
    /// Shared constructor: strategies opt in to the cluster structure
    /// (`with_proxies`) and the packed rookie operands (`with_packed`).
    pub(crate) fn build(
        lp: &LayerPredictor,
        node: &Node,
        cfg: &PredictorConfig,
        with_proxies: bool,
        with_packed: bool,
    ) -> LayerState {
        let n = lp.neurons();
        let enabled: Vec<bool> = (0..n).map(|i| lp.c[i] >= cfg.threshold).collect();
        // angle gate (ablation knob): members whose closest-neighbour angle
        // exceeds the gate fall out of their cluster and become singletons.
        let mut clusters: Vec<Vec<usize>> = Vec::new();
        let mut singled: Vec<usize> = Vec::new();
        for cl in &lp.clusters {
            let proxy = cl[0];
            let mut kept = vec![proxy];
            for &m in &cl[1..] {
                let ang = lp.closest_angle_deg.get(m).copied().unwrap_or(90.0);
                if ang <= cfg.max_cluster_angle_deg {
                    kept.push(m);
                } else {
                    singled.push(m);
                }
            }
            clusters.push(kept);
        }
        for s in singled {
            clusters.push(vec![s]);
        }
        let mut proxy_of = vec![0usize; n];
        for cl in &clusters {
            for &m in cl {
                proxy_of[m] = cl[0];
            }
        }
        let proxies: Vec<usize> = if with_proxies {
            clusters.iter().map(|cl| cl[0]).collect()
        } else {
            Vec::new()
        };
        let packed_w: Vec<PackedVec> = if with_packed {
            (0..n).map(|f| PackedVec::from_weights(node.filter(f))).collect()
        } else {
            Vec::new()
        };
        LayerState {
            c: lp.c.clone(),
            enabled,
            proxy_of,
            clusters,
            proxies,
            m: lp.m.clone(),
            b: lp.b.clone(),
            s: lp.s.clone(),
            packed_w: Arc::new(packed_w),
        }
    }

    /// A candidate-threshold variant of this state: only the `enabled`
    /// set depends on T, so everything expensive (clusters, packed sign
    /// bits) is shared — the unit of work `choose_threshold` sweeps.
    pub fn with_threshold(&self, t: f32) -> LayerState {
        LayerState {
            enabled: self.c.iter().map(|&c| c >= t).collect(),
            ..self.clone()
        }
    }

    pub fn neurons(&self) -> usize {
        self.enabled.len()
    }

    pub fn is_proxy(&self, f: usize) -> bool {
        self.proxy_of[f] == f
    }
}

/// Read-only view of one output row, everything a strategy may consult
/// while deciding which filters to skip.
pub struct RowCtx<'a> {
    /// Model node index of the layer (numeric-observation keying in
    /// debug builds — see [`crate::plan::observe`]).
    pub node: usize,
    pub lp: &'a LayerState,
    pub cfg: &'a PredictorConfig,
    /// Packed activation sign bits of this row's patch (rookie operand).
    pub packed: &'a PackedVec,
    /// The im2col patch itself (alignment-padded) — ground truth for
    /// the oracle strategy.
    pub patch: &'a [i8],
    /// Prepacked filters of the layer (ground-truth dots).
    pub pf: &'a PrepackedFilters,
    /// ReLU inputs of the already-evaluated cluster proxies; indexed by
    /// neuron, only proxy slots are meaningful.
    pub proxy_ri: &'a [f32],
    /// This output row's residual values, if the node has a residual.
    pub res_row: Option<&'a [f32]>,
    /// BatchNorm (scale, shift) of the layer, if any.
    pub bn: Option<&'a (Vec<f32>, Vec<f32>)>,
    /// Dequantization factor `sw * sx`.
    pub dq: f32,
    /// Dot length (MAC/bit-op accounting unit).
    pub k: u64,
    /// Filters in the layer.
    pub cout: usize,
}

impl RowCtx<'_> {
    #[inline]
    pub fn res(&self, f: usize) -> f32 {
        self.res_row.map(|r| r[f]).unwrap_or(0.0)
    }
}

/// The strategy's verdict for one row, written by
/// [`ZeroPredictor::fill_skip_mask`]. All three views cover the layer's
/// `cout` filters; `survivors` lists the filters the engine must still
/// evaluate, in evaluation order.
pub struct SkipMask<'a> {
    pub skip: &'a mut [bool],
    pub applied: &'a mut [bool],
    pub survivors: &'a mut Vec<usize>,
}

/// A pluggable zero-output predictor. See the module docs for the
/// contract; implementations must be pure per-row functions of
/// [`RowCtx`] (the engines call them from multiple worker threads).
pub trait ZeroPredictor {
    /// Stable CLI / config identifier.
    fn name(&self) -> &'static str;

    /// One-line description (`mor predictors`).
    fn describe(&self) -> &'static str;

    /// Build the per-layer decision state, once per (layer, params,
    /// config).
    fn prepare(&self, lp: &LayerPredictor, node: &Node, cfg: &PredictorConfig) -> LayerState;

    /// Decide skip/applied for every member output of one row.
    ///
    /// `bin_eval` (when tracing) and `ops` receive the decision's side
    /// accounting: a strategy that consults the binary rookie for
    /// filter `f` must set `bin_eval[f]` and add the dot length to
    /// `ops.bin_ops` — exactly once per consultation — so traces and
    /// stats agree with the cycle-level simulator's replay.
    fn fill_skip_mask(
        &self,
        ctx: &RowCtx,
        mask: &mut SkipMask,
        bin_eval: &mut Option<&mut [bool]>,
        ops: &mut OpsStats,
    );
}

/// The built-in strategy registry: enum-based static dispatch over the
/// [`ZeroPredictor`] implementations (no `dyn` on the hot path).
///
/// ```
/// use mor::predictor::strategies::{Strategy, ZeroPredictor};
///
/// let s = Strategy::parse("oracle").unwrap();
/// assert_eq!(s.name(), "oracle");
/// assert!(Strategy::parse("learned").is_err());
/// // the legacy component toggles map onto named strategies
/// assert_eq!(Strategy::from_components(true, true), Strategy::Mor);
/// assert_eq!(Strategy::ALL.len(), 5);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Hybrid Mixture-of-Rookies (paper default; bit-exact with the
    /// pre-strategy implementation).
    Mor,
    /// Binarized dot-product rookie alone.
    Binary,
    /// Angle-cluster proxy alone.
    Cluster,
    /// Perfect predictor: skips exactly the true zeros.
    Oracle,
    /// Dense baseline: never skips.
    None,
}

impl Strategy {
    /// Every built-in strategy, in presentation order.
    pub const ALL: [Strategy; 5] = [
        Strategy::Mor,
        Strategy::Binary,
        Strategy::Cluster,
        Strategy::Oracle,
        Strategy::None,
    ];

    /// Parse a CLI / TOML strategy name.
    pub fn parse(name: &str) -> Result<Strategy> {
        for s in Strategy::ALL {
            if s.name() == name {
                return Ok(s);
            }
        }
        bail!(
            "unknown predictor strategy '{name}' (expected one of: {})",
            Strategy::ALL.map(|s| s.name()).join(", ")
        )
    }

    /// The strategy the legacy `use_clusters` / `use_binary` component
    /// toggles described (kept so old TOML files and CLI flags keep
    /// working).
    pub fn from_components(use_clusters: bool, use_binary: bool) -> Strategy {
        match (use_clusters, use_binary) {
            (true, true) => Strategy::Mor,
            (true, false) => Strategy::Cluster,
            (false, true) => Strategy::Binary,
            (false, false) => Strategy::None,
        }
    }

    /// Does the decision involve the spatial (cluster/proxy) component?
    /// Gates the engines' proxy-first evaluation order and the cycle
    /// simulator's proxy→member dependency modelling.
    pub fn uses_clusters(self) -> bool {
        matches!(self, Strategy::Mor | Strategy::Cluster)
    }

    /// Does the decision consult the binary rookie (binCU datapath)?
    pub fn uses_binary(self) -> bool {
        matches!(self, Strategy::Mor | Strategy::Binary)
    }
}

/// Delegation: `Strategy` is itself a [`ZeroPredictor`]; the engines
/// hold the enum and the match compiles to direct calls.
impl ZeroPredictor for Strategy {
    fn name(&self) -> &'static str {
        match self {
            Strategy::Mor => MorStrategy.name(),
            Strategy::Binary => BinaryStrategy.name(),
            Strategy::Cluster => ClusterStrategy.name(),
            Strategy::Oracle => OracleStrategy.name(),
            Strategy::None => NoneStrategy.name(),
        }
    }

    fn describe(&self) -> &'static str {
        match self {
            Strategy::Mor => MorStrategy.describe(),
            Strategy::Binary => BinaryStrategy.describe(),
            Strategy::Cluster => ClusterStrategy.describe(),
            Strategy::Oracle => OracleStrategy.describe(),
            Strategy::None => NoneStrategy.describe(),
        }
    }

    fn prepare(&self, lp: &LayerPredictor, node: &Node, cfg: &PredictorConfig) -> LayerState {
        match self {
            Strategy::Mor => MorStrategy.prepare(lp, node, cfg),
            Strategy::Binary => BinaryStrategy.prepare(lp, node, cfg),
            Strategy::Cluster => ClusterStrategy.prepare(lp, node, cfg),
            Strategy::Oracle => OracleStrategy.prepare(lp, node, cfg),
            Strategy::None => NoneStrategy.prepare(lp, node, cfg),
        }
    }

    #[inline]
    fn fill_skip_mask(
        &self,
        ctx: &RowCtx,
        mask: &mut SkipMask,
        bin_eval: &mut Option<&mut [bool]>,
        ops: &mut OpsStats,
    ) {
        match self {
            Strategy::Mor => MorStrategy.fill_skip_mask(ctx, mask, bin_eval, ops),
            Strategy::Binary => BinaryStrategy.fill_skip_mask(ctx, mask, bin_eval, ops),
            Strategy::Cluster => ClusterStrategy.fill_skip_mask(ctx, mask, bin_eval, ops),
            Strategy::Oracle => OracleStrategy.fill_skip_mask(ctx, mask, bin_eval, ops),
            Strategy::None => NoneStrategy.fill_skip_mask(ctx, mask, bin_eval, ops),
        }
    }
}

// ---------------------------------------------------------------------------
// Shared decision arithmetic (used by the strategies *and* by the
// scalar reference engine, which keeps an independent copy of the
// decision structure as the bit-exactness oracle)
// ---------------------------------------------------------------------------

/// Apply the layer's BatchNorm affine to an estimated pre-activation.
#[inline]
pub fn bn_affine(v: f32, bn: Option<&(Vec<f32>, Vec<f32>)>, f: usize) -> f32 {
    match bn {
        Some((scale, shift)) => v * scale[f] + shift[f],
        None => v,
    }
}

/// Skip-confidence margin for neuron `f`: `margin_sigmas` regression
/// residual stds, propagated through the (multiplicative) BN scale. The
/// raw paper rule (skip iff estimate < 0) is `margin_sigmas = 0`.
#[inline]
pub fn margin_of(
    lp: &LayerState,
    bn: Option<&(Vec<f32>, Vec<f32>)>,
    f: usize,
    margin_sigmas: f32,
) -> f32 {
    if margin_sigmas == 0.0 {
        return 0.0;
    }
    let scale = bn.map(|(sc, _)| sc[f].abs()).unwrap_or(1.0);
    margin_sigmas * lp.s[f] * scale
}

/// The binary rookie's skip verdict for one (row, filter) pair, with
/// its side accounting (binCU op count, `bin_eval` trace bit). Callers
/// gate the call on "rookie consulted" (enabled + proxy-zero in hybrid
/// mode), so the accounting only happens when the predictor ran.
#[inline]
pub(crate) fn binary_says_skip(
    ctx: &RowCtx,
    f: usize,
    bin_eval: &mut Option<&mut [bool]>,
    ops: &mut OpsStats,
) -> bool {
    let p_bin = ctx.packed.dot(&ctx.lp.packed_w[f]);
    #[cfg(debug_assertions)]
    crate::plan::observe::record_proxy(ctx.node, p_bin);
    ops.bin_ops += ctx.k;
    if let Some(be) = bin_eval.as_deref_mut() {
        be[f] = true;
    }
    let est = ctx.lp.m[f] * p_bin as f32 + ctx.lp.b[f];
    let est_ri = bn_affine(est, ctx.bn, f) + ctx.res(f);
    est_ri < -margin_of(ctx.lp, ctx.bn, f, ctx.cfg.margin_sigmas)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_strategy() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::parse(s.name()).unwrap(), s);
        }
        assert!(Strategy::parse("learned").is_err());
    }

    #[test]
    fn component_mapping_matches_legacy_toggles() {
        assert_eq!(Strategy::from_components(true, true), Strategy::Mor);
        assert_eq!(Strategy::from_components(true, false), Strategy::Cluster);
        assert_eq!(Strategy::from_components(false, true), Strategy::Binary);
        assert_eq!(Strategy::from_components(false, false), Strategy::None);
    }

    #[test]
    fn component_flags_consistent() {
        assert!(Strategy::Mor.uses_clusters() && Strategy::Mor.uses_binary());
        assert!(!Strategy::Binary.uses_clusters() && Strategy::Binary.uses_binary());
        assert!(Strategy::Cluster.uses_clusters() && !Strategy::Cluster.uses_binary());
        assert!(!Strategy::Oracle.uses_clusters() && !Strategy::Oracle.uses_binary());
        assert!(!Strategy::None.uses_clusters() && !Strategy::None.uses_binary());
    }

    #[test]
    fn names_are_unique_and_lowercase() {
        let names: Vec<&str> = Strategy::ALL.iter().map(|s| s.name()).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
        for n in names {
            assert_eq!(n, n.to_lowercase());
        }
    }
}
