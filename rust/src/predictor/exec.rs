//! The MoR-aware forward pass: evaluates a *batch* of samples layer by
//! layer, skipping neuron evaluations the hybrid predictor declares zero
//! (Section 3.2).
//!
//! Two interchangeable engines implement each compute layer:
//!
//! * **Tiled** (default) — a cache-blocked, row-batched im2col GEMM with a
//!   two-phase predict-then-evaluate dataflow. The batch's output rows
//!   form one sample-major row space, so a tile of [`TILE_ROWS`] patches
//!   is filled across request boundaries — the serving coordinator's
//!   micro-batches keep the micro-kernel's weight blocks hot even when a
//!   single request contributes only a handful of rows. Per tile:
//!   (1) gather the patches (each from its own sample's quantized input),
//!   (2) run the packed binary predictor + cluster-proxy logic over the
//!   whole tile to produce a skip mask, (3) run the multi-filter
//!   micro-kernel ([`crate::engine::gemm`]) only over surviving
//!   (row, filter) pairs. The engine is **dual-sided sparse**: each tile
//!   row additionally carries a compressed nonzero-lane list of its
//!   patch, and [`RunOpts::input_sparsity`] selects (per row, on a
//!   density crossover in `Auto` mode) whether the surviving dots run
//!   on the dense block kernel or the input-zero-skipping sparse one —
//!   a pure kernel choice, bit-identical either way. Row tiles are
//!   optionally parallelized across `std::thread::scope` workers
//!   ([`RunOpts::threads`]); stats and traces are accounted per sample
//!   and merge deterministically.
//! * **ScalarRef** — the original per-neuron GEMV path, retained as the
//!   bit-exact test oracle and perf baseline. Logits, [`OpsStats`],
//!   [`PredStats`] and traces are identical between the two (all dot
//!   products are exact integer sums and the per-output float tail is the
//!   same code), which `rust/tests/engine_equivalence.rs` asserts.
//!
//! [`run_batch`] is bit-identical to mapping [`run_sample`] over the batch
//! (every output depends only on its own patch and filter, and per-row
//! accounting lands in its sample's counters) — asserted for batch sizes
//! 1..16 by `rust/tests/batch_equivalence.rs`.
//!
//! Execution order per output position mirrors the accelerator's Neurons
//! Controller (Section 4.1): proxies first (they are always evaluated and
//! "unlock" their cluster members), then members — each member whose proxy
//! produced a zero ReLU output is checked with the binary predictor, and
//! skipped only when *both* components agree on zero.

use super::strategies::{
    bn_affine, margin_of, LayerState, RowCtx, SkipMask, Strategy, ZeroPredictor,
};
use super::{EngineSel, LayerTrace, MorPolicy, OpsStats, PredStats, RunOpts, RunResult};
use crate::engine::gemm::{self, PatchTile, PrepackedFilters, NR, TILE_ROWS};
use crate::engine::{
    self, dot::dot_i8, relu_input, ConvGeom, InputSparsity, PatchGather, QuantizedTensor,
    Tensor,
};
use crate::model::{Model, Node};

/// Run one sample (H*W*C float input) through the model.
pub fn run_sample(
    model: &Model,
    policy: Option<&MorPolicy>,
    input: &[f32],
    opts: RunOpts,
) -> RunResult {
    run_batch(model, policy, &[input], opts)
        .pop()
        .expect("run_batch returns one result per input")
}

/// Run a batch of samples through the model, layer-synchronously: every
/// compute layer advances all `inputs.len()` samples at once, so im2col
/// row tiles are filled with patches from multiple samples and each
/// prepacked weight block is streamed once per tile for the whole batch.
///
/// Results are **bit-identical** to calling [`run_sample`] per input —
/// logits, [`OpsStats`], [`PredStats`] and traces — for any batch size,
/// thread count, tile alignment (ragged final tiles included), or
/// [`InputSparsity`] mode.
///
/// ```
/// use mor::model::synth;
/// use mor::predictor::{exec, RunOpts};
///
/// let model = synth::tiny_serving_model(7);
/// let (h, w, c) = model.input_shape;
/// let xs: Vec<Vec<f32>> = (0..3).map(|i| vec![0.1 * i as f32; h * w * c]).collect();
/// let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
/// let results = exec::run_batch(&model, None, &inputs, RunOpts::default());
/// assert_eq!(results.len(), 3);
/// assert_eq!(results[0].logits.len(), 4); // tiny_serving_model has 4 classes
/// ```
pub fn run_batch(
    model: &Model,
    policy: Option<&MorPolicy>,
    inputs: &[&[f32]],
    opts: RunOpts,
) -> Vec<RunResult> {
    let b = inputs.len();
    if b == 0 {
        return Vec::new();
    }
    let (h, w, c) = model.input_shape;
    let input_ts: Vec<Tensor> = inputs
        .iter()
        .map(|x| Tensor::from_slice(h, w, c, x))
        .collect();
    let relu_layers = model.relu_layers();

    let mut outs: Vec<Vec<Tensor>> = (0..b)
        .map(|_| Vec::with_capacity(model.nodes.len()))
        .collect();
    let mut pred = vec![PredStats::default(); b];
    let mut ops = vec![OpsStats::default(); b];
    let mut traces: Vec<Vec<LayerTrace>> = (0..b).map(|_| Vec::new()).collect();

    for (i, node) in model.nodes.iter().enumerate() {
        match node {
            Node::Conv { .. } | Node::Fc { .. } => {
                let lp = policy.and_then(|p| p.layers.get(&i));
                let pol = lp.map(|l| (l, policy.unwrap()));
                let is_relu_layer = relu_layers.contains(&i);
                match opts.engine {
                    EngineSel::ScalarRef => {
                        for s in 0..b {
                            let src = src_of(&input_ts[s], &outs[s], node);
                            let residual = res_tensor(node, &outs[s]);
                            let out = compute_layer_scalar(
                                node,
                                src,
                                residual,
                                pol,
                                is_relu_layer,
                                i,
                                opts,
                                &mut pred[s],
                                &mut ops[s],
                                &mut traces[s],
                            );
                            outs[s].push(out);
                        }
                    }
                    EngineSel::Tiled => compute_layer_tiled(
                        model.prepacked().layer(i),
                        node,
                        &input_ts,
                        &mut outs,
                        pol,
                        is_relu_layer,
                        i,
                        opts,
                        &mut pred,
                        &mut ops,
                        &mut traces,
                    ),
                }
            }
            Node::MaxPool { size, .. } => {
                for s in 0..b {
                    let src = src_of(&input_ts[s], &outs[s], node);
                    let out = engine::maxpool(src, *size);
                    outs[s].push(out);
                }
            }
            Node::Gap { .. } => {
                for s in 0..b {
                    let src = src_of(&input_ts[s], &outs[s], node);
                    let out = engine::gap(src);
                    outs[s].push(out);
                }
            }
            Node::Relu { .. } => {
                for s in 0..b {
                    let src = src_of(&input_ts[s], &outs[s], node);
                    let out = engine::relu(src);
                    outs[s].push(out);
                }
            }
        }
    }

    let mut results = Vec::with_capacity(b);
    for s in 0..b {
        results.push(RunResult {
            logits: outs[s].last().map(|t| t.data.clone()).unwrap_or_default(),
            pred: pred[s],
            ops: ops[s],
            traces: std::mem::take(&mut traces[s]),
        });
    }
    results
}

/// The input tensor a node reads: the model input or a prior node's output.
fn src_of<'a>(input: &'a Tensor, outs: &'a [Tensor], node: &Node) -> &'a Tensor {
    if node.consumes() < 0 {
        input
    } else {
        &outs[node.consumes() as usize]
    }
}

fn res_tensor<'a>(node: &Node, outs: &'a [Tensor]) -> Option<&'a Tensor> {
    match node {
        Node::Conv { res_from, .. } | Node::Fc { res_from, .. } => {
            res_from.map(|r| &outs[r])
        }
        _ => None,
    }
}

/// Output geometry + kernel parameters of a compute node (FC layers are
/// 1×1 "convolutions" over the h*w positions).
fn geom_of(node: &Node, src: &Tensor) -> (ConvGeom, usize, usize, usize) {
    match node {
        Node::Conv {
            kh, kw, stride, pad_same, ..
        } => (
            engine::conv_geom(src.h, src.w, *kh, *kw, *stride, *pad_same),
            *kh,
            *kw,
            *stride,
        ),
        _ => (
            ConvGeom {
                oh: src.h,
                ow: src.w,
                pad_top: 0,
                pad_left: 0,
            },
            0,
            0,
            1,
        ),
    }
}

// ---------------------------------------------------------------------------
// Tiled engine (batch-native)
// ---------------------------------------------------------------------------
//
// The batch's output rows form one sample-major global row space of
// `b * rows` rows (global row g → sample g / rows, sample-local row
// g % rows). Tiles and worker ranges are carved from the global space, so
// a tile may hold patches from several samples; every per-row accounting
// lands in that row's sample's counters, which keeps the batch bit-exact
// with the per-sample path.

/// Shared read-only context for one layer's tile workers.
struct TiledCtx<'a> {
    node: &'a Node,
    pf: &'a PrepackedFilters,
    /// One quantized input per sample of the batch.
    qts: &'a [QuantizedTensor],
    /// One optional residual tensor per sample of the batch.
    residuals: &'a [Option<&'a Tensor>],
    policy: Option<(&'a LayerState, &'a MorPolicy)>,
    geom: ConvGeom,
    kh: usize,
    kw: usize,
    stride: usize,
    /// Output rows per sample (`geom.oh * geom.ow`).
    rows: usize,
    cout: usize,
    k: u64,
    dq: f32,
    bn: Option<&'a (Vec<f32>, Vec<f32>)>,
    node_relu: bool,
    is_relu_layer: bool,
    is_conv: bool,
    oracle: bool,
    /// Input-side sparsity mode (kernel selection only — results are
    /// bit-identical in every mode).
    sparsity: InputSparsity,
}

impl TiledCtx<'_> {
    #[inline]
    fn res_at(&self, s: usize, row: usize, f: usize) -> f32 {
        self.residuals[s]
            .map(|r| r.data[row * self.cout + f])
            .unwrap_or(0.0)
    }
}

#[allow(clippy::too_many_arguments)]
fn compute_layer_tiled(
    pf: &PrepackedFilters,
    node: &Node,
    inputs: &[Tensor],
    outs: &mut [Vec<Tensor>],
    policy: Option<(&LayerState, &MorPolicy)>,
    is_relu_layer: bool,
    node_idx: usize,
    opts: RunOpts,
    pred: &mut [PredStats],
    ops: &mut [OpsStats],
    traces: &mut [Vec<LayerTrace>],
) {
    let b = inputs.len();
    let (sx, sw, bn, node_relu) = layer_params(node);
    // all samples share one geometry: same model, same input shape
    let (geom, kh, kw, stride) = geom_of(node, src_of(&inputs[0], &outs[0], node));
    let rows = geom.oh * geom.ow;
    let total_rows = rows * b;
    let cout = node.cout();

    // global sample-major buffers; split per sample after the compute
    let mut out = vec![0.0f32; total_rows * cout];
    let mut skipped =
        if opts.collect_trace { vec![false; total_rows * cout] } else { Vec::new() };
    let mut bin_eval =
        if opts.collect_trace { vec![false; total_rows * cout] } else { Vec::new() };

    {
        // the residual refs borrow `outs` for the duration of the compute;
        // the new tensors are pushed only after this scope releases them
        let qts: Vec<QuantizedTensor> = (0..b)
            .map(|s| QuantizedTensor::new(src_of(&inputs[s], &outs[s], node), sx))
            .collect();
        let residuals: Vec<Option<&Tensor>> =
            (0..b).map(|s| res_tensor(node, &outs[s])).collect();
        let ctx = TiledCtx {
            node,
            pf,
            qts: &qts,
            residuals: &residuals,
            policy,
            geom,
            kh,
            kw,
            stride,
            rows,
            cout,
            k: node.k_len() as u64,
            dq: sw * sx,
            bn,
            node_relu,
            is_relu_layer,
            is_conv: matches!(node, Node::Conv { .. }),
            // the oracle strategy's skip accounting IS the ground truth:
            // force it on so its Fig-12 categories are always populated
            oracle: opts.oracle
                || policy.is_some_and(|(_, mp)| mp.cfg.strategy == Strategy::Oracle),
            sparsity: opts.input_sparsity,
        };

        let n_tiles = total_rows.div_ceil(TILE_ROWS).max(1);
        let workers = opts.threads.max(1).min(n_tiles);
        if workers <= 1 {
            let trace = opts
                .collect_trace
                .then(|| (&mut skipped[..], &mut bin_eval[..]));
            let (p, o) = process_row_range(&ctx, 0, total_rows, &mut out, trace);
            for s in 0..b {
                pred[s].add(&p[s]);
                ops[s].add(&o[s]);
            }
        } else {
            // contiguous tile-aligned global row ranges, one per worker;
            // every buffer is split into disjoint per-range slices so
            // workers never share mutable state, and per-sample stats
            // merge in range order (deterministic)
            let tiles_per = n_tiles.div_ceil(workers);
            let mut ranges: Vec<(usize, usize)> = Vec::new();
            let mut start = 0usize;
            while start < total_rows {
                let end = total_rows.min(start + tiles_per * TILE_ROWS);
                ranges.push((start, end));
                start = end;
            }
            let mut out_parts: Vec<&mut [f32]> = Vec::with_capacity(ranges.len());
            let mut sk_parts: Vec<&mut [bool]> = Vec::with_capacity(ranges.len());
            let mut be_parts: Vec<&mut [bool]> = Vec::with_capacity(ranges.len());
            let mut out_rest: &mut [f32] = &mut out;
            let mut sk_rest: &mut [bool] = &mut skipped;
            let mut be_rest: &mut [bool] = &mut bin_eval;
            for &(r0, r1) in &ranges {
                let n = (r1 - r0) * cout;
                let (head, tail) = std::mem::take(&mut out_rest).split_at_mut(n);
                out_parts.push(head);
                out_rest = tail;
                if opts.collect_trace {
                    let (head, tail) = std::mem::take(&mut sk_rest).split_at_mut(n);
                    sk_parts.push(head);
                    sk_rest = tail;
                    let (head, tail) = std::mem::take(&mut be_rest).split_at_mut(n);
                    be_parts.push(head);
                    be_rest = tail;
                }
            }
            let mut trace_parts: Vec<Option<(&mut [bool], &mut [bool])>> = if opts.collect_trace
            {
                sk_parts
                    .into_iter()
                    .zip(be_parts)
                    .map(|(s, b)| Some((s, b)))
                    .collect()
            } else {
                ranges.iter().map(|_| None).collect()
            };

            let stats: Vec<(Vec<PredStats>, Vec<OpsStats>)> = std::thread::scope(|s| {
                let ctx = &ctx;
                let handles: Vec<_> = ranges
                    .iter()
                    .zip(out_parts)
                    .zip(trace_parts.drain(..))
                    .map(|((&(r0, r1), out_part), trace_part)| {
                        s.spawn(move || process_row_range(ctx, r0, r1, out_part, trace_part))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("tile worker panicked"))
                    .collect()
            });
            for (p, o) in stats {
                for s in 0..b {
                    pred[s].add(&p[s]);
                    ops[s].add(&o[s]);
                }
            }
        }
    }

    // scatter the global buffers back into per-sample tensors/traces
    for s in 0..b {
        let span = s * rows * cout..(s + 1) * rows * cout;
        if opts.collect_trace {
            traces[s].push(LayerTrace {
                node: node_idx,
                rows,
                cout,
                skipped: skipped[span.clone()].to_vec(),
                bin_eval: bin_eval[span.clone()].to_vec(),
            });
        }
        let mut t = Tensor::new(geom.oh, geom.ow, cout);
        t.data.copy_from_slice(&out[span]);
        outs[s].push(t);
    }
}

/// Process global rows `row0..row1` tile by tile. `out` and the optional
/// trace slices cover exactly those rows; returned stats are this range's
/// per-sample share (indexed by sample, length = batch size).
fn process_row_range(
    ctx: &TiledCtx,
    row0: usize,
    row1: usize,
    out: &mut [f32],
    trace: Option<(&mut [bool], &mut [bool])>,
) -> (Vec<PredStats>, Vec<OpsStats>) {
    let b = ctx.qts.len();
    let mut pred = vec![PredStats::default(); b];
    let mut ops = vec![OpsStats::default(); b];
    let cout = ctx.cout;
    let k = ctx.k;
    let (mut tr_skip, mut tr_bin) = match trace {
        Some((sk, be)) => (Some(sk), Some(be)),
        None => (None, None),
    };

    let mut pgs: Vec<PatchGather> = ctx.qts.iter().map(PatchGather::new).collect();
    let mut tile = PatchTile::new(ctx.node.k_len(), ctx.sparsity != InputSparsity::Off);
    let mut tile_sample = [0usize; TILE_ROWS]; // sample of each tile row
    // per-row kernel choice: iterate only nonzero input lanes when the
    // mode (and, in Auto, the measured density) says so — either kernel
    // yields the exact same integer dots
    let mut row_sparse = [false; TILE_ROWS];
    let mut dots = vec![0i32; TILE_ROWS * cout];
    let mut ri_cache = vec![0.0f32; cout]; // current row's proxy ReLU inputs
    let mut skip = vec![false; cout];
    let mut applied = vec![false; cout];
    let mut survivors: Vec<usize> = Vec::with_capacity(cout);
    let mut blk = [0i32; NR];

    // cluster proxies are row-invariant (prepared by the strategy):
    // empty for strategies without a spatial component
    let proxies: &[usize] = ctx.policy.map(|(lp, _)| lp.proxies.as_slice()).unwrap_or(&[]);

    let mut t0 = row0;
    while t0 < row1 {
        let trows = TILE_ROWS.min(row1 - t0);

        // ---- phase 1: gather a tile of im2col patches (cross-sample) ----
        for r in 0..trows {
            let g = t0 + r;
            let (s, row) = (g / ctx.rows, g % ctx.rows);
            tile_sample[r] = s;
            let pg = &mut pgs[s];
            if ctx.is_conv {
                let (oy, ox) = (row / ctx.geom.ow, row % ctx.geom.ow);
                pg.gather(ctx.geom, ctx.kh, ctx.kw, ctx.stride, oy, ox);
            } else {
                pg.gather_fc(row);
            }
            row_sparse[r] = match ctx.sparsity {
                InputSparsity::Off => false,
                InputSparsity::On => tile.has_sparse(),
                InputSparsity::Auto => {
                    tile.has_sparse() && gemm::sparse_wins(pg.nnz, ctx.node.k_len())
                }
            };
            // the compression pass only runs for rows that will use the
            // sparse kernel — dense rows pay one compare, nothing more
            tile.set_row(r, &pg.patch, &pg.packed, pg.nnz, row_sparse[r]);
            ops[s].macs_total += k * cout as u64;
            if ctx.is_relu_layer {
                ops[s].relu_macs += k * cout as u64;
                pred[s].relu_outputs += cout as u64;
            }
        }

        match ctx.policy {
            // ---- dense layer: every (row, filter) pair survives. Filter
            // blocks run outermost so each weight block is loaded once per
            // tile and reused across all TILE_ROWS patches. ---------------
            None => {
                let mut f0 = 0;
                while f0 < cout {
                    let nf = NR.min(cout - f0);
                    for r in 0..trows {
                        if row_sparse[r] {
                            let (li, lv) = tile.lanes(r);
                            gemm::dot_block_sparse(li, lv, ctx.pf, f0, nf, &mut blk);
                        } else {
                            gemm::dot_block(tile.patch(r), ctx.pf, f0, nf, &mut blk);
                        }
                        dots[r * cout + f0..r * cout + f0 + nf].copy_from_slice(&blk[..nf]);
                    }
                    f0 += NR;
                }
                for r in 0..trows {
                    let g = t0 + r;
                    let (s, row) = (tile_sample[r], g % ctx.rows);
                    let zeros = k - tile.nnz(r) as u64;
                    let out_row = &mut out[(g - row0) * cout..(g - row0 + 1) * cout];
                    for (f, o) in out_row.iter_mut().enumerate() {
                        let d = dots[r * cout + f];
                        account_eval(
                            ctx, d, s, row, f, false, zeros, o, &mut pred[s], &mut ops[s],
                        );
                    }
                }
            }

            Some((lp, mp)) => {
                let strategy = mp.cfg.strategy;

                // ---- phase 2a: proxies — always fully evaluated, filter
                // blocks outer for weight reuse across the tile -----------
                for chunk in proxies.chunks(NR) {
                    for r in 0..trows {
                        if row_sparse[r] {
                            let (li, lv) = tile.lanes(r);
                            gemm::dot_block_indexed_sparse(li, lv, ctx.pf, chunk, &mut blk);
                        } else {
                            gemm::dot_block_indexed(tile.patch(r), ctx.pf, chunk, &mut blk);
                        }
                        for (j, &f) in chunk.iter().enumerate() {
                            dots[r * cout + f] = blk[j];
                        }
                    }
                }

                for r in 0..trows {
                    let g = t0 + r;
                    let (s, row) = (tile_sample[r], g % ctx.rows);
                    let zeros = k - tile.nnz(r) as u64;
                    let local = (g - row0) * cout;
                    let out_row = &mut out[local..local + cout];

                    for &p in proxies {
                        let ri = account_eval(
                            ctx, dots[r * cout + p], s, row, p, false, zeros,
                            &mut out_row[p], &mut pred[s], &mut ops[s],
                        );
                        ri_cache[p] = ri;
                    }

                    // ---- phase 2b: skip decisions (strategy dispatch) ----
                    survivors.clear();
                    let rctx = RowCtx {
                        lp,
                        cfg: &mp.cfg,
                        packed: tile.packed(r),
                        patch: tile.patch(r),
                        pf: ctx.pf,
                        proxy_ri: &ri_cache,
                        res_row: ctx.residuals[s]
                            .map(|t| &t.data[row * cout..(row + 1) * cout]),
                        bn: ctx.bn,
                        dq: ctx.dq,
                        k: ctx.k,
                        cout,
                    };
                    let mut be_row =
                        tr_bin.as_deref_mut().map(|be| &mut be[local..local + cout]);
                    strategy.fill_skip_mask(
                        &rctx,
                        &mut SkipMask {
                            skip: &mut skip,
                            applied: &mut applied,
                            survivors: &mut survivors,
                        },
                        &mut be_row,
                        &mut ops[s],
                    );

                    // ---- phase 3: GEMM over surviving pairs only (the
                    // row's kernel flavour follows its input density) --
                    for chunk in survivors.chunks(NR) {
                        if row_sparse[r] {
                            let (li, lv) = tile.lanes(r);
                            gemm::dot_block_indexed_sparse(li, lv, ctx.pf, chunk, &mut blk);
                        } else {
                            gemm::dot_block_indexed(tile.patch(r), ctx.pf, chunk, &mut blk);
                        }
                        for (j, &f) in chunk.iter().enumerate() {
                            account_eval(
                                ctx, blk[j], s, row, f, applied[f], zeros, &mut out_row[f],
                                &mut pred[s], &mut ops[s],
                            );
                        }
                    }

                    // ---- skipped outputs: zero + optional oracle truth ---
                    // (proxies never set `skip`, so a full scan equals the
                    // strategy-shaped iteration)
                    for f in 0..cout {
                        if skip[f] {
                            account_skip(
                                ctx, tile.patch(r), local, s, row, f, &mut out_row[f],
                                tr_skip.as_deref_mut(), &mut pred[s], &mut ops[s],
                            );
                        }
                    }
                }
            }
        }
        t0 += trows;
    }
    (pred, ops)
}

/// Account one fully-evaluated output (dot already computed). Matches the
/// scalar path's `full_eval!` (with `applied = false`) and the non-skip
/// branch of `finish_neuron` exactly. `zeros` is the patch's zero-lane
/// count (`k - nnz`) — the ineffectual share of this output's MACs.
/// Returns the ReLU input.
#[allow(clippy::too_many_arguments)]
#[inline]
fn account_eval(
    ctx: &TiledCtx,
    d: i32,
    s: usize,
    row: usize,
    f: usize,
    applied: bool,
    zeros: u64,
    out_val: &mut f32,
    pred: &mut PredStats,
    ops: &mut OpsStats,
) -> f32 {
    let ri = relu_input(d, ctx.dq, ctx.bn, f, ctx.res_at(s, row, f));
    *out_val = if ctx.node_relu { ri.max(0.0) } else { ri };
    ops.macs_done += ctx.k;
    ops.macs_skipped_input_zero += zeros;
    ops.weight_bytes_fetched += ctx.k;
    if ctx.is_relu_layer {
        if ri <= 0.0 {
            ops.neg_relu_macs += ctx.k;
            ops.true_zero_outputs += 1;
        }
        if applied {
            if ri <= 0.0 {
                pred.incorrect_nonzero += 1;
            } else {
                pred.correct_nonzero += 1;
            }
        } else {
            pred.not_applied += 1;
        }
    }
    ri
}

/// Account one skipped output. Matches the skip branch of the scalar
/// path's `finish_neuron` exactly (`local` = row offset within this
/// worker's trace slice).
#[allow(clippy::too_many_arguments)]
fn account_skip(
    ctx: &TiledCtx,
    patch: &[i8],
    local: usize,
    s: usize,
    row: usize,
    f: usize,
    out_val: &mut f32,
    tr_skip: Option<&mut [bool]>,
    pred: &mut PredStats,
    ops: &mut OpsStats,
) {
    *out_val = 0.0;
    ops.weight_bytes_saved += ctx.k;
    if let Some(sk) = tr_skip {
        sk[local + f] = true;
    }
    if ctx.oracle {
        // ground truth for Fig 12 / accuracy accounting
        let d = dot_i8(patch, ctx.pf.filter(f));
        let ri = relu_input(d, ctx.dq, ctx.bn, f, ctx.res_at(s, row, f));
        if ctx.is_relu_layer {
            if ri <= 0.0 {
                pred.correct_zero += 1;
                ops.neg_relu_macs += ctx.k;
                ops.true_zero_outputs += 1;
            } else {
                pred.incorrect_zero += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar reference engine (the original per-neuron GEMV path)
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn compute_layer_scalar(
    node: &Node,
    src: &Tensor,
    residual: Option<&Tensor>,
    policy: Option<(&LayerState, &MorPolicy)>,
    is_relu_layer: bool,
    node_idx: usize,
    opts: RunOpts,
    pred: &mut PredStats,
    ops: &mut OpsStats,
    traces: &mut Vec<LayerTrace>,
) -> Tensor {
    // the oracle strategy's skip accounting IS the ground truth: force
    // it on (mirrors the tiled engine) so both engines stay bit-exact
    let opts = RunOpts {
        oracle: opts.oracle
            || policy.is_some_and(|(_, mp)| mp.cfg.strategy == Strategy::Oracle),
        ..opts
    };
    let (sx, sw, bn, node_relu) = layer_params(node);
    let dq = sw * sx;
    let cout = node.cout();
    let k = node.k_len() as u64;

    let (geom, kh, kw, stride) = geom_of(node, src);
    let rows = geom.oh * geom.ow;
    let mut out = Tensor::new(geom.oh, geom.ow, cout);

    let qt = QuantizedTensor::new(src, sx);
    let mut pg = PatchGather::new(&qt);
    let mut trace = if opts.collect_trace {
        Some(LayerTrace {
            node: node_idx,
            rows,
            cout,
            skipped: vec![false; rows * cout],
            bin_eval: vec![false; rows * cout],
        })
    } else {
        None
    };

    // scratch for proxy ReLU inputs (hybrid / clusters mode)
    let mut relu_in_cache: Vec<f32> = vec![0.0; cout];

    for row in 0..rows {
        match node {
            Node::Conv { .. } => {
                pg.gather(geom, kh, kw, stride, row / geom.ow, row % geom.ow)
            }
            _ => pg.gather_fc(row),
        }
        ops.macs_total += k * cout as u64;
        if is_relu_layer {
            ops.relu_macs += k * cout as u64;
            pred.relu_outputs += cout as u64;
        }

        let res_at = |f: usize| residual.map(|r| r.data[row * cout + f]).unwrap_or(0.0);

        // closure-free full evaluation to keep borrows simple
        macro_rules! full_eval {
            ($f:expr) => {{
                let f = $f;
                let d = dot_i8(&pg.patch, node.filter(f));
                let ri = relu_input(d, dq, bn, f, res_at(f));
                out.data[row * cout + f] = if node_relu { ri.max(0.0) } else { ri };
                ops.macs_done += k;
                ops.macs_skipped_input_zero += k - pg.nnz as u64;
                ops.weight_bytes_fetched += k;
                if is_relu_layer && ri <= 0.0 {
                    ops.neg_relu_macs += k;
                    ops.true_zero_outputs += 1;
                }
                ri
            }};
        }

        match policy {
            None => {
                for f in 0..cout {
                    full_eval!(f);
                    if is_relu_layer {
                        pred.not_applied += 1;
                    }
                }
            }
            Some((_lp, mp)) if mp.cfg.strategy == Strategy::Oracle => {
                // oracle: the true pre-activation decides; skipped
                // outputs are exactly the true zeros
                for f in 0..cout {
                    let d = dot_i8(&pg.patch, node.filter(f));
                    let ri = relu_input(d, dq, bn, f, res_at(f));
                    finish_neuron(
                        f, ri <= 0.0, true, row, cout, k, node, &pg, dq, bn, res_at(f),
                        node_relu, is_relu_layer, opts, &mut out, pred, ops, &mut trace,
                    );
                }
            }
            Some((lp, mp)) if !mp.cfg.strategy.uses_clusters() => {
                // binary-only mode (Fig 6) — and the `none` strategy,
                // whose rookie is never consulted so nothing is applied
                for f in 0..cout {
                    let mut skip = false;
                    let applied = mp.cfg.strategy.uses_binary() && lp.enabled[f];
                    if applied {
                        let p_bin = pg.packed.dot(&lp.packed_w[f]);
                        ops.bin_ops += k;
                        if let Some(t) = trace.as_mut() {
                            t.bin_eval[row * cout + f] = true;
                        }
                        let est = lp.m[f] * p_bin as f32 + lp.b[f];
                        let est_ri = bn_affine(est, bn, f) + res_at(f);
                        skip = est_ri < -margin_of(lp, bn, f, mp.cfg.margin_sigmas);
                    }
                    finish_neuron(
                        f, skip, applied, row, cout, k, node, &pg, dq, bn, res_at(f),
                        node_relu, is_relu_layer, opts, &mut out, pred, ops, &mut trace,
                    );
                }
            }
            Some((lp, mp)) => {
                // proxies first (always fully evaluated)
                for cl in &lp.clusters {
                    let ri = full_eval!(cl[0]);
                    relu_in_cache[cl[0]] = ri;
                    if is_relu_layer {
                        pred.not_applied += 1;
                    }
                }
                // members, cluster by cluster
                for cl in &lp.clusters {
                    let proxy_zero = relu_in_cache[cl[0]] <= 0.0;
                    for &f in &cl[1..] {
                        let mut skip;
                        let applied;
                        if mp.cfg.strategy.uses_binary() {
                            // hybrid: both components must agree; binary is
                            // only consulted when the proxy says zero
                            applied = lp.enabled[f];
                            skip = false;
                            if applied && proxy_zero {
                                let p_bin = pg.packed.dot(&lp.packed_w[f]);
                                ops.bin_ops += k;
                                if let Some(t) = trace.as_mut() {
                                    t.bin_eval[row * cout + f] = true;
                                }
                                let est = lp.m[f] * p_bin as f32 + lp.b[f];
                                let est_ri = bn_affine(est, bn, f) + res_at(f);
                                skip = est_ri < -margin_of(lp, bn, f, mp.cfg.margin_sigmas);
                            }
                        } else {
                            // clusters-only ablation: proxy alone decides
                            applied = true;
                            skip = proxy_zero;
                        }
                        finish_neuron(
                            f, skip, applied, row, cout, k, node, &pg, dq, bn, res_at(f),
                            node_relu, is_relu_layer, opts, &mut out, pred, ops, &mut trace,
                        );
                    }
                }
            }
        }
    }

    if let Some(t) = trace {
        traces.push(t);
    }
    out
}

/// Apply the skip/evaluate decision for one member neuron and account it.
#[allow(clippy::too_many_arguments)]
fn finish_neuron(
    f: usize,
    skip: bool,
    applied: bool,
    row: usize,
    cout: usize,
    k: u64,
    node: &Node,
    pg: &PatchGather,
    dq: f32,
    bn: Option<&(Vec<f32>, Vec<f32>)>,
    res: f32,
    node_relu: bool,
    is_relu_layer: bool,
    opts: RunOpts,
    out: &mut Tensor,
    pred: &mut PredStats,
    ops: &mut OpsStats,
    trace: &mut Option<LayerTrace>,
) {
    if skip {
        out.data[row * cout + f] = 0.0;
        ops.weight_bytes_saved += k;
        if let Some(t) = trace.as_mut() {
            t.skipped[row * cout + f] = true;
        }
        if opts.oracle {
            // ground truth for Fig 12 / accuracy accounting
            let d = dot_i8(&pg.patch, node.filter(f));
            let ri = relu_input(d, dq, bn, f, res);
            if is_relu_layer {
                if ri <= 0.0 {
                    pred.correct_zero += 1;
                    ops.neg_relu_macs += k;
                    ops.true_zero_outputs += 1;
                } else {
                    pred.incorrect_zero += 1;
                }
            }
        }
    } else {
        let d = dot_i8(&pg.patch, node.filter(f));
        let ri = relu_input(d, dq, bn, f, res);
        out.data[row * cout + f] = if node_relu { ri.max(0.0) } else { ri };
        ops.macs_done += k;
        ops.macs_skipped_input_zero += k - pg.nnz as u64;
        ops.weight_bytes_fetched += k;
        if is_relu_layer {
            if ri <= 0.0 {
                ops.neg_relu_macs += k;
                ops.true_zero_outputs += 1;
            }
            if applied {
                if ri <= 0.0 {
                    pred.incorrect_nonzero += 1;
                } else {
                    pred.correct_nonzero += 1;
                }
            } else {
                pred.not_applied += 1;
            }
        }
    }
}

fn layer_params(node: &Node) -> (f32, f32, Option<&(Vec<f32>, Vec<f32>)>, bool) {
    match node {
        Node::Conv { sx, sw, bn, relu, .. } | Node::Fc { sx, sw, bn, relu, .. } => {
            (*sx, *sw, bn.as_ref(), *relu)
        }
        _ => unreachable!("layer_params on non-compute node"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PredictorConfig;
    use crate::model::testutil::{tiny_conv, tiny_fc};
    use crate::model::PredictorParams;
    use crate::predictor::EngineSel;
    use crate::util::json::Json;
    use crate::util::rng::Rng;

    fn rand_input(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn plain_forward_shapes_fc() {
        let m = tiny_fc(1);
        let x = rand_input(8, 2);
        let r = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.logits.len(), 4);
        assert_eq!(r.ops.macs_total, 8 * 6 + 6 * 4);
        assert_eq!(r.ops.macs_done, r.ops.macs_total);
        assert_eq!(r.pred.relu_outputs, 6); // only the first layer has ReLU
    }

    #[test]
    fn plain_forward_shapes_conv() {
        let m = tiny_conv(1);
        let x = rand_input(6 * 6 * 2, 3);
        let r = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.logits.len(), 4); // gap output (1,1,4)
        let expect_total: u64 = m.mac_counts().iter().sum();
        assert_eq!(r.ops.macs_total, expect_total);
        assert!(r.ops.neg_relu_macs > 0, "some ReLU inputs should be negative");
        assert!(r.ops.neg_relu_macs <= r.ops.relu_macs);
    }

    /// Offline params whose fitted lines make the binary estimate always
    /// negative, with one cluster grouping everything under neuron 0.
    fn always_zero_params(layer: usize, n: usize) -> PredictorParams {
        let clusters: Vec<Vec<usize>> = vec![(0..n).collect()];
        let js = format!(
            r#"{{"model":"t","default_threshold":0.0,"layers":[
                {{"layer":{layer},"neurons":{n},
                  "c":{c:?},"m":{m_:?},"b":{b:?},
                  "clusters":{cl},
                  "closest_angle_deg":{ang:?}}}]}}"#,
            c = vec![1.0f32; n],
            m_ = vec![0.0f32; n],
            b = vec![-1.0f32; n],
            cl = format!(
                "[{}]",
                clusters
                    .iter()
                    .map(|cl| format!("{cl:?}"))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
            ang = vec![10.0f32; n],
        );
        PredictorParams::from_json(&Json::parse(&js).unwrap()).unwrap()
    }

    /// With these params MoR skips a member iff its proxy is zero, and
    /// skipped outputs are exactly 0.
    fn always_zero_policy(m: &crate::model::Model, layer: usize, n: usize) -> MorPolicy {
        always_zero_policy_with(m, layer, n, Strategy::Mor)
    }

    fn always_zero_policy_with(
        m: &crate::model::Model,
        layer: usize,
        n: usize,
        strategy: Strategy,
    ) -> MorPolicy {
        MorPolicy::new(
            m,
            &always_zero_params(layer, n),
            PredictorConfig { threshold: 0.5, strategy, ..Default::default() },
        )
    }

    #[test]
    fn skipped_outputs_are_zero_and_accounted() {
        let m = tiny_fc(5);
        let x = rand_input(8, 7);
        let pol = always_zero_policy(&m, 0, 6);
        let r = run_sample(
            &m,
            Some(&pol),
            &x,
            RunOpts { oracle: true, collect_trace: true, ..Default::default() },
        );

        // baseline for comparison
        let base = run_sample(&m, None, &x, RunOpts::default());

        // whenever the proxy (neuron 0) is zero, every member must be
        // skipped (binary estimate is forced negative), so outputs are 0
        let t = &r.traces[0];
        assert_eq!(t.rows, 1);
        for f in 1..6 {
            if t.skipped[f] {
                // predicted zero → output literally 0, and it saved MACs
                assert!(r.ops.macs_done < base.ops.macs_done);
            }
        }
        // categories partition applied outputs
        assert_eq!(
            r.pred.applied() + r.pred.not_applied,
            r.pred.relu_outputs
        );
        // conservation: done + saved == total (in MAC units)
        let saved_macs = r.ops.macs_total - r.ops.macs_done;
        assert_eq!(saved_macs / 8, r.ops.weight_bytes_saved / 8);
    }

    #[test]
    fn oracle_categories_consistent_with_baseline_zeros() {
        let m = tiny_conv(11);
        let x = rand_input(6 * 6 * 2, 13);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy(&m, 0, n);
        let r = run_sample(&m, Some(&pol), &x, RunOpts::default());
        // correct_zero + incorrect_nonzero + ... all bounded by relu outputs
        assert!(r.pred.applied() <= r.pred.relu_outputs);
        // skipping can only reduce MACs
        let base = run_sample(&m, None, &x, RunOpts::default());
        assert!(r.ops.macs_done <= base.ops.macs_done);
        assert_eq!(r.ops.macs_total, base.ops.macs_total);
    }

    #[test]
    fn none_strategy_never_skips() {
        let m = tiny_fc(5);
        let x = rand_input(8, 7);
        let pol = always_zero_policy_with(&m, 0, 6, Strategy::None);
        // the `none` strategy must behave exactly like running unpoliced
        let r = run_sample(&m, Some(&pol), &x, RunOpts::default());
        let base = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.ops.macs_done, base.ops.macs_done);
        assert_eq!(r.logits, base.logits);
        assert_eq!(r.pred, base.pred);
    }

    #[test]
    fn oracle_strategy_skips_exactly_true_zeros() {
        let m = tiny_conv(13);
        let x = rand_input(6 * 6 * 2, 29);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy_with(&m, 0, n, Strategy::Oracle);
        let r = run_sample(&m, Some(&pol), &x, RunOpts::default());
        let base = run_sample(&m, None, &x, RunOpts::default());
        // perfect prediction: no wrong skips, no missed zeros, and the
        // logits match the dense forward bit for bit
        assert_eq!(r.pred.incorrect_zero, 0);
        assert_eq!(r.pred.incorrect_nonzero, 0);
        assert_eq!(r.logits, base.logits);
        assert!(r.pred.correct_zero > 0, "conv layer should have true zeros");
        // exactly the policied layer's true zeros were skipped
        assert_eq!(
            r.ops.macs_done,
            base.ops.macs_done - r.pred.correct_zero * m.nodes[0].k_len() as u64
        );
    }

    #[test]
    fn residual_and_projection_path_exact() {
        // tiny_conv has a projection + residual; check the residual is
        // actually added: zero the main-path weights of node 3 and the
        // output before ReLU must equal bn(0) + residual.
        let mut m = tiny_conv(21);
        if let Node::Conv { w, .. } = &mut m.nodes[3] {
            for v in w.iter_mut() {
                *v = 0;
            }
        }
        let x = rand_input(6 * 6 * 2, 17);
        let r = run_sample(&m, None, &x, RunOpts::default());
        // recompute expectation: node 3 out = 0*dq*scale + shift + res(node1)
        // spot-check one element via an independent partial forward
        assert_eq!(r.logits.len(), 4);
        // (numerical check is covered by the python cross-validation test;
        // here we only assert the graph wiring executed without panic and
        // produced finite values)
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn trace_dimensions() {
        let m = tiny_conv(31);
        let x = rand_input(6 * 6 * 2, 19);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy(&m, 0, n);
        let r = run_sample(
            &m,
            Some(&pol),
            &x,
            RunOpts { oracle: false, collect_trace: true, ..Default::default() },
        );
        // every compute node gets a trace (the simulator replays them all);
        // only the policied layer (node 0) can contain skips
        assert_eq!(r.traces.len(), 4);
        let t = r.traces.iter().find(|t| t.node == 0).unwrap();
        assert_eq!(t.rows, 6 * 6);
        assert_eq!(t.cout, n);
        assert_eq!(t.skipped.len(), t.rows * t.cout);
        for other in r.traces.iter().filter(|t| t.node != 0) {
            assert!(other.skipped.iter().all(|&s| !s), "non-policied layer skipped");
        }
    }

    /// The tiled engine must be bit-identical to the scalar reference on
    /// the in-tree models, for every (policy, oracle, trace, threads)
    /// combination. Random-model coverage lives in
    /// rust/tests/engine_equivalence.rs.
    #[test]
    fn tiled_matches_scalar_reference() {
        for seed in [1u64, 9, 33] {
            let models = [tiny_fc(seed), tiny_conv(seed)];
            for m in &models {
                let (h, w, c) = m.input_shape;
                let x = rand_input(h * w * c, seed ^ 0xA5);
                let n = m.nodes[0].cout();
                let pol = always_zero_policy(m, 0, n);
                for policy in [None, Some(&pol)] {
                    for oracle in [false, true] {
                        for threads in [1usize, 3] {
                            let base = RunOpts {
                                oracle,
                                collect_trace: true,
                                threads: 1,
                                engine: EngineSel::ScalarRef,
                                ..Default::default()
                            };
                            let want = run_sample(m, policy, &x, base);
                            let got = run_sample(
                                m,
                                policy,
                                &x,
                                RunOpts { threads, engine: EngineSel::Tiled, ..base },
                            );
                            assert_eq!(want.logits, got.logits, "{} logits", m.name);
                            assert_eq!(want.pred, got.pred, "{} pred stats", m.name);
                            assert_eq!(want.ops, got.ops, "{} ops stats", m.name);
                            assert_eq!(want.traces, got.traces, "{} traces", m.name);
                        }
                    }
                }
            }
        }
    }

    /// Every input-sparsity mode must be invisible: logits, OpsStats
    /// (incl. the macs_skipped_input_zero counter), PredStats and traces
    /// identical whether the sparse kernels ran, the dense ones, or the
    /// auto crossover mixed them per row. The deep model's post-ReLU
    /// layers guarantee genuinely sparse inputs (and some all-zero
    /// patches under the always-zero policy).
    #[test]
    fn input_sparsity_modes_bit_identical() {
        let m = tiny_conv(61);
        let x = rand_input(6 * 6 * 2, 67);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy(&m, 0, n);
        for policy in [None, Some(&pol)] {
            let base = RunOpts {
                oracle: true,
                collect_trace: true,
                input_sparsity: InputSparsity::Off,
                ..Default::default()
            };
            let want = run_sample(&m, policy, &x, base);
            // post-ReLU layers make the ineffectual-input pool non-empty
            assert!(want.ops.macs_skipped_input_zero > 0);
            assert!(want.ops.effectual_macs() <= want.ops.macs_done);
            for mode in [InputSparsity::On, InputSparsity::Auto] {
                for threads in [1usize, 3] {
                    let got = run_sample(
                        &m,
                        policy,
                        &x,
                        RunOpts { input_sparsity: mode, threads, ..base },
                    );
                    assert_eq!(want.logits, got.logits, "mode={mode:?}");
                    assert_eq!(want.ops, got.ops, "mode={mode:?}");
                    assert_eq!(want.pred, got.pred, "mode={mode:?}");
                    assert_eq!(want.traces, got.traces, "mode={mode:?}");
                }
            }
            // the scalar reference path never runs sparse kernels but
            // must report the same data-derived counter
            let scalar = run_sample(&m, policy, &x, base.scalar_ref());
            assert_eq!(
                scalar.ops.macs_skipped_input_zero,
                want.ops.macs_skipped_input_zero
            );
        }
    }

    /// Every non-default strategy must agree between engines too — they
    /// exercise the other decision branches.
    #[test]
    fn tiled_matches_scalar_on_every_strategy() {
        let m = tiny_conv(47);
        let x = rand_input(6 * 6 * 2, 51);
        let n = m.nodes[0].cout();
        for strategy in Strategy::ALL {
            let pol = always_zero_policy_with(&m, 0, n, strategy);
            let base = RunOpts {
                oracle: true,
                collect_trace: true,
                threads: 1,
                engine: EngineSel::ScalarRef,
                ..Default::default()
            };
            let want = run_sample(&m, Some(&pol), &x, base);
            for threads in [1usize, 2] {
                let got = run_sample(
                    &m,
                    Some(&pol),
                    &x,
                    RunOpts { threads, engine: EngineSel::Tiled, ..base },
                );
                assert_eq!(want.logits, got.logits, "strategy={strategy:?}");
                assert_eq!(want.pred, got.pred, "strategy={strategy:?}");
                assert_eq!(want.ops, got.ops, "strategy={strategy:?}");
                assert_eq!(want.traces, got.traces, "strategy={strategy:?}");
            }
        }
    }
}
