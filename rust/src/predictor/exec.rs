//! The MoR-aware forward pass: evaluates a model on one sample, skipping
//! neuron evaluations the hybrid predictor declares zero (Section 3.2).
//!
//! Execution order per output position mirrors the accelerator's Neurons
//! Controller (Section 4.1): proxies first (they are always evaluated and
//! "unlock" their cluster members), then members — each member whose proxy
//! produced a zero ReLU output is checked with the binary predictor, and
//! skipped only when *both* components agree on zero.

use super::{LayerTrace, MorPolicy, OpsStats, PredStats, RunOpts, RunResult};
use crate::engine::{self, dot::dot_i8, relu_input, ConvGeom, PatchGather, Tensor};
use crate::model::{Model, Node};

/// Run one sample (H*W*C float input) through the model.
pub fn run_sample(
    model: &Model,
    policy: Option<&MorPolicy>,
    input: &[f32],
    opts: RunOpts,
) -> RunResult {
    let (h, w, c) = model.input_shape;
    let input_t = Tensor::from_slice(h, w, c, input);
    let relu_layers = model.relu_layers();

    let mut outs: Vec<Tensor> = Vec::with_capacity(model.nodes.len());
    let mut pred = PredStats::default();
    let mut ops = OpsStats::default();
    let mut traces = Vec::new();

    for (i, node) in model.nodes.iter().enumerate() {
        let src: &Tensor = if node.consumes() < 0 {
            &input_t
        } else {
            &outs[node.consumes() as usize]
        };
        let out = match node {
            Node::Conv { .. } | Node::Fc { .. } => {
                let residual = res_tensor(node, &outs);
                let lp = policy.and_then(|p| p.layers.get(&i));
                let is_relu_layer = relu_layers.contains(&i);
                compute_layer(
                    node,
                    src,
                    residual,
                    lp.map(|l| (l, policy.unwrap())),
                    is_relu_layer,
                    i,
                    opts,
                    &mut pred,
                    &mut ops,
                    &mut traces,
                )
            }
            Node::MaxPool { size, .. } => engine::maxpool(src, *size),
            Node::Gap { .. } => engine::gap(src),
            Node::Relu { .. } => engine::relu(src),
        };
        outs.push(out);
    }

    RunResult {
        logits: outs.last().map(|t| t.data.clone()).unwrap_or_default(),
        pred,
        ops,
        traces,
    }
}

fn res_tensor<'a>(node: &Node, outs: &'a [Tensor]) -> Option<&'a Tensor> {
    match node {
        Node::Conv { res_from, .. } | Node::Fc { res_from, .. } => {
            res_from.map(|r| &outs[r])
        }
        _ => None,
    }
}

#[allow(clippy::too_many_arguments)]
fn compute_layer(
    node: &Node,
    src: &Tensor,
    residual: Option<&Tensor>,
    policy: Option<(&super::LayerPolicy, &MorPolicy)>,
    is_relu_layer: bool,
    node_idx: usize,
    opts: RunOpts,
    pred: &mut PredStats,
    ops: &mut OpsStats,
    traces: &mut Vec<LayerTrace>,
) -> Tensor {
    let (sx, sw, bn, node_relu) = layer_params(node);
    let dq = sw * sx;
    let cout = node.cout();
    let k = node.k_len() as u64;

    let (geom, kh, kw, stride) = match node {
        Node::Conv {
            kh, kw, stride, pad_same, ..
        } => (
            engine::conv_geom(src.h, src.w, *kh, *kw, *stride, *pad_same),
            *kh,
            *kw,
            *stride,
        ),
        _ => (
            ConvGeom {
                oh: src.h,
                ow: src.w,
                pad_top: 0,
                pad_left: 0,
            },
            0,
            0,
            1,
        ),
    };
    let rows = geom.oh * geom.ow;
    let mut out = Tensor::new(geom.oh, geom.ow, cout);

    let mut pg = PatchGather::new(src, sx);
    let mut trace = if opts.collect_trace {
        Some(LayerTrace {
            node: node_idx,
            rows,
            cout,
            skipped: vec![false; rows * cout],
            bin_eval: vec![false; rows * cout],
        })
    } else {
        None
    };

    // scratch for proxy ReLU inputs (hybrid / clusters mode)
    let mut relu_in_cache: Vec<f32> = vec![0.0; cout];

    for row in 0..rows {
        match node {
            Node::Conv { .. } => {
                pg.gather(geom, kh, kw, stride, row / geom.ow, row % geom.ow)
            }
            _ => pg.gather_fc(row),
        }
        ops.macs_total += k * cout as u64;
        if is_relu_layer {
            ops.relu_macs += k * cout as u64;
            pred.relu_outputs += cout as u64;
        }

        let res_at = |f: usize| residual.map(|r| r.data[row * cout + f]).unwrap_or(0.0);

        // closure-free full evaluation to keep borrows simple
        macro_rules! full_eval {
            ($f:expr) => {{
                let f = $f;
                let d = dot_i8(&pg.patch, node.filter(f));
                let ri = relu_input(d, dq, bn, f, res_at(f));
                out.data[row * cout + f] = if node_relu { ri.max(0.0) } else { ri };
                ops.macs_done += k;
                ops.weight_bytes_fetched += k;
                if is_relu_layer && ri <= 0.0 {
                    ops.neg_relu_macs += k;
                    ops.true_zero_outputs += 1;
                }
                ri
            }};
        }

        match policy {
            None => {
                for f in 0..cout {
                    full_eval!(f);
                    if is_relu_layer {
                        pred.not_applied += 1;
                    }
                }
            }
            Some((lp, mp)) if !mp.cfg.use_clusters => {
                // binary-only mode (Fig 6): every enabled neuron predicted
                for f in 0..cout {
                    let mut skip = false;
                    let applied = mp.cfg.use_binary && lp.enabled[f];
                    if applied {
                        let p_bin = pg.packed.dot(&lp.packed_w[f]);
                        ops.bin_ops += k;
                        if let Some(t) = trace.as_mut() {
                            t.bin_eval[row * cout + f] = true;
                        }
                        let est = lp.m[f] * p_bin as f32 + lp.b[f];
                        let est_ri = bn_affine(est, bn, f) + res_at(f);
                        skip = est_ri < -margin_of(lp, bn, f, mp.cfg.margin_sigmas);
                    }
                    finish_neuron(
                        f, skip, applied, row, cout, k, node, &pg, dq, bn, res_at(f),
                        node_relu, is_relu_layer, opts, &mut out, pred, ops, &mut trace,
                    );
                }
            }
            Some((lp, mp)) => {
                // proxies first (always fully evaluated)
                for cl in &lp.clusters {
                    let ri = full_eval!(cl[0]);
                    relu_in_cache[cl[0]] = ri;
                    if is_relu_layer {
                        pred.not_applied += 1;
                    }
                }
                // members, cluster by cluster
                for cl in &lp.clusters {
                    let proxy_zero = relu_in_cache[cl[0]] <= 0.0;
                    for &f in &cl[1..] {
                        let mut skip;
                        let applied;
                        if mp.cfg.use_binary {
                            // hybrid: both components must agree; binary is
                            // only consulted when the proxy says zero
                            applied = lp.enabled[f];
                            skip = false;
                            if applied && proxy_zero {
                                let p_bin = pg.packed.dot(&lp.packed_w[f]);
                                ops.bin_ops += k;
                                if let Some(t) = trace.as_mut() {
                                    t.bin_eval[row * cout + f] = true;
                                }
                                let est = lp.m[f] * p_bin as f32 + lp.b[f];
                                let est_ri = bn_affine(est, bn, f) + res_at(f);
                                skip = est_ri < -margin_of(lp, bn, f, mp.cfg.margin_sigmas);
                            }
                        } else {
                            // clusters-only ablation: proxy alone decides
                            applied = true;
                            skip = proxy_zero;
                        }
                        finish_neuron(
                            f, skip, applied, row, cout, k, node, &pg, dq, bn, res_at(f),
                            node_relu, is_relu_layer, opts, &mut out, pred, ops, &mut trace,
                        );
                    }
                }
            }
        }
    }

    if let Some(t) = trace {
        traces.push(t);
    }
    out
}

/// Apply the skip/evaluate decision for one member neuron and account it.
#[allow(clippy::too_many_arguments)]
fn finish_neuron(
    f: usize,
    skip: bool,
    applied: bool,
    row: usize,
    cout: usize,
    k: u64,
    node: &Node,
    pg: &PatchGather,
    dq: f32,
    bn: Option<&(Vec<f32>, Vec<f32>)>,
    res: f32,
    node_relu: bool,
    is_relu_layer: bool,
    opts: RunOpts,
    out: &mut Tensor,
    pred: &mut PredStats,
    ops: &mut OpsStats,
    trace: &mut Option<LayerTrace>,
) {
    if skip {
        out.data[row * cout + f] = 0.0;
        ops.weight_bytes_saved += k;
        if let Some(t) = trace.as_mut() {
            t.skipped[row * cout + f] = true;
        }
        if opts.oracle {
            // ground truth for Fig 12 / accuracy accounting
            let d = dot_i8(&pg.patch, node.filter(f));
            let ri = relu_input(d, dq, bn, f, res);
            if is_relu_layer {
                if ri <= 0.0 {
                    pred.correct_zero += 1;
                    ops.neg_relu_macs += k;
                    ops.true_zero_outputs += 1;
                } else {
                    pred.incorrect_zero += 1;
                }
            }
        }
    } else {
        let d = dot_i8(&pg.patch, node.filter(f));
        let ri = relu_input(d, dq, bn, f, res);
        out.data[row * cout + f] = if node_relu { ri.max(0.0) } else { ri };
        ops.macs_done += k;
        ops.weight_bytes_fetched += k;
        if is_relu_layer {
            if ri <= 0.0 {
                ops.neg_relu_macs += k;
                ops.true_zero_outputs += 1;
            }
            if applied {
                if ri <= 0.0 {
                    pred.incorrect_nonzero += 1;
                } else {
                    pred.correct_nonzero += 1;
                }
            } else {
                pred.not_applied += 1;
            }
        }
    }
}

/// Skip-confidence margin for neuron `f`: `margin_sigmas` regression
/// residual stds, propagated through the (multiplicative) BN scale. The
/// raw paper rule (skip iff estimate < 0) is `margin_sigmas = 0`.
#[inline]
fn margin_of(
    lp: &super::LayerPolicy,
    bn: Option<&(Vec<f32>, Vec<f32>)>,
    f: usize,
    margin_sigmas: f32,
) -> f32 {
    if margin_sigmas == 0.0 {
        return 0.0;
    }
    let scale = bn.map(|(sc, _)| sc[f].abs()).unwrap_or(1.0);
    margin_sigmas * lp.s[f] * scale
}

#[inline]
fn bn_affine(v: f32, bn: Option<&(Vec<f32>, Vec<f32>)>, f: usize) -> f32 {
    match bn {
        Some((scale, shift)) => v * scale[f] + shift[f],
        None => v,
    }
}

fn layer_params(node: &Node) -> (f32, f32, Option<&(Vec<f32>, Vec<f32>)>, bool) {
    match node {
        Node::Conv { sx, sw, bn, relu, .. } | Node::Fc { sx, sw, bn, relu, .. } => {
            (*sx, *sw, bn.as_ref(), *relu)
        }
        _ => unreachable!("layer_params on non-compute node"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PredictorConfig;
    use crate::model::testutil::{tiny_conv, tiny_fc};
    use crate::model::PredictorParams;
    use crate::util::json::Json;
    use crate::util::rng::Rng;

    fn rand_input(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn plain_forward_shapes_fc() {
        let m = tiny_fc(1);
        let x = rand_input(8, 2);
        let r = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.logits.len(), 4);
        assert_eq!(r.ops.macs_total, 8 * 6 + 6 * 4);
        assert_eq!(r.ops.macs_done, r.ops.macs_total);
        assert_eq!(r.pred.relu_outputs, 6); // only the first layer has ReLU
    }

    #[test]
    fn plain_forward_shapes_conv() {
        let m = tiny_conv(1);
        let x = rand_input(6 * 6 * 2, 3);
        let r = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.logits.len(), 4); // gap output (1,1,4)
        let expect_total: u64 = m.mac_counts().iter().sum();
        assert_eq!(r.ops.macs_total, expect_total);
        assert!(r.ops.neg_relu_macs > 0, "some ReLU inputs should be negative");
        assert!(r.ops.neg_relu_macs <= r.ops.relu_macs);
    }

    /// A policy whose fitted lines make the binary estimate always negative
    /// and clusters grouping everything under neuron 0 — then MoR skips a
    /// member iff its proxy is zero, and skipped outputs are exactly 0.
    fn always_zero_policy(m: &crate::model::Model, layer: usize, n: usize) -> MorPolicy {
        let clusters: Vec<Vec<usize>> = vec![(0..n).collect()];
        let js = format!(
            r#"{{"model":"t","default_threshold":0.0,"layers":[
                {{"layer":{layer},"neurons":{n},
                  "c":{c:?},"m":{m_:?},"b":{b:?},
                  "clusters":{cl},
                  "closest_angle_deg":{ang:?}}}]}}"#,
            c = vec![1.0f32; n],
            m_ = vec![0.0f32; n],
            b = vec![-1.0f32; n],
            cl = format!(
                "[{}]",
                clusters
                    .iter()
                    .map(|cl| format!("{cl:?}"))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
            ang = vec![10.0f32; n],
        );
        let params = PredictorParams::from_json(&Json::parse(&js).unwrap()).unwrap();
        MorPolicy::new(m, &params, PredictorConfig { threshold: 0.5, ..Default::default() })
    }

    #[test]
    fn skipped_outputs_are_zero_and_accounted() {
        let m = tiny_fc(5);
        let x = rand_input(8, 7);
        let pol = always_zero_policy(&m, 0, 6);
        let r = run_sample(&m, Some(&pol), &x, RunOpts { oracle: true, collect_trace: true });

        // baseline for comparison
        let base = run_sample(&m, None, &x, RunOpts::default());

        // whenever the proxy (neuron 0) is zero, every member must be
        // skipped (binary estimate is forced negative), so outputs are 0
        let t = &r.traces[0];
        assert_eq!(t.rows, 1);
        for f in 1..6 {
            if t.skipped[f] {
                // predicted zero → output literally 0, and it saved MACs
                assert!(r.ops.macs_done < base.ops.macs_done);
            }
        }
        // categories partition applied outputs
        assert_eq!(
            r.pred.applied() + r.pred.not_applied,
            r.pred.relu_outputs
        );
        // conservation: done + saved == total (in MAC units)
        let saved_macs = r.ops.macs_total - r.ops.macs_done;
        assert_eq!(saved_macs / 8, r.ops.weight_bytes_saved / 8);
    }

    #[test]
    fn oracle_categories_consistent_with_baseline_zeros() {
        let m = tiny_conv(11);
        let x = rand_input(6 * 6 * 2, 13);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy(&m, 0, n);
        let r = run_sample(&m, Some(&pol), &x, RunOpts::default());
        // correct_zero + incorrect_nonzero + ... all bounded by relu outputs
        assert!(r.pred.applied() <= r.pred.relu_outputs);
        // skipping can only reduce MACs
        let base = run_sample(&m, None, &x, RunOpts::default());
        assert!(r.ops.macs_done <= base.ops.macs_done);
        assert_eq!(r.ops.macs_total, base.ops.macs_total);
    }

    #[test]
    fn disabled_components_never_skip() {
        let m = tiny_fc(5);
        let x = rand_input(8, 7);
        let mut pol = always_zero_policy(&m, 0, 6);
        pol.cfg.use_binary = false;
        pol.cfg.use_clusters = false;
        // with both components off the policy must behave like None
        let r = run_sample(&m, Some(&pol), &x, RunOpts::default());
        let base = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.ops.macs_done, base.ops.macs_done);
        assert_eq!(r.logits, base.logits);
    }

    #[test]
    fn residual_and_projection_path_exact() {
        // tiny_conv has a projection + residual; check the residual is
        // actually added: zero the main-path weights of node 3 and the
        // output before ReLU must equal bn(0) + residual.
        let mut m = tiny_conv(21);
        if let Node::Conv { w, .. } = &mut m.nodes[3] {
            for v in w.iter_mut() {
                *v = 0;
            }
        }
        let x = rand_input(6 * 6 * 2, 17);
        let r = run_sample(&m, None, &x, RunOpts::default());
        // recompute expectation: node 3 out = 0*dq*scale + shift + res(node1)
        // spot-check one element via an independent partial forward
        assert_eq!(r.logits.len(), 4);
        // (numerical check is covered by the python cross-validation test;
        // here we only assert the graph wiring executed without panic and
        // produced finite values)
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn trace_dimensions() {
        let m = tiny_conv(31);
        let x = rand_input(6 * 6 * 2, 19);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy(&m, 0, n);
        let r = run_sample(&m, Some(&pol), &x, RunOpts { oracle: false, collect_trace: true });
        // every compute node gets a trace (the simulator replays them all);
        // only the policied layer (node 0) can contain skips
        assert_eq!(r.traces.len(), 4);
        let t = r.traces.iter().find(|t| t.node == 0).unwrap();
        assert_eq!(t.rows, 6 * 6);
        assert_eq!(t.cout, n);
        assert_eq!(t.skipped.len(), t.rows * t.cout);
        for other in r.traces.iter().filter(|t| t.node != 0) {
            assert!(other.skipped.iter().all(|&s| !s), "non-policied layer skipped");
        }
    }
}
