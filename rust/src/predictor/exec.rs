//! The MoR-aware forward pass: evaluates a *batch* of samples layer by
//! layer, skipping neuron evaluations the hybrid predictor declares zero
//! (Section 3.2).
//!
//! Two interchangeable engines implement each compute layer:
//!
//! * **Tiled** (default) — the planned path: [`run_batch`] compiles the
//!   model into a [`crate::plan::ModelPlan`] and drives
//!   [`crate::plan::execute()`] over a [`crate::plan::Workspace`]. The
//!   engine itself is unchanged — a cache-blocked, row-batched im2col
//!   GEMM with a two-phase predict-then-evaluate dataflow, cross-sample
//!   tiles, triple-sided sparsity and optional row-tile threading (see
//!   the [`crate::plan`] docs) — but all per-layer decisions are frozen
//!   at compile time and all working memory lives in the workspace.
//!   These free functions build a throwaway plan + workspace per call
//!   (the correctness path the equivalence suites drive); the
//!   steady-state allocation-free path goes through
//!   [`crate::session::Session`], which compiles once and pools
//!   workspaces.
//! * **ScalarRef** — the original per-neuron GEMV path, retained as the
//!   bit-exact test oracle and perf baseline. It stays deliberately
//!   *unplanned* (it re-derives everything per call and retains every
//!   intermediate tensor), so the equivalence suites prove the planned
//!   path against an independent implementation. Logits, [`OpsStats`],
//!   [`PredStats`] and traces are identical between the two (all dot
//!   products are exact integer sums and the per-output float tail is the
//!   same code), which `rust/tests/engine_equivalence.rs` asserts.
//!
//! [`run_batch`] is bit-identical to mapping [`run_sample`] over the batch
//! (every output depends only on its own patch and filter, and per-row
//! accounting lands in its sample's counters) — asserted for batch sizes
//! 1..16 by `rust/tests/batch_equivalence.rs`.
//!
//! Execution order per output position mirrors the accelerator's Neurons
//! Controller (Section 4.1): proxies first (they are always evaluated and
//! "unlock" their cluster members), then members — each member whose proxy
//! produced a zero ReLU output is checked with the binary predictor, and
//! skipped only when *both* components agree on zero.

use super::strategies::{bn_affine, margin_of, LayerState, Strategy};
use super::{EngineSel, LayerTrace, MorPolicy, OpsStats, PredStats, RunOpts, RunResult};
use crate::engine::dot::{dot_i8, weight_zero_lanes};
use crate::engine::{self, relu_input, ConvGeom, PatchGather, QuantizedTensor, Tensor};
use crate::model::{Model, Node};
use crate::plan;

/// Run one sample (H*W*C float input) through the model.
pub fn run_sample(
    model: &Model,
    policy: Option<&MorPolicy>,
    input: &[f32],
    opts: RunOpts,
) -> RunResult {
    run_batch(model, policy, &[input], opts)
        .pop()
        .expect("run_batch returns one result per input")
}

/// Run a batch of samples through the model, layer-synchronously: every
/// compute layer advances all `inputs.len()` samples at once, so im2col
/// row tiles are filled with patches from multiple samples and each
/// prepacked weight block is streamed once per tile for the whole batch.
///
/// Results are **bit-identical** to calling [`run_sample`] per input —
/// logits, [`OpsStats`], [`PredStats`] and traces — for any batch size,
/// thread count, tile alignment (ragged final tiles included), or
/// [`crate::engine::InputSparsity`] mode.
///
/// ```
/// use mor::model::synth;
/// use mor::predictor::{exec, RunOpts};
///
/// let model = synth::tiny_serving_model(7);
/// let (h, w, c) = model.input_shape;
/// let xs: Vec<Vec<f32>> = (0..3).map(|i| vec![0.1 * i as f32; h * w * c]).collect();
/// let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
/// let results = exec::run_batch(&model, None, &inputs, RunOpts::default());
/// assert_eq!(results.len(), 3);
/// assert_eq!(results[0].logits.len(), 4); // tiny_serving_model has 4 classes
/// ```
pub fn run_batch(
    model: &Model,
    policy: Option<&MorPolicy>,
    inputs: &[&[f32]],
    opts: RunOpts,
) -> Vec<RunResult> {
    if inputs.is_empty() {
        return Vec::new();
    }
    match opts.engine {
        EngineSel::Tiled => {
            // throwaway plan + workspace: bit-identical to the session's
            // cached-plan path (same compile, same executor) — the
            // session only removes the per-call setup cost
            let compiled = plan::compile(model, policy, opts);
            let mut ws = plan::Workspace::new();
            plan::execute(&compiled, model, policy, &mut ws, inputs)
        }
        EngineSel::ScalarRef => run_batch_scalar(model, policy, inputs, opts),
    }
}

/// The unplanned per-neuron reference path (`EngineSel::ScalarRef`).
/// Keeps the pre-plan structure — including retaining every
/// intermediate tensor per sample — on purpose: it is the independent
/// oracle the planned path's slot reuse and frozen decisions are proven
/// against, so it shares none of that machinery.
fn run_batch_scalar(
    model: &Model,
    policy: Option<&MorPolicy>,
    inputs: &[&[f32]],
    opts: RunOpts,
) -> Vec<RunResult> {
    let b = inputs.len();
    let (h, w, c) = model.input_shape;
    let input_ts: Vec<Tensor> = inputs
        .iter()
        .map(|x| Tensor::from_slice(h, w, c, x))
        .collect();
    let relu_layers = model.relu_layers();

    let mut outs: Vec<Vec<Tensor>> = (0..b)
        .map(|_| Vec::with_capacity(model.nodes.len()))
        .collect();
    let mut pred = vec![PredStats::default(); b];
    let mut ops = vec![OpsStats::default(); b];
    let mut traces: Vec<Vec<LayerTrace>> = (0..b).map(|_| Vec::new()).collect();

    for (i, node) in model.nodes.iter().enumerate() {
        match node {
            Node::Conv { .. } | Node::Fc { .. } => {
                let lp = policy.and_then(|p| p.layers.get(&i));
                let pol = lp.map(|l| (l, policy.unwrap()));
                let is_relu_layer = relu_layers.contains(&i);
                for s in 0..b {
                    let src = src_of(&input_ts[s], &outs[s], node);
                    let residual = res_tensor(node, &outs[s]);
                    let out = compute_layer_scalar(
                        node,
                        src,
                        residual,
                        pol,
                        is_relu_layer,
                        i,
                        opts,
                        &mut pred[s],
                        &mut ops[s],
                        &mut traces[s],
                    );
                    outs[s].push(out);
                }
            }
            Node::MaxPool { size, .. } => {
                for s in 0..b {
                    let src = src_of(&input_ts[s], &outs[s], node);
                    let out = engine::maxpool(src, *size);
                    outs[s].push(out);
                }
            }
            Node::Gap { .. } => {
                for s in 0..b {
                    let src = src_of(&input_ts[s], &outs[s], node);
                    let out = engine::gap(src);
                    outs[s].push(out);
                }
            }
            Node::Relu { .. } => {
                for s in 0..b {
                    let src = src_of(&input_ts[s], &outs[s], node);
                    let out = engine::relu(src);
                    outs[s].push(out);
                }
            }
        }
    }

    let mut results = Vec::with_capacity(b);
    for s in 0..b {
        results.push(RunResult {
            logits: outs[s].last().map(|t| t.data.clone()).unwrap_or_default(),
            pred: pred[s],
            ops: ops[s],
            traces: std::mem::take(&mut traces[s]),
        });
    }
    results
}

/// The input tensor a node reads: the model input or a prior node's output.
fn src_of<'a>(input: &'a Tensor, outs: &'a [Tensor], node: &Node) -> &'a Tensor {
    if node.consumes() < 0 {
        input
    } else {
        &outs[node.consumes() as usize]
    }
}

fn res_tensor<'a>(node: &Node, outs: &'a [Tensor]) -> Option<&'a Tensor> {
    match node {
        Node::Conv { res_from, .. } | Node::Fc { res_from, .. } => {
            res_from.map(|r| &outs[r])
        }
        _ => None,
    }
}

/// Output geometry + kernel parameters of a compute node (FC layers are
/// 1×1 "convolutions" over the h*w positions).
fn geom_of(node: &Node, src: &Tensor) -> (ConvGeom, usize, usize, usize) {
    match node {
        Node::Conv {
            kh, kw, stride, pad_same, ..
        } => (
            engine::conv_geom(src.h, src.w, *kh, *kw, *stride, *pad_same),
            *kh,
            *kw,
            *stride,
        ),
        _ => (
            ConvGeom {
                oh: src.h,
                ow: src.w,
                pad_top: 0,
                pad_left: 0,
            },
            0,
            0,
            1,
        ),
    }
}

// ---------------------------------------------------------------------------
// Scalar reference engine (the original per-neuron GEMV path)
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn compute_layer_scalar(
    node: &Node,
    src: &Tensor,
    residual: Option<&Tensor>,
    policy: Option<(&LayerState, &MorPolicy)>,
    is_relu_layer: bool,
    node_idx: usize,
    opts: RunOpts,
    pred: &mut PredStats,
    ops: &mut OpsStats,
    traces: &mut Vec<LayerTrace>,
) -> Tensor {
    // the oracle strategy's skip accounting IS the ground truth: force
    // it on (mirrors the planned engine) so both engines stay bit-exact
    let opts = RunOpts {
        oracle: opts.oracle
            || policy.is_some_and(|(_, mp)| mp.cfg.strategy == Strategy::Oracle),
        ..opts
    };
    let (sx, sw, bn, node_relu) = layer_params(node);
    let dq = sw * sx;
    let cout = node.cout();
    let k = node.k_len() as u64;

    let (geom, kh, kw, stride) = geom_of(node, src);
    let rows = geom.oh * geom.ow;
    let mut out = Tensor::new(geom.oh, geom.ow, cout);

    let qt = QuantizedTensor::new(src, sx);
    let mut pg = PatchGather::new();
    let mut trace = if opts.collect_trace {
        Some(LayerTrace {
            node: node_idx,
            rows,
            cout,
            skipped: vec![false; rows * cout],
            bin_eval: vec![false; rows * cout],
        })
    } else {
        None
    };

    // scratch for proxy ReLU inputs (hybrid / clusters mode)
    let mut relu_in_cache: Vec<f32> = vec![0.0; cout];

    for row in 0..rows {
        match node {
            Node::Conv { .. } => {
                pg.gather(&qt, geom, kh, kw, stride, row / geom.ow, row % geom.ow)
            }
            _ => pg.gather_fc(&qt, row),
        }
        ops.macs_total += k * cout as u64;
        if is_relu_layer {
            ops.relu_macs += k * cout as u64;
            pred.relu_outputs += cout as u64;
        }

        let res_at = |f: usize| residual.map(|r| r.data[row * cout + f]).unwrap_or(0.0);

        // closure-free full evaluation to keep borrows simple
        macro_rules! full_eval {
            ($f:expr) => {{
                let f = $f;
                let d = dot_i8(&pg.patch, node.filter(f));
                let ri = relu_input(d, dq, bn, f, res_at(f));
                #[cfg(debug_assertions)]
                {
                    crate::plan::observe::record_dot(node_idx, d);
                    crate::plan::observe::record_ri(node_idx, ri);
                }
                out.data[row * cout + f] = if node_relu { ri.max(0.0) } else { ri };
                ops.macs_done += k;
                ops.macs_skipped_input_zero += k - pg.nnz as u64;
                ops.macs_skipped_weight_zero += weight_zero_lanes(&pg.patch, node.filter(f));
                ops.weight_bytes_fetched += k;
                if is_relu_layer && ri <= 0.0 {
                    ops.neg_relu_macs += k;
                    ops.true_zero_outputs += 1;
                }
                ri
            }};
        }

        match policy {
            None => {
                for f in 0..cout {
                    full_eval!(f);
                    if is_relu_layer {
                        pred.not_applied += 1;
                    }
                }
            }
            Some((_lp, mp)) if mp.cfg.strategy == Strategy::Oracle => {
                // oracle: the true pre-activation decides; skipped
                // outputs are exactly the true zeros
                for f in 0..cout {
                    let d = dot_i8(&pg.patch, node.filter(f));
                    let ri = relu_input(d, dq, bn, f, res_at(f));
                    #[cfg(debug_assertions)]
                    {
                        crate::plan::observe::record_dot(node_idx, d);
                        crate::plan::observe::record_ri(node_idx, ri);
                    }
                    finish_neuron(
                        f, ri <= 0.0, true, row, cout, k, node, &pg, dq, bn, res_at(f),
                        node_relu, is_relu_layer, opts, node_idx, &mut out, pred, ops,
                        &mut trace,
                    );
                }
            }
            Some((lp, mp)) if !mp.cfg.strategy.uses_clusters() => {
                // binary-only mode (Fig 6) — and the `none` strategy,
                // whose rookie is never consulted so nothing is applied
                for f in 0..cout {
                    let mut skip = false;
                    let applied = mp.cfg.strategy.uses_binary() && lp.enabled[f];
                    if applied {
                        let p_bin = pg.packed.dot(&lp.packed_w[f]);
                        #[cfg(debug_assertions)]
                        crate::plan::observe::record_proxy(node_idx, p_bin);
                        ops.bin_ops += k;
                        if let Some(t) = trace.as_mut() {
                            t.bin_eval[row * cout + f] = true;
                        }
                        let est = lp.m[f] * p_bin as f32 + lp.b[f];
                        let est_ri = bn_affine(est, bn, f) + res_at(f);
                        skip = est_ri < -margin_of(lp, bn, f, mp.cfg.margin_sigmas);
                    }
                    finish_neuron(
                        f, skip, applied, row, cout, k, node, &pg, dq, bn, res_at(f),
                        node_relu, is_relu_layer, opts, node_idx, &mut out, pred, ops,
                        &mut trace,
                    );
                }
            }
            Some((lp, mp)) => {
                // proxies first (always fully evaluated)
                for cl in &lp.clusters {
                    let ri = full_eval!(cl[0]);
                    relu_in_cache[cl[0]] = ri;
                    if is_relu_layer {
                        pred.not_applied += 1;
                    }
                }
                // members, cluster by cluster
                for cl in &lp.clusters {
                    let proxy_zero = relu_in_cache[cl[0]] <= 0.0;
                    for &f in &cl[1..] {
                        let mut skip;
                        let applied;
                        if mp.cfg.strategy.uses_binary() {
                            // hybrid: both components must agree; binary is
                            // only consulted when the proxy says zero
                            applied = lp.enabled[f];
                            skip = false;
                            if applied && proxy_zero {
                                let p_bin = pg.packed.dot(&lp.packed_w[f]);
                                #[cfg(debug_assertions)]
                                crate::plan::observe::record_proxy(node_idx, p_bin);
                                ops.bin_ops += k;
                                if let Some(t) = trace.as_mut() {
                                    t.bin_eval[row * cout + f] = true;
                                }
                                let est = lp.m[f] * p_bin as f32 + lp.b[f];
                                let est_ri = bn_affine(est, bn, f) + res_at(f);
                                skip = est_ri < -margin_of(lp, bn, f, mp.cfg.margin_sigmas);
                            }
                        } else {
                            // clusters-only ablation: proxy alone decides
                            applied = true;
                            skip = proxy_zero;
                        }
                        finish_neuron(
                            f, skip, applied, row, cout, k, node, &pg, dq, bn, res_at(f),
                            node_relu, is_relu_layer, opts, node_idx, &mut out, pred, ops,
                            &mut trace,
                        );
                    }
                }
            }
        }
    }

    if let Some(t) = trace {
        traces.push(t);
    }
    out
}

/// Apply the skip/evaluate decision for one member neuron and account it.
#[allow(clippy::too_many_arguments)]
#[cfg_attr(not(debug_assertions), allow(unused_variables))]
fn finish_neuron(
    f: usize,
    skip: bool,
    applied: bool,
    row: usize,
    cout: usize,
    k: u64,
    node: &Node,
    pg: &PatchGather,
    dq: f32,
    bn: Option<&(Vec<f32>, Vec<f32>)>,
    res: f32,
    node_relu: bool,
    is_relu_layer: bool,
    opts: RunOpts,
    node_idx: usize,
    out: &mut Tensor,
    pred: &mut PredStats,
    ops: &mut OpsStats,
    trace: &mut Option<LayerTrace>,
) {
    if skip {
        out.data[row * cout + f] = 0.0;
        ops.weight_bytes_saved += k;
        if let Some(t) = trace.as_mut() {
            t.skipped[row * cout + f] = true;
        }
        if opts.oracle {
            // ground truth for Fig 12 / accuracy accounting
            let d = dot_i8(&pg.patch, node.filter(f));
            let ri = relu_input(d, dq, bn, f, res);
            #[cfg(debug_assertions)]
            {
                crate::plan::observe::record_dot(node_idx, d);
                crate::plan::observe::record_ri(node_idx, ri);
            }
            if is_relu_layer {
                if ri <= 0.0 {
                    pred.correct_zero += 1;
                    ops.neg_relu_macs += k;
                    ops.true_zero_outputs += 1;
                } else {
                    pred.incorrect_zero += 1;
                }
            }
        }
    } else {
        let d = dot_i8(&pg.patch, node.filter(f));
        let ri = relu_input(d, dq, bn, f, res);
        #[cfg(debug_assertions)]
        {
            crate::plan::observe::record_dot(node_idx, d);
            crate::plan::observe::record_ri(node_idx, ri);
        }
        out.data[row * cout + f] = if node_relu { ri.max(0.0) } else { ri };
        ops.macs_done += k;
        ops.macs_skipped_input_zero += k - pg.nnz as u64;
        ops.macs_skipped_weight_zero += weight_zero_lanes(&pg.patch, node.filter(f));
        ops.weight_bytes_fetched += k;
        if is_relu_layer {
            if ri <= 0.0 {
                ops.neg_relu_macs += k;
                ops.true_zero_outputs += 1;
            }
            if applied {
                if ri <= 0.0 {
                    pred.incorrect_nonzero += 1;
                } else {
                    pred.correct_nonzero += 1;
                }
            } else {
                pred.not_applied += 1;
            }
        }
    }
}

/// Quantization scales, folded BN and activation flag of a compute node
/// (shared with the planned executor in [`crate::plan`]).
pub(crate) fn layer_params(node: &Node) -> (f32, f32, Option<&(Vec<f32>, Vec<f32>)>, bool) {
    match node {
        Node::Conv { sx, sw, bn, relu, .. } | Node::Fc { sx, sw, bn, relu, .. } => {
            (*sx, *sw, bn.as_ref(), *relu)
        }
        _ => unreachable!("layer_params on non-compute node"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PredictorConfig;
    use crate::engine::InputSparsity;
    use crate::model::testutil::{tiny_conv, tiny_fc};
    use crate::model::PredictorParams;
    use crate::predictor::EngineSel;
    use crate::util::json::Json;
    use crate::util::rng::Rng;

    fn rand_input(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn plain_forward_shapes_fc() {
        let m = tiny_fc(1);
        let x = rand_input(8, 2);
        let r = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.logits.len(), 4);
        assert_eq!(r.ops.macs_total, 8 * 6 + 6 * 4);
        assert_eq!(r.ops.macs_done, r.ops.macs_total);
        assert_eq!(r.pred.relu_outputs, 6); // only the first layer has ReLU
    }

    #[test]
    fn plain_forward_shapes_conv() {
        let m = tiny_conv(1);
        let x = rand_input(6 * 6 * 2, 3);
        let r = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.logits.len(), 4); // gap output (1,1,4)
        let expect_total: u64 = m.mac_counts().iter().sum();
        assert_eq!(r.ops.macs_total, expect_total);
        assert!(r.ops.neg_relu_macs > 0, "some ReLU inputs should be negative");
        assert!(r.ops.neg_relu_macs <= r.ops.relu_macs);
    }

    /// Offline params whose fitted lines make the binary estimate always
    /// negative, with one cluster grouping everything under neuron 0.
    fn always_zero_params(layer: usize, n: usize) -> PredictorParams {
        let clusters: Vec<Vec<usize>> = vec![(0..n).collect()];
        let js = format!(
            r#"{{"model":"t","default_threshold":0.0,"layers":[
                {{"layer":{layer},"neurons":{n},
                  "c":{c:?},"m":{m_:?},"b":{b:?},
                  "clusters":{cl},
                  "closest_angle_deg":{ang:?}}}]}}"#,
            c = vec![1.0f32; n],
            m_ = vec![0.0f32; n],
            b = vec![-1.0f32; n],
            cl = format!(
                "[{}]",
                clusters
                    .iter()
                    .map(|cl| format!("{cl:?}"))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
            ang = vec![10.0f32; n],
        );
        PredictorParams::from_json(&Json::parse(&js).unwrap()).unwrap()
    }

    /// With these params MoR skips a member iff its proxy is zero, and
    /// skipped outputs are exactly 0.
    fn always_zero_policy(m: &crate::model::Model, layer: usize, n: usize) -> MorPolicy {
        always_zero_policy_with(m, layer, n, Strategy::Mor)
    }

    fn always_zero_policy_with(
        m: &crate::model::Model,
        layer: usize,
        n: usize,
        strategy: Strategy,
    ) -> MorPolicy {
        MorPolicy::new(
            m,
            &always_zero_params(layer, n),
            PredictorConfig { threshold: 0.5, strategy, ..Default::default() },
        )
    }

    #[test]
    fn skipped_outputs_are_zero_and_accounted() {
        let m = tiny_fc(5);
        let x = rand_input(8, 7);
        let pol = always_zero_policy(&m, 0, 6);
        let r = run_sample(
            &m,
            Some(&pol),
            &x,
            RunOpts { oracle: true, collect_trace: true, ..Default::default() },
        );

        // baseline for comparison
        let base = run_sample(&m, None, &x, RunOpts::default());

        // whenever the proxy (neuron 0) is zero, every member must be
        // skipped (binary estimate is forced negative), so outputs are 0
        let t = &r.traces[0];
        assert_eq!(t.rows, 1);
        for f in 1..6 {
            if t.skipped[f] {
                // predicted zero → output literally 0, and it saved MACs
                assert!(r.ops.macs_done < base.ops.macs_done);
            }
        }
        // categories partition applied outputs
        assert_eq!(
            r.pred.applied() + r.pred.not_applied,
            r.pred.relu_outputs
        );
        // conservation: done + saved == total (in MAC units)
        let saved_macs = r.ops.macs_total - r.ops.macs_done;
        assert_eq!(saved_macs / 8, r.ops.weight_bytes_saved / 8);
    }

    #[test]
    fn oracle_categories_consistent_with_baseline_zeros() {
        let m = tiny_conv(11);
        let x = rand_input(6 * 6 * 2, 13);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy(&m, 0, n);
        let r = run_sample(&m, Some(&pol), &x, RunOpts::default());
        // correct_zero + incorrect_nonzero + ... all bounded by relu outputs
        assert!(r.pred.applied() <= r.pred.relu_outputs);
        // skipping can only reduce MACs
        let base = run_sample(&m, None, &x, RunOpts::default());
        assert!(r.ops.macs_done <= base.ops.macs_done);
        assert_eq!(r.ops.macs_total, base.ops.macs_total);
    }

    #[test]
    fn none_strategy_never_skips() {
        let m = tiny_fc(5);
        let x = rand_input(8, 7);
        let pol = always_zero_policy_with(&m, 0, 6, Strategy::None);
        // the `none` strategy must behave exactly like running unpoliced
        let r = run_sample(&m, Some(&pol), &x, RunOpts::default());
        let base = run_sample(&m, None, &x, RunOpts::default());
        assert_eq!(r.ops.macs_done, base.ops.macs_done);
        assert_eq!(r.logits, base.logits);
        assert_eq!(r.pred, base.pred);
    }

    #[test]
    fn oracle_strategy_skips_exactly_true_zeros() {
        let m = tiny_conv(13);
        let x = rand_input(6 * 6 * 2, 29);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy_with(&m, 0, n, Strategy::Oracle);
        let r = run_sample(&m, Some(&pol), &x, RunOpts::default());
        let base = run_sample(&m, None, &x, RunOpts::default());
        // perfect prediction: no wrong skips, no missed zeros, and the
        // logits match the dense forward bit for bit
        assert_eq!(r.pred.incorrect_zero, 0);
        assert_eq!(r.pred.incorrect_nonzero, 0);
        assert_eq!(r.logits, base.logits);
        assert!(r.pred.correct_zero > 0, "conv layer should have true zeros");
        // exactly the policied layer's true zeros were skipped
        assert_eq!(
            r.ops.macs_done,
            base.ops.macs_done - r.pred.correct_zero * m.nodes[0].k_len() as u64
        );
    }

    #[test]
    fn residual_and_projection_path_exact() {
        // tiny_conv has a projection + residual; check the residual is
        // actually added: zero the main-path weights of node 3 and the
        // output before ReLU must equal bn(0) + residual.
        let mut m = tiny_conv(21);
        if let Node::Conv { w, .. } = &mut m.nodes[3] {
            for v in w.iter_mut() {
                *v = 0;
            }
        }
        let x = rand_input(6 * 6 * 2, 17);
        let r = run_sample(&m, None, &x, RunOpts::default());
        // recompute expectation: node 3 out = 0*dq*scale + shift + res(node1)
        // spot-check one element via an independent partial forward
        assert_eq!(r.logits.len(), 4);
        // (numerical check is covered by the python cross-validation test;
        // here we only assert the graph wiring executed without panic and
        // produced finite values)
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn trace_dimensions() {
        let m = tiny_conv(31);
        let x = rand_input(6 * 6 * 2, 19);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy(&m, 0, n);
        let r = run_sample(
            &m,
            Some(&pol),
            &x,
            RunOpts { oracle: false, collect_trace: true, ..Default::default() },
        );
        // every compute node gets a trace (the simulator replays them all);
        // only the policied layer (node 0) can contain skips
        assert_eq!(r.traces.len(), 4);
        let t = r.traces.iter().find(|t| t.node == 0).unwrap();
        assert_eq!(t.rows, 6 * 6);
        assert_eq!(t.cout, n);
        assert_eq!(t.skipped.len(), t.rows * t.cout);
        for other in r.traces.iter().filter(|t| t.node != 0) {
            assert!(other.skipped.iter().all(|&s| !s), "non-policied layer skipped");
        }
    }

    /// The planned tiled engine must be bit-identical to the scalar
    /// reference on the in-tree models, for every (policy, oracle,
    /// trace, threads) combination. Random-model coverage lives in
    /// rust/tests/engine_equivalence.rs.
    #[test]
    fn tiled_matches_scalar_reference() {
        for seed in [1u64, 9, 33] {
            let models = [tiny_fc(seed), tiny_conv(seed)];
            for m in &models {
                let (h, w, c) = m.input_shape;
                let x = rand_input(h * w * c, seed ^ 0xA5);
                let n = m.nodes[0].cout();
                let pol = always_zero_policy(m, 0, n);
                for policy in [None, Some(&pol)] {
                    for oracle in [false, true] {
                        for threads in [1usize, 3] {
                            let base = RunOpts {
                                oracle,
                                collect_trace: true,
                                threads: 1,
                                engine: EngineSel::ScalarRef,
                                ..Default::default()
                            };
                            let want = run_sample(m, policy, &x, base);
                            let got = run_sample(
                                m,
                                policy,
                                &x,
                                RunOpts { threads, engine: EngineSel::Tiled, ..base },
                            );
                            assert_eq!(want.logits, got.logits, "{} logits", m.name);
                            assert_eq!(want.pred, got.pred, "{} pred stats", m.name);
                            assert_eq!(want.ops, got.ops, "{} ops stats", m.name);
                            assert_eq!(want.traces, got.traces, "{} traces", m.name);
                        }
                    }
                }
            }
        }
    }

    /// Every input-sparsity mode must be invisible: logits, OpsStats
    /// (incl. the macs_skipped_input_zero counter), PredStats and traces
    /// identical whether the sparse kernels ran, the dense ones, or the
    /// auto crossover mixed them per row. The deep model's post-ReLU
    /// layers guarantee genuinely sparse inputs (and some all-zero
    /// patches under the always-zero policy).
    #[test]
    fn input_sparsity_modes_bit_identical() {
        let m = tiny_conv(61);
        let x = rand_input(6 * 6 * 2, 67);
        let n = m.nodes[0].cout();
        let pol = always_zero_policy(&m, 0, n);
        for policy in [None, Some(&pol)] {
            let base = RunOpts {
                oracle: true,
                collect_trace: true,
                input_sparsity: InputSparsity::Off,
                ..Default::default()
            };
            let want = run_sample(&m, policy, &x, base);
            // post-ReLU layers make the ineffectual-input pool non-empty
            assert!(want.ops.macs_skipped_input_zero > 0);
            assert!(want.ops.effectual_macs() <= want.ops.macs_done);
            for mode in [InputSparsity::On, InputSparsity::Auto] {
                for threads in [1usize, 3] {
                    let got = run_sample(
                        &m,
                        policy,
                        &x,
                        RunOpts { input_sparsity: mode, threads, ..base },
                    );
                    assert_eq!(want.logits, got.logits, "mode={mode:?}");
                    assert_eq!(want.ops, got.ops, "mode={mode:?}");
                    assert_eq!(want.pred, got.pred, "mode={mode:?}");
                    assert_eq!(want.traces, got.traces, "mode={mode:?}");
                }
            }
            // the scalar reference path never runs sparse kernels but
            // must report the same data-derived counter
            let scalar = run_sample(&m, policy, &x, base.scalar_ref());
            assert_eq!(
                scalar.ops.macs_skipped_input_zero,
                want.ops.macs_skipped_input_zero
            );
        }
    }

    /// Every non-default strategy must agree between engines too — they
    /// exercise the other decision branches.
    #[test]
    fn tiled_matches_scalar_on_every_strategy() {
        let m = tiny_conv(47);
        let x = rand_input(6 * 6 * 2, 51);
        let n = m.nodes[0].cout();
        for strategy in Strategy::ALL {
            let pol = always_zero_policy_with(&m, 0, n, strategy);
            let base = RunOpts {
                oracle: true,
                collect_trace: true,
                threads: 1,
                engine: EngineSel::ScalarRef,
                ..Default::default()
            };
            let want = run_sample(&m, Some(&pol), &x, base);
            for threads in [1usize, 2] {
                let got = run_sample(
                    &m,
                    Some(&pol),
                    &x,
                    RunOpts { threads, engine: EngineSel::Tiled, ..base },
                );
                assert_eq!(want.logits, got.logits, "strategy={strategy:?}");
                assert_eq!(want.pred, got.pred, "strategy={strategy:?}");
                assert_eq!(want.ops, got.ops, "strategy={strategy:?}");
                assert_eq!(want.traces, got.traces, "strategy={strategy:?}");
            }
        }
    }
}
