//! # Mixture-of-Rookies (MoR) — full-system reproduction
//!
//! Reproduction of *"Mixture-of-Rookies: Saving DNN Computations by
//! Predicting ReLU Outputs"* (Pinto, Arnau, González — 2022) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's evaluation platform: a cycle-level
//!   accelerator simulator ([`sim`]) with an LPDDR4 DRAM model, an
//!   energy/area model ([`energy`]), the functional int8 inference engine
//!   ([`engine`]) — triple-sided sparse: the predictor's output skipping
//!   composes with input-zero lane elision ([`engine::InputSparsity`])
//!   and weight-zero lane elision ([`engine::WeightSparsity`]) —
//!   the online MoR predictor ([`predictor`]), the offline
//!   angle clustering re-implementation ([`cluster`]), a PJRT runtime to
//!   execute the AOT-compiled JAX artifacts (`runtime`, behind the
//!   `pjrt` feature) and a serving coordinator ([`coordinator`]).
//! * **L2 (python/compile)** — the JAX model zoo lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the dot-product
//!   hot spots, verified against pure-jnp oracles.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/`, after which the `mor` binary is self-contained.
//!
//! Entry points:
//! * [`model::Artifacts::load`] — load a model + predictor + data bundle.
//! * [`session::Session`] — build an inference context (model + skip
//!   strategy + engine options); the single entry point evaluation,
//!   serving and the figure harness go through. `finish()` compiles the
//!   model into a [`plan::ModelPlan`] and owns a [`plan::WorkspacePool`],
//!   so the steady-state forward is allocation-free.
//! * [`plan`] — the compile/execute split itself: frozen per-layer
//!   execution plans, reusable workspaces, and the tile-loop executor.
//! * [`predictor::strategies`] — the pluggable `ZeroPredictor` API
//!   (`mor`, `binary`, `cluster`, `oracle`, `none`).
//! * [`predictor::MorRun`] — run inference with prediction, collect stats.
//! * [`sim::Simulator`] — replay a skip-trace on the cycle-level model.
//! * [`figures`] — regenerate every table/figure of the paper.

// Every unsafe operation must sit in its own `unsafe {}` block with an
// adjacent `// SAFETY:` justification, even inside unsafe fns — the
// contract `tools/unsafe_audit.sh` lints for. The only unsafe code in
// the crate is the AVX2 kernels (`engine/dot.rs`, `engine/gemm.rs`)
// and the counting allocator (`util/alloc_count.rs`).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod engine;
pub mod figures;
pub mod model;
pub mod plan;
pub mod predictor;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod session;
pub mod sim;
pub mod util;
pub mod workload;

/// Default artifacts directory, relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// The four benchmark models of the paper (Section 5.1).
pub const MODELS: [&str; 4] = ["tds", "cnn10", "darknet19m", "resnet18m"];
