//! Tiny property-testing harness (proptest is not in the offline vendor
//! set; see DESIGN.md §3). Deterministic by default, shrink-free: on
//! failure it reports the seed + case index so the exact case replays.
//!
//! ```no_run
//! use mor::util::prop::{property, Gen};
//! use mor::prop_assert;
//! property("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.i64(-100, 100);
//!     let b = g.i64(-100, 100);
//!     prop_assert!(g, a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Per-case generator handle; wraps the RNG and carries case metadata.
pub struct Gen {
    rng: Rng,
    pub case: usize,
    pub seed: u64,
}

impl Gen {
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.int_in(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.int_in(lo as i64, hi as i64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn int8(&mut self) -> i8 {
        self.rng.int8()
    }

    pub fn vec_i8(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.int8()).collect()
    }

    pub fn vec_f32(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..n).map(|_| self.f64(lo, hi) as f32).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs)
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `f`. Panics (failing the enclosing test)
/// with the seed and case index on the first failed case.
///
/// Override the base seed with `MOR_PROP_SEED` to replay a failure.
/// Under Miri the case count shrinks ~30x (floor 3): the interpreter is
/// orders of magnitude slower than native, and the undefined-behavior
/// check it contributes needs case *diversity*, not case volume.
pub fn property<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let cases = if cfg!(miri) { cases.min((cases / 30).max(3)) } else { cases };
    let base_seed: u64 = std::env::var("MOR_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(seed),
            case,
            seed,
        };
        if let Err(msg) = f(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, \
                 set MOR_PROP_SEED={base_seed} to replay): {msg}"
            );
        }
    }
}

/// assert-like helper that returns Err instead of panicking, so `property`
/// can attach case/seed context.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("trivial", 50, |_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_context() {
        property("fails", 10, |g| {
            let v = g.i64(0, 100);
            if v >= 0 {
                Err(format!("v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_in_bounds() {
        property("bounds", 100, |g| {
            let v = g.usize(3, 9);
            prop_assert!(g, (3..=9).contains(&v), "usize out of bounds: {v}");
            let f = g.f64(-1.0, 1.0);
            prop_assert!(g, (-1.0..1.0).contains(&f), "f64 out of bounds: {f}");
            Ok(())
        });
    }
}
