//! Synchronization primitives, swappable for [loom]'s model-checked
//! versions.
//!
//! The two bespoke concurrent structures in this crate — the
//! coordinator's [`crate::coordinator::queue::SharedQueue`] and the
//! plan layer's [`crate::plan::WorkspacePool`] — import their mutexes,
//! condvars and atomics from here instead of `std::sync`. Normal builds
//! re-export `std` (zero cost, identical types); `RUSTFLAGS="--cfg
//! loom"` builds re-export `loom::sync`, whose scheduler exhaustively
//! explores every thread interleaving of the models in
//! `rust/tests/loom_models.rs` (no lost wakeups, no deadlock on close,
//! exact drop accounting, grow-to-peak-once, no workspace aliasing).
//!
//! Only the types those structures use are re-exported, so the shim
//! cannot drift into a parallel std. `loom` mirrors the `std::sync` API
//! (including `LockResult` returns), which is what lets the production
//! sources compile unchanged under both cfgs.

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};
