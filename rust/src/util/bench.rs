//! Bench harness (criterion is not in the offline vendor set).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary that uses this
//! module for (a) wall-clock micro-benchmarks with warmup + robust stats,
//! and (b) table printing in the paper's row format. `cargo bench` runs
//! them all; each prints the figure/table it regenerates.

use std::time::Instant;

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn report(&self) {
        println!(
            "  {:<42} {:>12.3} ms/iter (median {:.3}, min {:.3}, sd {:.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.median_ns / 1e6,
            self.min_ns / 1e6,
            self.stddev_ns / 1e6,
            self.iters
        );
    }
}

/// Time `f` with warmup; chooses iteration count so total time ≈ budget.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Timing {
    bench_with(name, 3, 0.5, &mut f)
}

/// Like [`bench`] but with explicit warmup iterations and time budget (s).
pub fn bench_with<F: FnMut()>(name: &str, warmup: usize, budget_s: f64, f: &mut F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    // estimate single-iteration cost
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / est) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let median = samples[samples.len() / 2];
    let min = samples[0];
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    Timing {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: median,
        min_ns: min,
        stddev_ns: var.sqrt(),
    }
}

/// Pretty table printer used by the figure benches.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        println!("\n=== {} ===", self.title);
        let header: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:<w$}", h, w = widths[i]))
            .collect();
        println!("{}", header.join("  "));
        println!("{}", "-".repeat(header.join("  ").len()));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(0)))
                .collect();
            println!("{}", cells.join("  "));
        }
    }

    /// CSV dump (figures_out/*.csv) so plots can be made outside.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.headers.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }

    pub fn write_csv(&self, dir: &str, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(format!("{dir}/{name}.csv"), self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let t = bench_with("noop-ish", 1, 0.02, &mut || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(t.iters >= 5);
        assert!(t.min_ns <= t.mean_ns * 1.01);
        assert!(t.median_ns > 0.0);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,bb\n1,2\n");
    }
}
