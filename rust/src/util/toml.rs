//! Minimal TOML-subset parser for the config system (`configs/*.toml`).
//!
//! Supported grammar (what our configs use — see configs/table1.toml):
//! `[section]` headers, `key = value` with integer / float / bool / string
//! values, `#` comments, blank lines. Keys are addressed as
//! `"section.key"` (or bare `"key"` before any section header).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl TomlValue {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

// hand-rolled Display/Error (thiserror is not in the offline vendor set)
impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

#[derive(Clone, Debug, Default)]
pub struct Toml {
    pub entries: BTreeMap<String, TomlValue>,
}

impl Toml {
    pub fn parse(src: &str) -> Result<Toml, TomlError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(TomlError {
                    line: ln + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(TomlError {
                line: ln + 1,
                msg: "expected 'key = value'".into(),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim()).ok_or(TomlError {
                line: ln + 1,
                msg: format!("cannot parse value '{}'", v.trim()),
            })?;
            entries.insert(key, value);
        }
        Ok(Toml { entries })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string is respected
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<TomlValue> {
    if s == "true" {
        return Some(TomlValue::Bool(true));
    }
    if s == "false" {
        return Some(TomlValue::Bool(false));
    }
    if let Some(q) = s.strip_prefix('"') {
        return q.strip_suffix('"').map(|v| TomlValue::Str(v.to_string()));
    }
    // underscores as digit separators, like real TOML
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# accelerator configuration (Table 1)
name = "table1"

[accelerator]
frequency_mhz = 1200
num_cus = 8
cu_width = 8          # MACs per cycle per CU
input_sram_kb = 16.0
predictor = true

[dram]
capacity_gb = 1
port_bytes = 8
burst_bytes = 64
"#;

    #[test]
    fn parses_sample() {
        let t = Toml::parse(SAMPLE).unwrap();
        assert_eq!(t.str_or("name", ""), "table1");
        assert_eq!(t.i64_or("accelerator.frequency_mhz", 0), 1200);
        assert_eq!(t.i64_or("accelerator.cu_width", 0), 8);
        assert_eq!(t.f64_or("accelerator.input_sram_kb", 0.0), 16.0);
        assert!(t.bool_or("accelerator.predictor", false));
        assert_eq!(t.i64_or("dram.burst_bytes", 0), 64);
    }

    #[test]
    fn defaults_for_missing_keys() {
        let t = Toml::parse("").unwrap();
        assert_eq!(t.i64_or("nope", 7), 7);
        assert_eq!(t.str_or("nope", "x"), "x");
    }

    #[test]
    fn comments_and_underscores() {
        let t = Toml::parse("big = 1_000_000 # one million").unwrap();
        assert_eq!(t.i64_or("big", 0), 1_000_000);
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = Toml::parse("s = \"a#b\"").unwrap();
        assert_eq!(t.str_or("s", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Toml::parse("a = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn int_vs_float() {
        let t = Toml::parse("a = 2\nb = 2.5").unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(2));
        assert_eq!(t.get("b").unwrap().as_i64(), None);
        assert_eq!(t.f64_or("a", 0.0), 2.0);
        assert_eq!(t.f64_or("b", 0.0), 2.5);
    }
}
