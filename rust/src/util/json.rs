//! Minimal JSON parser/writer (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar as produced by python's `json.dump`:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Numbers are kept as f64 (the artifacts never need 64-bit integers).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

// hand-rolled Display/Error (thiserror is not in the offline vendor set)
impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array of numbers as f32 (the predictor parameter vectors).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // (surrogate pairs unsupported — artifacts are ASCII)
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && self.b[self.pos] & 0xC0 == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---- writer ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Convenience: build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"m":[0.5,-1.25,3],"name":"tds","n":64,"ok":true,"x":null}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        let again = Json::parse(&printed).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn f32_vec_accessor() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_usize_vec().unwrap(), vec![1, 2]);
    }
}
