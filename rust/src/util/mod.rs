//! Substrate utilities implemented in-tree (the offline vendor set has no
//! serde/clap/rand/proptest/criterion — see DESIGN.md §3).

pub mod alloc_count;
pub mod bench;
pub mod bits;
pub mod interval;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod toml;

/// Column-major/row-major-agnostic ceil-division helper used all over the
/// simulator's cycle math.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Grow a vector's capacity to at least `want` elements without touching
/// its length or contents — the capacity-only warmup idiom the
/// zero-allocation forward path ([`crate::plan`]) is built on.
#[inline]
pub fn reserve_capacity<T>(v: &mut Vec<T>, want: usize) {
    if v.capacity() < want {
        v.reserve(want - v.len());
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean (the paper's "on average" for speedups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile with linear interpolation (p in [0,100]).
///
/// Clones and sorts the input; when taking several percentiles of the same
/// data, sort once yourself and use [`percentile_sorted`].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.to_vec();
    // total_cmp: NaN sorts after +inf instead of panicking mid-report
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// p-th percentile of an already-sorted slice (no clone, no re-sort).
pub fn percentile_sorted(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        xs[lo] + (xs[hi] - xs[lo]) * (rank - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_speedups() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_single_element() {
        let xs = [7.5];
        assert_eq!(percentile(&xs, 0.0), 7.5);
        assert_eq!(percentile(&xs, 50.0), 7.5);
        assert_eq!(percentile(&xs, 100.0), 7.5);
        assert_eq!(percentile_sorted(&xs, 99.0), 7.5);
    }

    #[test]
    fn percentile_nan_does_not_panic() {
        // total_cmp orders NaN after +inf: low percentiles stay finite and
        // no comparison panics (partial_cmp().unwrap() used to)
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let p0 = percentile(&xs, 0.0);
        assert_eq!(p0, 1.0);
        let p100 = percentile(&xs, 100.0);
        assert!(p100.is_nan());
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        for p in [0.0, 25.0, 50.0, 90.0, 100.0] {
            assert_eq!(percentile(&xs, p), percentile_sorted(&sorted, p));
        }
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }
}
