//! Substrate utilities implemented in-tree (the offline vendor set has no
//! serde/clap/rand/proptest/criterion — see DESIGN.md §3).

pub mod bench;
pub mod bits;
pub mod json;
pub mod prop;
pub mod rng;
pub mod toml;

/// Column-major/row-major-agnostic ceil-division helper used all over the
/// simulator's cycle math.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean (the paper's "on average" for speedups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile with linear interpolation (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_speedups() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
    }
}
