//! Allocation-counting global allocator for the zero-allocation
//! contracts of the planned forward path ([`crate::plan`]).
//!
//! The counter is **per-thread** (const-initialized TLS, so the counter
//! access itself never allocates and other test threads in the same
//! process don't disturb a measurement). Each consumer binary installs
//! it itself — a `#[global_allocator]` must live in the final binary,
//! not in this library:
//!
//! ```ignore
//! use mor::util::alloc_count::{allocs_on_this_thread, CountingAlloc};
//!
//! #[global_allocator]
//! static COUNTING: CountingAlloc = CountingAlloc;
//!
//! let before = allocs_on_this_thread();
//! // ... steady-state forward ...
//! assert_eq!(allocs_on_this_thread() - before, 0);
//! ```
//!
//! Used by `rust/tests/plan_contracts.rs` (debug) and
//! `rust/benches/perf_hotpaths.rs` (release) so both assertions measure
//! exactly the same thing.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator wrapper that counts `alloc`/`alloc_zeroed`/`realloc`
/// calls on the current thread (deallocations are free and not counted).
pub struct CountingAlloc;

impl CountingAlloc {
    #[inline]
    fn bump() {
        // try_with: TLS may be unavailable during thread teardown —
        // losing those counts is fine, panicking in the allocator is not
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
    }
}

// SAFETY: pure pass-through to `System` — every method forwards its
// arguments unchanged after a TLS counter bump that never allocates or
// unwinds (`try_with` + `Cell`), so `System`'s own GlobalAlloc contract
// (layout validity, pointer provenance) is preserved verbatim.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        Self::bump();
        // SAFETY: caller upholds GlobalAlloc's contract for `l`;
        // forwarded unchanged.
        unsafe { System.alloc(l) }
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        Self::bump();
        // SAFETY: caller upholds GlobalAlloc's contract for `l`;
        // forwarded unchanged.
        unsafe { System.alloc_zeroed(l) }
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        Self::bump();
        // SAFETY: caller guarantees `p` came from this allocator (i.e.
        // from `System`) with layout `l`; forwarded unchanged.
        unsafe { System.realloc(p, l, new_size) }
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        // SAFETY: caller guarantees `p` came from this allocator (i.e.
        // from `System`) with layout `l`; forwarded unchanged.
        unsafe { System.dealloc(p, l) }
    }
}

/// Heap allocations performed by the current thread since it started
/// (meaningful only when [`CountingAlloc`] is installed as the global
/// allocator).
pub fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}
