//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! The vendored crate set has `rand_core` but no `rand`, so distributions
//! are implemented here. Used by the property harness, the Monte Carlo
//! angle verification (paper §3.2.2) and the workload generators.

/// xoshiro256++ (Blackman & Vigna). Deterministic, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive). Uses rejection-free Lemire.
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let range = (hi - lo) as u64 + 1;
        if range == 0 {
            return self.next_u64() as i64; // full range
        }
        lo + (self.next_u64() % range) as i64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Random int8 uniform over the full range (for synthetic tensors).
    pub fn int8(&mut self) -> i8 {
        (self.next_u64() & 0xFF) as u8 as i8
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Exponential with rate lambda (inter-arrival times for the workload
    /// generator's Poisson process).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(-self.f64()).ln_1p() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn int_in_bounds_inclusive() {
        let mut r = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.int_in(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs = r.normal_vec(50_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
