//! Interval-arithmetic domains for the quantized-numerics analyzer
//! ([`crate::plan::ranges`]).
//!
//! Two abstract domains:
//!
//! * [`Ival`] — closed integer intervals over i64 with **checked**
//!   arithmetic: any operation whose exact result cannot be represented
//!   in i64 widens to [`Ival::TOP`] instead of wrapping. TOP then fails
//!   every `fits_signed` query, so overflow in the *analysis* can only
//!   make the verdict more conservative, never unsound.
//! * [`Fival`] — closed f64 intervals for the float pipeline
//!   (dequantize → BN affine → residual add → ReLU). The engine
//!   evaluates the same expressions in f32, so the analyzer widens each
//!   derived interval outward ([`Fival::widen`]) before treating it as
//!   a bound on runtime values; NaN bounds are sticky (they propagate
//!   through every operation) so a poisoned pipeline is always flagged
//!   by [`Fival::fits_f32`] at the end.

/// Closed integer interval `[lo, hi]` over i64, or TOP (unknown).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ival {
    pub lo: i64,
    pub hi: i64,
}

impl Ival {
    /// The widened "anything" element: the full i64 range. Produced by
    /// any checked operation that overflows; absorbing for add/mul.
    pub const TOP: Ival = Ival {
        lo: i64::MIN,
        hi: i64::MAX,
    };

    pub fn new(lo: i64, hi: i64) -> Ival {
        debug_assert!(lo <= hi, "interval bounds out of order: [{lo}, {hi}]");
        Ival { lo, hi }
    }

    pub fn exact(v: i64) -> Ival {
        Ival { lo: v, hi: v }
    }

    pub fn is_top(&self) -> bool {
        *self == Ival::TOP
    }

    /// `self + o`, widening to TOP if either endpoint overflows i64.
    pub fn add(self, o: Ival) -> Ival {
        match (self.lo.checked_add(o.lo), self.hi.checked_add(o.hi)) {
            (Some(lo), Some(hi)) => Ival { lo, hi },
            _ => Ival::TOP,
        }
    }

    /// `k * self` for a scalar of either sign (endpoints swap when
    /// `k < 0`), widening to TOP on overflow.
    pub fn mul_scalar(self, k: i64) -> Ival {
        match (self.lo.checked_mul(k), self.hi.checked_mul(k)) {
            (Some(a), Some(b)) => Ival {
                lo: a.min(b),
                hi: a.max(b),
            },
            _ => Ival::TOP,
        }
    }

    /// `Σ cᵢ · xᵢ` where each `xᵢ` ranges over `iv` — the sum-of-products
    /// form every dot-product bound reduces to once weights are grouped
    /// by sign (`Σ max(w,0)` and `Σ min(w,0)` against the activation
    /// interval). Widens to TOP on any intermediate overflow.
    pub fn sum_products(terms: &[(i64, Ival)]) -> Ival {
        terms
            .iter()
            .fold(Ival::exact(0), |acc, &(c, iv)| acc.add(iv.mul_scalar(c)))
    }

    /// Smallest interval containing both.
    pub fn hull(self, o: Ival) -> Ival {
        Ival {
            lo: self.lo.min(o.lo),
            hi: self.hi.max(o.hi),
        }
    }

    /// Clamp both endpoints into `[lo, hi]` (e.g. a saturating quantizer).
    pub fn clamp(self, lo: i64, hi: i64) -> Ival {
        Ival {
            lo: self.lo.clamp(lo, hi),
            hi: self.hi.clamp(lo, hi),
        }
    }

    pub fn contains(&self, v: i64) -> bool {
        self.lo <= v && v <= self.hi
    }

    /// Largest absolute value in the interval (u64 so `|i64::MIN|` is
    /// representable).
    pub fn max_abs(&self) -> u64 {
        self.lo.unsigned_abs().max(self.hi.unsigned_abs())
    }

    /// Does every value in the interval fit a signed `bits`-wide
    /// accumulator? TOP never fits (unknown ⇒ unprovable). `bits` must
    /// be in `2..=63`; the analyzer only asks about 8..=32.
    pub fn fits_signed(&self, bits: u32) -> bool {
        debug_assert!((2..=63).contains(&bits));
        if self.is_top() {
            return false;
        }
        let min = -(1i64 << (bits - 1));
        let max = (1i64 << (bits - 1)) - 1;
        self.lo >= min && self.hi <= max
    }
}

/// Closed f64 interval for the dequantized float pipeline. NaN bounds
/// are sticky: once poisoned, every derived interval stays poisoned and
/// [`Fival::fits_f32`] reports false.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Fival {
    pub lo: f64,
    pub hi: f64,
}

/// Order two candidates into (min, max), poisoning on NaN instead of
/// silently dropping it the way `f64::min`/`f64::max` would.
fn order(a: f64, b: f64) -> (f64, f64) {
    if a.is_nan() || b.is_nan() {
        (f64::NAN, f64::NAN)
    } else if a <= b {
        (a, b)
    } else {
        (b, a)
    }
}

impl Fival {
    pub fn new(lo: f64, hi: f64) -> Fival {
        debug_assert!(
            lo <= hi || lo.is_nan() || hi.is_nan(),
            "interval bounds out of order: [{lo}, {hi}]"
        );
        Fival { lo, hi }
    }

    pub fn exact(v: f64) -> Fival {
        Fival { lo: v, hi: v }
    }

    pub fn from_ival(iv: Ival) -> Fival {
        Fival {
            lo: iv.lo as f64,
            hi: iv.hi as f64,
        }
    }

    pub fn is_nan(&self) -> bool {
        self.lo.is_nan() || self.hi.is_nan()
    }

    pub fn add(self, o: Fival) -> Fival {
        let (lo, hi) = order(self.lo + o.lo, self.hi + o.hi);
        Fival { lo, hi }
    }

    /// `k * self` for a scalar of either sign; NaN scalars poison.
    pub fn scale(self, k: f64) -> Fival {
        let (lo, hi) = order(self.lo * k, self.hi * k);
        Fival { lo, hi }
    }

    /// `scale * self + shift` — the BN affine per filter.
    pub fn affine(self, scale: f64, shift: f64) -> Fival {
        let s = self.scale(scale);
        let (lo, hi) = order(s.lo + shift, s.hi + shift);
        Fival { lo, hi }
    }

    /// `max(·, 0)` applied pointwise. NaN intervals pass through
    /// unchanged so a poisoned pipeline is still flagged downstream.
    pub fn relu(self) -> Fival {
        if self.is_nan() {
            return self;
        }
        Fival {
            lo: self.lo.max(0.0),
            hi: self.hi.max(0.0),
        }
    }

    pub fn hull(self, o: Fival) -> Fival {
        if self.is_nan() || o.is_nan() {
            return Fival {
                lo: f64::NAN,
                hi: f64::NAN,
            };
        }
        Fival {
            lo: self.lo.min(o.lo),
            hi: self.hi.max(o.hi),
        }
    }

    pub fn contains(&self, v: f64) -> bool {
        self.lo <= v && v <= self.hi
    }

    /// Expand both bounds outward by `abs + rel · max(|lo|, |hi|)` — the
    /// slack that covers the engine evaluating the same expression in
    /// f32 (each op rounds at ≤ 2⁻²⁴ relative; the analyzer uses a far
    /// larger margin so slack is never the thing a test debugs). NaN
    /// intervals pass through unchanged.
    pub fn widen(self, rel: f64, abs: f64) -> Fival {
        if self.is_nan() {
            return self;
        }
        let pad = abs + rel * self.lo.abs().max(self.hi.abs());
        Fival {
            lo: self.lo - pad,
            hi: self.hi + pad,
        }
    }

    /// Is every value in the interval a finite f32? The requantization
    /// soundness question: false means some runtime f32 in this range
    /// could be ±inf or NaN.
    pub fn fits_f32(&self) -> bool {
        self.lo.is_finite()
            && self.hi.is_finite()
            && self.lo.abs() <= f32::MAX as f64
            && self.hi.abs() <= f32::MAX as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_widens_on_overflow() {
        let near = Ival::exact(i64::MAX - 1);
        assert!(near.add(Ival::exact(2)).is_top());
        assert_eq!(near.add(Ival::exact(1)).hi, i64::MAX);
        assert!(Ival::TOP.add(Ival::exact(0)).is_top());
    }

    #[test]
    fn mul_scalar_sign_handling() {
        let iv = Ival::new(-3, 5);
        assert_eq!(iv.mul_scalar(2), Ival::new(-6, 10));
        assert_eq!(iv.mul_scalar(-2), Ival::new(-10, 6));
        assert_eq!(iv.mul_scalar(0), Ival::exact(0));
        assert!(Ival::new(1, i64::MAX / 2 + 1).mul_scalar(2).is_top());
    }

    #[test]
    fn sum_products_matches_manual_bound() {
        // Σ max(w,0)=7, Σ min(w,0)=-4 against x ∈ [-127, 127]:
        // exact dot range is [-(7+4)·127, (7+4)·127].
        let q = Ival::new(-127, 127);
        let d = Ival::sum_products(&[(7, q), (-4, q)]);
        assert_eq!(d, Ival::new(-11 * 127, 11 * 127));
        // tighter when the activation range is one-sided (post-ReLU)
        let q = Ival::new(0, 127);
        let d = Ival::sum_products(&[(7, q), (-4, q)]);
        assert_eq!(d, Ival::new(-4 * 127, 7 * 127));
    }

    #[test]
    fn fits_signed_boundaries() {
        assert!(Ival::new(-32768, 32767).fits_signed(16));
        assert!(!Ival::new(-32769, 0).fits_signed(16));
        assert!(!Ival::new(0, 32768).fits_signed(16));
        assert!(Ival::new(i32::MIN as i64, i32::MAX as i64).fits_signed(32));
        assert!(!Ival::new(0, i32::MAX as i64 + 1).fits_signed(32));
        assert!(!Ival::TOP.fits_signed(32));
    }

    #[test]
    fn hull_clamp_contains_max_abs() {
        let h = Ival::new(-2, 3).hull(Ival::new(1, 9));
        assert_eq!(h, Ival::new(-2, 9));
        assert!(h.contains(-2) && h.contains(9) && !h.contains(10));
        assert_eq!(Ival::new(-300, 50).clamp(-127, 127), Ival::new(-127, 50));
        assert_eq!(Ival::new(-9, 4).max_abs(), 9);
        assert_eq!(Ival::exact(i64::MIN).max_abs(), 1u64 << 63);
    }

    #[test]
    fn fival_affine_and_relu() {
        let v = Fival::new(-2.0, 3.0);
        let a = v.affine(-2.0, 1.0); // [-6,4] + 1 = [-5, 7]
        assert_eq!((a.lo, a.hi), (-5.0, 7.0));
        let r = a.relu();
        assert_eq!((r.lo, r.hi), (0.0, 7.0));
    }

    #[test]
    fn fival_nan_is_sticky() {
        let bad = Fival::exact(1.0).scale(f64::NAN);
        assert!(bad.is_nan());
        assert!(bad.add(Fival::exact(0.0)).is_nan());
        assert!(bad.relu().is_nan());
        assert!(bad.hull(Fival::exact(0.0)).is_nan());
        assert!(!bad.fits_f32());
    }

    #[test]
    fn fival_widen_and_fits_f32() {
        let v = Fival::new(-1.0, 2.0).widen(0.01, 0.5);
        assert!(v.lo < -1.5 && v.hi > 2.5);
        assert!(v.contains(-1.0) && v.contains(2.52));
        assert!(Fival::new(-1e38, 1e38).fits_f32());
        assert!(!Fival::new(0.0, 1e39).fits_f32());
        assert!(!Fival::new(f64::NEG_INFINITY, 0.0).fits_f32());
    }
}
