//! Bit-packed binary vectors for the binCU fast path.
//!
//! The paper's Binary Prediction Unit computes ±1 dot products with XNOR +
//! popcount gates. On the host, the same trick makes the functional engine
//! fast: pack "activation bits" (x > 0) and "weight sign bits" (w >= 0)
//! into u64 words and compute
//!
//! ```text
//! p_bin = matches - mismatches = K_valid - 2 * popcount(a XOR b)   (valid lanes)
//! ```
//!
//! with a per-word validity mask so SAME-padding lanes contribute 0
//! (matching the jnp calibration path, which zero-pads the *binarized*
//! tensor — see python/compile/quantize.py).

/// A packed ±1/invalid vector: `bits[i]` = 1 for +1 lanes, 0 for -1 lanes;
/// `valid[i]` = 1 where the lane participates (0 ⇒ contributes nothing).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedVec {
    pub bits: Vec<u64>,
    pub valid: Vec<u64>,
    pub len: usize,
}

impl PackedVec {
    pub fn zeros(len: usize) -> Self {
        let words = len.div_ceil(64);
        PackedVec {
            bits: vec![0; words],
            valid: vec![0; words],
            len,
        }
    }

    /// Pack weight signs: +1 iff w >= 0; every lane valid.
    pub fn from_weights(w: &[i8]) -> Self {
        let mut p = PackedVec::zeros(w.len());
        for (i, &v) in w.iter().enumerate() {
            if v >= 0 {
                p.set_bit(i);
            }
            p.set_valid(i);
        }
        p
    }

    /// Pack activation bits: +1 iff x > 0; every lane valid.
    pub fn from_acts(x: &[i8]) -> Self {
        let mut p = PackedVec::zeros(x.len());
        for (i, &v) in x.iter().enumerate() {
            if v > 0 {
                p.set_bit(i);
            }
            p.set_valid(i);
        }
        p
    }

    #[inline]
    pub fn set_bit(&mut self, i: usize) {
        self.bits[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    pub fn set_valid(&mut self, i: usize) {
        self.valid[i / 64] |= 1 << (i % 64);
    }

    /// Mark lane i as +1 (bit set) or -1 (clear), valid either way.
    #[inline]
    pub fn push_lane(&mut self, i: usize, plus_one: bool) {
        if plus_one {
            self.set_bit(i);
        }
        self.set_valid(i);
    }

    /// Binary dot product over jointly-valid lanes:
    /// sum over lanes of (+1 if bits agree else -1), invalid lanes add 0.
    ///
    /// §Perf: the word loop runs four independent accumulator streams so
    /// the xor/and/popcount chains pipeline (and LLVM can vectorize them)
    /// instead of serializing on one accumulator. u32 accumulators are
    /// safe: per stream ≤ (words/4)·64 lanes ≪ 2^32 for any K here.
    pub fn dot(&self, other: &PackedVec) -> i32 {
        debug_assert_eq!(self.len, other.len);
        let n = self.bits.len();
        let mut vc = [0u32; 4];
        let mut mm = [0u32; 4];
        let mut w = 0;
        while w + 4 <= n {
            for j in 0..4 {
                let valid = self.valid[w + j] & other.valid[w + j];
                vc[j] += valid.count_ones();
                mm[j] += ((self.bits[w + j] ^ other.bits[w + j]) & valid).count_ones();
            }
            w += 4;
        }
        let mut valid_count: u32 = vc.iter().sum();
        let mut mismatches: u32 = mm.iter().sum();
        while w < n {
            let valid = self.valid[w] & other.valid[w];
            valid_count += valid.count_ones();
            mismatches += ((self.bits[w] ^ other.bits[w]) & valid).count_ones();
            w += 1;
        }
        valid_count as i32 - 2 * mismatches as i32
    }
}

/// Reference (unpacked) binary dot used by tests: act(x) in {-1,+1,0-pad},
/// sign(w) in {-1,+1}.
pub fn binary_dot_ref(x: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    x.iter()
        .zip(w)
        .map(|(&xv, &wv)| {
            let a: i32 = if xv > 0 { 1 } else { -1 };
            let s: i32 = if wv >= 0 { 1 } else { -1 };
            a * s
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::property;

    #[test]
    fn packed_matches_reference() {
        property("packed binary dot == reference", 200, |g| {
            let n = g.usize(1, 300);
            let x = g.vec_i8(n);
            let w = g.vec_i8(n);
            let got = PackedVec::from_acts(&x).dot(&PackedVec::from_weights(&w));
            let want = binary_dot_ref(&x, &w);
            crate::prop_assert!(g, got == want, "n={n} got={got} want={want}");
            Ok(())
        });
    }

    #[test]
    fn invalid_lanes_contribute_zero() {
        let mut a = PackedVec::zeros(128);
        let mut b = PackedVec::zeros(128);
        // all lanes valid on a; only first 10 valid on b, all agreeing (+1)
        for i in 0..128 {
            a.push_lane(i, true);
        }
        for i in 0..10 {
            b.push_lane(i, true);
        }
        assert_eq!(a.dot(&b), 10);
    }

    #[test]
    fn zero_conventions() {
        // act(0) = -1, sign(0) = +1
        let x = [0i8, 5, 0];
        let w = [0i8, 0, -3];
        // lanes: (-1)(+1) + (+1)(+1) + (-1)(-1) = -1 + 1 + 1 = 1
        assert_eq!(binary_dot_ref(&x, &w), 1);
        assert_eq!(
            PackedVec::from_acts(&x).dot(&PackedVec::from_weights(&w)),
            1
        );
    }

    #[test]
    fn bounds() {
        let x = vec![1i8; 130];
        let w = vec![1i8; 130];
        assert_eq!(PackedVec::from_acts(&x).dot(&PackedVec::from_weights(&w)), 130);
        let w2 = vec![-1i8; 130];
        assert_eq!(PackedVec::from_acts(&x).dot(&PackedVec::from_weights(&w2)), -130);
    }
}
