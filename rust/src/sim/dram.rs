//! Bank-state LPDDR4 timing model (the DRAMsim3 substitute — DESIGN.md §3).
//!
//! First-order behaviour the evaluation depends on:
//! * a single shared data bus of `port_bytes`/cycle (Table 1: 8 B @ 1200 MHz),
//! * 64 B bursts,
//! * per-bank row-buffer state: row hits pay tCL, misses pay tRP+tRCD+tCL,
//! * bank-level parallelism across `num_banks` banks,
//! * refresh modelled as a bandwidth tax of tRFC/tREFI.
//!
//! Requests complete in issue order per bank and occupy the bus for
//! `burst_cycles` each — enough to capture the streaming-vs-strided
//! behaviour that separates weight fetches (sequential, row-hit-heavy)
//! from scattered accesses.

use crate::config::DramConfig;

#[derive(Clone, Debug)]
struct Bank {
    open_row: Option<u64>,
    /// Cycle of the last ACTIVATE (tRAS gates the next precharge).
    act_at: u64,
}

/// Cycle-level DRAM channel.
pub struct Dram {
    cfg: DramConfig,
    banks: Vec<Bank>,
    bus_free_at: u64,
    pub stats: DramStats,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct DramStats {
    pub reads: u64,
    pub writes: u64,
    pub bytes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub busy_cycles: u64,
}

impl Dram {
    pub fn new(cfg: DramConfig) -> Dram {
        let banks = (0..cfg.num_banks)
            .map(|_| Bank {
                open_row: None,
                act_at: 0,
            })
            .collect();
        Dram {
            cfg,
            banks,
            bus_free_at: 0,
            stats: DramStats::default(),
        }
    }

    pub fn cfg(&self) -> &DramConfig {
        &self.cfg
    }

    /// Issue a read/write of `bytes` starting at `addr` at time `now`;
    /// returns the completion cycle of the last burst.
    pub fn access(&mut self, now: u64, addr: u64, bytes: u64, write: bool) -> u64 {
        if bytes == 0 {
            return now;
        }
        let burst = self.cfg.burst_bytes;
        let mut t_done = now;
        let mut a = addr - addr % burst;
        let end = addr + bytes;
        while a < end {
            t_done = self.burst_at(now.max(t_done.saturating_sub(self.pipeline_overlap())), a);
            a += burst;
        }
        self.stats.bytes += bytes;
        if write {
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
        }
        // refresh tax: the bank is unavailable tRFC out of every tREFI
        if self.cfg.t_refi > 0 {
            let stretch = 1.0 + self.cfg.t_rfc as f64 / self.cfg.t_refi as f64;
            t_done = now + ((t_done - now) as f64 * stretch) as u64;
        }
        t_done
    }

    /// Back-to-back bursts in an open row pipeline: the next burst's CAS
    /// overlaps the previous data transfer by up to tCL.
    fn pipeline_overlap(&self) -> u64 {
        self.cfg.t_cl
    }

    fn burst_at(&mut self, now: u64, addr: u64) -> u64 {
        let cfg = &self.cfg;
        let row_global = addr / cfg.row_bytes;
        let bank_idx = (row_global % cfg.num_banks as u64) as usize;
        let row = row_global / cfg.num_banks as u64;
        let bank = &mut self.banks[bank_idx];

        let mut t = now;
        match bank.open_row {
            Some(r) if r == row => {
                // open-row hit: CAS may issue immediately (tCCD is enforced
                // by the burst occupying the shared bus)
                self.stats.row_hits += 1;
            }
            Some(_) => {
                // conflict: precharge (respecting tRAS since ACT) + activate
                self.stats.row_misses += 1;
                t = t.max(bank.act_at + cfg.t_ras);
                t += cfg.t_rp + cfg.t_rcd;
                bank.act_at = t;
            }
            None => {
                self.stats.row_misses += 1;
                t += cfg.t_rcd; // activate only
                bank.act_at = t;
            }
        }
        bank.open_row = Some(row);
        // CAS latency, then data occupies the bus
        let data_start = (t + cfg.t_cl).max(self.bus_free_at);
        let burst_cycles = cfg.burst_cycles().max(cfg.t_ccd);
        let done = data_start + burst_cycles;
        self.bus_free_at = done;
        self.stats.busy_cycles += burst_cycles;
        done
    }

    /// Lower bound on cycles to move `bytes` at peak bus bandwidth.
    pub fn min_cycles(&self, bytes: u64) -> u64 {
        crate::util::ceil_div(bytes, self.cfg.port_bytes)
    }

    /// Achieved bandwidth utilisation so far (busy / wall).
    pub fn utilization(&self, wall_cycles: u64) -> f64 {
        if wall_cycles == 0 {
            0.0
        } else {
            self.stats.busy_cycles as f64 / wall_cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DramConfig;

    fn dram() -> Dram {
        Dram::new(DramConfig {
            t_refi: 0, // disable refresh tax for deterministic unit tests
            ..Default::default()
        })
    }

    #[test]
    fn sequential_stream_is_row_hit_dominated() {
        let mut d = dram();
        let mut t = 0;
        for i in 0..64u64 {
            t = d.access(t, i * 64, 64, false);
        }
        assert!(d.stats.row_hits > d.stats.row_misses * 4,
            "hits={} misses={}", d.stats.row_hits, d.stats.row_misses);
    }

    #[test]
    fn random_strided_access_misses_rows() {
        let mut d = dram();
        let mut t = 0;
        // stride across rows in the same bank group
        for i in 0..64u64 {
            t = d.access(t, i * 2048 * 8, 64, false);
        }
        assert!(d.stats.row_misses >= d.stats.row_hits,
            "hits={} misses={}", d.stats.row_hits, d.stats.row_misses);
    }

    #[test]
    fn bandwidth_bounded_by_port() {
        let mut d = dram();
        let bytes = 1 << 20; // 1 MB sequential
        let done = d.access(0, 0, bytes, false);
        let floor = d.min_cycles(bytes);
        assert!(done >= floor, "done={done} < floor={floor}");
        // sequential streaming should get reasonably close to peak
        assert!(
            (done as f64) < floor as f64 * 1.6,
            "sequential stream too slow: {done} vs floor {floor}"
        );
    }

    #[test]
    fn zero_bytes_is_free() {
        let mut d = dram();
        assert_eq!(d.access(17, 0, 0, false), 17);
    }

    #[test]
    fn refresh_tax_stretches_time() {
        let mut with_refresh = Dram::new(DramConfig::default());
        let mut without = dram();
        let a = with_refresh.access(0, 0, 1 << 16, false);
        let b = without.access(0, 0, 1 << 16, false);
        assert!(a > b);
        let stretch = a as f64 / b as f64;
        assert!(stretch < 1.10, "refresh tax too large: {stretch}");
    }

    #[test]
    fn monotonic_time() {
        let mut d = dram();
        let t1 = d.access(0, 0, 256, false);
        let t2 = d.access(t1, 4096, 256, true);
        assert!(t2 > t1);
        assert_eq!(d.stats.reads, 1);
        assert_eq!(d.stats.writes, 1);
    }
}
