//! Cycle-level simulator of the paper's accelerator (Fig 10) — Layer/Row/
//! Neuron controllers, CUs, binCUs, input SRAM, binWeight SRAM, LPDDR4.
//!
//! The simulator replays a *skip trace* produced by the functional engine
//! ([`crate::predictor::exec`]) on the hardware model, so numerics and
//! timing are decoupled exactly as in the paper's methodology (their
//! simulator consumed DNN execution profiles; ours consumes traces).
//!
//! Modelled structure per layer (Section 4.1):
//! * the Row Controller loads input windows block-by-block (sliding-window
//!   reuse: `stride` new input rows per output row) and double-buffers, so
//!   input loads overlap compute;
//! * the Neuron Controller schedules **proxies first**; a member's binCU
//!   check may only start once its proxy finished (dependency), and
//!   surviving members go to any free CU (non-proxy priority is implicit
//!   in list order);
//! * each CU evaluation streams its weights from DRAM (Fig 11 layout:
//!   sequential per neuron) and computes `ceil(K / cu_width)` MAC cycles,
//!   whichever is slower;
//! * binCU evaluations read packed sign bits from the binWeight SRAM
//!   (modelled as a cache — reload traffic appears when a layer's bin
//!   weights exceed its 2 KB);
//! * outputs are written back to DRAM at row granularity.

pub mod dram;

use crate::config::Config;
use crate::model::{Model, Node};
use crate::predictor::{LayerTrace, MorPolicy};
use crate::util::ceil_div;
use dram::Dram;

/// Aggregate counters from one simulated sample (plus energy inputs).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    pub cycles: u64,
    pub macs: u64,
    pub bin_ops: u64,
    pub neurons_computed: u64,
    pub neurons_skipped: u64,
    pub dram_bytes: u64,
    pub dram_weight_bytes: u64,
    pub dram_input_bytes: u64,
    pub dram_output_bytes: u64,
    pub dram_binweight_bytes: u64,
    pub input_sram_read_bytes: u64,
    pub binw_sram_read_bytes: u64,
    pub dram_row_hits: u64,
    pub dram_row_misses: u64,
}

impl SimStats {
    pub fn time_us(&self, freq_mhz: u64) -> f64 {
        self.cycles as f64 / freq_mhz as f64
    }
}

/// The accelerator simulator.
pub struct Simulator {
    pub cfg: Config,
}

impl Simulator {
    pub fn new(cfg: Config) -> Simulator {
        Simulator { cfg }
    }

    /// Simulate one sample. `traces`/`policy` are None for the baseline
    /// accelerator (every neuron computed, no binary datapath).
    pub fn simulate_sample(
        &self,
        model: &Model,
        policy: Option<&MorPolicy>,
        traces: Option<&[LayerTrace]>,
    ) -> SimStats {
        let mut dram = Dram::new(self.cfg.dram.clone());
        let mut st = SimStats::default();
        let shapes = model.node_shapes();
        let a = &self.cfg.accel;

        let mut cu_free = vec![0u64; a.num_cus];
        let mut bincu_free = vec![0u64; a.num_bincus];
        let mut now: u64 = 0;

        // DRAM address map: weights | activations ping-pong | bin weights
        let mut weight_base: u64 = 0;
        let act_a: u64 = 1 << 28;
        let act_b: u64 = act_a + (1 << 27);
        let bin_base: u64 = 1 << 29;

        for (i, node) in model.nodes.iter().enumerate() {
            if !node.is_compute() {
                // pooling / gap / relu happen on the write-back path of the
                // producing layer (negligible — elementwise at SRAM speed);
                // model as a pass.
                continue;
            }
            let (oh, ow, _) = shapes[i];
            let rows = oh * ow;
            let cout = node.cout();
            let k = node.k_len() as u64;
            let src = node.consumes();
            let (ih, iw, ic) = if src < 0 {
                model.input_shape
            } else {
                shapes[src as usize]
            };
            let (kh, stride) = match node {
                Node::Conv { kh, stride, .. } => (*kh, *stride),
                _ => (1, 1),
            };

            let trace = traces.and_then(|ts| ts.iter().find(|t| t.node == i));
            let lpol = policy.and_then(|p| p.layers.get(&i));

            // --- per-layer bin-weight fill (binWeight SRAM) ----------------
            // Members' packed sign bits stream from DRAM into the 2 KB
            // binWeight SRAM once per layer; a layer whose working set
            // exceeds the SRAM pays a 2x thrash penalty (row-block reloads)
            // but binCU *reads* always hit on-chip, as in Section 4.4.
            let mut bin_bytes_per_eval = 0u64;
            if let Some(lp) = lpol {
                let members: u64 = lp
                    .clusters
                    .iter()
                    .map(|c| (c.len() - 1) as u64)
                    .sum();
                let total_bin_bytes = members * ceil_div(k, 8);
                bin_bytes_per_eval = ceil_div(k, 8);
                if total_bin_bytes > 0 {
                    let reload = if total_bin_bytes > a.binweight_sram_bytes { 2 } else { 1 };
                    let fill = total_bin_bytes * reload;
                    now = dram.access(now, bin_base, fill, false);
                    st.dram_binweight_bytes += fill;
                }
            }

            // --- input loading (sliding window) --------------------------
            let first_block = (kh.min(ih) * iw * ic) as u64;
            let row_block = (stride * iw * ic) as u64;
            let input_region = if i % 2 == 0 { act_a } else { act_b };
            let out_region = if i % 2 == 0 { act_b } else { act_a };

            let mut input_ready = dram.access(now, input_region, first_block, false);
            st.dram_input_bytes += first_block;

            let mut out_write_addr = out_region;

            for row in 0..rows {
                // double-buffered load of the next output row's new inputs
                if row + 1 < rows && row % ow == ow - 1 {
                    let t = dram.access(
                        input_ready,
                        input_region + (row as u64 + 1) * row_block,
                        row_block,
                        false,
                    );
                    st.dram_input_bytes += row_block;
                    input_ready = t;
                }
                let row_start = now.max(input_ready.saturating_sub(first_block.min(1)));

                let mut row_last_end = row_start;

                // job scheduler: returns end time of a CU evaluation
                let run_cu = |ready: u64,
                                  dram: &mut Dram,
                                  st: &mut SimStats,
                                  cu_free: &mut Vec<u64>,
                                  f: usize|
                 -> u64 {
                    let slot = argmin(cu_free);
                    let start = cu_free[slot].max(ready);
                    let w_addr = weight_base + (f as u64) * k;
                    let w_done = dram.access(start, w_addr, k, false);
                    st.dram_weight_bytes += k;
                    let compute = ceil_div(k, a.cu_width as u64);
                    let end = start + compute.max(w_done - start);
                    cu_free[slot] = end;
                    st.macs += k;
                    st.input_sram_read_bytes += k;
                    st.neurons_computed += 1;
                    end
                };

                match (lpol, trace) {
                    (Some(lp), Some(tr))
                        if policy.map(|p| p.strategy().uses_clusters()).unwrap_or(false) =>
                    {
                        // proxies first
                        let mut proxy_end = vec![row_start; cout];
                        for cl in &lp.clusters {
                            let e = run_cu(row_start, &mut dram, &mut st, &mut cu_free, cl[0]);
                            proxy_end[cl[0]] = e;
                            row_last_end = row_last_end.max(e);
                        }
                        for cl in &lp.clusters {
                            let p_end = proxy_end[cl[0]];
                            for &f in &cl[1..] {
                                let idx = row * cout + f;
                                let mut gate = p_end;
                                if tr.bin_eval[idx] {
                                    let slot = argmin(&bincu_free);
                                    let bstart = bincu_free[slot].max(p_end);
                                    let bdur = ceil_div(k, a.bincu_width as u64);
                                    let bend = bstart + bdur;
                                    bincu_free[slot] = bend;
                                    st.bin_ops += k;
                                    st.binw_sram_read_bytes += bin_bytes_per_eval;
                                    gate = gate.max(bend);
                                    row_last_end = row_last_end.max(bend);
                                }
                                if tr.skipped[idx] {
                                    st.neurons_skipped += 1;
                                } else {
                                    let e = run_cu(gate, &mut dram, &mut st, &mut cu_free, f);
                                    row_last_end = row_last_end.max(e);
                                }
                            }
                        }
                    }
                    (Some(_lp), Some(tr)) => {
                        // binary-only mode: no proxy dependencies
                        for f in 0..cout {
                            let idx = row * cout + f;
                            let mut gate = row_start;
                            if tr.bin_eval[idx] {
                                let slot = argmin(&bincu_free);
                                let bstart = bincu_free[slot].max(row_start);
                                let bend = bstart + ceil_div(k, a.bincu_width as u64);
                                bincu_free[slot] = bend;
                                st.bin_ops += k;
                                st.binw_sram_read_bytes += bin_bytes_per_eval;
                                gate = bend;
                                row_last_end = row_last_end.max(bend);
                            }
                            if tr.skipped[idx] {
                                st.neurons_skipped += 1;
                            } else {
                                let e = run_cu(gate, &mut dram, &mut st, &mut cu_free, f);
                                row_last_end = row_last_end.max(e);
                            }
                        }
                    }
                    _ => {
                        // baseline: every neuron on the CUs
                        for f in 0..cout {
                            let e = run_cu(row_start, &mut dram, &mut st, &mut cu_free, f);
                            row_last_end = row_last_end.max(e);
                        }
                    }
                }

                // write the row's outputs back (1 byte per output)
                let t = dram.access(row_last_end, out_write_addr, cout as u64, true);
                st.dram_output_bytes += cout as u64;
                out_write_addr += cout as u64;
                now = now.max(row_last_end);
                let _ = t; // writes are posted; they only occupy the bus
            }

            weight_base += cout as u64 * k;
            // layer barrier: all compute + the bus drain
            let drain = cu_free.iter().chain(bincu_free.iter()).copied().max().unwrap_or(now);
            now = now.max(drain);
        }

        st.cycles = now;
        st.dram_bytes = dram.stats.bytes;
        st.dram_row_hits = dram.stats.row_hits;
        st.dram_row_misses = dram.stats.row_misses;
        st
    }
}

fn argmin(xs: &[u64]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v < xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PredictorConfig};
    use crate::model::testutil::tiny_conv;
    use crate::model::PredictorParams;
    use crate::predictor::{exec, MorPolicy, RunOpts};
    use crate::util::json::Json;
    use crate::util::rng::Rng;

    fn input(len: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..len).map(|_| r.uniform(-1.0, 1.0) as f32).collect()
    }

    fn zero_policy(m: &crate::model::Model, layer: usize) -> MorPolicy {
        let n = m.nodes[layer].cout();
        let js = format!(
            r#"{{"model":"t","default_threshold":0.0,"layers":[
                {{"layer":{layer},"neurons":{n},
                  "c":{c:?},"m":{mm:?},"b":{b:?},
                  "clusters":[{cl}],
                  "closest_angle_deg":{ang:?}}}]}}"#,
            c = vec![1.0f32; n],
            mm = vec![0.0f32; n],
            b = vec![-1.0f32; n],
            cl = format!("{:?}", (0..n).collect::<Vec<_>>()),
            ang = vec![10.0f32; n],
        );
        let params = PredictorParams::from_json(&Json::parse(&js).unwrap()).unwrap();
        MorPolicy::new(m, &params, PredictorConfig::default())
    }

    #[test]
    fn baseline_vs_predictor_cycles() {
        let m = tiny_conv(3);
        let x = input(6 * 6 * 2, 5);
        let pol = zero_policy(&m, 0);
        let r = exec::run_sample(
            &m,
            Some(&pol),
            &x,
            RunOpts { oracle: false, collect_trace: true, ..Default::default() },
        );

        let sim = Simulator::new(Config::default());
        let base = sim.simulate_sample(&m, None, None);
        let mor = sim.simulate_sample(&m, Some(&pol), Some(&r.traces));

        assert!(base.cycles > 0);
        assert!(base.neurons_skipped == 0);
        // On this toy model the savings are small and the predictor's fixed
        // costs (binWeight fill, proxy→member dependency) are visible, so
        // allow a few % of slack; real-model speedup (>1x) is asserted by
        // the integration tests over the artifacts (fig13).
        assert!(
            mor.cycles <= base.cycles + base.cycles / 20,
            "mor={} base={}",
            mor.cycles,
            base.cycles
        );
        // MoR computed fewer MACs iff anything was skipped
        if mor.neurons_skipped > 0 {
            assert!(mor.macs < base.macs);
            assert!(mor.dram_weight_bytes < base.dram_weight_bytes);
        }
        // baseline has no binary datapath
        assert_eq!(base.bin_ops, 0);
        assert_eq!(base.dram_binweight_bytes, 0);
    }

    #[test]
    fn all_computed_matches_total_macs() {
        let m = tiny_conv(7);
        let sim = Simulator::new(Config::default());
        let st = sim.simulate_sample(&m, None, None);
        let want: u64 = m.mac_counts().iter().sum();
        assert_eq!(st.macs, want);
        assert_eq!(st.neurons_computed as u64 * 0 + st.neurons_skipped, 0);
    }

    #[test]
    fn cycles_at_least_compute_bound() {
        let m = tiny_conv(9);
        let sim = Simulator::new(Config::default());
        let st = sim.simulate_sample(&m, None, None);
        let peak = Config::default().accel.peak_macs_per_cycle();
        assert!(
            st.cycles >= st.macs / peak,
            "cycles {} below compute roofline {}",
            st.cycles,
            st.macs / peak
        );
    }

    #[test]
    fn weight_traffic_accounting() {
        let m = tiny_conv(13);
        let sim = Simulator::new(Config::default());
        let st = sim.simulate_sample(&m, None, None);
        // every computed neuron fetched exactly K weight bytes
        assert_eq!(st.dram_weight_bytes, st.macs);
        assert!(st.dram_input_bytes > 0);
        assert!(st.dram_output_bytes > 0);
    }
}
