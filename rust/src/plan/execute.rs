//! Plan execution: the steady-state forward loop.
//!
//! [`execute`] / [`execute_into`] walk a [`ModelPlan`]'s frozen steps
//! over a [`Workspace`], running the batch-native tiled engine exactly
//! as the pre-plan `exec::run_batch` did — the tile loop, the
//! predict-then-evaluate phases, the triple-sided sparse kernel choice
//! (per-row compressed inputs, per-layer compressed weights, and their
//! doubly-sparse intersection) and every stats/trace accounting line
//! are ported verbatim, so the planned path stays **bit-identical** to
//! the `EngineSel::ScalarRef` oracle (the `engine_equivalence` /
//! `batch_equivalence` / `strategy_contracts` / `input_sparsity` /
//! `weight_sparsity` suites all run through this code).
//!
//! What changed is *where state lives*: geometry, slot wiring, sparsity
//! cutoffs and scratch sizes come from the plan; activations ping-pong
//! through the workspace's slot tensors (O(1) live per sample); im2col
//! tiles, dot/skip/survivor scratch and per-sample stats live in
//! per-worker [`super::workspace::WorkerScratch`]es. After warmup,
//! [`execute_into`] performs zero heap allocations in the
//! single-threaded non-tracing configuration (the serving default) —
//! proven by `rust/tests/plan_contracts.rs` with a counting allocator.
//! The row-tile-threaded path additionally allocates only the O(workers)
//! spawn bookkeeping, and trace collection allocates the traces it
//! returns.

use super::compile::{ComputeStep, ModelPlan, Src, StepPlan};
use super::workspace::{WorkerScratch, Workspace};
use crate::engine::gemm::{self, PrepackedFilters, NR, TILE_ROWS};
use crate::engine::{self, dot::dot_i8, relu_input, ConvGeom, QuantizedTensor, Tensor};
use crate::model::Model;
use crate::predictor::exec::layer_params;
use crate::predictor::strategies::{LayerState, RowCtx, SkipMask, ZeroPredictor};
use crate::predictor::{LayerTrace, MorPolicy, OpsStats, PredStats, RunResult};

/// Run a batch through a compiled plan, allocating fresh results. See
/// [`execute_into`] for the allocation-free form.
///
/// ```
/// use mor::model::synth;
/// use mor::plan::{self, Workspace};
/// use mor::predictor::{exec, RunOpts};
///
/// let model = synth::tiny_serving_model(9);
/// let plan = plan::compile(&model, None, RunOpts::default());
/// let mut ws = Workspace::new();
/// let (h, w, c) = model.input_shape;
/// let x = vec![0.3f32; h * w * c];
/// let planned = plan::execute(&plan, &model, None, &mut ws, &[x.as_slice()]);
/// let legacy = exec::run_sample(&model, None, &x, RunOpts::default());
/// assert_eq!(planned[0].logits, legacy.logits);
/// ```
pub fn execute(
    plan: &ModelPlan,
    model: &Model,
    policy: Option<&MorPolicy>,
    ws: &mut Workspace,
    inputs: &[&[f32]],
) -> Vec<RunResult> {
    let mut results = Vec::new();
    execute_into(plan, model, policy, ws, inputs, &mut results);
    results
}

/// Like [`execute`], but reuses the caller's `results` vector (and the
/// logits buffers inside it) — the zero-allocation steady-state entry
/// point the serving workers drive.
///
/// `model` and `policy` must be the ones the plan was compiled against
/// (same node list, same set of policied layers) — the session
/// guarantees this; debug builds assert it.
pub fn execute_into(
    plan: &ModelPlan,
    model: &Model,
    policy: Option<&MorPolicy>,
    ws: &mut Workspace,
    inputs: &[&[f32]],
    results: &mut Vec<RunResult>,
) {
    let b = inputs.len();
    // batch shrank: park the warmed envelopes in the workspace; batch
    // grew: take them back — a serve loop with fluctuating micro-batch
    // sizes never reallocates result envelopes once it has seen its
    // largest batch
    while results.len() > b {
        ws.spare_results.push(results.pop().expect("len > b"));
    }
    while results.len() < b {
        results.push(ws.spare_results.pop().unwrap_or_else(|| RunResult {
            logits: Vec::new(),
            pred: PredStats::default(),
            ops: OpsStats::default(),
            traces: Vec::new(),
        }));
    }
    if b == 0 {
        return;
    }
    debug_assert_eq!(plan.n_nodes, model.nodes.len(), "plan compiled for another model");
    // allocation-free even in debug builds (the zero-alloc contract is
    // asserted under a counting allocator in debug test runs)
    debug_assert!(
        policy.map_or(plan.policied.is_empty(), |p| {
            p.layers.keys().copied().eq(plan.policied.iter().copied())
        }),
        "plan compiled against a different policied-layer set"
    );
    let opts = plan.opts;
    ws.prepare(plan, b);
    // field-level split borrows: slots/qts are read-only while the
    // global out buffer and worker scratches are written
    let Workspace {
        input,
        slots,
        qts,
        out,
        skipped,
        bin_eval,
        pred,
        ops,
        ranges,
        workers,
        spare_results: _, // consumed by the envelope parking above
    } = ws;

    let (h, w, c) = model.input_shape;
    for (s, x) in inputs.iter().enumerate() {
        input[s].assign(h, w, c, x);
    }
    pred.clear();
    pred.resize(b, PredStats::default());
    ops.clear();
    ops.resize(b, OpsStats::default());
    let mut traces: Vec<Vec<LayerTrace>> = if opts.collect_trace {
        (0..b).map(|_| Vec::new()).collect()
    } else {
        Vec::new()
    };

    let n_slots = plan.n_slots;
    for step in &plan.steps {
        match step {
            StepPlan::Compute(cs) => {
                let lp = policy.and_then(|p| p.layers.get(&cs.node));
                let pol = lp.map(|l| (l, policy.unwrap()));
                compute_step(
                    cs,
                    model,
                    model.prepacked().layer(cs.node),
                    pol,
                    plan,
                    b,
                    input,
                    slots,
                    qts,
                    out,
                    skipped,
                    bin_eval,
                    pred,
                    ops,
                    &mut traces,
                    ranges,
                    workers,
                );
            }
            StepPlan::MaxPool { size, src, dst, .. } => {
                for s in 0..b {
                    let di = s * n_slots + dst;
                    match src {
                        Src::Input => engine::maxpool_into(&input[s], *size, &mut slots[di]),
                        Src::Slot(k) => {
                            let (t_src, t_dst) = split_two(slots, s * n_slots + k, di);
                            engine::maxpool_into(t_src, *size, t_dst);
                        }
                    }
                }
            }
            StepPlan::Gap { src, dst, .. } => {
                for s in 0..b {
                    let di = s * n_slots + dst;
                    match src {
                        Src::Input => engine::gap_into(&input[s], &mut slots[di]),
                        Src::Slot(k) => {
                            let (t_src, t_dst) = split_two(slots, s * n_slots + k, di);
                            engine::gap_into(t_src, t_dst);
                        }
                    }
                }
            }
            StepPlan::Relu { src, dst, .. } => {
                for s in 0..b {
                    let di = s * n_slots + dst;
                    match src {
                        Src::Input => engine::relu_into(&input[s], &mut slots[di]),
                        Src::Slot(k) => {
                            let (t_src, t_dst) = split_two(slots, s * n_slots + k, di);
                            engine::relu_into(t_src, t_dst);
                        }
                    }
                }
            }
        }
    }

    for (s, r) in results.iter_mut().enumerate() {
        r.logits.clear();
        if plan.logits_slot != usize::MAX {
            r.logits
                .extend_from_slice(&slots[s * n_slots + plan.logits_slot].data);
        }
        r.pred = pred[s];
        r.ops = ops[s];
        if opts.collect_trace {
            r.traces = std::mem::take(&mut traces[s]);
        } else {
            r.traces.clear();
        }
    }
}

/// Disjoint (src, dst) tensor refs out of the slot arena.
fn split_two(slots: &mut [Tensor], si: usize, di: usize) -> (&Tensor, &mut Tensor) {
    debug_assert_ne!(si, di, "plan aliased a step's input and output slots");
    if si < di {
        let (l, r) = slots.split_at_mut(di);
        (&l[si], &mut r[0])
    } else {
        let (l, r) = slots.split_at_mut(si);
        (&r[0], &mut l[di])
    }
}

// ---------------------------------------------------------------------------
// Tiled engine (batch-native) — ported from the pre-plan exec.rs
// ---------------------------------------------------------------------------
//
// The batch's output rows form one sample-major global row space of
// `b * rows` rows (global row g → sample g / rows, sample-local row
// g % rows). Tiles and worker ranges are carved from the global space, so
// a tile may hold patches from several samples; every per-row accounting
// lands in that row's sample's counters, which keeps the batch bit-exact
// with the per-sample path.

/// Shared read-only context for one layer's tile workers.
struct TiledCtx<'a> {
    /// Model node index (numeric-observation keying in debug builds).
    node: usize,
    pf: &'a PrepackedFilters,
    /// One quantized input per sample of the batch.
    qts: &'a [QuantizedTensor],
    /// The activation slot arena (residual reads go through it).
    slots: &'a [Tensor],
    n_slots: usize,
    /// Residual source slot, if the node has one.
    res_slot: Option<usize>,
    policy: Option<(&'a LayerState, &'a MorPolicy)>,
    geom: ConvGeom,
    kh: usize,
    kw: usize,
    stride: usize,
    /// Output rows per sample (`geom.oh * geom.ow`).
    rows: usize,
    cout: usize,
    k_len: usize,
    k: u64,
    dq: f32,
    bn: Option<&'a (Vec<f32>, Vec<f32>)>,
    node_relu: bool,
    is_relu_layer: bool,
    is_conv: bool,
    oracle: bool,
    /// Frozen input-sparsity decision (kernel selection only — results
    /// are bit-identical either way).
    lanes: bool,
    sparse_cutoff: f32,
    /// Frozen per-layer weight-sparsity decision: dots run on the
    /// compressed-weight kernels (and, for rows that also compress
    /// their input, the doubly-sparse index-intersection kernel).
    /// Kernel selection only — bit-identical either way.
    w_sparse: bool,
    /// Tile height from the plan's frozen [`crate::engine::tune::TuneProfile`],
    /// clamped to the fixed scratch capacity `1..=TILE_ROWS`. A
    /// host-performance knob only: any height partitions the global row
    /// space into the same rows, so results are bit-identical.
    tile_rows: usize,
}

impl TiledCtx<'_> {
    #[inline]
    fn res_at(&self, s: usize, row: usize, f: usize) -> f32 {
        self.res_slot
            .map(|k| self.slots[s * self.n_slots + k].data[row * self.cout + f])
            .unwrap_or(0.0)
    }

    #[inline]
    fn res_row(&self, s: usize, row: usize) -> Option<&[f32]> {
        self.res_slot.map(|k| {
            &self.slots[s * self.n_slots + k].data[row * self.cout..(row + 1) * self.cout]
        })
    }
}

#[allow(clippy::too_many_arguments)]
fn compute_step(
    cs: &ComputeStep,
    model: &Model,
    pf: &PrepackedFilters,
    pol: Option<(&LayerState, &MorPolicy)>,
    plan: &ModelPlan,
    b: usize,
    input: &[Tensor],
    slots: &mut [Tensor],
    qts: &mut [QuantizedTensor],
    out: &mut Vec<f32>,
    skipped: &mut Vec<bool>,
    bin_eval: &mut Vec<bool>,
    pred: &mut [PredStats],
    ops: &mut [OpsStats],
    traces: &mut [Vec<LayerTrace>],
    ranges: &mut Vec<(usize, usize)>,
    workers: &mut [WorkerScratch],
) {
    let opts = plan.opts;
    let n_slots = plan.n_slots;
    let rows = cs.rows;
    let cout = cs.cout;
    let total_rows = rows * b;
    let (_, _, bn, _) = layer_params(&model.nodes[cs.node]);

    // quantize each sample's layer input once (reused buffers)
    for s in 0..b {
        let src: &Tensor = match cs.src {
            Src::Input => &input[s],
            Src::Slot(k) => &slots[s * n_slots + k],
        };
        qts[s].requantize(src, cs.sx);
    }

    // global sample-major buffers; split per sample after the compute
    out.clear();
    out.resize(total_rows * cout, 0.0);
    if opts.collect_trace {
        skipped.clear();
        skipped.resize(total_rows * cout, false);
        bin_eval.clear();
        bin_eval.resize(total_rows * cout, false);
    }

    let n_used_workers;
    {
        let ctx = TiledCtx {
            node: cs.node,
            pf,
            qts: &qts[..b],
            slots,
            n_slots,
            res_slot: cs.res,
            policy: pol,
            geom: cs.geom,
            kh: cs.kh,
            kw: cs.kw,
            stride: cs.stride,
            rows,
            cout,
            k_len: cs.k_len,
            k: cs.k_len as u64,
            dq: cs.dq,
            bn,
            node_relu: cs.node_relu,
            is_relu_layer: cs.is_relu_layer,
            is_conv: cs.is_conv,
            oracle: cs.oracle,
            lanes: cs.lanes,
            sparse_cutoff: cs.sparse_cutoff,
            w_sparse: cs.w_sparse,
            tile_rows: opts.tune.tile_rows.clamp(1, TILE_ROWS),
        };

        let n_tiles = total_rows.div_ceil(ctx.tile_rows).max(1);
        let nw = opts.threads.max(1).min(n_tiles);
        if nw <= 1 {
            let trace = opts
                .collect_trace
                .then(|| (&mut skipped[..], &mut bin_eval[..]));
            process_row_range(&ctx, 0, total_rows, out, trace, &mut workers[0]);
            n_used_workers = 1;
        } else {
            // contiguous tile-aligned global row ranges, one per worker;
            // every buffer is split into disjoint per-range slices so
            // workers never share mutable state, and per-sample stats
            // merge in range order (deterministic)
            let tiles_per = n_tiles.div_ceil(nw);
            ranges.clear();
            let mut start = 0usize;
            while start < total_rows {
                let end = total_rows.min(start + tiles_per * ctx.tile_rows);
                ranges.push((start, end));
                start = end;
            }
            let mut out_parts: Vec<&mut [f32]> = Vec::with_capacity(ranges.len());
            let mut sk_parts: Vec<&mut [bool]> = Vec::with_capacity(ranges.len());
            let mut be_parts: Vec<&mut [bool]> = Vec::with_capacity(ranges.len());
            let mut out_rest: &mut [f32] = out;
            let mut sk_rest: &mut [bool] = skipped;
            let mut be_rest: &mut [bool] = bin_eval;
            for &(r0, r1) in ranges.iter() {
                let n = (r1 - r0) * cout;
                let (head, tail) = std::mem::take(&mut out_rest).split_at_mut(n);
                out_parts.push(head);
                out_rest = tail;
                if opts.collect_trace {
                    let (head, tail) = std::mem::take(&mut sk_rest).split_at_mut(n);
                    sk_parts.push(head);
                    sk_rest = tail;
                    let (head, tail) = std::mem::take(&mut be_rest).split_at_mut(n);
                    be_parts.push(head);
                    be_rest = tail;
                }
            }
            let mut trace_parts: Vec<Option<(&mut [bool], &mut [bool])>> =
                if opts.collect_trace {
                    sk_parts
                        .into_iter()
                        .zip(be_parts)
                        .map(|(s, b)| Some((s, b)))
                        .collect()
                } else {
                    ranges.iter().map(|_| None).collect()
                };

            n_used_workers = ranges.len();
            let scratches = &mut workers[..n_used_workers];
            std::thread::scope(|sc| {
                let ctx = &ctx;
                let handles: Vec<_> = ranges
                    .iter()
                    .zip(out_parts)
                    .zip(trace_parts.drain(..))
                    .zip(scratches.iter_mut())
                    .map(|(((&(r0, r1), out_part), trace_part), scratch)| {
                        sc.spawn(move || {
                            process_row_range(ctx, r0, r1, out_part, trace_part, scratch)
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().expect("tile worker panicked");
                }
            });
        }
    }
    // merge per-sample stats in deterministic range order
    for scratch in workers[..n_used_workers].iter() {
        for s in 0..b {
            pred[s].add(&scratch.pred[s]);
            ops[s].add(&scratch.ops[s]);
        }
    }

    // scatter the global buffers back into per-sample slot tensors/traces
    for s in 0..b {
        let span = s * rows * cout..(s + 1) * rows * cout;
        if opts.collect_trace {
            traces[s].push(LayerTrace {
                node: cs.node,
                rows,
                cout,
                skipped: skipped[span.clone()].to_vec(),
                bin_eval: bin_eval[span.clone()].to_vec(),
            });
        }
        slots[s * n_slots + cs.dst].assign(cs.geom.oh, cs.geom.ow, cout, &out[span]);
    }
}

/// Process global rows `row0..row1` tile by tile. `out` and the optional
/// trace slices cover exactly those rows; this range's per-sample stats
/// share lands in `scratch.pred` / `scratch.ops` (indexed by sample,
/// length = batch size), merged by the caller in range order.
fn process_row_range(
    ctx: &TiledCtx,
    row0: usize,
    row1: usize,
    out: &mut [f32],
    trace: Option<(&mut [bool], &mut [bool])>,
    scratch: &mut WorkerScratch,
) {
    let b = ctx.qts.len();
    let cout = ctx.cout;
    let k = ctx.k;
    let WorkerScratch {
        gather,
        tile,
        dots,
        ri_cache,
        skip,
        applied,
        survivors,
        pred,
        ops,
    } = scratch;
    // re-dimension the reusable scratch for this layer — identical
    // starting state to the old per-call allocations, zero new heap
    pred.clear();
    pred.resize(b, PredStats::default());
    ops.clear();
    ops.resize(b, OpsStats::default());
    tile.reset(ctx.k_len, ctx.lanes);
    dots.clear();
    dots.resize(TILE_ROWS * cout, 0);
    ri_cache.clear();
    ri_cache.resize(cout, 0.0);
    skip.clear();
    skip.resize(cout, false);
    applied.clear();
    applied.resize(cout, false);
    survivors.clear();

    let (mut tr_skip, mut tr_bin) = match trace {
        Some((sk, be)) => (Some(sk), Some(be)),
        None => (None, None),
    };
    let mut tile_sample = [0usize; TILE_ROWS]; // sample of each tile row
    // per-row kernel choice: iterate only nonzero input lanes when the
    // plan's frozen mode (and, in Auto, the measured density vs the
    // pre-multiplied cutoff) says so — either kernel yields the exact
    // same integer dots
    let mut row_sparse = [false; TILE_ROWS];
    let mut blk = [0i32; NR];

    // cluster proxies are row-invariant (prepared by the strategy):
    // empty for strategies without a spatial component
    let proxies: &[usize] = ctx.policy.map(|(lp, _)| lp.proxies.as_slice()).unwrap_or(&[]);

    let mut t0 = row0;
    while t0 < row1 {
        let trows = ctx.tile_rows.min(row1 - t0);

        // ---- phase 1: gather a tile of im2col patches (cross-sample) ----
        for r in 0..trows {
            let g = t0 + r;
            let (s, row) = (g / ctx.rows, g % ctx.rows);
            tile_sample[r] = s;
            let src = &ctx.qts[s];
            if ctx.is_conv {
                let (oy, ox) = (row / ctx.geom.ow, row % ctx.geom.ow);
                gather.gather(src, ctx.geom, ctx.kh, ctx.kw, ctx.stride, oy, ox);
            } else {
                gather.gather_fc(src, row);
            }
            row_sparse[r] = ctx.lanes && (gather.nnz as f32) < ctx.sparse_cutoff;
            // the compression pass only runs for rows that will use the
            // sparse kernel — dense rows pay one compare, nothing more
            tile.set_row(
                r,
                &gather.patch,
                &gather.packed,
                gather.nnz,
                &gather.nzmask,
                row_sparse[r],
            );
            ops[s].macs_total += k * cout as u64;
            if ctx.is_relu_layer {
                ops[s].relu_macs += k * cout as u64;
                pred[s].relu_outputs += cout as u64;
            }
        }

        match ctx.policy {
            // ---- dense layer: every (row, filter) pair survives. Filter
            // blocks run outermost so each weight block is loaded once per
            // tile and reused across all TILE_ROWS patches. ---------------
            None => {
                let mut f0 = 0;
                while f0 < cout {
                    let nf = NR.min(cout - f0);
                    for r in 0..trows {
                        if ctx.w_sparse {
                            if row_sparse[r] {
                                let (li, lv) = tile.lanes(r);
                                gemm::dot_block_wsparse_x(li, lv, ctx.pf, f0, nf, &mut blk);
                            } else {
                                gemm::dot_block_wsparse(tile.patch(r), ctx.pf, f0, nf, &mut blk);
                            }
                        } else if row_sparse[r] {
                            let (li, lv) = tile.lanes(r);
                            gemm::dot_block_sparse(li, lv, ctx.pf, f0, nf, &mut blk);
                        } else {
                            gemm::dot_block(tile.patch(r), ctx.pf, f0, nf, &mut blk);
                        }
                        dots[r * cout + f0..r * cout + f0 + nf].copy_from_slice(&blk[..nf]);
                    }
                    f0 += NR;
                }
                for r in 0..trows {
                    let g = t0 + r;
                    let (s, row) = (tile_sample[r], g % ctx.rows);
                    let nnz_x = tile.nnz(r) as u64;
                    let zeros = k - nnz_x;
                    let xm = tile.xmask(r);
                    let out_row = &mut out[(g - row0) * cout..(g - row0 + 1) * cout];
                    for (f, o) in out_row.iter_mut().enumerate() {
                        let d = dots[r * cout + f];
                        let wz = nnz_x - gemm::masked_nnz(xm, ctx.pf.wmask(f));
                        account_eval(
                            ctx, d, s, row, f, false, zeros, wz, o, &mut pred[s], &mut ops[s],
                        );
                    }
                }
            }

            Some((lp, mp)) => {
                let strategy = mp.cfg.strategy;

                // ---- phase 2a: proxies — always fully evaluated, filter
                // blocks outer for weight reuse across the tile -----------
                for chunk in proxies.chunks(NR) {
                    for r in 0..trows {
                        if ctx.w_sparse {
                            if row_sparse[r] {
                                let (li, lv) = tile.lanes(r);
                                gemm::dot_block_indexed_wsparse_x(li, lv, ctx.pf, chunk, &mut blk);
                            } else {
                                gemm::dot_block_indexed_wsparse(tile.patch(r), ctx.pf, chunk, &mut blk);
                            }
                        } else if row_sparse[r] {
                            let (li, lv) = tile.lanes(r);
                            gemm::dot_block_indexed_sparse(li, lv, ctx.pf, chunk, &mut blk);
                        } else {
                            gemm::dot_block_indexed(tile.patch(r), ctx.pf, chunk, &mut blk);
                        }
                        for (j, &f) in chunk.iter().enumerate() {
                            dots[r * cout + f] = blk[j];
                        }
                    }
                }

                for r in 0..trows {
                    let g = t0 + r;
                    let (s, row) = (tile_sample[r], g % ctx.rows);
                    let nnz_x = tile.nnz(r) as u64;
                    let zeros = k - nnz_x;
                    let xm = tile.xmask(r);
                    let local = (g - row0) * cout;
                    let out_row = &mut out[local..local + cout];

                    for &p in proxies {
                        let wz = nnz_x - gemm::masked_nnz(xm, ctx.pf.wmask(p));
                        let ri = account_eval(
                            ctx, dots[r * cout + p], s, row, p, false, zeros, wz,
                            &mut out_row[p], &mut pred[s], &mut ops[s],
                        );
                        ri_cache[p] = ri;
                    }

                    // ---- phase 2b: skip decisions (strategy dispatch) ----
                    survivors.clear();
                    let rctx = RowCtx {
                        node: ctx.node,
                        lp,
                        cfg: &mp.cfg,
                        packed: tile.packed(r),
                        patch: tile.patch(r),
                        pf: ctx.pf,
                        proxy_ri: ri_cache,
                        res_row: ctx.res_row(s, row),
                        bn: ctx.bn,
                        dq: ctx.dq,
                        k: ctx.k,
                        cout,
                    };
                    let mut be_row =
                        tr_bin.as_deref_mut().map(|be| &mut be[local..local + cout]);
                    strategy.fill_skip_mask(
                        &rctx,
                        &mut SkipMask {
                            skip: &mut skip[..],
                            applied: &mut applied[..],
                            survivors: &mut *survivors,
                        },
                        &mut be_row,
                        &mut ops[s],
                    );

                    // ---- phase 3: GEMM over surviving pairs only (the
                    // row's kernel flavour follows its input density) --
                    for chunk in survivors.chunks(NR) {
                        if ctx.w_sparse {
                            if row_sparse[r] {
                                let (li, lv) = tile.lanes(r);
                                gemm::dot_block_indexed_wsparse_x(li, lv, ctx.pf, chunk, &mut blk);
                            } else {
                                gemm::dot_block_indexed_wsparse(tile.patch(r), ctx.pf, chunk, &mut blk);
                            }
                        } else if row_sparse[r] {
                            let (li, lv) = tile.lanes(r);
                            gemm::dot_block_indexed_sparse(li, lv, ctx.pf, chunk, &mut blk);
                        } else {
                            gemm::dot_block_indexed(tile.patch(r), ctx.pf, chunk, &mut blk);
                        }
                        for (j, &f) in chunk.iter().enumerate() {
                            let wz = nnz_x - gemm::masked_nnz(xm, ctx.pf.wmask(f));
                            account_eval(
                                ctx, blk[j], s, row, f, applied[f], zeros, wz, &mut out_row[f],
                                &mut pred[s], &mut ops[s],
                            );
                        }
                    }

                    // ---- skipped outputs: zero + optional oracle truth ---
                    // (proxies never set `skip`, so a full scan equals the
                    // strategy-shaped iteration)
                    for f in 0..cout {
                        if skip[f] {
                            account_skip(
                                ctx, tile.patch(r), local, s, row, f, &mut out_row[f],
                                tr_skip.as_deref_mut(), &mut pred[s], &mut ops[s],
                            );
                        }
                    }
                }
            }
        }
        t0 += trows;
    }
}

/// Account one fully-evaluated output (dot already computed). Matches the
/// scalar path's `full_eval!` (with `applied = false`) and the non-skip
/// branch of `finish_neuron` exactly. `zeros` is the patch's zero-lane
/// count (`k - nnz`) — the input-side ineffectual share of this output's
/// MACs; `wz` is the weight-side share (lanes with a live activation but
/// a zero weight), disjoint from `zeros` by construction. Returns the
/// ReLU input.
#[allow(clippy::too_many_arguments)]
#[inline]
fn account_eval(
    ctx: &TiledCtx,
    d: i32,
    s: usize,
    row: usize,
    f: usize,
    applied: bool,
    zeros: u64,
    wz: u64,
    out_val: &mut f32,
    pred: &mut PredStats,
    ops: &mut OpsStats,
) -> f32 {
    let ri = relu_input(d, ctx.dq, ctx.bn, f, ctx.res_at(s, row, f));
    #[cfg(debug_assertions)]
    {
        super::observe::record_dot(ctx.node, d);
        super::observe::record_ri(ctx.node, ri);
    }
    *out_val = if ctx.node_relu { ri.max(0.0) } else { ri };
    ops.macs_done += ctx.k;
    ops.macs_skipped_input_zero += zeros;
    ops.macs_skipped_weight_zero += wz;
    ops.weight_bytes_fetched += ctx.k;
    if ctx.is_relu_layer {
        if ri <= 0.0 {
            ops.neg_relu_macs += ctx.k;
            ops.true_zero_outputs += 1;
        }
        if applied {
            if ri <= 0.0 {
                pred.incorrect_nonzero += 1;
            } else {
                pred.correct_nonzero += 1;
            }
        } else {
            pred.not_applied += 1;
        }
    }
    ri
}

/// Account one skipped output. Matches the skip branch of the scalar
/// path's `finish_neuron` exactly (`local` = row offset within this
/// worker's trace slice).
#[allow(clippy::too_many_arguments)]
fn account_skip(
    ctx: &TiledCtx,
    patch: &[i8],
    local: usize,
    s: usize,
    row: usize,
    f: usize,
    out_val: &mut f32,
    tr_skip: Option<&mut [bool]>,
    pred: &mut PredStats,
    ops: &mut OpsStats,
) {
    *out_val = 0.0;
    ops.weight_bytes_saved += ctx.k;
    if let Some(sk) = tr_skip {
        sk[local + f] = true;
    }
    if ctx.oracle {
        // ground truth for Fig 12 / accuracy accounting
        let d = dot_i8(patch, ctx.pf.filter(f));
        let ri = relu_input(d, ctx.dq, ctx.bn, f, ctx.res_at(s, row, f));
        #[cfg(debug_assertions)]
        {
            super::observe::record_dot(ctx.node, d);
            super::observe::record_ri(ctx.node, ri);
        }
        if ctx.is_relu_layer {
            if ri <= 0.0 {
                pred.correct_zero += 1;
                ops.neg_relu_macs += ctx.k;
                ops.true_zero_outputs += 1;
            } else {
                pred.incorrect_zero += 1;
            }
        }
    }
}
