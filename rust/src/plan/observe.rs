//! Test-only runtime observation hook: min/max of every accumulator,
//! pre-activation value and binarized proxy dot the engines produce,
//! keyed by model node — what `rust/tests/numeric_ranges.rs` compares
//! against the statically predicted intervals of [`super::ranges`].
//!
//! The module is always compiled (so integration tests can link it
//! without a cargo feature), but the *call sites* in the engines are
//! `#[cfg(debug_assertions)]` — the release-build forward path carries
//! zero bookkeeping. Recording itself is additionally gated behind
//! [`begin`]/[`take`], so even debug builds pay only one relaxed atomic
//! load per recorded value while no test is observing.
//!
//! One global recorder: tests that observe must not run concurrently
//! with each other (the numeric_ranges suite keeps all observation in a
//! single `#[test]` for exactly this reason).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Observed min/max per model node. `dot` covers the integer
/// accumulators (every final dot the kernels emit), `ri` the
/// pre-activation f32 (`relu_input`: dot·dq → BN → +residual), `proxy`
/// the binarized rookie dots.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeObs {
    pub dot: Option<(i32, i32)>,
    pub ri: Option<(f32, f32)>,
    pub proxy: Option<(i32, i32)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static LOG: Mutex<Option<BTreeMap<usize, NodeObs>>> = Mutex::new(None);

/// Start recording (clears any previous log).
pub fn begin() {
    *LOG.lock().unwrap() = Some(BTreeMap::new());
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop recording and return everything observed since [`begin`].
pub fn take() -> BTreeMap<usize, NodeObs> {
    ENABLED.store(false, Ordering::SeqCst);
    LOG.lock().unwrap().take().unwrap_or_default()
}

#[inline]
fn active() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn with(node: usize, f: impl FnOnce(&mut NodeObs)) {
    if let Some(map) = LOG.lock().unwrap().as_mut() {
        f(map.entry(node).or_default());
    }
}

fn merge_i32(slot: &mut Option<(i32, i32)>, v: i32) {
    *slot = Some(match *slot {
        Some((lo, hi)) => (lo.min(v), hi.max(v)),
        None => (v, v),
    });
}

fn merge_f32(slot: &mut Option<(f32, f32)>, v: f32) {
    // min/max would silently drop a NaN observation — keep it sticky
    *slot = Some(match *slot {
        Some((lo, hi)) if !v.is_nan() => (lo.min(v), hi.max(v)),
        Some(_) => (f32::NAN, f32::NAN),
        None => (v, v),
    });
}

/// Record one integer dot-product accumulator of `node`.
#[inline]
pub fn record_dot(node: usize, d: i32) {
    if active() {
        with(node, |o| merge_i32(&mut o.dot, d));
    }
}

/// Record one pre-activation value of `node`.
#[inline]
pub fn record_ri(node: usize, ri: f32) {
    if active() {
        with(node, |o| merge_f32(&mut o.ri, ri));
    }
}

/// Record one binarized proxy dot of `node`.
#[inline]
pub fn record_proxy(node: usize, p_bin: i32) {
    if active() {
        with(node, |o| merge_i32(&mut o.proxy, p_bin));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_only_between_begin_and_take() {
        // lib tests run in parallel and debug-build forwards elsewhere
        // may record real node indices concurrently — use node keys no
        // model can reach and only assert about those
        const A: usize = usize::MAX - 2;
        const B: usize = usize::MAX - 1;
        const C: usize = usize::MAX;
        record_dot(A, 5); // inert: no begin yet (may also race a begin
                          // from this test's past/future self — harmless)
        begin();
        record_dot(B, -3);
        record_dot(B, 9);
        record_ri(B, 0.5);
        record_ri(B, f32::NAN);
        record_proxy(C, -7);
        let log = take();
        assert_eq!(log[&B].dot, Some((-3, 9)));
        let (lo, hi) = log[&B].ri.unwrap();
        assert!(lo.is_nan() && hi.is_nan(), "NaN observation must stick");
        assert_eq!(log[&C].proxy, Some((-7, -7)));
        record_dot(C, 1); // inert again after take
        begin();
        assert!(!take().contains_key(&C));
    }
}
