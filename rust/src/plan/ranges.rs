//! Quantized-numerics abstract interpreter over a frozen [`ModelPlan`].
//!
//! [`analyze`] propagates per-layer **value intervals** through the
//! whole network — using the *actual prepacked weights* (per-filter
//! `Σ|w| · max|x|` via [`PrepackedFilters::filter_sums`]) instead of the
//! blanket `127·128·K` worst case — and statically proves, per compute
//! site:
//!
//! * **`num.acc`** — the int8 dot kernels' i32 accumulators cannot
//!   overflow. The bound `Σ|w| · max|x|` dominates the magnitude of
//!   *every* partial sum under *any* accumulation order or lane subset
//!   (each term's magnitude is `|wₖ|·|xₖ| ≤ |wₖ|·max|x|`, and elided
//!   lanes contribute exactly 0), so one number covers the dense
//!   16-chunk scalar loop, the AVX2 `vpmaddwd` chains, the 4-stream
//!   input-sparse kernel, the weight-sparse lane walks and the
//!   doubly-sparse intersection dot alike.
//! * **`num.width`** — the same bound against a *claimed* accumulator
//!   width ([`NumericOpts::acc_bits`] < 32): the gate a future i16
//!   fast path must pass before narrowing.
//! * **`num.vnni`** — the AVX-512 VNNI lowering's *offset* accumulator.
//!   `vpdpbusd` is unsigned×signed, so the kernel computes
//!   `Σ(x⊕0x80)·w = Σ(x+128)·w` and subtracts `128·Σw` afterwards (see
//!   `engine/dot.rs`); its partial sums are bounded by
//!   `Σ|w| · (max|x| + 128)` — wider than the true dot's
//!   `Σ|w| · max|x|`. Per VNNI-eligible layer
//!   (`k_pad ≤ `[`VNNI_K_MAX`]) that bound must fit i32. It always
//!   does — the dispatch gate makes `128·255·2¹⁶ < 2³¹−1` a static
//!   fact — and this pass re-proves it per layer from the actual
//!   weights, which is `mor lint --numeric`'s explicit answer to "can
//!   the VNNI kernel overflow". Under a narrowed `--acc-bits` claim the
//!   offset bound is also checked against the claimed width: a width
//!   that holds the true dot may still be too narrow for the offset
//!   partials, so a VNNI lowering cannot ride a `num.width` pass alone.
//! * **`num.requant`** — the float pipeline (`dot · dq` → BN affine →
//!   residual add) stays inside the finite f32 range, with saturation
//!   only where `quantize` intends it (the `±127` clamp). Intervals are
//!   computed in f64 and widened outward ([`Fival::widen`]) to absorb
//!   the engine evaluating the same expressions in f32.
//! * **`num.scale`** — quantization/dequantization scales are positive
//!   finite numbers (a NaN or non-positive `sx` makes every downstream
//!   bound meaningless).
//! * **`num.threshold`** — each policied layer's skip comparison
//!   `m·p_bin + b` (BN-affined, residual-added) against `-margin` is
//!   sound: the line parameters and margin are finite, and the
//!   binarized dot `p_bin ∈ [-k_len, k_len]` doesn't force a degenerate
//!   verdict. Layers where *every* binary-consulted neuron provably
//!   always skips (or never skips) get a Warning — the rookie is then
//!   constant and the threshold comparison pointless.
//!
//! Findings reuse the structural verifier's [`LintReport`] machinery
//! (`mor lint --numeric`, `--json`, debug-build `Session::build`); the
//! computed [`StepRanges`] ride along in the [`NumericReport`] so
//! future work can key off proven bounds instead of worst cases
//! ([`NumericReport::max_acc_bits`]). The runtime property suite
//! (`rust/tests/numeric_ranges.rs`) checks observed values ⊆ these
//! intervals via the [`super::observe`] hook.

use crate::engine::dot::VNNI_K_MAX;
use crate::engine::gemm::PrepackedFilters;
use crate::model::{Model, Node};
use crate::plan::compile::{ComputeStep, ModelPlan, Src, StepPlan};
use crate::plan::verify::{Finding, LintReport, Severity};
use crate::predictor::strategies::margin_of;
use crate::predictor::MorPolicy;
use crate::util::interval::{Fival, Ival};
use crate::util::json::{obj, Json};
use std::fmt;

/// Knobs for [`analyze_with`]. `acc_bits` is the *claimed* signed
/// accumulator width: 32 (the default) asks only the native-kernel
/// question; anything narrower additionally emits `num.width` wherever
/// the proven bound does not fit — the static gate for a narrower
/// fast-path accumulator.
#[derive(Clone, Copy, Debug)]
pub struct NumericOpts {
    pub acc_bits: u32,
}

impl Default for NumericOpts {
    fn default() -> NumericOpts {
        NumericOpts { acc_bits: 32 }
    }
}

/// Outward widening applied to every derived float interval: the engine
/// evaluates the same expressions in f32 (≤ 2⁻²⁴ relative rounding per
/// op, a handful of ops per value), so a few orders of magnitude more
/// slack keeps the runtime-containment property trivially true without
/// visibly loosening any bound.
const SLACK_REL: f64 = 1e-4;
const SLACK_ABS: f64 = 1e-6;

/// "Unknown but finite-f32" — the range of a slot nothing has
/// constrained yet. Only ever consumed through the saturating
/// quantizer, which collapses it to `[-127, 127]`.
const WIDE: Fival = Fival {
    lo: -(f32::MAX as f64),
    hi: f32::MAX as f64,
};

/// The proven per-step value ranges — the analysis result beyond the
/// pass/fail findings.
#[derive(Clone, Debug)]
pub struct StepRanges {
    /// Plan step index.
    pub step: usize,
    /// Model node index.
    pub node: usize,
    /// Quantized input activations (`[-127, 127]` at worst — `quantize`
    /// saturates by design; tighter after a ReLU-bounded producer).
    pub q: Ival,
    /// Max over filters of `Σ|w| · max|q|`: bounds the magnitude of
    /// every accumulator partial sum under any order/subset.
    pub acc_peak: u64,
    /// Max over filters of `Σ|w| · (max|q| + 128)`: bounds the VNNI
    /// offset kernel's partial sums (`vpdpbusd` accumulates
    /// `Σ(x+128)·w` before the `128·Σw` correction).
    pub vnni_peak: u64,
    /// The VNNI kernels can dispatch on this layer
    /// (`k_pad ≤ VNNI_K_MAX`); the `num.vnni` checks apply iff true.
    pub vnni_eligible: bool,
    /// Hull over filters of the exact final-dot interval
    /// `[pos·qlo + neg·qhi, pos·qhi + neg·qlo]`.
    pub dot: Ival,
    /// Hull over filters of the pre-activation value (`dot·dq` → BN →
    /// `+ residual`), f32-widened.
    pub pre_act: Fival,
    /// What the destination slot holds after the step (fused ReLU
    /// applied; includes 0 when the predictor may write skip-zeros).
    pub out: Fival,
    /// Binarized proxy-dot range `[-k_len, k_len]`, when the policy
    /// consults the binary rookie on this layer.
    pub proxy: Option<Ival>,
    /// Hull over binary-consulted neurons of the threshold estimate
    /// `bn_affine(m·p_bin + b) + residual`, f32-widened.
    pub est_ri: Option<Fival>,
    /// Binary-consulted neuron count and how many of them are provably
    /// degenerate (always-skip / never-skip for every possible input).
    pub consulted: usize,
    pub always_skip: usize,
    pub never_skip: usize,
}

impl StepRanges {
    /// Smallest signed accumulator width (bits) that holds every
    /// partial sum of this step: the proven requirement a narrower
    /// fast path must meet. 33+ means even i32 is not enough.
    pub fn acc_bits_needed(&self) -> u32 {
        bits_needed(self.acc_peak)
    }

    /// Same, for the VNNI offset accumulator: the width `vpdpbusd`'s
    /// pre-correction partial sums provably need on this layer.
    pub fn vnni_bits_needed(&self) -> u32 {
        bits_needed(self.vnni_peak)
    }
}

/// Findings plus the proven ranges. The `lint` field reuses the
/// structural verifier's report type, so severity counting, `has`,
/// JSON and Display formatting behave identically.
#[derive(Clone, Debug)]
pub struct NumericReport {
    pub lint: LintReport,
    pub steps: Vec<StepRanges>,
}

impl NumericReport {
    pub fn is_clean(&self) -> bool {
        self.lint.is_clean()
    }

    pub fn errors(&self) -> usize {
        self.lint.errors()
    }

    pub fn warnings(&self) -> usize {
        self.lint.warnings()
    }

    pub fn has(&self, code: &str) -> bool {
        self.lint.has(code)
    }

    /// The proven ranges of the step computing `node`, if any.
    pub fn step_for(&self, node: usize) -> Option<&StepRanges> {
        self.steps.iter().find(|s| s.node == node)
    }

    /// Max over compute steps of [`StepRanges::acc_bits_needed`] — the
    /// accumulator width this whole model provably fits in (0 for a
    /// model with no compute step).
    pub fn max_acc_bits(&self) -> u32 {
        self.steps.iter().map(|s| s.acc_bits_needed()).max().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let steps = self
            .steps
            .iter()
            .map(|s| {
                let mut pairs = vec![
                    ("step", Json::Num(s.step as f64)),
                    ("node", Json::Num(s.node as f64)),
                    ("q", ival_json(s.q)),
                    ("acc_peak", Json::Num(s.acc_peak as f64)),
                    ("acc_bits_needed", Json::Num(s.acc_bits_needed() as f64)),
                    ("vnni_peak", Json::Num(s.vnni_peak as f64)),
                    ("vnni_bits_needed", Json::Num(s.vnni_bits_needed() as f64)),
                    ("vnni_eligible", Json::Bool(s.vnni_eligible)),
                    ("dot", ival_json(s.dot)),
                    ("pre_act", fival_json(s.pre_act)),
                    ("out", fival_json(s.out)),
                ];
                pairs.push(("proxy", s.proxy.map_or(Json::Null, ival_json)));
                pairs.push(("est_ri", s.est_ri.map_or(Json::Null, fival_json)));
                pairs.push(("consulted", Json::Num(s.consulted as f64)));
                pairs.push(("always_skip", Json::Num(s.always_skip as f64)));
                pairs.push(("never_skip", Json::Num(s.never_skip as f64)));
                obj(pairs)
            })
            .collect();
        obj(vec![
            ("findings", self.lint.to_json()),
            ("steps", Json::Arr(steps)),
        ])
    }
}

/// Smallest signed width `b` with `peak ≤ 2^(b−1) − 1`; 65 means the
/// magnitude exceeds even i64.
fn bits_needed(peak: u64) -> u32 {
    (65 - peak.leading_zeros()).max(2)
}

fn ival_json(iv: Ival) -> Json {
    // i64 endpoints as f64: lossy above 2^53, fine for reporting (the
    // proofs themselves run on the exact i64 values)
    Json::Arr(vec![Json::Num(iv.lo as f64), Json::Num(iv.hi as f64)])
}

fn fival_json(iv: Fival) -> Json {
    // JSON has no NaN/inf literal: a poisoned bound serializes as null
    let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
    Json::Arr(vec![num(iv.lo), num(iv.hi)])
}

impl fmt::Display for NumericReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.lint)?;
        for s in &self.steps {
            writeln!(
                f,
                "range step {} node {}: q=[{}, {}] |acc|<={} ({} bits) |vnni|<={} ({} bits{}) dot=[{}, {}] out=[{:.3}, {:.3}]",
                s.step,
                s.node,
                s.q.lo,
                s.q.hi,
                s.acc_peak,
                s.acc_bits_needed(),
                s.vnni_peak,
                s.vnni_bits_needed(),
                if s.vnni_eligible { "" } else { ", ineligible" },
                s.dot.lo,
                s.dot.hi,
                s.out.lo,
                s.out.hi
            )?;
        }
        Ok(())
    }
}

/// Run the numeric analysis with default options (native i32
/// accumulators). `model` and `policy` must be the ones `plan` was
/// compiled from.
pub fn analyze(plan: &ModelPlan, model: &Model, policy: Option<&MorPolicy>) -> NumericReport {
    analyze_with(plan, model, policy, &NumericOpts::default())
}

/// [`analyze`] with explicit [`NumericOpts`].
pub fn analyze_with(
    plan: &ModelPlan,
    model: &Model,
    policy: Option<&MorPolicy>,
    opts: &NumericOpts,
) -> NumericReport {
    let mut findings: Vec<Finding> = Vec::new();
    let mut steps: Vec<StepRanges> = Vec::new();
    // per-slot value ranges; None = not written yet (the *structural*
    // verifier owns use-before-def errors — here we just stay sound)
    let mut slots: Vec<Option<Fival>> = vec![None; plan.n_slots];
    let prep = model.prepacked();
    for (si, step) in plan.steps.iter().enumerate() {
        match step {
            StepPlan::Compute(cs) => {
                let sr = analyze_compute(
                    si,
                    cs,
                    model,
                    prep.layer(cs.node),
                    policy,
                    opts,
                    &slots,
                    &mut findings,
                );
                slots[cs.dst] = Some(sr.out);
                steps.push(sr);
            }
            // max / mean of a tensor stay inside its hull
            StepPlan::MaxPool { src, dst, .. } | StepPlan::Gap { src, dst, .. } => {
                slots[*dst] = Some(src_range(*src, &slots));
            }
            StepPlan::Relu { src, dst, .. } => {
                slots[*dst] = Some(src_range(*src, &slots).relu());
            }
        }
    }
    NumericReport { lint: LintReport { findings }, steps }
}

fn src_range(src: Src, slots: &[Option<Fival>]) -> Fival {
    match src {
        Src::Input => WIDE,
        Src::Slot(k) => slots[k].unwrap_or(WIDE),
    }
}

fn err(step: usize, code: &'static str, message: String) -> Finding {
    Finding { code, severity: Severity::Error, step: Some(step), message }
}

fn warn(step: usize, code: &'static str, message: String) -> Finding {
    Finding { code, severity: Severity::Warning, step: Some(step), message }
}

/// The quantized-activation interval `quantize(x)` can produce for
/// `x ∈ src`: `round_half_even(x / sx)` clamped to `[-127, 127]` —
/// the one saturation site the engine *intends*. Widened by ±1 lane
/// before the clamp (f32 division/rounding slack); a non-negative
/// source (post-ReLU) keeps its exact one-sidedness.
fn quantize_interval(src: Fival, sx: f32) -> Ival {
    if src.is_nan() {
        // runtime: NaN clamps to NaN, and `NaN as i8` saturates to 0 —
        // still inside the full quantizer range
        return Ival::new(-127, 127);
    }
    let inv = 1.0 / sx as f64;
    let (a, b) = (src.lo * inv, src.hi * inv);
    // float→int casts saturate in Rust, so huge ranges land on the clamp
    let mut lo = (a.min(b).floor() as i64).saturating_sub(1);
    let hi = (a.max(b).ceil() as i64).saturating_add(1);
    if src.lo >= 0.0 && sx > 0.0 {
        lo = lo.max(0); // x ≥ 0 ⇒ round(x/sx) ≥ 0, exactly
    }
    Ival::new(lo.clamp(-127, 127), hi.clamp(-127, 127))
}

#[allow(clippy::too_many_arguments)]
fn analyze_compute(
    si: usize,
    cs: &ComputeStep,
    model: &Model,
    pf: &PrepackedFilters,
    policy: Option<&MorPolicy>,
    opts: &NumericOpts,
    slots: &[Option<Fival>],
    findings: &mut Vec<Finding>,
) -> StepRanges {
    let node = &model.nodes[cs.node];
    let bn = match node {
        Node::Conv { bn, .. } | Node::Fc { bn, .. } => bn.as_ref(),
        _ => None,
    };

    // ---- scale sanity (num.scale) --------------------------------------
    let mut scale_ok = true;
    if !(cs.sx.is_finite() && cs.sx > 0.0) {
        findings.push(err(
            si,
            "num.scale",
            format!(
                "input quantization scale sx = {} is not positive finite: \
                 quantize() output is unbounded garbage",
                cs.sx
            ),
        ));
        scale_ok = false;
    }
    if !cs.dq.is_finite() {
        findings.push(err(
            si,
            "num.scale",
            format!("dequantization factor dq = {} is not finite", cs.dq),
        ));
        scale_ok = false;
    }

    // ---- quantized input interval --------------------------------------
    let src = src_range(cs.src, slots);
    let q = if scale_ok {
        quantize_interval(src, cs.sx)
    } else {
        Ival::new(-127, 127) // the clamp still saturates whatever comes in
    };
    let qmax = q.max_abs() as i64; // ≤ 127

    // ---- per-filter integer dots + accumulator bounds (num.acc/width) --
    let res_range = match cs.res {
        Some(s) => slots[s].unwrap_or(WIDE),
        None => Fival::exact(0.0),
    };
    let eff_bits = opts.acc_bits.clamp(2, 32);
    let vnni_eligible = cs.k_pad <= VNNI_K_MAX;
    let mut acc_peak: u64 = 0;
    let mut vnni_peak: u64 = 0;
    let mut dot_hull: Option<Ival> = None;
    let mut pre_hull: Option<Fival> = None;
    let mut out_hull: Option<Fival> = None;
    // one finding per code per step: the first offending filter names
    // itself, the rest would only repeat the same root cause
    let (mut acc_hit, mut width_hit, mut requant_hit) = (false, false, false);
    let mut vnni_hit = false;
    for f in 0..cs.cout {
        let (pos, neg) = pf.filter_sums(f);
        // exact final-dot interval: positive weights pull toward q.hi,
        // negative ones toward q.lo
        let dot_iv = Ival::sum_products(&[(pos, q), (neg, q)]);
        // prefix-safe magnitude bound: Σ|w| · max|q| dominates every
        // partial sum under any accumulation order or lane subset
        let abs_sum = pos - neg;
        let bound = (abs_sum as u64).checked_mul(qmax as u64);
        let acc_iv = match bound {
            Some(b) if b <= i64::MAX as u64 => Ival::new(-(b as i64), b as i64),
            _ => Ival::TOP,
        };
        acc_peak = acc_peak.max(bound.unwrap_or(u64::MAX));
        if !acc_hit && !acc_iv.fits_signed(32) {
            findings.push(err(
                si,
                "num.acc",
                format!(
                    "filter {f}: worst-case accumulator magnitude Σ|w|·max|x| = \
                     {abs_sum}·{qmax} exceeds i32 — the int8 dot kernels can overflow"
                ),
            ));
            acc_hit = true;
        }
        if eff_bits < 32 && !width_hit && !acc_iv.fits_signed(eff_bits) {
            findings.push(err(
                si,
                "num.width",
                format!(
                    "filter {f}: accumulator bound {abs_sum}·{qmax} does not fit the \
                     claimed i{eff_bits} accumulator (needs {} bits)",
                    bits_needed(bound.unwrap_or(u64::MAX))
                ),
            ));
            width_hit = true;
        }
        // VNNI offset accumulator: Σ(x+128)·w partial sums are bounded
        // by Σ|w|·(max|x|+128) — checked against i32 (provably always
        // fits under the k_pad ≤ VNNI_K_MAX dispatch gate) and against
        // any narrower claimed width (which it legitimately can exceed)
        let vnni_bound = (abs_sum as u64).checked_mul(qmax as u64 + 128);
        let vnni_iv = match vnni_bound {
            Some(b) if b <= i64::MAX as u64 => Ival::new(-(b as i64), b as i64),
            _ => Ival::TOP,
        };
        vnni_peak = vnni_peak.max(vnni_bound.unwrap_or(u64::MAX));
        if vnni_eligible && !vnni_hit && !vnni_iv.fits_signed(eff_bits) {
            findings.push(err(
                si,
                "num.vnni",
                format!(
                    "filter {f}: VNNI offset bound Σ|w|·(max|x|+128) = \
                     {abs_sum}·{} does not fit the i{eff_bits} accumulator \
                     (needs {} bits) — the vpdpbusd partial sums are wider \
                     than the true dot's",
                    qmax + 128,
                    bits_needed(vnni_bound.unwrap_or(u64::MAX))
                ),
            ));
            vnni_hit = true;
        }
        dot_hull = Some(dot_hull.map_or(dot_iv, |h| h.hull(dot_iv)));

        // ---- float pipeline (num.requant) ------------------------------
        let mut v = Fival::from_ival(dot_iv).scale(cs.dq as f64);
        if let Some((scale, shift)) = bn {
            v = v.affine(scale[f] as f64, shift[f] as f64);
        }
        let v = v.add(res_range).widen(SLACK_REL, SLACK_ABS);
        if !requant_hit && !v.fits_f32() {
            findings.push(err(
                si,
                "num.requant",
                format!(
                    "filter {f}: pre-activation range [{}, {}] leaves the finite f32 \
                     range — dequantize/BN/residual arithmetic can overflow or poison \
                     (saturation is only intended inside quantize)",
                    v.lo, v.hi
                ),
            ));
            requant_hit = true;
        }
        pre_hull = Some(pre_hull.map_or(v, |h| h.hull(v)));
        let o = if cs.node_relu { v.relu() } else { v };
        out_hull = Some(out_hull.map_or(o, |h| h.hull(o)));
    }
    let mut out = out_hull.unwrap_or(Fival::exact(0.0));
    if cs.policied {
        // skipped neurons write exactly 0.0
        out = out.hull(Fival::exact(0.0));
    }

    // ---- predictor threshold comparison (num.threshold) ----------------
    let mut proxy = None;
    let mut est_hull: Option<Fival> = None;
    let (mut consulted, mut always_skip, mut never_skip) = (0usize, 0usize, 0usize);
    if cs.policied {
        if let Some(p) = policy.filter(|p| p.cfg.strategy.uses_binary()) {
            if let Some(lp) = p.layers.get(&cs.node) {
                // PackedVec::dot = (jointly valid lanes) − 2·mismatches,
                // and at most k_len lanes are jointly valid
                let k = cs.k_len as i64;
                let p_iv = Ival::new(-k, k);
                proxy = Some(p_iv);
                let mut thr_hit = false;
                for f in 0..cs.cout {
                    if !lp.enabled[f] {
                        continue;
                    }
                    if p.cfg.strategy.uses_clusters() && lp.is_proxy(f) {
                        continue; // proxies are always evaluated, never consulted
                    }
                    consulted += 1;
                    let (m, b, s) = (lp.m[f], lp.b[f], lp.s[f]);
                    let margin = margin_of(lp, bn, f, p.cfg.margin_sigmas);
                    if !(m.is_finite() && b.is_finite() && s.is_finite() && s >= 0.0)
                        || !margin.is_finite()
                        || margin < 0.0
                    {
                        if !thr_hit {
                            findings.push(err(
                                si,
                                "num.threshold",
                                format!(
                                    "filter {f}: predictor line m={m} b={b} s={s} \
                                     margin={margin} is not finite/non-negative — the \
                                     skip comparison est < -margin is unsound"
                                ),
                            ));
                            thr_hit = true;
                        }
                        continue;
                    }
                    // est_ri = bn_affine(m·p_bin + b) + residual, the exact
                    // expression binary_says_skip compares against -margin
                    let est = Fival::from_ival(p_iv)
                        .scale(m as f64)
                        .add(Fival::exact(b as f64));
                    let est_ri = match bn {
                        Some((scale, shift)) => est.affine(scale[f] as f64, shift[f] as f64),
                        None => est,
                    }
                    .add(res_range)
                    .widen(SLACK_REL, SLACK_ABS);
                    if est_ri.is_nan() {
                        if !thr_hit {
                            findings.push(err(
                                si,
                                "num.threshold",
                                format!(
                                    "filter {f}: threshold estimate range is NaN \
                                     (poisoned BN/residual parameters)"
                                ),
                            ));
                            thr_hit = true;
                        }
                        continue;
                    }
                    est_hull = Some(est_hull.map_or(est_ri, |h| h.hull(est_ri)));
                    if est_ri.hi < -(margin as f64) {
                        always_skip += 1;
                    } else if est_ri.lo >= -(margin as f64) {
                        never_skip += 1;
                    }
                }
                if consulted > 0 && always_skip == consulted {
                    findings.push(warn(
                        si,
                        "num.threshold",
                        format!(
                            "all {consulted} binary-consulted neurons provably always \
                             skip (est_ri < -margin for every input): the layer \
                             degenerates to constant zeros"
                        ),
                    ));
                } else if consulted > 0 && never_skip == consulted {
                    findings.push(warn(
                        si,
                        "num.threshold",
                        format!(
                            "all {consulted} binary-consulted neurons provably never \
                             skip (est_ri ≥ -margin for every input): the binary \
                             rookie is inert on this layer"
                        ),
                    ));
                }
            }
        }
    }

    StepRanges {
        step: si,
        node: cs.node,
        q,
        acc_peak,
        vnni_peak,
        vnni_eligible,
        dot: dot_hull.unwrap_or(Ival::exact(0)),
        pre_act: pre_hull.unwrap_or(Fival::exact(0.0)),
        out,
        proxy,
        est_ri: est_hull,
        consulted,
        always_skip,
        never_skip,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth;
    use crate::plan;
    use crate::predictor::RunOpts;

    #[test]
    fn zoo_models_prove_clean() {
        for model in [synth::cnn10_like(7), synth::tiny_serving_model(7)] {
            let p = plan::compile(&model, None, RunOpts::default());
            let rep = analyze(&p, &model, None);
            assert_eq!(rep.errors(), 0, "{}: {rep}", model.name);
            assert!(!rep.steps.is_empty());
            // every compute step proves i32 is enough
            assert!(rep.max_acc_bits() <= 32, "{}", model.name);
        }
    }

    #[test]
    fn per_filter_bound_beats_blanket_worst_case() {
        // actual-weight bounds: uniform random i8 weights average
        // |w| ≈ 64, so the real Σ|w|·127 of the first conv sits well
        // under the blanket 127·128·k_len worst case the kernel docs
        // used to quote
        let model = synth::cnn10_like(7);
        let p = plan::compile(&model, None, RunOpts::default());
        let rep = analyze(&p, &model, None);
        let first = &rep.steps[0];
        let k = model.nodes[first.node].k_len() as u64;
        let blanket = 127u64 * 128 * k;
        assert!(first.acc_peak < blanket, "{} !< {blanket}", first.acc_peak);
        assert!(first.acc_peak > 0);
    }

    #[test]
    fn oversized_dot_is_rejected_with_num_acc() {
        // Σ|w|·127 = 262144·128·127 ≈ 4.26e9 > 2³¹: no i32 accumulator
        // can hold the worst case of this (absurd) layer
        let k = 262_144usize;
        let model = Model::new(
            "acc_overflow".into(),
            0.02,
            (1, 1, k),
            vec![Node::Fc {
                cin: k,
                cout: 2,
                sw: 0.01,
                sx: 0.02,
                w: vec![-128i8; k * 2],
                bn: None,
                relu: false,
                res_from: None,
                consumes: -1,
            }],
        );
        let p = plan::compile(&model, None, RunOpts::default());
        let rep = analyze(&p, &model, None);
        assert!(rep.has("num.acc"), "{rep}");
        assert!(rep.errors() > 0);
        assert!(rep.max_acc_bits() > 32);
    }

    #[test]
    fn vnni_worst_case_at_the_dispatch_gate_fits_i32() {
        // the static fact behind VNNI_K_MAX: even all-(-128) weights at
        // the largest dispatchable dot length keep the offset partial
        // sums 128·2¹⁶·255 = 2,139,095,040 inside i32 — lint answers
        // "can vpdpbusd overflow" with a per-layer proof, not a shrug
        let k = VNNI_K_MAX;
        let model = Model::new(
            "vnni_worst".into(),
            0.02,
            (1, 1, k),
            vec![Node::Fc {
                cin: k,
                cout: 2,
                sw: 0.01,
                sx: 0.02,
                w: vec![-128i8; k * 2],
                bn: None,
                relu: false,
                res_from: None,
                consumes: -1,
            }],
        );
        let p = plan::compile(&model, None, RunOpts::default());
        let rep = analyze(&p, &model, None);
        assert!(!rep.has("num.vnni"), "{rep}");
        let s = &rep.steps[0];
        assert!(s.vnni_eligible);
        assert_eq!(s.vnni_peak, 128 * (VNNI_K_MAX as u64) * 255);
        assert_eq!(s.vnni_bits_needed(), 32);
        assert!(s.vnni_peak > s.acc_peak);
    }

    #[test]
    fn narrow_claim_can_pass_acc_but_fail_vnni() {
        // Σ|w| = 128·256 = 32768: the true dot (·127) fits a claimed
        // i23, the VNNI offset partials (·255) need i24 — the explicit
        // "wider than the true dot" answer under --acc-bits
        let k = 256usize;
        let model = Model::new(
            "vnni_width".into(),
            0.02,
            (1, 1, k),
            vec![Node::Fc {
                cin: k,
                cout: 2,
                sw: 0.01,
                sx: 0.02,
                w: vec![-128i8; k * 2],
                bn: None,
                relu: false,
                res_from: None,
                consumes: -1,
            }],
        );
        let p = plan::compile(&model, None, RunOpts::default());
        let rep = analyze_with(&p, &model, None, &NumericOpts { acc_bits: 23 });
        assert!(!rep.has("num.width"), "{rep}");
        assert!(rep.has("num.vnni"), "{rep}");
        assert!(rep.errors() > 0);
    }

    #[test]
    fn oversized_layers_are_vnni_ineligible() {
        // k_pad beyond VNNI_K_MAX never dispatches the VNNI kernels, so
        // no num.vnni finding applies even where num.acc fires
        let k = 262_144usize;
        let model = Model::new(
            "vnni_inel".into(),
            0.02,
            (1, 1, k),
            vec![Node::Fc {
                cin: k,
                cout: 2,
                sw: 0.01,
                sx: 0.02,
                w: vec![-128i8; k * 2],
                bn: None,
                relu: false,
                res_from: None,
                consumes: -1,
            }],
        );
        let p = plan::compile(&model, None, RunOpts::default());
        let rep = analyze(&p, &model, None);
        assert!(!rep.steps[0].vnni_eligible);
        assert!(!rep.has("num.vnni"), "{rep}");
    }

    #[test]
    fn narrow_width_claim_is_rejected_with_num_width() {
        let model = synth::cnn10_like(7);
        let p = plan::compile(&model, None, RunOpts::default());
        let rep = analyze_with(&p, &model, None, &NumericOpts { acc_bits: 16 });
        assert!(rep.has("num.width"), "{rep}");
        assert!(!rep.has("num.acc"), "i32 itself is fine for this model");
    }

    #[test]
    fn quantize_interval_is_saturating_and_one_sided() {
        assert_eq!(quantize_interval(WIDE, 0.02), Ival::new(-127, 127));
        // post-ReLU source keeps q non-negative; the upper bound carries
        // the ±1 rounding slack (1.0/0.02 rounds to ~50, +ceil, +1)
        let q = quantize_interval(Fival::new(0.0, 1.0), 0.02);
        assert_eq!(q.lo, 0);
        assert!((51..=52).contains(&q.hi), "q.hi = {}", q.hi);
        // two-sided source: symmetric-ish with slack, inside the clamp
        let q = quantize_interval(Fival::new(-0.1, 0.1), 0.02);
        assert!(q.contains(-5) && q.contains(5));
        assert!(q.lo >= -8 && q.hi <= 8, "q = [{}, {}]", q.lo, q.hi);
    }
}
