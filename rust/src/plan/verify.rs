//! Static plan verifier — prove a frozen [`ModelPlan`]'s structural
//! invariants **without executing it** (`mor lint`, and the debug-build
//! assertion at `Session::finish()`).
//!
//! The plan/execute split (PR 5/6) moved every correctness-critical
//! decision out of the request path and into compile time: slot wiring
//! from the liveness analysis, scratch high-water marks, per-layer
//! sparse-vs-dense kernel choices, residual/BN indices, the oracle
//! accounting flag. The property suites exercise those decisions
//! dynamically, on inputs we happened to generate; this pass checks
//! them *statically*, by walking the plan against its model and
//! re-deriving what each frozen field must be. A plan that lints clean
//! cannot read an activation slot before it is written, alias two live
//! tensors onto one slot, undersize a workspace buffer, or run a kernel
//! the plan's frozen [`TuneProfile`] cutoffs (or the u16 lane-index
//! range) forbid.
//!
//! Invariant catalogue (finding `code` prefixes):
//!
//! * `plan.*` — plan/model correspondence: one step per node, matching
//!   kinds, matching node indices.
//! * `slot.*` — the activation-slot register allocation: indices in
//!   range, no step overwrites its own live inputs, a forward
//!   simulation of slot contents proves every read (graph edge and
//!   residual edge alike) sees exactly the producer it expects (this
//!   one mechanism catches read-before-write, aliased live tensors and
//!   mis-wired residuals, with distinct diagnostics), the logits slot
//!   holds the last node's output, slots are big enough for every
//!   tensor they host, and the slot count equals the liveness peak
//!   (O(1) for chains — more is a waste warning, fewer is impossible
//!   without an aliasing bug).
//! * `scratch.*` — the workspace high-water marks dominate the
//!   worst-case tile of every layer geometry (undersized marks are
//!   errors: they mean a buffer the executor indexes out of capacity;
//!   oversized marks are warnings: wasted memory, not wrong results).
//! * `geom.*` — frozen geometry fields re-derived from the model:
//!   conv/FC output shape, row count, filter count, dot length, its
//!   [`pad_k`]-aligned padding (what the AVX2 block kernel's `# Safety`
//!   contract relies on), quantization scales.
//! * `sparsity.*` — the frozen kernel decisions against the plan's
//!   [`TuneProfile`] (or one supplied to [`verify_with`], which is how
//!   `mor lint --tune-profile` audits a plan against a saved profile):
//!   the lane builder must run iff the mode asks for it *and* the dot
//!   length fits the u16 lane index ([`SPARSE_K_MAX`]); `Auto`'s
//!   pre-multiplied cutoff must equal `tune.input_cutoff * k_len`; the
//!   weight-sparse flag must match the prepacked per-layer density
//!   against `tune.weight_cutoff`.
//! * `tune.*` — the frozen profile itself is well-formed
//!   ([`TuneProfile::validate`]), and, under [`verify_with`], matches
//!   the supplied profile's ISA.
//! * `policy.*` — the policied-layer set matches the prepared policy,
//!   and the oracle accounting flag is on exactly when `RunOpts`
//!   requests it or the oracle strategy runs.
//! * `mac.*` — the MAC-partition identity `(total − done) + input_zero
//!   + weight_zero + effectual == total` is derivable from plan
//!   metadata alone: per layer, `rows * cout * k_len` (the plan's
//!   `total`) must equal the model's [`Model::mac_counts`], and `k_len`
//!   must tile the consumed tensor exactly — otherwise the engines'
//!   per-lane attribution could not sum back to the model's totals.
//!
//! The mutation suite (`rust/tests/plan_verify.rs`) corrupts plans in
//! each of these dimensions and asserts the right diagnostic fires;
//! every pristine synthetic model must lint clean in every sparsity
//! mode.

use super::compile::{ModelPlan, Src, StepPlan};
use crate::engine::gemm::{pad_k, K_ALIGN, SPARSE_K_MAX};
use crate::engine::tune::TuneProfile;
use crate::engine::{conv_geom, ConvGeom, InputSparsity, WeightSparsity};
use crate::model::{Model, Node};
use crate::predictor::strategies::Strategy;
use crate::predictor::MorPolicy;
use crate::util::json::{obj, Json};
use std::fmt;

/// How bad a finding is. `Error` means executing the plan can read
/// wrong data or index out of a presized buffer; `Warning` means the
/// plan is safe but wasteful (extra slots, oversized marks).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One verifier diagnostic: a stable machine-readable `code` (the
/// mutation suite pins corruptions to codes), the step it anchors to
/// (`None` for plan-level findings) and a human message.
#[derive(Clone, Debug)]
pub struct Finding {
    pub code: &'static str,
    pub severity: Severity,
    /// Step index the finding is about, if any.
    pub step: Option<usize>,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.step {
            Some(s) => write!(
                f,
                "{}[{}] step {}: {}",
                self.severity.name(),
                self.code,
                s,
                self.message
            ),
            None => write!(f, "{}[{}] {}", self.severity.name(), self.code, self.message),
        }
    }
}

/// Everything [`verify`] found about one plan.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
}

impl LintReport {
    /// No findings at all (not even warnings).
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Number of `Error`-severity findings — the exit-code driver.
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.findings.len() - self.errors()
    }

    /// Is a finding with this code present (any severity)?
    pub fn has(&self, code: &str) -> bool {
        self.findings.iter().any(|f| f.code == code)
    }

    /// Machine-readable form for `mor lint --json`.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.findings
                .iter()
                .map(|f| {
                    obj(vec![
                        ("code", Json::Str(f.code.to_string())),
                        ("severity", Json::Str(f.severity.name().to_string())),
                        (
                            "step",
                            f.step.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null),
                        ),
                        ("message", Json::Str(f.message.clone())),
                    ])
                })
                .collect(),
        )
    }
}

impl fmt::Display for LintReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_clean() {
            return write!(f, "clean");
        }
        for (i, finding) in self.findings.iter().enumerate() {
            if i > 0 {
                writeln!(f)?;
            }
            write!(f, "{finding}")?;
        }
        Ok(())
    }
}

struct Lint {
    findings: Vec<Finding>,
}

impl Lint {
    fn error(&mut self, code: &'static str, step: Option<usize>, message: String) {
        self.findings.push(Finding { code, severity: Severity::Error, step, message });
    }

    fn warn(&mut self, code: &'static str, step: Option<usize>, message: String) {
        self.findings.push(Finding { code, severity: Severity::Warning, step, message });
    }
}

/// Statically verify `plan` against the `model` (and `policy`) it was
/// compiled for. Pure inspection: no activations are touched, no step
/// executes; weight data is only read through the shared prepack cache,
/// and only when the plan's weight-sparsity mode is on (mirroring
/// [`super::compile`]'s own short-circuit).
///
/// ```
/// use mor::model::synth;
/// use mor::plan;
/// use mor::predictor::RunOpts;
///
/// let model = synth::cnn10_like(3);
/// let p = plan::compile(&model, None, RunOpts::default());
/// let report = plan::verify(&p, &model, None);
/// assert!(report.is_clean(), "{report}");
/// ```
pub fn verify(plan: &ModelPlan, model: &Model, policy: Option<&MorPolicy>) -> LintReport {
    verify_with(plan, model, policy, None)
}

/// [`verify`], but auditing the plan's frozen kernel decisions against
/// `profile` instead of the plan's own `opts.tune` — how
/// `mor lint --tune-profile` proves a plan was compiled under a given
/// saved [`TuneProfile`]. With `None` the plan is checked for
/// self-consistency against its own frozen profile (every compile
/// freezes its decisions *from* `opts.tune`, so a pristine plan is
/// always self-consistent; a plan compiled under a different profile
/// than the one supplied fails with `sparsity.cutoff` /
/// `sparsity.weight` / `tune.isa` findings).
pub fn verify_with(
    plan: &ModelPlan,
    model: &Model,
    policy: Option<&MorPolicy>,
    profile: Option<&TuneProfile>,
) -> LintReport {
    let mut l = Lint { findings: Vec::new() };
    // the profile the frozen decisions are audited against
    let tune = profile.copied().unwrap_or(plan.opts.tune);
    if let Err(e) = plan.opts.tune.validate() {
        l.error(
            "tune.profile",
            None,
            format!("the plan's frozen tune profile is malformed: {e}"),
        );
    }
    if let Some(p) = profile {
        if let Err(e) = p.validate() {
            l.error("tune.profile", None, format!("supplied tune profile is malformed: {e}"));
        }
        if p.isa != plan.opts.tune.isa {
            l.error(
                "tune.isa",
                None,
                format!(
                    "plan was frozen for isa {} but the supplied profile targets {}",
                    plan.opts.tune.isa.name(),
                    p.isa.name()
                ),
            );
        }
    }
    let n = model.nodes.len();
    let shapes = model.node_shapes();
    let relu_layers = model.relu_layers();
    let mac_counts = model.mac_counts();

    // ---- plan/model correspondence ------------------------------------
    if plan.n_nodes != n || plan.steps.len() != n {
        l.error(
            "plan.nodes",
            None,
            format!(
                "plan covers {} steps / {} nodes but the model has {} nodes",
                plan.steps.len(),
                plan.n_nodes,
                n
            ),
        );
        // everything below indexes steps and nodes in lockstep
        return LintReport { findings: l.findings };
    }
    if plan.slot_elems.len() != plan.n_slots {
        l.error(
            "slot.elems-len",
            None,
            format!(
                "slot_elems has {} entries for n_slots = {}",
                plan.slot_elems.len(),
                plan.n_slots
            ),
        );
    }
    let (ih, iw, ic) = model.input_shape;
    if plan.input_elems != ih * iw * ic {
        l.error(
            "scratch.input",
            None,
            format!(
                "input_elems = {} but the model input is {}x{}x{} = {}",
                plan.input_elems,
                ih,
                iw,
                ic,
                ih * iw * ic
            ),
        );
    }

    // ---- reference liveness: what the slot map must satisfy -----------
    // last step that reads each node's output (graph edge or residual)
    let mut last_use: Vec<usize> = (0..n).collect();
    for (i, nd) in model.nodes.iter().enumerate() {
        if nd.consumes() >= 0 {
            let v = nd.consumes() as usize;
            last_use[v] = last_use[v].max(i);
        }
        if let Node::Conv { res_from: Some(r), .. } | Node::Fc { res_from: Some(r), .. } =
            nd
        {
            last_use[*r] = last_use[*r].max(i);
        }
    }
    if n > 0 {
        last_use[n - 1] = usize::MAX; // the logits outlive the walk
    }
    // peak simultaneous liveness = the minimal slot count any allocator
    // can achieve (a step's output overlaps every input it still reads)
    let mut peak = 0usize;
    for i in 0..n {
        let live = (0..=i).filter(|&v| last_use[v] >= i).count();
        peak = peak.max(live);
    }
    if plan.n_slots < peak {
        l.error(
            "slot.count",
            None,
            format!(
                "{} slots cannot host a liveness peak of {} tensors",
                plan.n_slots, peak
            ),
        );
    } else if plan.n_slots > peak {
        l.warn(
            "slot.excess",
            None,
            format!(
                "{} slots allocated but peak liveness is {} (wasted workspace)",
                plan.n_slots, peak
            ),
        );
    }

    // ---- forward slot-contents simulation ------------------------------
    // contents[k] = node whose output currently occupies slot k. Every
    // read must find exactly the producer the graph names; a clobbered
    // or mis-wired slot surfaces as a stale/foreign producer.
    let mut contents: Vec<Option<usize>> = vec![None; plan.n_slots];
    let strategy: Option<Strategy> = policy.map(|p| p.cfg.strategy);
    let policied_set = |i: usize| policy.is_some_and(|p| p.layers.contains_key(&i));

    // max over compute layers, recomputed for the scratch-mark checks
    let mut want_cout = 0usize;
    let mut want_k_len = 0usize;
    let mut want_row_elems = 0usize;
    let mut want_qt_elems = 0usize;
    let mut want_lanes_k_len = 0usize;

    for (i, (step, nd)) in plan.steps.iter().zip(&model.nodes).enumerate() {
        let (node_idx, src, dst, res) = match step {
            StepPlan::Compute(c) => (c.node, c.src, c.dst, c.res),
            StepPlan::MaxPool { node, src, dst, .. }
            | StepPlan::Gap { node, src, dst, .. }
            | StepPlan::Relu { node, src, dst, .. } => (*node, *src, *dst, None),
        };
        if node_idx != i {
            l.error(
                "plan.node-index",
                Some(i),
                format!("step carries node index {node_idx}"),
            );
        }
        let kind_ok = matches!(
            (step, nd),
            (StepPlan::Compute(_), Node::Conv { .. } | Node::Fc { .. })
                | (StepPlan::MaxPool { .. }, Node::MaxPool { .. })
                | (StepPlan::Gap { .. }, Node::Gap { .. })
                | (StepPlan::Relu { .. }, Node::Relu { .. })
        );
        if !kind_ok {
            l.error(
                "plan.step-kind",
                Some(i),
                format!("step kind does not match the model's {nd:?}"),
            );
            continue;
        }

        // -- slot indices in range ---------------------------------------
        let mut in_range = true;
        if dst >= plan.n_slots {
            l.error(
                "slot.range",
                Some(i),
                format!("dst slot {dst} out of range (n_slots = {})", plan.n_slots),
            );
            in_range = false;
        }
        if let Src::Slot(k) = src {
            if k >= plan.n_slots {
                l.error(
                    "slot.range",
                    Some(i),
                    format!("src slot {k} out of range (n_slots = {})", plan.n_slots),
                );
                in_range = false;
            }
        }
        if let Some(r) = res {
            if r >= plan.n_slots {
                l.error(
                    "slot.range",
                    Some(i),
                    format!("residual slot {r} out of range (n_slots = {})", plan.n_slots),
                );
                in_range = false;
            }
        }

        // -- a step never writes over its own still-live inputs ----------
        if let Src::Slot(k) = src {
            if k == dst {
                l.error(
                    "slot.self-overwrite",
                    Some(i),
                    format!("dst slot {dst} is also the src slot"),
                );
            }
        }
        if res == Some(dst) {
            l.error(
                "slot.self-overwrite",
                Some(i),
                format!("dst slot {dst} is also the residual slot"),
            );
        }

        // -- graph edge: src must hold exactly the consumed output -------
        if nd.consumes() < 0 {
            if src != Src::Input {
                l.error(
                    "slot.src-kind",
                    Some(i),
                    format!("node consumes the model input but src is {src:?}"),
                );
            }
        } else {
            let want = nd.consumes() as usize;
            match src {
                Src::Input => l.error(
                    "slot.src-kind",
                    Some(i),
                    format!("node consumes node {want}'s output but src is Input"),
                ),
                Src::Slot(k) if k < plan.n_slots => match contents[k] {
                    None => l.error(
                        "slot.read-before-write",
                        Some(i),
                        format!("src slot {k} read before any step wrote it"),
                    ),
                    Some(have) if have != want => l.error(
                        "slot.aliased",
                        Some(i),
                        format!(
                            "src slot {k} holds node {have}'s output, expected node {want}'s \
                             (live tensor aliased or clobbered)"
                        ),
                    ),
                    Some(_) => {}
                },
                Src::Slot(_) => {} // already reported slot.range
            }
        }

        // -- residual edge ------------------------------------------------
        let res_from = match nd {
            Node::Conv { res_from, .. } | Node::Fc { res_from, .. } => *res_from,
            _ => None,
        };
        match (res_from, res) {
            (None, None) => {}
            (Some(r), None) => l.error(
                "slot.residual",
                Some(i),
                format!("node has res_from = {r} but the step carries no residual slot"),
            ),
            (None, Some(k)) => l.error(
                "slot.residual",
                Some(i),
                format!("step carries residual slot {k} but the node has no res_from"),
            ),
            (Some(r), Some(k)) if k < plan.n_slots => match contents[k] {
                Some(have) if have == r => {}
                Some(have) => l.error(
                    "slot.residual",
                    Some(i),
                    format!(
                        "residual slot {k} holds node {have}'s output, expected node {r}'s"
                    ),
                ),
                None => l.error(
                    "slot.residual",
                    Some(i),
                    format!("residual slot {k} read before any step wrote it"),
                ),
            },
            (Some(_), Some(_)) => {} // already reported slot.range
        }

        // -- the output fits its slot ------------------------------------
        let (oh, ow, oc) = shapes[i];
        let out_elems = oh * ow * oc;
        if in_range && dst < plan.slot_elems.len() && plan.slot_elems[dst] < out_elems {
            l.error(
                "slot.undersized",
                Some(i),
                format!(
                    "dst slot {dst} sized for {} elems but the output is {}x{}x{} = {}",
                    plan.slot_elems[dst], oh, ow, oc, out_elems
                ),
            );
        }

        // -- compute-step frozen fields ----------------------------------
        if let (StepPlan::Compute(c), Node::Conv { .. } | Node::Fc { .. }) = (step, nd) {
            let (sh, sw2, sc) = if nd.consumes() < 0 {
                model.input_shape
            } else {
                shapes[nd.consumes() as usize]
            };
            let (want_geom, wkh, wkw, wstride) = match nd {
                Node::Conv { kh, kw, stride, pad_same, .. } => (
                    conv_geom(sh, sw2, *kh, *kw, *stride, *pad_same),
                    *kh,
                    *kw,
                    *stride,
                ),
                _ => (ConvGeom { oh: sh, ow: sw2, pad_top: 0, pad_left: 0 }, 0, 0, 1),
            };
            if c.geom != want_geom
                || c.kh != wkh
                || c.kw != wkw
                || c.stride != wstride
                || c.is_conv != matches!(nd, Node::Conv { .. })
            {
                l.error(
                    "geom.shape",
                    Some(i),
                    format!(
                        "frozen geometry {:?} (kh {} kw {} stride {}) differs from the \
                         model's {:?} (kh {wkh} kw {wkw} stride {wstride})",
                        c.geom, c.kh, c.kw, c.stride, want_geom
                    ),
                );
            }
            if c.rows != want_geom.oh * want_geom.ow {
                l.error(
                    "geom.rows",
                    Some(i),
                    format!(
                        "rows = {} but the output geometry is {}x{}",
                        c.rows, want_geom.oh, want_geom.ow
                    ),
                );
            }
            if c.cout != nd.cout() {
                l.error(
                    "geom.cout",
                    Some(i),
                    format!("cout = {} but the node has {} filters", c.cout, nd.cout()),
                );
            }
            if c.k_len != nd.k_len() {
                l.error(
                    "geom.k-len",
                    Some(i),
                    format!("k_len = {} but the node's dot length is {}", c.k_len, nd.k_len()),
                );
            }
            // the AVX2 block kernel's # Safety contract: every filter
            // pointer addresses exactly k_pad = pad_k(k_len) bytes, a
            // multiple of K_ALIGN
            if c.k_pad != pad_k(c.k_len) || c.k_pad % K_ALIGN != 0 || c.k_pad < c.k_len {
                l.error(
                    "geom.k-pad",
                    Some(i),
                    format!(
                        "k_pad = {} violates the kernel contract pad_k({}) = {}",
                        c.k_pad,
                        c.k_len,
                        pad_k(c.k_len)
                    ),
                );
            }
            let (want_sx, want_sw) = match nd {
                Node::Conv { sx, sw, .. } | Node::Fc { sx, sw, .. } => (*sx, *sw),
                _ => unreachable!("compute step checked above"),
            };
            if c.sx != want_sx || c.dq != want_sw * want_sx {
                l.error(
                    "geom.scale",
                    Some(i),
                    format!(
                        "quantization scales (sx {}, dq {}) differ from the node's \
                         (sx {want_sx}, dq {})",
                        c.sx,
                        c.dq,
                        want_sw * want_sx
                    ),
                );
            }
            if c.node_relu != nd.relu() || c.is_relu_layer != relu_layers.contains(&i) {
                l.error(
                    "geom.relu",
                    Some(i),
                    format!(
                        "relu flags (node_relu {}, is_relu_layer {}) differ from the \
                         model's ({}, {})",
                        c.node_relu,
                        c.is_relu_layer,
                        nd.relu(),
                        relu_layers.contains(&i)
                    ),
                );
            }

            // MAC-partition identity: total = rows * cout * k_len must be
            // the model's per-layer MAC count, and k_len must cover the
            // consumed tensor (FC) / the kernel window (conv) exactly, or
            // the per-lane input-zero / weight-zero / effectual
            // attribution could not sum back to (total - done)
            let want_k = match nd {
                Node::Conv { kh, kw, cin, .. } => kh * kw * cin,
                Node::Fc { cin, .. } => {
                    if sh * sw2 * sc != *cin {
                        l.error(
                            "mac.partition",
                            Some(i),
                            format!(
                                "FC consumes {sh}x{sw2}x{sc} = {} elems but cin = {cin}",
                                sh * sw2 * sc
                            ),
                        );
                    }
                    *cin
                }
                _ => unreachable!("compute step checked above"),
            };
            let total = (c.rows * c.cout * want_k) as u64;
            if total != mac_counts[i] {
                l.error(
                    "mac.partition",
                    Some(i),
                    format!(
                        "plan-derived MAC total {total} != model mac_counts {} — the \
                         (total-done)+input_zero+weight_zero+effectual identity is not \
                         derivable from this plan",
                        mac_counts[i]
                    ),
                );
            }

            // input-sparsity decision: the lane builder runs iff the mode
            // asks for it AND the dot length fits the u16 lane index
            let want_lanes =
                plan.opts.input_sparsity != InputSparsity::Off && c.k_len <= SPARSE_K_MAX;
            if c.lanes != want_lanes {
                l.error(
                    "sparsity.lanes",
                    Some(i),
                    format!(
                        "lanes = {} but mode {:?} with k_len {} (SPARSE_K_MAX {}) \
                         requires {}",
                        c.lanes, plan.opts.input_sparsity, c.k_len, SPARSE_K_MAX, want_lanes
                    ),
                );
            }
            let want_cutoff = match plan.opts.input_sparsity {
                InputSparsity::Off => 0.0,
                InputSparsity::On => f32::INFINITY,
                InputSparsity::Auto => tune.input_cutoff * c.k_len.max(1) as f32,
            };
            if c.sparse_cutoff != want_cutoff {
                l.error(
                    "sparsity.cutoff",
                    Some(i),
                    format!(
                        "sparse_cutoff = {} but mode {:?} requires {} (profile cutoff {} x \
                         k_len {})",
                        c.sparse_cutoff,
                        plan.opts.input_sparsity,
                        want_cutoff,
                        tune.input_cutoff,
                        c.k_len
                    ),
                );
            }
            // weight-sparsity decision: per-layer, from the frozen
            // prepacked density (only read when the mode is on, mirroring
            // compile's short-circuit — Off must never touch the cache)
            let want_w_sparse = plan.opts.weight_sparsity != WeightSparsity::Off && {
                let pf = model.prepacked().layer(i);
                pf.has_lanes() && pf.density() < tune.weight_cutoff
            };
            if c.w_sparse != want_w_sparse {
                let detail = if plan.opts.weight_sparsity == WeightSparsity::Off {
                    "mode off forbids the weight-sparse kernels".to_string()
                } else {
                    let pf = model.prepacked().layer(i);
                    format!(
                        "prepacked density {} vs profile cutoff {} (has_lanes {})",
                        pf.density(),
                        tune.weight_cutoff,
                        pf.has_lanes()
                    )
                };
                l.error(
                    "sparsity.weight",
                    Some(i),
                    format!("w_sparse = {} but {detail} requires {want_w_sparse}", c.w_sparse),
                );
            }

            // policy wiring
            let want_policied = policied_set(i);
            if c.policied != want_policied {
                l.error(
                    "policy.set",
                    Some(i),
                    format!(
                        "policied = {} but the prepared policy {} layer {i}",
                        c.policied,
                        if want_policied { "contains" } else { "does not contain" }
                    ),
                );
            }
            let want_oracle =
                plan.opts.oracle || (want_policied && strategy == Some(Strategy::Oracle));
            if c.oracle != want_oracle {
                l.error(
                    "policy.oracle",
                    Some(i),
                    format!(
                        "oracle = {} but opts.oracle = {} and strategy {:?} require {}",
                        c.oracle, plan.opts.oracle, strategy, want_oracle
                    ),
                );
            }

            want_cout = want_cout.max(nd.cout());
            want_k_len = want_k_len.max(nd.k_len());
            want_row_elems = want_row_elems.max((want_geom.oh * want_geom.ow) * nd.cout());
            want_qt_elems = want_qt_elems.max(sh * sw2 * sc);
            if plan.opts.input_sparsity != InputSparsity::Off && nd.k_len() <= SPARSE_K_MAX {
                want_lanes_k_len = want_lanes_k_len.max(nd.k_len());
            }
        }

        if dst < plan.n_slots {
            contents[dst] = Some(i);
        }
    }

    // ---- the logits come out of the right slot -------------------------
    if n > 0 {
        if plan.logits_slot >= plan.n_slots {
            l.error(
                "slot.logits",
                None,
                format!(
                    "logits_slot = {} out of range (n_slots = {})",
                    plan.logits_slot, plan.n_slots
                ),
            );
        } else if contents[plan.logits_slot] != Some(n - 1) {
            l.error(
                "slot.logits",
                None,
                format!(
                    "logits_slot {} holds {:?}, expected node {}'s output",
                    plan.logits_slot,
                    contents[plan.logits_slot],
                    n - 1
                ),
            );
        }
    }

    // ---- scratch high-water marks dominate every layer ------------------
    for (code, have, want) in [
        ("scratch.cout", plan.max_cout, want_cout),
        ("scratch.k-len", plan.max_k_len, want_k_len),
        ("scratch.rows", plan.max_row_elems, want_row_elems),
        ("scratch.qt", plan.max_qt_elems, want_qt_elems),
        ("scratch.lanes", plan.max_lanes_k_len, want_lanes_k_len),
    ] {
        if have < want {
            l.error(
                code,
                None,
                format!(
                    "high-water mark {have} is below the worst-case layer's {want} — a \
                     presized workspace buffer would be indexed past its capacity"
                ),
            );
        } else if have > want {
            l.warn(
                code,
                None,
                format!("high-water mark {have} exceeds the worst-case layer's {want}"),
            );
        }
    }

    // ---- the policied-layer set is the policy's ------------------------
    let want_policied: Vec<usize> =
        policy.map(|p| p.layers.keys().copied().collect()).unwrap_or_default();
    if plan.policied != want_policied {
        l.error(
            "policy.set",
            None,
            format!(
                "plan.policied = {:?} but the prepared policy's layer set is {:?}",
                plan.policied, want_policied
            ),
        );
    }

    LintReport { findings: l.findings }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth;
    use crate::predictor::RunOpts;

    #[test]
    fn pristine_plans_lint_clean() {
        for seed in [1u64, 9, 23] {
            let m = synth::cnn10_like(seed);
            let plan = super::super::compile(&m, None, RunOpts::default());
            let report = verify(&plan, &m, None);
            assert!(report.is_clean(), "cnn10_like({seed}): {report}");
        }
    }

    #[test]
    fn corrupted_slot_is_flagged() {
        let m = synth::tiny_serving_model(4);
        let mut plan = super::super::compile(&m, None, RunOpts::default());
        if let StepPlan::Compute(c) = &mut plan.steps[0] {
            c.dst = 99;
        }
        let report = verify(&plan, &m, None);
        assert!(report.has("slot.range"), "{report}");
        assert!(report.errors() > 0);
    }

    #[test]
    fn profile_override_audits_frozen_cutoffs() {
        use crate::engine::tune::TuneProfile;
        let m = synth::tiny_serving_model(4);
        let plan = super::super::compile(&m, None, RunOpts::default());
        // the plan's own profile: self-consistent
        let report = verify_with(&plan, &m, None, Some(&plan.opts.tune));
        assert!(report.is_clean(), "{report}");
        // a profile with a different input cutoff: every Auto layer's
        // pre-multiplied cutoff now disagrees
        let other = TuneProfile {
            input_cutoff: plan.opts.tune.input_cutoff * 0.5,
            ..plan.opts.tune
        };
        let report = verify_with(&plan, &m, None, Some(&other));
        assert!(report.has("sparsity.cutoff"), "{report}");
        // a profile for a different ISA: flagged even when cutoffs agree
        let mut foreign = plan.opts.tune;
        foreign.isa = if foreign.isa == crate::engine::isa::Isa::Scalar {
            crate::engine::isa::Isa::Avx2
        } else {
            crate::engine::isa::Isa::Scalar
        };
        let report = verify_with(&plan, &m, None, Some(&foreign));
        assert!(report.has("tune.isa"), "{report}");
    }

    #[test]
    fn malformed_frozen_profile_is_flagged() {
        let m = synth::tiny_serving_model(4);
        let mut plan = super::super::compile(&m, None, RunOpts::default());
        plan.opts.tune.input_cutoff = 2.0;
        let report = verify(&plan, &m, None);
        assert!(report.has("tune.profile"), "{report}");
        assert!(report.errors() > 0);
    }

    #[test]
    fn report_display_and_json_carry_the_code() {
        let m = synth::tiny_serving_model(4);
        let mut plan = super::super::compile(&m, None, RunOpts::default());
        plan.max_k_len = 0;
        let report = verify(&plan, &m, None);
        assert!(report.has("scratch.k-len"));
        let text = report.to_string();
        assert!(text.contains("scratch.k-len"), "{text}");
        let json = report.to_json().to_string();
        assert!(json.contains("scratch.k-len"), "{json}");
    }
}
