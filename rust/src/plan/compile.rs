//! Plan compilation: freeze everything the forward pass used to
//! re-derive per request into a [`ModelPlan`].
//!
//! [`compile`] walks the model graph once and resolves, per layer:
//!
//! * conv/FC **geometry** (output shape, SAME-padding offsets, row
//!   count, dot length and its kernel-aligned padding);
//! * the **input-sparsity decision** — whether the compressed-lane
//!   builder runs at all for the layer (`Off`, or a dot length beyond
//!   the u16 index range, disables it) and the `Auto` mode's density
//!   crossover, pre-multiplied into an absolute nonzero-lane cutoff so
//!   the per-row check is a single compare;
//! * **residual / graph wiring** as activation-slot indices: a
//!   liveness analysis (classic linear-scan register allocation over
//!   the node outputs) maps every node's output onto a small set of
//!   ping-pong slots, so the steady-state forward keeps O(1) tensors
//!   live per sample instead of one per layer;
//! * whether the layer is **policied** (has a prepared
//!   [`crate::predictor::strategies::LayerState`]) and whether skipped
//!   outputs need ground-truth (oracle) accounting;
//! * the exact **scratch high-water marks** a [`super::Workspace`]
//!   needs (max filters, max dot length, max output rows, max
//!   quantized input size), so workspaces can be pre-grown and the
//!   steady-state loop never allocates.
//!
//! The plan stores plain data and node *indices* — the bulk payloads
//! (prepacked weight blocks, strategy layer states) stay shared behind
//! the `Arc`s a [`crate::session::Session`] owns, which is what makes
//! threshold re-planning ([`crate::session::Session::with_threshold`])
//! free: the plan is reusable as long as the set of policied layers and
//! the execution options are unchanged.

use crate::engine::gemm::{pad_k, SPARSE_K_MAX};
use crate::engine::{conv_geom, ConvGeom, InputSparsity, WeightSparsity};
use crate::model::{Model, Node};
use crate::predictor::strategies::Strategy;
use crate::predictor::{MorPolicy, RunOpts};

/// Where a step reads its input tensor from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    /// The model input (`consumes: -1`), held per sample in the
    /// workspace.
    Input,
    /// Activation slot `k` of the same sample.
    Slot(usize),
}

/// One frozen execution step; `Compute` covers conv and FC layers, the
/// rest are the shape-only graph nodes.
#[derive(Clone, Debug)]
pub enum StepPlan {
    Compute(ComputeStep),
    MaxPool { node: usize, size: usize, src: Src, dst: usize },
    Gap { node: usize, src: Src, dst: usize },
    Relu { node: usize, src: Src, dst: usize },
}

/// Everything a conv/FC layer's tile loop needs, resolved once at
/// compile time. See the field docs; `sparse_cutoff` encodes the whole
/// input-sparsity mode decision (`lanes == false` → dense-only,
/// `+inf` → always sparse, finite → `Auto`'s pre-multiplied density
/// crossover in absolute nonzero lanes).
#[derive(Clone, Debug)]
pub struct ComputeStep {
    /// Model node index (prepacked weights, BN, filters live there).
    pub node: usize,
    pub is_conv: bool,
    /// Output geometry incl. SAME-padding offsets.
    pub geom: ConvGeom,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    /// Output rows per sample (`geom.oh * geom.ow`).
    pub rows: usize,
    pub cout: usize,
    /// Dot length and its kernel-aligned padding.
    pub k_len: usize,
    pub k_pad: usize,
    /// Input quantization scale and dequantization factor `sw * sx`.
    pub sx: f32,
    pub dq: f32,
    /// The node applies ReLU to its own output.
    pub node_relu: bool,
    /// The node's output feeds a ReLU (predictable layer).
    pub is_relu_layer: bool,
    /// A prepared `LayerState` exists for this layer.
    pub policied: bool,
    /// Skipped outputs get ground-truth accounting (RunOpts::oracle, or
    /// the oracle strategy which *is* its own ground truth).
    pub oracle: bool,
    /// The compressed-lane builder runs for this layer.
    pub lanes: bool,
    /// A row uses the sparse kernels iff `lanes && (nnz as f32) <
    /// sparse_cutoff` — frozen from the plan's
    /// [`crate::engine::tune::TuneProfile`] (`opts.tune.input_cutoff *
    /// k_len` under `Auto`, `+inf` under `On`). The default profile's
    /// cutoff equals the compiled-in crossover constant, so plans built
    /// without autotuning are unchanged.
    pub sparse_cutoff: f32,
    /// The layer's dot products run on the compressed-*weight* kernels
    /// ([`crate::engine::gemm::dot_block_wsparse`] and friends). Frozen
    /// at compile time from the prepacked per-layer weight density
    /// against the plan's `opts.tune.weight_cutoff`: unlike activation
    /// density, weight density is a constant of the model, so the
    /// decision is
    /// per layer, not per row. Always `false` under
    /// [`WeightSparsity::Off`], and when the prepack skipped lane lists
    /// (`k_len` beyond the u16 index range).
    pub w_sparse: bool,
    pub src: Src,
    /// Residual source's activation slot, if the node has one.
    pub res: Option<usize>,
    /// Output activation slot.
    pub dst: usize,
}

/// A compiled model: the frozen per-layer steps plus the activation
/// slot map and scratch high-water marks a [`super::Workspace`] is
/// sized from. Built by [`compile`], owned by a
/// [`crate::session::Session`], executed by [`super::execute()`].
///
/// ```
/// use mor::model::synth;
/// use mor::plan::{self, Workspace};
/// use mor::predictor::RunOpts;
///
/// let model = synth::cnn10_like(3);
/// let plan = plan::compile(&model, None, RunOpts::default());
/// // a 10-node chain needs only 2 live activation slots per sample
/// assert_eq!(plan.n_slots, 2);
/// assert_eq!(plan.steps.len(), model.nodes.len());
/// # let _ = Workspace::for_plan(&plan, 1);
/// ```
#[derive(Clone, Debug)]
pub struct ModelPlan {
    pub steps: Vec<StepPlan>,
    /// Activation slots per sample — the peak number of simultaneously
    /// live tensors (O(1) for chains, +1 per concurrently-live residual
    /// branch), NOT the layer count.
    pub n_slots: usize,
    /// Max f32 elements each slot ever holds (workspace presizing).
    pub slot_elems: Vec<usize>,
    /// Slot holding the final node's output (the logits); `usize::MAX`
    /// for an empty model.
    pub logits_slot: usize,
    /// Node count of the model this plan was compiled for.
    pub n_nodes: usize,
    /// Sorted node indices that carry a prepared `LayerState` — a plan
    /// is valid for any policy with this exact layer set (threshold
    /// re-plans reuse it).
    pub policied: Vec<usize>,
    /// Execution options the plan was compiled for (engine, threads,
    /// sparsity mode, oracle, tracing).
    pub opts: RunOpts,
    /// Model input elements (`h * w * c`).
    pub input_elems: usize,
    // ---- scratch high-water marks -------------------------------------
    /// Max filters over compute layers.
    pub max_cout: usize,
    /// Max dot length over compute layers (per-worker tile/gather
    /// buffers are presized from it; the kernel-aligned padding is
    /// derived via `pad_k` where needed).
    pub max_k_len: usize,
    /// Max `rows * cout` per sample over compute layers (global output
    /// buffer sizing).
    pub max_row_elems: usize,
    /// Max elements any compute layer quantizes (its input tensor).
    pub max_qt_elems: usize,
    /// Max dot length over *lane-enabled* layers (0 when the compressed
    /// lane builder never runs) — sizes the tile lane buffers without
    /// letting a dense-only giant layer inflate them, and without a
    /// lane-enabled layer being missed when a larger dense layer drives
    /// `max_k_len`.
    pub max_lanes_k_len: usize,
}

/// Compile `model` (+ the prepared `policy`, if any) into a
/// [`ModelPlan`] under `opts`. Cheap — one O(nodes²) walk over graph
/// metadata; no activation data is touched, and weight data only
/// through the shared prepack cache (forced once here when
/// `opts.weight_sparsity` is on, to read the frozen per-layer weight
/// densities) — so the unplanned entry points
/// ([`crate::predictor::exec::run_batch`]) compile per call; a
/// [`crate::session::Session`] compiles once at `finish()` and reuses
/// the plan for every request.
pub fn compile(model: &Model, policy: Option<&MorPolicy>, opts: RunOpts) -> ModelPlan {
    let n = model.nodes.len();
    let shapes = model.node_shapes();
    let relu_layers = model.relu_layers();

    // ---- liveness: last step that reads each node's output ------------
    let mut last_use: Vec<usize> = (0..n).collect();
    for (i, nd) in model.nodes.iter().enumerate() {
        if nd.consumes() >= 0 {
            let v = nd.consumes() as usize;
            last_use[v] = last_use[v].max(i);
        }
        if let Node::Conv { res_from: Some(r), .. } | Node::Fc { res_from: Some(r), .. } = nd {
            last_use[*r] = last_use[*r].max(i);
        }
    }
    if n > 0 {
        last_use[n - 1] = usize::MAX; // the logits are read after the walk
    }

    // ---- linear-scan slot assignment -----------------------------------
    let mut slot_of = vec![usize::MAX; n];
    let mut slot_elems: Vec<usize> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    for i in 0..n {
        let dst = free.pop().unwrap_or_else(|| {
            slot_elems.push(0);
            slot_elems.len() - 1
        });
        slot_of[i] = dst;
        let (h, w, c) = shapes[i];
        slot_elems[dst] = slot_elems[dst].max(h * w * c);
        // outputs whose last reader is step i die here; their slots are
        // reusable from step i+1 on (the output slot was taken first, so
        // a step never writes over its own still-live inputs)
        for v in 0..=i {
            if last_use[v] == i {
                free.push(slot_of[v]);
            }
        }
    }

    // ---- per-step freezing ---------------------------------------------
    let strategy = policy.map(|p| p.cfg.strategy);
    let mut steps = Vec::with_capacity(n);
    let mut max_cout = 0usize;
    let mut max_k_len = 0usize;
    let mut max_row_elems = 0usize;
    let mut max_qt_elems = 0usize;
    let mut max_lanes_k_len = 0usize;
    for (i, nd) in model.nodes.iter().enumerate() {
        let src = if nd.consumes() < 0 {
            Src::Input
        } else {
            Src::Slot(slot_of[nd.consumes() as usize])
        };
        let dst = slot_of[i];
        let step = match nd {
            Node::Conv { .. } | Node::Fc { .. } => {
                let (sh, sw2, sc) = if nd.consumes() < 0 {
                    model.input_shape
                } else {
                    shapes[nd.consumes() as usize]
                };
                let (geom, kh, kw, stride) = match nd {
                    Node::Conv { kh, kw, stride, pad_same, .. } => (
                        conv_geom(sh, sw2, *kh, *kw, *stride, *pad_same),
                        *kh,
                        *kw,
                        *stride,
                    ),
                    _ => (
                        ConvGeom { oh: sh, ow: sw2, pad_top: 0, pad_left: 0 },
                        0,
                        0,
                        1,
                    ),
                };
                let (sx, sw) = match nd {
                    Node::Conv { sx, sw, .. } | Node::Fc { sx, sw, .. } => (*sx, *sw),
                    _ => unreachable!(),
                };
                let res = match nd {
                    Node::Conv { res_from, .. } | Node::Fc { res_from, .. } => {
                        res_from.map(|r| slot_of[r])
                    }
                    _ => None,
                };
                let k_len = nd.k_len();
                let cout = nd.cout();
                let rows = geom.oh * geom.ow;
                let policied = policy.is_some_and(|p| p.layers.contains_key(&i));
                let lanes = opts.input_sparsity != InputSparsity::Off && k_len <= SPARSE_K_MAX;
                // pre-resolved per-row kernel decision (see field docs):
                // identical float compare to the unplanned path's
                // `sparse_wins(nnz, k_len)`
                let sparse_cutoff = match opts.input_sparsity {
                    InputSparsity::Off => 0.0,
                    InputSparsity::On => f32::INFINITY,
                    InputSparsity::Auto => {
                        opts.tune.input_cutoff * k_len.max(1) as f32
                    }
                };
                // weight side: density is a model constant, so the
                // kernel choice is per layer; reading it forces the
                // shared prepack cache only when the mode is on
                let w_sparse = opts.weight_sparsity != WeightSparsity::Off && {
                    let pf = model.prepacked().layer(i);
                    pf.has_lanes() && pf.density() < opts.tune.weight_cutoff
                };
                max_cout = max_cout.max(cout);
                max_k_len = max_k_len.max(k_len);
                max_row_elems = max_row_elems.max(rows * cout);
                max_qt_elems = max_qt_elems.max(sh * sw2 * sc);
                if lanes {
                    max_lanes_k_len = max_lanes_k_len.max(k_len);
                }
                StepPlan::Compute(ComputeStep {
                    node: i,
                    is_conv: matches!(nd, Node::Conv { .. }),
                    geom,
                    kh,
                    kw,
                    stride,
                    rows,
                    cout,
                    k_len,
                    k_pad: pad_k(k_len),
                    sx,
                    dq: sw * sx,
                    node_relu: nd.relu(),
                    is_relu_layer: relu_layers.contains(&i),
                    policied,
                    // the oracle strategy's skip accounting IS the ground
                    // truth: force it on so Fig-12 categories populate
                    oracle: opts.oracle || (policied && strategy == Some(Strategy::Oracle)),
                    lanes,
                    sparse_cutoff,
                    w_sparse,
                    src,
                    res,
                    dst,
                })
            }
            Node::MaxPool { size, .. } => StepPlan::MaxPool { node: i, size: *size, src, dst },
            Node::Gap { .. } => StepPlan::Gap { node: i, src, dst },
            Node::Relu { .. } => StepPlan::Relu { node: i, src, dst },
        };
        steps.push(step);
    }

    let (h, w, c) = model.input_shape;
    ModelPlan {
        steps,
        n_slots: slot_elems.len(),
        slot_elems,
        logits_slot: if n > 0 { slot_of[n - 1] } else { usize::MAX },
        n_nodes: n,
        policied: policy
            .map(|p| p.layers.keys().copied().collect())
            .unwrap_or_default(),
        opts,
        input_elems: h * w * c,
        max_cout,
        max_k_len,
        max_row_elems,
        max_qt_elems,
        max_lanes_k_len,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth;

    #[test]
    fn chain_model_uses_two_slots() {
        // 10 sequential nodes ping-pong between exactly two slots
        let m = synth::cnn10_like(5);
        let plan = compile(&m, None, RunOpts::default());
        assert_eq!(plan.steps.len(), m.nodes.len());
        assert_eq!(plan.n_slots, 2);
        assert!(plan.logits_slot < plan.n_slots);
        // slots are sized to the largest tensor they ever host
        let shapes = m.node_shapes();
        let biggest = shapes.iter().map(|&(h, w, c)| h * w * c).max().unwrap();
        assert_eq!(plan.slot_elems.iter().copied().max().unwrap(), biggest);
    }

    #[test]
    fn residual_branch_needs_a_third_slot() {
        // tiny_conv: node 1 (projection) stays live until node 3 reads it
        // as a residual while nodes 2..3 produce outputs — 3 live max
        let m = crate::model::testutil::tiny_conv(1);
        let plan = compile(&m, None, RunOpts::default());
        assert_eq!(plan.n_slots, 3);
        // the residual wiring resolves to node 1's slot
        let res = plan.steps.iter().find_map(|s| match s {
            StepPlan::Compute(c) if c.node == 3 => c.res,
            _ => None,
        });
        let slot1 = match &plan.steps[1] {
            StepPlan::Compute(c) => c.dst,
            _ => panic!("node 1 is a conv"),
        };
        assert_eq!(res, Some(slot1));
    }

    #[test]
    fn no_step_writes_over_a_live_input() {
        // every step's dst differs from its src and residual slots
        let mut rng = crate::util::rng::Rng::new(17);
        for _ in 0..30 {
            let m = synth::random_model(&mut rng);
            let plan = compile(&m, None, RunOpts::default());
            for step in &plan.steps {
                let (src, dst, res) = match step {
                    StepPlan::Compute(c) => (c.src, c.dst, c.res),
                    StepPlan::MaxPool { src, dst, .. }
                    | StepPlan::Gap { src, dst, .. }
                    | StepPlan::Relu { src, dst, .. } => (*src, *dst, None),
                };
                if let Src::Slot(k) = src {
                    assert_ne!(k, dst, "step would overwrite its own input");
                }
                if let Some(r) = res {
                    assert_ne!(r, dst, "step would overwrite its residual");
                }
                assert!(dst < plan.n_slots);
            }
        }
    }

    #[test]
    fn sparsity_decision_is_frozen_per_mode() {
        use crate::engine::InputSparsity;
        let m = synth::tiny_serving_model(2);
        for (mode, want_lanes) in [
            (InputSparsity::Off, false),
            (InputSparsity::On, true),
            (InputSparsity::Auto, true),
        ] {
            let plan = compile(
                &m,
                None,
                RunOpts { input_sparsity: mode, ..Default::default() },
            );
            for step in &plan.steps {
                if let StepPlan::Compute(c) = step {
                    assert_eq!(c.lanes, want_lanes, "mode {mode:?}");
                    match mode {
                        InputSparsity::Off => assert_eq!(c.sparse_cutoff, 0.0),
                        InputSparsity::On => assert_eq!(c.sparse_cutoff, f32::INFINITY),
                        // compared against the plan's own frozen profile
                        // (not a live crossover re-read — the tune
                        // profile is the single source now)
                        InputSparsity::Auto => assert_eq!(
                            c.sparse_cutoff,
                            plan.opts.tune.input_cutoff * c.k_len as f32
                        ),
                    }
                }
            }
            assert_eq!(plan.max_lanes_k_len > 0, want_lanes);
        }
    }

    #[test]
    fn weight_sparsity_decision_is_frozen_per_layer() {
        // Off never takes the weight-sparse kernels; Exact freezes the
        // per-layer choice from the prepacked density vs the crossover
        let dense = synth::tiny_serving_model(2);
        for ws in WeightSparsity::EXACT_MODES {
            let plan = compile(
                &dense,
                None,
                RunOpts { weight_sparsity: ws, ..Default::default() },
            );
            for step in &plan.steps {
                if let StepPlan::Compute(c) = step {
                    let want = ws != WeightSparsity::Off && {
                        let pf = dense.prepacked().layer(c.node);
                        pf.has_lanes() && pf.density() < plan.opts.tune.weight_cutoff
                    };
                    assert_eq!(c.w_sparse, want, "mode {ws:?} node {}", c.node);
                }
            }
        }
        // with 90% of the weight lanes zeroed every layer crosses under
        // the cutoff and the sparse kernels are baked in
        let mut sparse = synth::tiny_serving_model(2);
        synth::sparsify_weights(&mut sparse, 7, 90);
        let plan = compile(
            &sparse,
            None,
            RunOpts { weight_sparsity: WeightSparsity::Exact, ..Default::default() },
        );
        let mut n_compute = 0;
        for step in &plan.steps {
            if let StepPlan::Compute(c) = step {
                n_compute += 1;
                assert!(
                    c.w_sparse,
                    "node {} density {}",
                    c.node,
                    sparse.prepacked().layer(c.node).density()
                );
            }
        }
        assert!(n_compute >= 2);
    }
}
