//! Reusable forward-pass working memory ([`Workspace`]) and the
//! serve-time pool it is checked out of ([`WorkspacePool`]).
//!
//! A workspace owns every buffer the planned forward path writes:
//! activation slot tensors (the ping-pong buffers the
//! [`super::ModelPlan`]'s register allocation maps node outputs onto),
//! per-sample quantized inputs, the sample-major global output buffer
//! the row tiles write into, optional trace planes, and one
//! [`WorkerScratch`] per row-tile worker thread (im2col gather buffers,
//! the [`PatchTile`], dot/skip/survivor scratch, per-sample stats).
//!
//! Buffers grow to the plan's high-water marks on first use and never
//! shrink, so after warmup [`super::execute_into`] performs **zero**
//! heap allocations (single-threaded, non-tracing configuration — the
//! serving default); `rust/tests/plan_contracts.rs` proves it with a
//! counting allocator.
//!
//! ```
//! use mor::model::synth;
//! use mor::plan::{self, Workspace};
//! use mor::predictor::RunOpts;
//!
//! let model = synth::tiny_serving_model(1);
//! let plan = plan::compile(&model, None, RunOpts::default());
//! // one workspace serves any number of forwards; buffers are reused
//! let mut ws = Workspace::for_plan(&plan, 2);
//! let (h, w, c) = model.input_shape;
//! let xs: Vec<Vec<f32>> = (0..2).map(|i| vec![0.2 * i as f32; h * w * c]).collect();
//! let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
//! let r1 = plan::execute(&plan, &model, None, &mut ws, &inputs);
//! let r2 = plan::execute(&plan, &model, None, &mut ws, &inputs);
//! assert_eq!(r1[0].logits, r2[0].logits);
//! assert!(ws.heap_bytes() > 0);
//! ```

use super::compile::ModelPlan;
use crate::engine::gemm::{PatchTile, TILE_ROWS};
use crate::engine::{PatchGather, QuantizedTensor, Tensor};
use crate::predictor::{OpsStats, PredStats, RunResult};
use crate::util::bits::PackedVec;
use crate::util::reserve_capacity;
use crate::util::sync::{AtomicUsize, Mutex, Ordering};
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

/// Per-worker (per row-tile thread) scratch: everything one
/// `process_row_range` invocation writes besides the output rows.
/// Formerly reallocated on every call (`ri_cache`, `skip`, `applied`,
/// `survivors` in the old `exec::process_row_range`); now owned here
/// and re-dimensioned per layer without freeing.
pub struct WorkerScratch {
    /// im2col patch gather buffers (patch, packed ±1 plane, nnz).
    pub gather: PatchGather,
    /// The row tile (patches, packed planes, compressed lanes).
    pub tile: PatchTile,
    /// Per-tile dot products, `TILE_ROWS * cout`.
    pub dots: Vec<i32>,
    /// Current row's proxy ReLU inputs (cluster strategies).
    pub ri_cache: Vec<f32>,
    /// Current row's skip verdicts.
    pub skip: Vec<bool>,
    /// Current row's "predictor applied" flags.
    pub applied: Vec<bool>,
    /// Current row's surviving filters, in evaluation order.
    pub survivors: Vec<usize>,
    /// This range's per-sample stats share (merged by the caller in
    /// deterministic range order).
    pub pred: Vec<PredStats>,
    pub ops: Vec<OpsStats>,
}

impl WorkerScratch {
    fn new() -> WorkerScratch {
        WorkerScratch {
            gather: PatchGather::new(),
            tile: PatchTile::empty(),
            dots: Vec::new(),
            ri_cache: Vec::new(),
            skip: Vec::new(),
            applied: Vec::new(),
            survivors: Vec::new(),
            pred: Vec::new(),
            ops: Vec::new(),
        }
    }

    fn heap_bytes(&self) -> usize {
        self.gather.patch.capacity()
            + packed_bytes(&self.gather.packed)
            + self.gather.nzmask.capacity() * 8
            + self.tile.heap_bytes()
            + self.dots.capacity() * 4
            + self.ri_cache.capacity() * 4
            + self.skip.capacity()
            + self.applied.capacity()
            + self.survivors.capacity() * std::mem::size_of::<usize>()
            + self.pred.capacity() * std::mem::size_of::<PredStats>()
            + self.ops.capacity() * std::mem::size_of::<OpsStats>()
    }
}

fn packed_bytes(p: &PackedVec) -> usize {
    (p.bits.capacity() + p.valid.capacity()) * 8
}

/// One forward pass's working memory. See the module docs; created
/// empty ([`Workspace::new`]) or presized ([`Workspace::for_plan`]),
/// checked out of a [`WorkspacePool`] on the serve path.
pub struct Workspace {
    /// Per-sample copy of the model input (the graph's `consumes: -1`
    /// source tensor).
    pub(crate) input: Vec<Tensor>,
    /// Activation slot tensors, sample-major: slot `k` of sample `s`
    /// lives at `s * plan.n_slots + k`. Only `plan.n_slots` tensors per
    /// sample are ever live — the plan's liveness analysis keeps peak
    /// live tensors O(1) in the layer count.
    pub(crate) slots: Vec<Tensor>,
    /// Per-sample quantized layer input (requantized per layer).
    pub(crate) qts: Vec<QuantizedTensor>,
    /// Sample-major global output rows of the current layer.
    pub(crate) out: Vec<f32>,
    /// Trace planes (only sized when the plan collects traces).
    pub(crate) skipped: Vec<bool>,
    pub(crate) bin_eval: Vec<bool>,
    /// Per-sample stats accumulators for the whole forward.
    pub(crate) pred: Vec<PredStats>,
    pub(crate) ops: Vec<OpsStats>,
    /// Worker row-range list (threaded path).
    pub(crate) ranges: Vec<(usize, usize)>,
    /// One scratch per row-tile worker.
    pub(crate) workers: Vec<WorkerScratch>,
    /// Warmed `RunResult` envelopes parked here when a caller-reused
    /// results vector shrinks to a smaller batch — a later larger batch
    /// takes them back instead of allocating, so serve loops with
    /// fluctuating micro-batch sizes stay allocation-free too.
    pub(crate) spare_results: Vec<RunResult>,
}

impl Default for Workspace {
    fn default() -> Self {
        Self::new()
    }
}

impl Workspace {
    /// An empty workspace (no heap allocation); buffers grow to the
    /// plan's high-water marks on first use.
    pub fn new() -> Workspace {
        Workspace {
            input: Vec::new(),
            slots: Vec::new(),
            qts: Vec::new(),
            out: Vec::new(),
            skipped: Vec::new(),
            bin_eval: Vec::new(),
            pred: Vec::new(),
            ops: Vec::new(),
            ranges: Vec::new(),
            workers: Vec::new(),
            spare_results: Vec::new(),
        }
    }

    /// A workspace pre-grown to `plan`'s exact scratch requirements for
    /// batches up to `batch` — the first forward is already
    /// allocation-free.
    pub fn for_plan(plan: &ModelPlan, batch: usize) -> Workspace {
        let mut ws = Workspace::new();
        ws.prepare(plan, batch);
        ws
    }

    /// Grow every buffer to `plan`'s high-water marks for a batch of
    /// `batch` samples. Idempotent and allocation-free once the sizes
    /// have been reached; called by [`super::execute_into`] on entry.
    pub fn prepare(&mut self, plan: &ModelPlan, batch: usize) {
        if self.input.len() < batch {
            self.input.resize_with(batch, || Tensor::new(0, 0, 0));
        }
        if self.qts.len() < batch {
            self.qts.resize_with(batch, QuantizedTensor::empty);
        }
        let want_slots = batch * plan.n_slots;
        if self.slots.len() < want_slots {
            self.slots.resize_with(want_slots, || Tensor::new(0, 0, 0));
        }
        for s in 0..batch {
            reserve_capacity(&mut self.input[s].data, plan.input_elems);
            reserve_capacity(&mut self.qts[s].q, plan.max_qt_elems);
            for (k, &elems) in plan.slot_elems.iter().enumerate() {
                reserve_capacity(&mut self.slots[s * plan.n_slots + k].data, elems);
            }
        }
        reserve_capacity(&mut self.out, batch * plan.max_row_elems);
        if plan.opts.collect_trace {
            reserve_capacity(&mut self.skipped, batch * plan.max_row_elems);
            reserve_capacity(&mut self.bin_eval, batch * plan.max_row_elems);
        }
        reserve_capacity(&mut self.pred, batch);
        reserve_capacity(&mut self.ops, batch);
        // parked result envelopes never outnumber the largest batch seen
        reserve_capacity(&mut self.spare_results, batch);
        let n_workers = plan.opts.threads.max(1);
        if self.workers.len() < n_workers {
            self.workers.resize_with(n_workers, WorkerScratch::new);
        }
        reserve_capacity(&mut self.ranges, n_workers);
        for w in &mut self.workers[..n_workers] {
            // capacity-only growth: the per-layer `tile.reset` inside the
            // row loop re-dimensions it; here we just make sure that
            // reset never allocates (lane buffers sized from the largest
            // lane-enabled layer, not a dense-only giant)
            w.tile.reserve(plan.max_k_len, plan.max_lanes_k_len);
            w.gather.reserve(plan.max_k_len);
            reserve_capacity(&mut w.dots, TILE_ROWS * plan.max_cout);
            reserve_capacity(&mut w.ri_cache, plan.max_cout);
            reserve_capacity(&mut w.skip, plan.max_cout);
            reserve_capacity(&mut w.applied, plan.max_cout);
            reserve_capacity(&mut w.survivors, plan.max_cout);
            reserve_capacity(&mut w.pred, batch);
            reserve_capacity(&mut w.ops, batch);
        }
    }

    /// Total heap bytes currently held by this workspace's buffers —
    /// the "workspace bytes per worker" figure `BENCH_hotpaths.json`
    /// reports.
    pub fn heap_bytes(&self) -> usize {
        let tensors = |ts: &[Tensor]| ts.iter().map(|t| t.data.capacity() * 4).sum::<usize>();
        tensors(&self.input)
            + tensors(&self.slots)
            + self.qts.iter().map(|q| q.q.capacity()).sum::<usize>()
            + self.out.capacity() * 4
            + self.skipped.capacity()
            + self.bin_eval.capacity()
            + self.pred.capacity() * std::mem::size_of::<PredStats>()
            + self.ops.capacity() * std::mem::size_of::<OpsStats>()
            + self.ranges.capacity() * std::mem::size_of::<(usize, usize)>()
            + self.workers.iter().map(|w| w.heap_bytes()).sum::<usize>()
            + self.spare_results.capacity() * std::mem::size_of::<RunResult>()
            + self
                .spare_results
                .iter()
                .map(|r| r.logits.capacity() * 4)
                .sum::<usize>()
    }
}

/// A grow-on-demand pool of [`Workspace`]s, owned by a
/// [`crate::session::Session`] and shared (behind an `Arc`) with the
/// serving coordinator's workers. `checkout` never blocks: when the
/// free list is empty a fresh workspace is created, so the pool grows
/// to the peak concurrency and then stabilizes — each serve worker
/// checks one out for its whole lifetime and returns it on drop.
///
/// The pool invariants are pinned as `debug_assert!`s *inside* the
/// implementation (not just in tests), so the loom model
/// (`rust/tests/loom_models.rs`), the unit tests and every debug build
/// check the same properties: at all times `outstanding <= created`
/// (the pool grows to the peak concurrency exactly once — a checkout
/// can never observe more live guards than workspaces ever created),
/// and the free list never holds more workspaces than were created (a
/// double return — the aliasing bug — would trip it). The sync types
/// come from [`crate::util::sync`] so `--cfg loom` explores every
/// interleaving of these paths.
pub struct WorkspacePool {
    free: Mutex<Vec<Workspace>>,
    created: AtomicUsize,
    /// Guards currently live (checkouts minus returns) — only consulted
    /// by the invariant asserts; `SeqCst` keeps the counters' total
    /// order consistent so the asserts cannot fire spuriously (checkout
    /// is once per worker lifetime, never hot).
    outstanding: AtomicUsize,
}

impl Default for WorkspacePool {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkspacePool {
    pub fn new() -> WorkspacePool {
        WorkspacePool {
            free: Mutex::new(Vec::new()),
            created: AtomicUsize::new(0),
            outstanding: AtomicUsize::new(0),
        }
    }

    /// Check a workspace out of `pool` (creating one if the free list
    /// is empty). The guard returns it on drop; while held, the
    /// workspace is exclusively owned — no aliasing between concurrent
    /// workers.
    pub fn checkout(pool: &Arc<WorkspacePool>) -> PooledWorkspace {
        let reused = pool.free.lock().expect("workspace pool poisoned").pop();
        let ws = reused.unwrap_or_else(|| {
            pool.created.fetch_add(1, Ordering::SeqCst);
            Workspace::new()
        });
        // counted after `created`: a guard either reuses a returned
        // workspace (its drop decremented `outstanding` before pushing
        // it back) or created a fresh one above, so the live-guard count
        // can never exceed the created count
        let before = pool.outstanding.fetch_add(1, Ordering::SeqCst);
        debug_assert!(
            before < pool.created.load(Ordering::SeqCst),
            "workspace pool invariant: {} guards live with only {} workspaces ever created",
            before + 1,
            pool.created.load(Ordering::SeqCst)
        );
        PooledWorkspace {
            ws: Some(ws),
            pool: Arc::clone(pool),
        }
    }

    /// Workspaces ever created by this pool (= peak concurrent checkouts).
    pub fn created(&self) -> usize {
        self.created.load(Ordering::SeqCst)
    }

    /// Workspaces currently idle in the free list.
    pub fn available(&self) -> usize {
        self.free.lock().expect("workspace pool poisoned").len()
    }
}

/// An exclusively-held workspace; dereferences to [`Workspace`] and
/// returns itself to the pool on drop.
pub struct PooledWorkspace {
    ws: Option<Workspace>,
    pool: Arc<WorkspacePool>,
}

impl Deref for PooledWorkspace {
    type Target = Workspace;
    fn deref(&self) -> &Workspace {
        self.ws.as_ref().expect("workspace taken")
    }
}

impl DerefMut for PooledWorkspace {
    fn deref_mut(&mut self) -> &mut Workspace {
        self.ws.as_mut().expect("workspace taken")
    }
}

impl Drop for PooledWorkspace {
    fn drop(&mut self) {
        if let Some(ws) = self.ws.take() {
            // decrement BEFORE the workspace reappears on the free list:
            // once pushed it can be checked out again immediately, and
            // counting the return late would let that checkout observe
            // `outstanding > created` and trip the invariant spuriously
            let prev = self.pool.outstanding.fetch_sub(1, Ordering::SeqCst);
            debug_assert!(prev >= 1, "workspace returned with no guards outstanding");
            // a poisoned pool only loses the workspace, never panics in drop
            if let Ok(mut free) = self.pool.free.lock() {
                debug_assert!(
                    free.len() < self.pool.created.load(Ordering::SeqCst),
                    "workspace pool invariant: returning to a full free list \
                     (double return / aliased workspace)"
                );
                free.push(ws);
            }
        }
    }
}
