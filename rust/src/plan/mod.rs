//! Compiled layer plans + reusable workspaces: the zero-allocation
//! steady-state forward path.
//!
//! The tiled engine used to re-derive per-layer decisions and
//! re-allocate its working memory on every request: output tensors,
//! quantized activations, im2col tiles, dot/skip/survivor scratch and
//! trace buffers were rebuilt per `run_batch` call, and each layer
//! re-resolved geometry, strategy state and the sparse-vs-dense kernel
//! choice at runtime. Hardware proposals like Mixture-of-Rookies fix
//! the dataflow up front so the per-inference work is only the
//! effectual math; this module is the software analogue — a
//! plan/execute split:
//!
//! * [`compile()`] freezes the model into a [`ModelPlan`]: per-layer
//!   [`ComputeStep`]s with resolved geometry, residual/graph wiring
//!   (as ping-pong activation-slot indices from a liveness analysis,
//!   so peak live tensors per sample is O(1), not O(layers)), the
//!   input-sparsity decision with `auto`'s cutoff pre-resolved per
//!   layer, and exact scratch high-water marks.
//! * [`Workspace`] owns every buffer the forward writes, grown once to
//!   the plan's marks and reused forever; [`WorkspacePool`] hands
//!   workspaces to serve workers (one checkout per worker lifetime,
//!   grows under contention, no aliasing).
//! * [`execute()`] / [`execute_into`] run the batch-native tile loop over
//!   (plan, workspace) — bit-identical to the `EngineSel::ScalarRef`
//!   oracle, and **zero heap allocations** after warmup in the
//!   single-threaded non-tracing serving configuration.
//!
//! [`crate::predictor::exec::run_batch`] compiles a throwaway plan per
//! call (the correctness path the equivalence suites drive);
//! [`crate::session::Session`] compiles once at `finish()`, owns the
//! pool, and re-uses the plan across requests — and across threshold
//! sweeps, since a re-thresholded policy keeps the same layer set.
//!
//! * [`verify()`] statically checks a frozen plan against its model —
//!   slot liveness and residual wiring, scratch-mark domination, the
//!   frozen sparsity/policy decisions — without executing a step. It
//!   backs the `mor lint` subcommand, runs automatically in debug
//!   builds at `Session::finish()`, and its mutation suite
//!   (`tests/plan_verify.rs`) proves each invariant is actually
//!   enforced.
//! * [`ranges::analyze`] is the *numeric* counterpart: an
//!   abstract-interpretation pass that propagates value intervals
//!   through the plan using the actual prepacked weights and proves
//!   accumulator non-overflow (`num.acc`), requantization range safety
//!   (`num.requant`) and predictor-threshold soundness
//!   (`num.threshold`) per compute site — `mor lint --numeric`, also
//!   run in debug builds at `Session::finish()`. The [`observe`] hook
//!   lets the numeric property suite (`tests/numeric_ranges.rs`) check
//!   observed runtime values against the proven intervals.
//!
//! See EXPERIMENTS.md §Plan for the sizing rules and how a new layer
//! kind registers a step, §Lint for the verifier's invariant
//! catalogue, and §Numeric for the abstract domain and per-site bound
//! derivations.

pub mod compile;
pub mod execute;
pub mod observe;
pub mod ranges;
pub mod verify;
pub mod workspace;

pub use compile::{compile, ComputeStep, ModelPlan, Src, StepPlan};
pub use execute::{execute, execute_into};
pub use ranges::{NumericOpts, NumericReport, StepRanges};
pub use verify::{verify, verify_with, Finding, LintReport, Severity};
pub use workspace::{PooledWorkspace, WorkerScratch, Workspace, WorkspacePool};
