//! Build probe: AVX-512 intrinsics (`_mm512_dpbusd_epi32` and friends)
//! stabilized in rustc 1.89. The crate's MSRV is 1.77, so the VNNI
//! kernels are compiled only when the active toolchain is new enough —
//! `cfg(mor_avx512)` gates them, and the runtime dispatch
//! (`engine::isa`) tops out at AVX2 on older compilers. A probe failure
//! (unparseable `rustc --version`) conservatively disables the cfg.

use std::process::Command;

fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc 2025-08-01)" → 89; tolerate channel suffixes
    let ver = text.split_whitespace().nth(1)?;
    let minor = ver.split('.').nth(1)?;
    let minor = minor.split(|c: char| !c.is_ascii_digit()).next()?;
    minor.parse().ok()
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    // always declare the cfg so -D warnings builds accept it either way
    println!("cargo:rustc-check-cfg=cfg(mor_avx512)");
    if rustc_minor().is_some_and(|m| m >= 89) {
        println!("cargo:rustc-cfg=mor_avx512");
    }
}
