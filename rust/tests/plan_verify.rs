//! Mutation suite for the static plan verifier (`mor::plan::verify`,
//! surfaced as `mor lint` — see EXPERIMENTS.md §Lint).
//!
//! Two halves:
//!
//! * **Pristine plans lint clean** — every synthetic model generator ×
//!   every input-sparsity mode × every exact weight-sparsity mode ×
//!   {no policy, MoR policy} compiles to a plan with zero findings.
//!   This is what lets `Session::finish()` assert cleanliness in debug
//!   builds without false positives.
//! * **Each invariant is actually enforced** — we corrupt a compiled
//!   plan one field at a time (all `ModelPlan`/`ComputeStep` fields are
//!   public precisely so this suite and the bench harnesses can poke
//!   them) and assert the verifier reports the *right* diagnostic code,
//!   not merely "something". A verifier that flags everything as one
//!   generic error would pass a weaker test and be useless for
//!   triaging; pinning codes keeps the catalogue honest.

use mor::config::PredictorConfig;
use mor::engine::{InputSparsity, WeightSparsity};
use mor::model::{synth, Model, Node};
use mor::plan::{self, Src, StepPlan};
use mor::predictor::{MorPolicy, RunOpts};
use mor::util::rng::Rng;

// ---- helpers ---------------------------------------------------------------

fn opts(is: InputSparsity, ws: WeightSparsity) -> RunOpts {
    RunOpts { input_sparsity: is, weight_sparsity: ws, ..Default::default() }
}

fn policy_for(model: &Model, seed: u64) -> MorPolicy {
    let params = synth::predictor_for(model, seed);
    MorPolicy::new(model, &params, PredictorConfig::default())
}

/// A 4-node FC model with one residual edge: node 2 adds node 0's
/// output. Liveness peaks at 3 (nodes 0, 1 live while 2 is produced),
/// so the linear scan allocates three slots — enough room to corrupt
/// reads without also tripping the self-overwrite check.
/// (`model::testutil::tiny_conv` is `cfg(test)`-gated inside the crate,
/// so integration tests build their residual model by hand.)
fn residual_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let mut fc = |cin: usize, cout: usize, consumes: i32, res_from: Option<usize>| Node::Fc {
        cin,
        cout,
        sw: 0.02,
        sx: 1.0 / 127.0,
        w: (0..cin * cout).map(|_| rng.int8()).collect(),
        bn: None,
        relu: true,
        res_from,
        consumes,
    };
    let nodes = vec![
        fc(8, 8, -1, None),
        fc(8, 8, 0, None),
        fc(8, 8, 1, Some(0)),
        fc(8, 4, 2, None),
    ];
    Model::new("residual_fc".into(), 1.0 / 127.0, (1, 1, 8), nodes)
}

/// Corrupt the first compute step of `plan` in place.
fn mutate_first_compute(plan: &mut plan::ModelPlan, f: impl FnOnce(&mut plan::ComputeStep)) {
    let c = plan
        .steps
        .iter_mut()
        .find_map(|s| match s {
            StepPlan::Compute(c) => Some(c),
            _ => None,
        })
        .expect("model has at least one compute step");
    f(c);
}

// ---- pristine plans lint clean --------------------------------------------

#[test]
fn every_pristine_synthetic_model_lints_clean() {
    let mut zoo = vec![
        synth::cnn10_like(7),
        synth::tiny_serving_model(7),
        residual_model(7),
    ];
    let mut sparse = synth::tiny_serving_model(7);
    synth::sparsify_weights(&mut sparse, 7, 90);
    zoo.push(sparse);
    let mut rng = Rng::new(71);
    zoo.extend((0..12).map(|_| synth::random_model(&mut rng)));

    for model in &zoo {
        let policy = policy_for(model, 11);
        for is in InputSparsity::ALL {
            for ws in WeightSparsity::EXACT_MODES {
                for pol in [None, Some(&policy)] {
                    let plan = plan::compile(model, pol, opts(is, ws));
                    let report = plan::verify(&plan, model, pol);
                    assert!(
                        report.is_clean(),
                        "[{}] is={is:?} ws={ws:?} policy={}: {report}",
                        model.name,
                        pol.is_some()
                    );
                }
            }
        }
    }
}

#[test]
fn oracle_and_trace_opts_lint_clean_too() {
    // the oracle flag and trace collection change frozen fields — the
    // verifier must re-derive them from opts, not assume defaults
    let model = synth::tiny_serving_model(3);
    let policy = policy_for(&model, 3);
    let o = RunOpts { oracle: true, collect_trace: true, ..Default::default() };
    let plan = plan::compile(&model, Some(&policy), o);
    let report = plan::verify(&plan, &model, Some(&policy));
    assert!(report.is_clean(), "{report}");
}

// ---- structural corruptions: slots ----------------------------------------

#[test]
fn out_of_range_dst_slot_is_flagged() {
    let model = synth::cnn10_like(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.dst = 99);
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.range"), "{report}");
    assert!(report.errors() > 0);
}

#[test]
fn self_overwriting_step_is_flagged() {
    let model = synth::cnn10_like(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    // point a mid-chain step's src at its own dst
    let c = plan
        .steps
        .iter_mut()
        .filter_map(|s| match s {
            StepPlan::Compute(c) if matches!(c.src, Src::Slot(_)) => Some(c),
            _ => None,
        })
        .next()
        .expect("a compute step reads a slot");
    c.src = Src::Slot(c.dst);
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.self-overwrite"), "{report}");
}

#[test]
fn read_before_write_is_flagged() {
    let model = residual_model(9);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    assert_eq!(plan.n_slots, 3, "residual model should need 3 slots");
    // step 1 reads node 0's slot; redirect it at the slot that is only
    // written later, by step 2 — a read of uninitialized memory
    let (dst2, src1_dst) = match (&plan.steps[2], &plan.steps[1]) {
        (StepPlan::Compute(c2), StepPlan::Compute(c1)) => (c2.dst, c1.dst),
        _ => panic!("FC nodes compile to compute steps"),
    };
    assert_ne!(dst2, src1_dst);
    if let StepPlan::Compute(c) = &mut plan.steps[1] {
        c.src = Src::Slot(dst2);
    }
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.read-before-write"), "{report}");
}

#[test]
fn aliased_live_tensor_is_flagged() {
    let model = residual_model(9);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    // the last step consumes node 2; point it at node 0's slot instead —
    // a live tensor is still there, but it is the *wrong* one
    let slot0 = match &plan.steps[0] {
        StepPlan::Compute(c) => c.dst,
        _ => panic!(),
    };
    if let StepPlan::Compute(c) = &mut plan.steps[3] {
        assert_ne!(slot0, c.dst);
        c.src = Src::Slot(slot0);
    }
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.aliased"), "{report}");
}

#[test]
fn wrong_src_kind_is_flagged() {
    let model = synth::cnn10_like(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    // step 0 consumes the model input; claim it reads a slot instead
    mutate_first_compute(&mut plan, |c| c.src = Src::Slot(0));
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.src-kind"), "{report}");
}

#[test]
fn broken_residual_wiring_is_flagged() {
    let model = residual_model(9);

    // dropped residual edge
    let mut plan = plan::compile(&model, None, RunOpts::default());
    if let StepPlan::Compute(c) = &mut plan.steps[2] {
        assert!(c.res.is_some());
        c.res = None;
    }
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.residual"), "dropped edge: {report}");

    // residual pointed at the wrong producer's slot
    let mut plan = plan::compile(&model, None, RunOpts::default());
    let slot1 = match &plan.steps[1] {
        StepPlan::Compute(c) => c.dst,
        _ => panic!(),
    };
    if let StepPlan::Compute(c) = &mut plan.steps[2] {
        assert_ne!(c.res, Some(slot1));
        c.res = Some(slot1);
    }
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.residual"), "wrong producer: {report}");
}

#[test]
fn undersized_slot_is_flagged() {
    let model = synth::cnn10_like(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    let dst = match &plan.steps[0] {
        StepPlan::Compute(c) => c.dst,
        _ => panic!(),
    };
    plan.slot_elems[dst] = 1;
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.undersized"), "{report}");
}

#[test]
fn excess_slots_are_a_warning_not_an_error() {
    let model = synth::cnn10_like(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    plan.n_slots += 2;
    plan.slot_elems.push(64);
    plan.slot_elems.push(64);
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.excess"), "{report}");
    assert_eq!(report.errors(), 0, "waste is a warning: {report}");
    assert!(report.warnings() > 0);
    assert!(!report.is_clean());
}

#[test]
fn corrupted_logits_slot_is_flagged() {
    let model = synth::cnn10_like(5);

    // out of range
    let mut plan = plan::compile(&model, None, RunOpts::default());
    plan.logits_slot = 77;
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.logits"), "{report}");

    // in range but holding a stale tensor
    let mut plan = plan::compile(&model, None, RunOpts::default());
    let wrong = (0..plan.n_slots)
        .find(|&s| s != plan.logits_slot)
        .expect("two slots");
    plan.logits_slot = wrong;
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("slot.logits"), "{report}");
}

// ---- scratch high-water marks ---------------------------------------------

#[test]
fn undersized_scratch_marks_are_errors_oversized_are_warnings() {
    let model = synth::cnn10_like(5);
    let corruptions: [(&str, fn(&mut plan::ModelPlan)); 5] = [
        ("scratch.cout", |p| p.max_cout = 0),
        ("scratch.k-len", |p| p.max_k_len = 0),
        ("scratch.rows", |p| p.max_row_elems = 0),
        ("scratch.qt", |p| p.max_qt_elems = 0),
        ("scratch.lanes", |p| p.max_lanes_k_len = 0),
    ];
    for (name, corrupt) in corruptions {
        let mut plan = plan::compile(&model, None, RunOpts::default());
        corrupt(&mut plan);
        let report = plan::verify(&plan, &model, None);
        assert!(report.has(name), "{name}: {report}");
        assert!(report.errors() > 0, "{name} undersized must be an error");
    }
    // oversizing wastes memory but cannot misindex: warning only
    let mut plan = plan::compile(&model, None, RunOpts::default());
    plan.max_qt_elems *= 2;
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("scratch.qt"), "{report}");
    assert_eq!(report.errors(), 0, "{report}");
}

// ---- frozen geometry -------------------------------------------------------

#[test]
fn corrupted_geometry_is_flagged() {
    let model = synth::cnn10_like(5);

    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.geom.oh += 1);
    assert!(plan::verify(&plan, &model, None).has("geom.shape"));

    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.rows += 3);
    assert!(plan::verify(&plan, &model, None).has("geom.rows"));

    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.cout += 1);
    assert!(plan::verify(&plan, &model, None).has("geom.cout"));

    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.sx *= 2.0);
    assert!(plan::verify(&plan, &model, None).has("geom.scale"));

    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.node_relu = !c.node_relu);
    assert!(plan::verify(&plan, &model, None).has("geom.relu"));
}

#[test]
fn kernel_alignment_contract_is_enforced() {
    // k_pad feeds the AVX2 block kernel's # Safety contract (every
    // filter pointer addresses k_pad bytes, a multiple of K_ALIGN) —
    // an unaligned or undersized pad must be an error
    let model = synth::tiny_serving_model(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.k_pad -= 1);
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("geom.k-pad"), "{report}");
    assert!(report.errors() > 0);
}

#[test]
fn corrupted_k_len_breaks_the_mac_partition_identity() {
    let model = synth::tiny_serving_model(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.k_len += 8);
    let report = plan::verify(&plan, &model, None);
    // the corrupted dot length is caught both as a geometry mismatch and
    // as a violation of (total-done)+input_zero+weight_zero+effectual
    assert!(report.has("geom.k-len"), "{report}");
    assert!(report.has("mac.partition"), "{report}");
}

// ---- frozen sparsity decisions --------------------------------------------

#[test]
fn lane_builder_under_off_mode_is_flagged() {
    let model = synth::tiny_serving_model(5);
    let mut plan = plan::compile(&model, None, opts(InputSparsity::Off, WeightSparsity::Off));
    mutate_first_compute(&mut plan, |c| c.lanes = true);
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("sparsity.lanes"), "{report}");
}

#[test]
fn wrong_auto_cutoff_is_flagged() {
    let model = synth::tiny_serving_model(5);
    let mut plan = plan::compile(&model, None, opts(InputSparsity::Auto, WeightSparsity::Off));
    mutate_first_compute(&mut plan, |c| c.sparse_cutoff *= 0.5);
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("sparsity.cutoff"), "{report}");
}

#[test]
fn weight_sparse_kernel_under_off_mode_is_flagged() {
    let model = synth::tiny_serving_model(5);
    let mut plan = plan::compile(&model, None, opts(InputSparsity::Auto, WeightSparsity::Off));
    mutate_first_compute(&mut plan, |c| c.w_sparse = true);
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("sparsity.weight"), "{report}");
}

#[test]
fn weight_sparse_flag_must_match_the_frozen_density() {
    // 90% zeroed weights cross the density cutoff on every layer; a
    // plan claiming dense kernels under Exact contradicts the crossover
    let mut model = synth::tiny_serving_model(5);
    synth::sparsify_weights(&mut model, 7, 90);
    let mut plan = plan::compile(&model, None, opts(InputSparsity::Auto, WeightSparsity::Exact));
    let mut saw_sparse = false;
    for s in &mut plan.steps {
        if let StepPlan::Compute(c) = s {
            saw_sparse |= c.w_sparse;
            c.w_sparse = false;
        }
    }
    assert!(saw_sparse, "sparsified model should freeze sparse kernels");
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("sparsity.weight"), "{report}");
}

// ---- policy wiring ---------------------------------------------------------

#[test]
fn flipped_oracle_flag_is_flagged() {
    let model = synth::tiny_serving_model(5);
    let policy = policy_for(&model, 5);
    let mut plan = plan::compile(&model, Some(&policy), RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.oracle = !c.oracle);
    let report = plan::verify(&plan, &model, Some(&policy));
    assert!(report.has("policy.oracle"), "{report}");
}

#[test]
fn tampered_policied_set_is_flagged() {
    let model = synth::tiny_serving_model(5);
    let policy = policy_for(&model, 5);
    let mut plan = plan::compile(&model, Some(&policy), RunOpts::default());
    assert!(!plan.policied.is_empty(), "MoR policy prepares layers");
    // per-step flag
    let dropped = plan.policied[0];
    if let StepPlan::Compute(c) = &mut plan.steps[dropped] {
        assert!(c.policied);
        c.policied = false;
    }
    let report = plan::verify(&plan, &model, Some(&policy));
    assert!(report.has("policy.set"), "step flag: {report}");
    // plan-level set
    let mut plan = plan::compile(&model, Some(&policy), RunOpts::default());
    plan.policied.pop();
    let report = plan::verify(&plan, &model, Some(&policy));
    assert!(report.has("policy.set"), "layer set: {report}");
}

// ---- plan/model correspondence --------------------------------------------

#[test]
fn truncated_plan_is_flagged_and_short_circuits() {
    let model = synth::cnn10_like(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    plan.steps.pop();
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("plan.nodes"), "{report}");
    // nothing else should pile on — the walk is abandoned
    assert_eq!(report.findings.len(), 1, "{report}");
}

#[test]
fn wrong_node_index_is_flagged() {
    let model = synth::cnn10_like(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.node += 1);
    let report = plan::verify(&plan, &model, None);
    assert!(report.has("plan.node-index"), "{report}");
}

#[test]
fn report_json_is_machine_readable() {
    let model = synth::cnn10_like(5);
    let mut plan = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut plan, |c| c.dst = 99);
    let report = plan::verify(&plan, &model, None);
    let json = report.to_json().to_string();
    let parsed = mor::util::json::Json::parse(&json).expect("valid json");
    match parsed {
        mor::util::json::Json::Arr(items) => assert!(!items.is_empty()),
        other => panic!("expected an array, got {other:?}"),
    }
}
