//! Strategy-contract property suite: every named [`Strategy`] must obey
//! its documented contract on random `synth` models, across both
//! engines, thread counts and batch sizes.
//!
//! * `none` — skips nothing; bit-identical to running with no policy.
//! * `oracle` — `incorrect_zero == 0` and `incorrect_nonzero == 0` by
//!   construction; logits bit-identical to the dense forward; skips
//!   exactly the predictable layers' true zeros.
//! * `mor` — bit-identical across `EngineSel` variants and batch sizes
//!   1..16 (the scalar per-neuron path is the retained pre-refactor
//!   decision code, so scalar-vs-tiled identity pins the strategy
//!   implementation to the golden behaviour).
//! * `binary` — only T-enabled neurons are ever skipped.
//! * `cluster` — proxies are never skipped; the hybrid's skip set is a
//!   subset of the cluster strategy's (both components must agree).
//!
//! Runs fully offline — models come from `mor::model::synth`, no
//! `make artifacts` needed. CI runs one `contract_<name>` filter per
//! matrix leg.

use mor::config::PredictorConfig;
use mor::model::synth;
use mor::predictor::strategies::Strategy;
use mor::predictor::{EngineSel, MorPolicy, RunOpts, RunResult};
use mor::session::Session;
use mor::util::prop::property;
use mor::util::rng::Rng;

fn rand_input(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

fn diff(want: &RunResult, got: &RunResult) -> Option<String> {
    if want.logits != got.logits {
        return Some("logits differ".into());
    }
    if want.pred != got.pred {
        return Some(format!("pred stats differ: want {:?} got {:?}", want.pred, got.pred));
    }
    if want.ops != got.ops {
        return Some(format!("ops stats differ: want {:?} got {:?}", want.ops, got.ops));
    }
    if want.traces != got.traces {
        return Some("skip traces differ".into());
    }
    None
}

/// A session over a random model with the given strategy; always traces
/// and computes oracle ground truth so every stat is populated.
fn session_for(
    model: &mor::model::Model,
    seed: u64,
    strategy: Strategy,
    threshold: f32,
) -> Session {
    let params = synth::predictor_for(model, seed);
    Session::build(model)
        .params(&params)
        .strategy(strategy)
        .threshold(threshold)
        .oracle(true)
        .collect_trace(true)
        .finish()
}

/// Stats identities every strategy must maintain.
fn assert_identities(r: &RunResult, label: &str) {
    assert_eq!(
        r.pred.applied() + r.pred.not_applied,
        r.pred.relu_outputs,
        "{label}: outcome categories must partition ReLU outputs"
    );
    assert!(r.ops.macs_done <= r.ops.macs_total, "{label}: did more MACs than dense");
    let saved = r.ops.macs_total - r.ops.macs_done;
    assert_eq!(
        saved, r.ops.weight_bytes_saved,
        "{label}: MAC savings and weight-byte savings must agree (1 B/weight)"
    );
}

#[test]
fn contract_none() {
    property("`none` skips nothing and equals the unpoliced run", 25, |g| {
        let model = synth::random_model(g.rng());
        let (h, w, c) = model.input_shape;
        let x = rand_input(g.rng(), h * w * c);
        // Session::finish shortcuts the `none` strategy to "no policy";
        // force the policied path too, so the NoneStrategy mask fill
        // itself (not just the shortcut) is under test
        let params = synth::predictor_for(&model, g.seed);
        let pol = MorPolicy::new(
            &model,
            &params,
            PredictorConfig { strategy: Strategy::None, threshold: 0.5, ..Default::default() },
        );
        let sess = session_for(&model, g.seed, Strategy::None, 0.5);
        if sess.policy().is_some() {
            return Err("`none` session must run dense".into());
        }
        let dense = sess.run_sample(&x);
        let r = sess.with_policy(Some(pol)).run_sample(&x);
        if let Some(msg) = diff(&dense, &r) {
            return Err(format!("policied `none` differs from unpoliced: {msg}"));
        }
        if r.pred.applied() != 0 {
            return Err("`none` applied a prediction".into());
        }
        if r.traces.iter().any(|t| t.skipped.iter().any(|&s| s)) {
            return Err("`none` skipped an output".into());
        }
        assert_identities(&r, "none");
        Ok(())
    });
}

#[test]
fn contract_oracle() {
    property("`oracle` skips exactly the true zeros", 25, |g| {
        let model = synth::random_model(g.rng());
        let (h, w, c) = model.input_shape;
        let x = rand_input(g.rng(), h * w * c);
        let sess = session_for(&model, g.seed, Strategy::Oracle, 0.5);
        let r = sess.run_sample(&x);
        let dense = sess.with_policy(None).run_sample(&x);
        if r.pred.incorrect_zero != 0 {
            return Err(format!("oracle made {} wrong skips", r.pred.incorrect_zero));
        }
        if r.pred.incorrect_nonzero != 0 {
            return Err(format!("oracle missed {} true zeros", r.pred.incorrect_nonzero));
        }
        // a skipped output's true ReLU value is 0, so logits are dense-exact
        if r.logits != dense.logits {
            return Err("oracle changed the logits".into());
        }
        assert_identities(&r, "oracle");
        // engines agree on the oracle too
        let scalar = sess.with_opts(sess.opts().scalar_ref()).run_sample(&x);
        if let Some(msg) = diff(&scalar, &r) {
            return Err(format!("oracle tiled != scalar: {msg}"));
        }
        Ok(())
    });
}

#[test]
fn contract_binary() {
    property("`binary` only skips T-enabled neurons", 25, |g| {
        let model = synth::random_model(g.rng());
        let (h, w, c) = model.input_shape;
        let x = rand_input(g.rng(), h * w * c);
        let threshold = *g.pick(&[0.0f32, 0.5, 0.9]);
        let sess = session_for(&model, g.seed, Strategy::Binary, threshold);
        let r = sess.run_sample(&x);
        let pol = sess.policy().expect("binary builds a policy");
        for t in &r.traces {
            let Some(lp) = pol.layers.get(&t.node) else {
                if t.skipped.iter().any(|&s| s) {
                    return Err(format!("layer {} skipped without a policy", t.node));
                }
                continue;
            };
            for row in 0..t.rows {
                for f in 0..t.cout {
                    if t.skipped[row * t.cout + f] && !lp.enabled[f] {
                        return Err(format!(
                            "layer {} neuron {f} skipped below threshold {threshold}",
                            t.node
                        ));
                    }
                }
            }
        }
        assert_identities(&r, "binary");
        let scalar = sess.with_opts(sess.opts().scalar_ref()).run_sample(&x);
        if let Some(msg) = diff(&scalar, &r) {
            return Err(format!("binary tiled != scalar: {msg}"));
        }
        Ok(())
    });
}

#[test]
fn contract_cluster() {
    property("`cluster` never skips proxies; `mor` skips ⊆ `cluster` skips", 25, |g| {
        let model = synth::random_model(g.rng());
        let (h, w, c) = model.input_shape;
        let x = rand_input(g.rng(), h * w * c);
        let cl_sess = session_for(&model, g.seed, Strategy::Cluster, 0.0);
        let mor_sess = session_for(&model, g.seed, Strategy::Mor, 0.0);
        let rc = cl_sess.run_sample(&x);
        let rm = mor_sess.run_sample(&x);
        let pol = cl_sess.policy().expect("cluster builds a policy");
        for (tc, tm) in rc.traces.iter().zip(&rm.traces) {
            if let Some(lp) = pol.layers.get(&tc.node) {
                for row in 0..tc.rows {
                    for f in 0..tc.cout {
                        let i = row * tc.cout + f;
                        if tc.skipped[i] && lp.is_proxy(f) {
                            return Err(format!("layer {} proxy {f} was skipped", tc.node));
                        }
                        // hybrid requires the proxy verdict AND the rookie:
                        // it can never skip where the proxy said non-zero
                        if tm.skipped[i] && !tc.skipped[i] {
                            return Err(format!(
                                "layer {} neuron {f}: mor skipped where cluster did not",
                                tc.node
                            ));
                        }
                    }
                }
            }
        }
        assert_identities(&rc, "cluster");
        let scalar = cl_sess.with_opts(cl_sess.opts().scalar_ref()).run_sample(&x);
        if let Some(msg) = diff(&scalar, &rc) {
            return Err(format!("cluster tiled != scalar: {msg}"));
        }
        Ok(())
    });
}

#[test]
fn contract_mor() {
    // The acceptance sweep: `mor` must be bit-identical between the
    // tiled engine and the retained pre-refactor per-neuron path, and
    // between run_batch and per-sample runs, for batch sizes 1..16.
    let mut rng = Rng::new(0x5717A7);
    let model = synth::tiny_serving_model(41);
    let params = synth::predictor_for(&model, 42);
    let (h, w, c) = model.input_shape;
    let sess = Session::build(&model)
        .params(&params)
        .predictor("mor")
        .expect("mor is a registered strategy")
        .threshold(0.5)
        .oracle(true)
        .collect_trace(true)
        .finish();
    let scalar = sess.with_opts(sess.opts().scalar_ref());
    for b in 1..=16usize {
        let xs: Vec<Vec<f32>> = (0..b).map(|_| rand_input(&mut rng, h * w * c)).collect();
        let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let batch = sess.run_batch(&inputs);
        assert_eq!(batch.len(), b);
        for (s, x) in inputs.iter().enumerate() {
            let golden = scalar.run_sample(x);
            if let Some(msg) = diff(&golden, &batch[s]) {
                panic!("b={b} sample {s}: tiled batch != scalar golden: {msg}");
            }
            assert_identities(&batch[s], "mor");
        }
    }
}

#[test]
fn contract_mor_random_models() {
    property("`mor` bit-identical across engines and thread counts", 20, |g| {
        let model = synth::random_model(g.rng());
        let (h, w, c) = model.input_shape;
        let x = rand_input(g.rng(), h * w * c);
        let sess = session_for(&model, g.seed, Strategy::Mor, *g.pick(&[0.0f32, 0.5, 0.9]));
        let golden = sess.with_opts(sess.opts().scalar_ref()).run_sample(&x);
        for threads in [1usize, 3] {
            let mut opts = sess.opts();
            opts.threads = threads;
            opts.engine = EngineSel::Tiled;
            let got = sess.with_opts(opts).run_sample(&x);
            if let Some(msg) = diff(&golden, &got) {
                return Err(format!("threads={threads}: {msg}"));
            }
        }
        Ok(())
    });
}

#[test]
fn strategies_are_ordered_by_aggressiveness() {
    // On one fixed model: none saves nothing, every realizable strategy
    // saves no more than the oracle, and the hybrid's wrong skips are
    // bounded by the cluster strategy's (binary gating only removes
    // skips).
    let model = synth::tiny_serving_model(77);
    let mut rng = Rng::new(78);
    let (h, w, c) = model.input_shape;
    let x = rand_input(&mut rng, h * w * c);
    let run = |strategy| session_for(&model, 79, strategy, 0.0).run_sample(&x);
    let none = run(Strategy::None);
    let oracle = run(Strategy::Oracle);
    assert_eq!(none.ops.macs_done, none.ops.macs_total);
    for s in [Strategy::Mor, Strategy::Binary, Strategy::Cluster] {
        let r = run(s);
        assert!(
            r.pred.correct_zero <= oracle.pred.correct_zero,
            "{s:?} out-skipped the oracle"
        );
        assert!(r.ops.macs_done <= none.ops.macs_done);
    }
    let mor = run(Strategy::Mor);
    let cluster = run(Strategy::Cluster);
    assert!(mor.pred.incorrect_zero <= cluster.pred.incorrect_zero);
}

#[test]
fn run_opts_default_unchanged() {
    // choose_threshold's wrong-skip gate depends on oracle accounting
    // being on by default; pin it so a future default change is loud.
    let d = RunOpts::default();
    assert!(d.oracle);
    assert!(!d.collect_trace);
    assert_eq!(d.threads, 1);
    assert_eq!(d.engine, EngineSel::Tiled);
}
