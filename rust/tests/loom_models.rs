//! Exhaustive concurrency model checking with loom (`--cfg loom`).
//!
//! Run with:
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test --test loom_models --release
//! ```
//!
//! Under `--cfg loom`, [`mor::util::sync`] re-exports loom's
//! instrumented `Mutex`/`Condvar`/`AtomicUsize`, and `loom::model`
//! explores **every** interleaving of the threads in each model — not a
//! sample of schedules like a stress test, the full permutation space
//! (bounded by loom's partial-order reduction). The models are kept
//! tiny (2–3 threads, 1–2 operations each) so the space stays tractable
//! while still covering the races that matter:
//!
//! * [`SharedQueue`] — no lost wakeups (every push is drained), no
//!   deadlock on close (a blocked worker always wakes), exact
//!   accounting (each request handed out exactly once — the
//!   coordinator's `completed + dropped == pushed` arithmetic rests on
//!   this).
//! * [`WorkspacePool`] — grows to the peak concurrency exactly once,
//!   every drop returns its workspace, and the pool's internal
//!   `debug_assert!` invariants (`outstanding <= created`, free list
//!   never overfull — the double-return/aliasing tripwire) hold on
//!   every interleaving, since loom runs debug assertions too.
//! * [`TierQueue`] + [`Notifier`] — the serving tier's work-stealing
//!   substrate: racing `try_pop` calls (a home worker and a stealer)
//!   conserve requests and never hand one out twice, and a stealer
//!   that samples the notifier epoch *before* its scan can never miss
//!   a push or close that lands mid-scan (the tier's
//!   `completed + dropped + shed == submitted` invariant rests on
//!   these).
//!
//! Wall-clock caveat: loom requires deterministic executions, so the
//! linger model uses a deadline far in the future — the
//! `Instant::now() >= deadline` branch is then constant-false and the
//! timed wait degenerates to a modelled condvar wait, which is exactly
//! the wakeup logic we want checked.

#![cfg(loom)]

use loom::thread;
use mor::coordinator::queue::{Notifier, Poll, SharedQueue, TierQueue};
use mor::plan::WorkspacePool;
use mor::workload::Request;
use std::sync::Arc;
use std::time::Duration;

fn req(id: u64) -> Request {
    Request { id, sample_idx: 0, arrival_us: 0, tenant: 0 }
}

fn treq(id: u64, tenant: usize) -> Request {
    Request { id, sample_idx: 0, arrival_us: 0, tenant }
}

/// A deadline the model never reaches — keeps the linger loop on the
/// deterministic condvar path (see module docs).
const FOREVER: Duration = Duration::from_secs(3600);

// ---- SharedQueue -----------------------------------------------------------

#[test]
fn queue_concurrent_pushes_are_conserved() {
    loom::model(|| {
        let q = Arc::new(SharedQueue::new());
        let producers: Vec<_> = (0..2u64)
            .map(|id| {
                let q = Arc::clone(&q);
                thread::spawn(move || q.push(req(id)))
            })
            .collect();
        let consumer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                let mut ids = Vec::new();
                while let Some(batch) = q.next_batch(1, Duration::ZERO) {
                    ids.extend(batch.into_iter().map(|(r, _)| r.id));
                }
                ids
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut ids = consumer.join().unwrap();
        ids.sort_unstable();
        // exactly once each: nothing lost to a missed wakeup, nothing
        // duplicated by a double drain
        assert_eq!(ids, vec![0, 1]);
        assert!(q.depth_hwm() <= 2);
    });
}

#[test]
fn queue_close_always_wakes_a_blocked_worker() {
    loom::model(|| {
        let q = Arc::new(SharedQueue::new());
        let worker = {
            let q = Arc::clone(&q);
            // blocks on the condvar (empty queue) in some interleavings;
            // close() must wake it in all of them or loom deadlocks
            thread::spawn(move || q.next_batch(4, Duration::ZERO))
        };
        q.close();
        assert!(worker.join().unwrap().is_none());
    });
}

#[test]
fn queue_drains_fully_after_close() {
    loom::model(|| {
        let q = Arc::new(SharedQueue::new());
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                q.push(req(0));
                q.push(req(1));
                q.close();
            })
        };
        // the worker may observe any prefix of {push, push, close}; after
        // close it must still hand out everything already queued, then None
        let mut got = 0usize;
        let consumer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                let mut n = 0usize;
                while let Some(batch) = q.next_batch(2, Duration::ZERO) {
                    n += batch.len();
                }
                n
            })
        };
        producer.join().unwrap();
        got += consumer.join().unwrap();
        assert_eq!(got, 2, "closed queue dropped a queued request");
    });
}

#[test]
fn queue_linger_batch_conserves_requests() {
    loom::model(|| {
        let q = Arc::new(SharedQueue::new());
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                q.push(req(0));
                q.push(req(1));
                q.close();
            })
        };
        // max_batch 2 + a far-future deadline: the batcher takes the
        // wait_timeout linger path and must exit it on close (or a full
        // batch) in every interleaving — no stuck linger, no lost request
        let consumer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                let mut ids = Vec::new();
                while let Some(batch) = q.next_batch(2, FOREVER) {
                    ids.extend(batch.into_iter().map(|(r, _)| r.id));
                }
                ids
            })
        };
        producer.join().unwrap();
        let mut ids = consumer.join().unwrap();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1]);
    });
}

// ---- TierQueue + Notifier (work stealing) ----------------------------------

#[test]
fn tier_queue_racing_steals_conserve_requests() {
    loom::model(|| {
        let n = Arc::new(Notifier::new());
        let q = Arc::new(TierQueue::new(&[1, 1], Arc::clone(&n)));
        q.push(treq(0, 0), 0);
        q.push(treq(1, 1), 0);
        q.close();
        // a home worker and a stealer race try_pop on the same queue;
        // closed-before-spawn keeps Poll::Empty unreachable, so every
        // interleaving is a pure pop-ordering race
        let poppers: Vec<_> = (0..2)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut ids = Vec::new();
                    loop {
                        match q.try_pop() {
                            Poll::Item(it) => ids.push(it.req.id),
                            Poll::Closed => return ids,
                            Poll::Empty => unreachable!("closed before spawn"),
                        }
                    }
                })
            })
            .collect();
        let mut all: Vec<u64> =
            poppers.into_iter().flat_map(|p| p.join().unwrap()).collect();
        all.sort_unstable();
        // exactly once each, across both contenders
        assert_eq!(all, vec![0, 1], "a request was lost or handed out twice");
    });
}

#[test]
fn tier_queue_stolen_request_executes_exactly_once() {
    loom::model(|| {
        let n = Arc::new(Notifier::new());
        let q = Arc::new(TierQueue::new(&[1], Arc::clone(&n)));
        q.push(treq(7, 0), 0);
        q.close();
        // one request, two racing contenders: in every interleaving
        // exactly one of them wins the pop — the mutex-serialized
        // hand-out is what makes stealing double-execution-free
        let contenders: Vec<_> = (0..2)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut ids = Vec::new();
                    loop {
                        match q.try_pop() {
                            Poll::Item(it) => ids.push(it.req.id),
                            Poll::Closed => return ids,
                            Poll::Empty => unreachable!("closed before spawn"),
                        }
                    }
                })
            })
            .collect();
        let all: Vec<u64> =
            contenders.into_iter().flat_map(|t| t.join().unwrap()).collect();
        assert_eq!(all, vec![7], "a stolen request must execute exactly once");
    });
}

#[test]
fn tier_notifier_close_wakes_parked_stealer() {
    loom::model(|| {
        let n = Arc::new(Notifier::new());
        let q = Arc::new(TierQueue::new(&[1], Arc::clone(&n)));
        // the stealer's protocol: sample the epoch BEFORE the scan,
        // park with wait_past after a failed scan. A push or close
        // landing between scan and park bumps the epoch, so wait_past
        // returns immediately — loom proves no interleaving deadlocks
        // (i.e. no lost wakeup) and the item plus the close are both
        // eventually observed.
        let stealer = {
            let (n, q) = (Arc::clone(&n), Arc::clone(&q));
            thread::spawn(move || {
                let mut got = 0usize;
                loop {
                    let seen = n.epoch();
                    match q.try_pop() {
                        Poll::Item(_) => got += 1,
                        Poll::Closed => return got,
                        Poll::Empty => {
                            n.wait_past(seen, FOREVER);
                        }
                    }
                }
            })
        };
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                q.push(treq(0, 0), 0);
                q.close();
            })
        };
        producer.join().unwrap();
        assert_eq!(stealer.join().unwrap(), 1, "push or close missed a parked stealer");
    });
}

// ---- WorkspacePool ---------------------------------------------------------

#[test]
fn pool_grows_to_peak_exactly_once() {
    loom::model(|| {
        let pool = Arc::new(WorkspacePool::new());
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let pool = Arc::clone(&pool);
                thread::spawn(move || {
                    let ws = WorkspacePool::checkout(&pool);
                    // the guard is exclusively owned while held; the
                    // pool's internal debug_asserts police aliasing on
                    // every interleaving
                    drop(ws);
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        // peak concurrency was at most 2, and every guard returned its
        // workspace: the free list holds exactly what was ever created
        let created = pool.created();
        assert!(created >= 1 && created <= 2, "created = {created}");
        assert_eq!(pool.available(), created, "a workspace leaked");
        // a later checkout reuses — the pool never grows past the peak
        let ws = WorkspacePool::checkout(&pool);
        assert_eq!(pool.created(), created);
        drop(ws);
        assert_eq!(pool.available(), created);
    });
}

#[test]
fn pool_concurrent_checkouts_never_alias() {
    loom::model(|| {
        let pool = Arc::new(WorkspacePool::new());
        // two guards live at once in one thread: they must be two
        // distinct workspaces (the second checkout cannot steal the
        // first's), so the pool creates twice
        let a = WorkspacePool::checkout(&pool);
        let b = WorkspacePool::checkout(&pool);
        assert_eq!(pool.created(), 2);
        assert_eq!(pool.available(), 0);
        // a racing return/checkout pair: the worker returns one guard
        // while the main thread checks out a third — every interleaving
        // either reuses the returned workspace or creates a fresh one,
        // never hands out a workspace that is still owned
        let worker = thread::spawn(move || drop(a));
        let c = WorkspacePool::checkout(&pool);
        worker.join().unwrap();
        assert!(pool.created() <= 3);
        drop(b);
        drop(c);
        assert_eq!(pool.available(), pool.created(), "a workspace leaked");
    });
}

#[test]
fn pool_drop_guard_always_returns() {
    loom::model(|| {
        let pool = Arc::new(WorkspacePool::new());
        let worker = {
            let pool = Arc::clone(&pool);
            thread::spawn(move || {
                let _ws = WorkspacePool::checkout(&pool);
                // dropped at scope end — the Drop impl must run the
                // return path in every interleaving
            })
        };
        worker.join().unwrap();
        assert_eq!(pool.created(), 1);
        assert_eq!(pool.available(), 1);
    });
}
