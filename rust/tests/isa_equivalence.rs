//! Cross-ISA equivalence suite: every SIMD tier this host can run
//! (scalar, NEON, AVX2, AVX-512 VNNI — whatever [`isa::available`]
//! reports) must produce **bit-identical** logits, stats and skip
//! traces to the retained per-neuron scalar reference, across random
//! models, predictor strategies, input-sparsity modes and exact
//! weight-sparsity modes. The i32-dot contract says the ISA knob is a
//! pure host-performance choice; this suite is the oracle for it.
//!
//! The forced-ISA override ([`isa::force`]) is process-global, so this
//! file is the only test binary that mutates it, and every test here
//! serializes on one lock and restores the default on drop.

use std::sync::{Mutex, MutexGuard};

use mor::config::PredictorConfig;
use mor::engine::isa::{self, Isa};
use mor::engine::tune::TuneProfile;
use mor::engine::{InputSparsity, WeightSparsity};
use mor::model::synth;
use mor::plan;
use mor::predictor::strategies::Strategy;
use mor::predictor::{exec::run_sample, EngineSel, MorPolicy, RunOpts, RunResult};
use mor::util::prop::property;
use mor::util::rng::Rng;

static ISA_LOCK: Mutex<()> = Mutex::new(());

/// Holds the global ISA lock for a test's lifetime and clears any
/// forced tier when dropped, even if the test panics.
struct ForcedIsa(#[allow(dead_code)] MutexGuard<'static, ()>);

impl ForcedIsa {
    fn lock() -> ForcedIsa {
        ForcedIsa(ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl Drop for ForcedIsa {
    fn drop(&mut self) {
        isa::force(None);
    }
}

fn rand_input(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

fn diff(want: &RunResult, got: &RunResult) -> Option<String> {
    if want.logits != got.logits {
        return Some(format!("logits differ: want {:?} got {:?}", want.logits, got.logits));
    }
    if want.pred != got.pred {
        return Some(format!("pred stats differ: want {:?} got {:?}", want.pred, got.pred));
    }
    if want.ops != got.ops {
        return Some(format!("ops stats differ: want {:?} got {:?}", want.ops, got.ops));
    }
    if want.traces != got.traces {
        return Some("skip traces differ".to_string());
    }
    None
}

#[test]
fn every_available_isa_matches_scalar_reference() {
    let _guard = ForcedIsa::lock();
    let tiers = isa::available();
    assert!(tiers.contains(&Isa::Scalar), "scalar must always be available");

    property("every ISA tier == scalar reference", 12, |g| {
        let mut model = synth::random_model(g.rng());
        // half the cases get real weight zeros so the weight-sparse
        // kernels (and their per-ISA lane paths) are actually exercised
        if g.bool() {
            synth::sparsify_weights(&mut model, g.seed ^ 3, 80);
        }
        let params = synth::predictor_for(&model, g.seed);
        let (h, w, c) = model.input_shape;
        let x = rand_input(g.rng(), h * w * c);
        let cfg = PredictorConfig {
            threshold: *g.pick(&[0.0f32, 0.5]),
            strategy: *g.pick(&Strategy::ALL),
            ..Default::default()
        };
        let pol = MorPolicy::new(&model, &params, cfg);
        let policy = g.bool().then_some(&pol);

        // the scalar reference path never dispatches on ISA, so one
        // baseline serves every forced tier below
        isa::force(None);
        let want = run_sample(
            &model,
            policy,
            &x,
            RunOpts {
                oracle: true,
                collect_trace: true,
                threads: 1,
                engine: EngineSel::ScalarRef,
                ..Default::default()
            },
        );

        for &tier in &tiers {
            isa::force(Some(tier));
            assert_eq!(isa::active(), tier, "force must pin an available tier exactly");
            for is in InputSparsity::ALL {
                for ws in WeightSparsity::EXACT_MODES {
                    for threads in [1usize, 3] {
                        let got = run_sample(
                            &model,
                            policy,
                            &x,
                            RunOpts {
                                oracle: true,
                                collect_trace: true,
                                threads,
                                engine: EngineSel::Tiled,
                                input_sparsity: is,
                                weight_sparsity: ws,
                                // defaulted *after* force: freezes this
                                // tier's own crossover cutoffs into the plan
                                ..Default::default()
                            },
                        );
                        if let Some(msg) = diff(&want, &got) {
                            isa::force(None);
                            return Err(format!(
                                "isa={} input_sparsity={is:?} weight_sparsity={ws:?} \
                                 threads={threads} policy={}: {msg}",
                                tier.name(),
                                policy.is_some()
                            ));
                        }
                    }
                }
            }
        }
        isa::force(None);
        Ok(())
    });
}

#[test]
fn forcing_beyond_detected_clamps_to_detected() {
    let _guard = ForcedIsa::lock();
    let top = isa::detected();
    // asking for the highest tier in the lattice can only ever deliver
    // what the CPU has — force mins with detection, never widens it
    isa::force(Some(Isa::Avx512Vnni));
    assert_eq!(isa::active(), top);
    isa::force(Some(Isa::Scalar));
    assert_eq!(isa::active(), Isa::Scalar);
    assert!(!isa::avx2_enabled() && !isa::vnni_enabled() && !isa::neon_enabled());
    // host_default() follows the active tier, so scalar-forced sessions
    // freeze the scalar crossovers
    assert_eq!(TuneProfile::host_default().isa, Isa::Scalar);
    isa::force(None);
    assert_eq!(isa::active(), isa::detected().min(isa::active()));
}

#[test]
fn profile_file_round_trip_preserves_plan_decisions() {
    let _guard = ForcedIsa::lock();
    let profile = TuneProfile {
        isa: isa::active(),
        input_cutoff: 0.33,
        weight_cutoff: 0.44,
        tile_rows: 8,
        threads: 2,
    };
    let path = std::env::temp_dir().join(format!("mor_tune_{}.profile", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    profile.save(&path).unwrap();
    let loaded = TuneProfile::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(profile, loaded);
    assert_eq!(profile.hash(), loaded.hash());

    // the loaded profile must freeze the exact same plan: same cutoff,
    // same per-layer weight-sparse choice, verifier-clean against the
    // file's contents
    let mut model = synth::tiny_serving_model(5);
    synth::sparsify_weights(&mut model, 5, 85);
    let mk = |p: TuneProfile| {
        plan::compile(
            &model,
            None,
            RunOpts { weight_sparsity: WeightSparsity::Exact, tune: p, ..Default::default() },
        )
    };
    let (saved_plan, loaded_plan) = (mk(profile), mk(loaded));
    let mut computes = 0;
    for (a, b) in std::iter::zip(&saved_plan.steps, &loaded_plan.steps) {
        if let (plan::StepPlan::Compute(ca), plan::StepPlan::Compute(cb)) = (a, b) {
            assert_eq!(ca.sparse_cutoff, cb.sparse_cutoff);
            assert_eq!(ca.w_sparse, cb.w_sparse);
            assert_eq!(ca.sparse_cutoff, 0.33 * ca.k_len as f32);
            computes += 1;
        }
    }
    assert!(computes > 0, "model must have compute steps to compare");
    let report = plan::verify_with(&loaded_plan, &model, None, Some(&loaded));
    assert!(report.is_clean(), "round-tripped profile must audit clean:\n{report}");
}
