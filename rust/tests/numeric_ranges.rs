//! Property suite for the quantized-numerics abstract interpreter
//! (`mor::plan::ranges`, surfaced as `mor lint --numeric` — see
//! EXPERIMENTS.md §Numeric).
//!
//! Three halves:
//!
//! * **Pristine models prove clean** — every synthetic model generator ×
//!   every input-sparsity mode × every exact weight-sparsity mode ×
//!   {no policy, MoR policy} passes its overflow/saturation/threshold
//!   proofs with zero error-severity findings. This is what lets
//!   `Session` assert numeric cleanliness in debug builds.
//! * **Observed ⊆ predicted** — actually run the engines (both the
//!   tiled and the scalar-reference path, every strategy, the sparsity
//!   kernel modes, single- and multi-threaded) with the
//!   `plan::observe` hook recording every accumulator, pre-activation
//!   and binarized proxy dot, and assert each observed value lies
//!   inside the statically predicted interval of its layer. An
//!   interval analysis that is merely *plausible* would pass the clean
//!   sweep; this half pins it to the real dataflow.
//! * **Each proof actually rejects** — seeded numeric corruptions
//!   (an accumulator-overflow layer, a narrowed width claim, a NaN
//!   quantization scale, an f32-overflowing BN fold, a poisoned
//!   predictor line) must each be caught with their *own* `num.*`
//!   diagnostic code, not a generic failure.

use mor::config::PredictorConfig;
use mor::engine::{InputSparsity, WeightSparsity};
use mor::model::{synth, Model, Node};
use mor::plan::{self, NumericOpts, StepPlan};
use mor::predictor::strategies::Strategy;
use mor::predictor::{exec::run_batch, EngineSel, MorPolicy, RunOpts};
use mor::util::rng::Rng;

// ---- helpers ---------------------------------------------------------------

fn opts(is: InputSparsity, ws: WeightSparsity) -> RunOpts {
    RunOpts { input_sparsity: is, weight_sparsity: ws, ..Default::default() }
}

fn policy_for(model: &Model, seed: u64, cfg: PredictorConfig) -> MorPolicy {
    let params = synth::predictor_for(model, seed);
    MorPolicy::new(model, &params, cfg)
}

fn zoo(seed: u64) -> Vec<Model> {
    let mut zoo = vec![synth::cnn10_like(seed), synth::tiny_serving_model(seed)];
    let mut sparse = synth::tiny_serving_model(seed);
    synth::sparsify_weights(&mut sparse, seed, 90);
    sparse.name = format!("{}_sparse90", sparse.name);
    zoo.push(sparse);
    let mut rng = Rng::new(seed ^ 0x9e37);
    zoo.extend((0..6).map(|_| synth::random_model(&mut rng)));
    zoo
}

fn rand_input(rng: &mut Rng, model: &Model) -> Vec<f32> {
    let (h, w, c) = model.input_shape;
    (0..h * w * c).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

/// Corrupt the first compute step of `plan` in place.
fn mutate_first_compute(plan: &mut plan::ModelPlan, f: impl FnOnce(&mut plan::ComputeStep)) {
    let c = plan
        .steps
        .iter_mut()
        .find_map(|s| match s {
            StepPlan::Compute(c) => Some(c),
            _ => None,
        })
        .expect("model has at least one compute step");
    f(c);
}

/// A one-layer FC model with hand-picked weights/BN for the corruption
/// tests (integration tests cannot reach `Model.prepacked`, so models
/// are built through the public constructor).
fn fc_model(name: &str, cin: usize, cout: usize, w: Vec<i8>, bn: Option<(Vec<f32>, Vec<f32>)>) -> Model {
    assert_eq!(w.len(), cin * cout);
    Model::new(
        name.into(),
        0.02,
        (1, 1, cin),
        vec![Node::Fc {
            cin,
            cout,
            sw: 0.01,
            sx: 0.02,
            w,
            bn,
            relu: false,
            res_from: None,
            consumes: -1,
        }],
    )
}

// ---- pristine models prove clean ------------------------------------------

#[test]
fn every_pristine_model_proves_numeric_clean() {
    for model in &zoo(7) {
        let policy = policy_for(model, 11, PredictorConfig::default());
        for is in InputSparsity::ALL {
            for ws in WeightSparsity::EXACT_MODES {
                for pol in [None, Some(&policy)] {
                    let compiled = plan::compile(model, pol, opts(is, ws));
                    let rep = plan::ranges::analyze(&compiled, model, pol);
                    assert_eq!(
                        rep.errors(),
                        0,
                        "[{}] is={is:?} ws={ws:?} policy={}: {rep}",
                        model.name,
                        pol.is_some()
                    );
                    assert!(!rep.steps.is_empty(), "[{}] no compute steps analyzed", model.name);
                    // every compute step proves the native i32 suffices
                    assert!(
                        rep.max_acc_bits() <= 32,
                        "[{}] needs {} bits",
                        model.name,
                        rep.max_acc_bits()
                    );
                }
            }
        }
    }
}

#[test]
fn report_json_is_machine_readable() {
    let model = synth::tiny_serving_model(5);
    let policy = policy_for(&model, 5, PredictorConfig::default());
    let compiled = plan::compile(&model, Some(&policy), RunOpts::default());
    let rep = plan::ranges::analyze(&compiled, &model, Some(&policy));
    let json = rep.to_json().to_string();
    let parsed = mor::util::json::Json::parse(&json).expect("valid json");
    match parsed {
        mor::util::json::Json::Obj(pairs) => {
            assert!(pairs.iter().any(|(k, _)| k == "findings"), "{json}");
            let steps = pairs.iter().find(|(k, _)| k == "steps").expect("steps key");
            match &steps.1 {
                mor::util::json::Json::Arr(items) => assert!(!items.is_empty()),
                other => panic!("steps should be an array, got {other:?}"),
            }
        }
        other => panic!("expected an object, got {other:?}"),
    }
}

// ---- observed runtime values ⊆ predicted intervals -------------------------

/// Run one configuration with the observation hook armed and assert
/// every recorded value lies inside its layer's predicted interval.
fn check_containment(
    model: &Model,
    pol: Option<&MorPolicy>,
    run_opts: RunOpts,
    inputs: &[&[f32]],
    label: &str,
) {
    let compiled = plan::compile(model, pol, run_opts);
    let rep = plan::ranges::analyze(&compiled, model, pol);
    assert_eq!(rep.errors(), 0, "{label}: pristine model must prove clean: {rep}");

    mor::plan::observe::begin();
    let _ = run_batch(model, pol, inputs, run_opts);
    let log = mor::plan::observe::take();
    assert!(!log.is_empty(), "{label}: forward recorded nothing");

    for (node, obs) in &log {
        let sr = rep
            .step_for(*node)
            .unwrap_or_else(|| panic!("{label}: observed node {node} has no analyzed step"));
        if let Some((lo, hi)) = obs.dot {
            for d in [lo, hi] {
                assert!(
                    sr.dot.contains(d as i64),
                    "{label} node {node}: dot {d} outside predicted [{}, {}]",
                    sr.dot.lo,
                    sr.dot.hi
                );
                assert!(
                    (d as i64).unsigned_abs() <= sr.acc_peak,
                    "{label} node {node}: |dot {d}| exceeds proven peak {}",
                    sr.acc_peak
                );
            }
        }
        if let Some((lo, hi)) = obs.ri {
            assert!(
                !lo.is_nan() && !hi.is_nan(),
                "{label} node {node}: runtime pre-activation went NaN"
            );
            for v in [lo, hi] {
                assert!(
                    sr.pre_act.contains(v as f64),
                    "{label} node {node}: ri {v} outside predicted [{}, {}]",
                    sr.pre_act.lo,
                    sr.pre_act.hi
                );
            }
        }
        if let Some((lo, hi)) = obs.proxy {
            let p = sr.proxy.unwrap_or_else(|| {
                panic!("{label} node {node}: proxy dot observed but not predicted")
            });
            for v in [lo, hi] {
                assert!(
                    p.contains(v as i64),
                    "{label} node {node}: proxy {v} outside predicted [{}, {}]",
                    p.lo,
                    p.hi
                );
            }
        }
    }
}

/// One `#[test]` on purpose: the observation recorder is a process-wide
/// global, so all observing runs stay in a single test and cycle
/// `begin`/`take` sequentially (the other tests in this binary never
/// run a forward, so parallel test threads cannot pollute the log).
#[test]
fn observed_values_lie_inside_predicted_intervals() {
    if !cfg!(debug_assertions) {
        // the engines' record calls are compiled out in release builds
        return;
    }
    let mut rng = Rng::new(0x4a11);
    let mut models = vec![synth::tiny_serving_model(7), synth::cnn10_like(7)];
    models.push(synth::random_model(&mut rng));

    for model in &models {
        let xs: Vec<Vec<f32>> = (0..2).map(|_| rand_input(&mut rng, model)).collect();
        let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();

        for engine in [EngineSel::Tiled, EngineSel::ScalarRef] {
            // no policy: the dense baseline on both engines
            let o = RunOpts { engine, ..Default::default() };
            check_containment(model, None, o, &inputs, &format!("[{}] {engine:?} none", model.name));
            // every strategy (threshold 0.0 keeps all neurons enabled so
            // the binary rookie is consulted as widely as possible)
            for strategy in Strategy::ALL {
                let cfg = PredictorConfig { strategy, threshold: 0.0, ..Default::default() };
                let pol = policy_for(model, 11, cfg);
                check_containment(
                    model,
                    Some(&pol),
                    o,
                    &inputs,
                    &format!("[{}] {engine:?} {strategy:?}", model.name),
                );
            }
        }

        // sparsity kernel modes under the default hybrid strategy
        let pol = policy_for(model, 11, PredictorConfig::default());
        for is in InputSparsity::ALL {
            for ws in WeightSparsity::EXACT_MODES {
                let o = opts(is, ws);
                check_containment(
                    model,
                    Some(&pol),
                    o,
                    &inputs,
                    &format!("[{}] is={is:?} ws={ws:?}", model.name),
                );
            }
        }

        // multi-threaded tiled run: records cross worker threads
        let o = RunOpts { threads: 2, ..Default::default() };
        check_containment(model, Some(&pol), o, &inputs, &format!("[{}] threads=2", model.name));
    }
}

// ---- seeded corruptions: each rejected with its own code -------------------

#[test]
fn accumulator_overflow_is_rejected_with_num_acc() {
    // Σ|w|·max|x| = (2^18·128)·127 ≈ 4.26e9 > 2³¹: no i32 accumulator
    // holds the worst case of this (absurdly wide) layer
    let k = 1usize << 18;
    let model = fc_model("acc_overflow", k, 2, vec![-128i8; k * 2], None);
    let compiled = plan::compile(&model, None, RunOpts::default());
    let rep = plan::ranges::analyze(&compiled, &model, None);
    assert!(rep.has("num.acc"), "{rep}");
    assert!(rep.errors() > 0);
    assert!(rep.max_acc_bits() > 32, "needs {} bits", rep.max_acc_bits());
}

#[test]
fn narrowed_width_claim_is_rejected_with_num_width() {
    // cnn10 is safe for i32 but nowhere near an i16 accumulator: the
    // width gate must fire without tripping the native num.acc proof
    let model = synth::cnn10_like(7);
    let compiled = plan::compile(&model, None, RunOpts::default());
    let rep = plan::ranges::analyze_with(&compiled, &model, None, &NumericOpts { acc_bits: 16 });
    assert!(rep.has("num.width"), "{rep}");
    assert!(!rep.has("num.acc"), "i32 itself is provably fine: {rep}");
    assert!(rep.errors() > 0);
}

#[test]
fn poisoned_quantization_scale_is_rejected_with_num_scale() {
    let model = synth::tiny_serving_model(5);
    let mut compiled = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut compiled, |c| c.sx = f32::NAN);
    let rep = plan::ranges::analyze(&compiled, &model, None);
    assert!(rep.has("num.scale"), "{rep}");
    assert!(rep.errors() > 0);

    let mut compiled = plan::compile(&model, None, RunOpts::default());
    mutate_first_compute(&mut compiled, |c| c.dq = f32::INFINITY);
    let rep = plan::ranges::analyze(&compiled, &model, None);
    assert!(rep.has("num.scale"), "{rep}");
}

#[test]
fn f32_overflowing_bn_fold_is_rejected_with_num_requant() {
    // dot ∈ ±800·127, dq = 2e-4 → ±20.3; a 1e38 BN scale pushes the
    // pre-activation range past f32::MAX — saturation the engine never
    // intends outside quantize()
    let cin = 8;
    let model = fc_model(
        "requant_overflow",
        cin,
        2,
        vec![100i8; cin * 2],
        Some((vec![1e38; 2], vec![0.0; 2])),
    );
    let compiled = plan::compile(&model, None, RunOpts::default());
    let rep = plan::ranges::analyze(&compiled, &model, None);
    assert!(rep.has("num.requant"), "{rep}");
    assert!(rep.errors() > 0);
    assert!(!rep.has("num.acc"), "the integer side is fine: {rep}");
}

#[test]
fn poisoned_predictor_line_is_rejected_with_num_threshold() {
    let model = synth::tiny_serving_model(5);
    // binary-only strategy + threshold 0.0: every neuron's line is
    // consulted, so poisoning layer 0's slopes must be seen
    let cfg = PredictorConfig { strategy: Strategy::Binary, threshold: 0.0, ..Default::default() };
    let mut policy = policy_for(&model, 5, cfg);
    let (&node, _) = policy.layers.iter().next().expect("policy prepares a layer");
    let lp = policy.layers.get_mut(&node).expect("layer state");
    for m in lp.m.iter_mut() {
        *m = f32::NAN;
    }
    let compiled = plan::compile(&model, Some(&policy), RunOpts::default());
    let rep = plan::ranges::analyze(&compiled, &model, Some(&policy));
    assert!(rep.has("num.threshold"), "{rep}");
    assert!(rep.errors() > 0);
}

#[test]
fn provably_degenerate_layer_warns_with_num_threshold() {
    // m = 0, b = -10⁶: the estimate is the constant -10⁶ < -margin for
    // every input, so every consulted neuron provably always skips —
    // a Warning (the layer degenerates), not an Error (nothing overflows)
    let model = synth::tiny_serving_model(5);
    let cfg = PredictorConfig { strategy: Strategy::Binary, threshold: 0.0, ..Default::default() };
    let mut policy = policy_for(&model, 5, cfg);
    let (&node, _) = policy.layers.iter().next().expect("policy prepares a layer");
    let lp = policy.layers.get_mut(&node).expect("layer state");
    for m in lp.m.iter_mut() {
        *m = 0.0;
    }
    for b in lp.b.iter_mut() {
        *b = -1e6;
    }
    let compiled = plan::compile(&model, Some(&policy), RunOpts::default());
    let rep = plan::ranges::analyze(&compiled, &model, Some(&policy));
    assert!(rep.has("num.threshold"), "{rep}");
    assert_eq!(rep.errors(), 0, "degeneracy is a warning, not an error: {rep}");
    assert!(rep.warnings() > 0);
}

#[test]
fn corruption_codes_are_distinct() {
    // the catalogue stays honest: the overflow corruption must NOT be
    // reported as a requant or threshold problem, and vice versa
    let k = 1usize << 18;
    let model = fc_model("acc_overflow_distinct", k, 2, vec![-128i8; k * 2], None);
    let compiled = plan::compile(&model, None, RunOpts::default());
    let rep = plan::ranges::analyze(&compiled, &model, None);
    assert!(rep.has("num.acc"));
    assert!(!rep.has("num.scale"), "{rep}");
    assert!(!rep.has("num.threshold"), "{rep}");
}
