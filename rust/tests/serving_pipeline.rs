//! End-to-end serving smoke tests over **synthetic** artifacts — unlike
//! `integration.rs`, these never skip: `mor::model::synth::artifacts_for`
//! builds a full bundle in memory, so CI exercises the coordinator
//! (queue, batcher, drop accounting, closed loop) on every run.

use mor::config::PredictorConfig;
use mor::coordinator::{serve, Backend, ServeOpts};
use mor::model::synth;
use mor::model::Artifacts;
use mor::session::Session;
use mor::workload::{Arrival, RequestStream};

fn synth_arts() -> Artifacts {
    synth::artifacts_for(synth::tiny_serving_model(9), 10, 32, 4)
}

fn session(arts: &Artifacts) -> Session {
    Session::from_artifacts(
        arts,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    )
}

/// A compressed Poisson trace: ~`n`-ish requests whose arrivals replay in
/// a few tens of milliseconds (time_scale applied at serve time).
fn trace(arts: &Artifacts, seed: u64) -> Vec<mor::workload::Request> {
    let mut s = RequestStream::new(800.0, arts.data.n_test(), seed);
    s.generate(0.25)
}

#[test]
fn serve_smoke_unbatched() {
    let arts = synth_arts();
    let requests = trace(&arts, 1);
    let n = requests.len();
    assert!(n > 50, "trace too short: {n}");
    let rep = serve(
        &arts,
        &session(&arts),
        Backend::Engine,
        requests,
        "unused",
        ServeOpts { workers: 2, time_scale: 0.1, ..Default::default() },
    )
    .expect("serve");
    assert_eq!(rep.completed, n, "requests lost without batching");
    assert_eq!(rep.predictor, "mor", "report must name the active strategy");
    assert_eq!(rep.dropped, 0);
    assert!(rep.first_error.is_none());
    assert!((rep.batch_occupancy - 1.0).abs() < 1e-9, "max_batch=1 must not batch");
    assert!(rep.busy_s > 0.0 && rep.busy_s <= rep.duration_s + 1e-9);
    assert!(rep.throughput_rps > 0.0);
}

#[test]
fn serve_smoke_batched_matches_unbatched_answers() {
    let arts = synth_arts();
    let requests = trace(&arts, 2);
    let n = requests.len();
    let sess = session(&arts);
    let run = |max_batch: usize| {
        serve(
            &arts,
            &sess,
            Backend::Engine,
            requests.clone(),
            "unused",
            ServeOpts {
                workers: 2,
                time_scale: 0.1,
                max_batch,
                batch_wait_us: 500,
                ..Default::default()
            },
        )
        .expect("serve")
    };
    let unbatched = run(1);
    let batched = run(8);
    assert_eq!(unbatched.completed, n);
    assert_eq!(batched.completed, n, "requests lost with batching");
    assert_eq!(batched.dropped, 0);
    // run_batch is bit-exact with run_sample, so per-request correctness
    // — and therefore accuracy — must be identical batched or not
    assert_eq!(unbatched.accuracy, batched.accuracy);
    assert!(batched.batch_occupancy >= 1.0);
}

#[test]
fn serve_closed_loop_completes_all() {
    let arts = synth_arts();
    let requests = trace(&arts, 3);
    let n = requests.len();
    let rep = serve(
        &arts,
        &session(&arts),
        Backend::Engine,
        requests,
        "unused",
        ServeOpts {
            workers: 2,
            max_batch: 4,
            batch_wait_us: 200,
            closed_loop: true,
            concurrency: 8,
            ..Default::default()
        },
    )
    .expect("serve");
    assert_eq!(rep.completed, n, "closed loop lost requests");
    assert_eq!(rep.dropped, 0);
    // with 8 outstanding and batches of up to 4, real coalescing happens
    assert!(rep.batch_occupancy >= 1.0);
}

#[test]
fn serve_bursty_arrivals_complete() {
    let arts = synth_arts();
    let mut s = RequestStream::with_arrival(
        Arrival::Bursty { rate_on_per_s: 3000.0, mean_on_s: 0.05, mean_off_s: 0.1 },
        arts.data.n_test(),
        4,
    );
    let requests = s.generate(0.5);
    let n = requests.len();
    assert!(n > 20, "burst trace too short: {n}");
    let rep = serve(
        &arts,
        // dense baseline: accuracy vs self-consistent labels is 1.0
        &session(&arts).with_policy(None),
        Backend::Engine,
        requests,
        "unused",
        ServeOpts {
            workers: 2,
            time_scale: 0.1,
            max_batch: 8,
            batch_wait_us: 500,
            ..Default::default()
        },
    )
    .expect("serve");
    assert_eq!(rep.completed, n);
    assert_eq!(rep.dropped, 0);
    assert_eq!(rep.accuracy, 1.0, "dense forward must reproduce its own labels");
    assert_eq!(rep.predictor, "none");
}

#[test]
fn serve_dense_batched_accuracy_is_exact() {
    // Batched dense serving over self-consistent labels: every answer
    // must match the per-sample forward that produced the labels.
    let arts = synth_arts();
    let requests = trace(&arts, 5);
    let n = requests.len();
    let rep = serve(
        &arts,
        &session(&arts).with_policy(None),
        Backend::Engine,
        requests,
        "unused",
        ServeOpts {
            workers: 1,
            time_scale: 0.02,
            max_batch: 16,
            // generous linger: even with coarse scheduler sleep granularity
            // stretching the compressed replay, batches must still form
            batch_wait_us: 5_000,
            ..Default::default()
        },
    )
    .expect("serve");
    assert_eq!(rep.completed, n);
    assert_eq!(rep.accuracy, 1.0);
    // everything arrives almost at once with a 16-deep batcher: real
    // cross-request tiles must have formed
    assert!(
        rep.batch_occupancy > 1.0,
        "expected coalescing, occupancy {}",
        rep.batch_occupancy
    );
}
