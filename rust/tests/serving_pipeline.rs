//! End-to-end serving smoke tests over **synthetic** artifacts — unlike
//! `integration.rs`, these never skip: `mor::model::synth::artifacts_for`
//! builds a full bundle in memory, so CI exercises the coordinator
//! (queue, batcher, drop accounting, closed loop) on every run.
//!
//! The `tier_*` suites below drive the sharded [`ServingTier`] through
//! its deterministic virtual-clock simulator: overload shedding,
//! conservation (`completed + dropped + shed == submitted`), weighted
//! fairness, flash-crowd isolation, work stealing, expiry-at-dequeue,
//! and bit-exact reproducibility — assertions that would be flaky on
//! wall-clock threads are theorems on the virtual clock. One real
//! threaded `ServingTier::serve` smoke test rides along.

use mor::config::PredictorConfig;
use mor::coordinator::tier::{ServingTier, VirtualService};
use mor::coordinator::{serve, Backend, ServeOpts};
use mor::model::synth;
use mor::model::Artifacts;
use mor::session::Session;
use mor::workload::{merge, Arrival, Request, RequestStream};

fn synth_arts() -> Artifacts {
    synth::artifacts_for(synth::tiny_serving_model(9), 10, 32, 4)
}

fn session(arts: &Artifacts) -> Session {
    Session::from_artifacts(
        arts,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    )
}

/// A compressed Poisson trace: ~`n`-ish requests whose arrivals replay in
/// a few tens of milliseconds (time_scale applied at serve time).
fn trace(arts: &Artifacts, seed: u64) -> Vec<mor::workload::Request> {
    let mut s = RequestStream::new(800.0, arts.data.n_test(), seed);
    s.generate(0.25)
}

#[test]
fn serve_smoke_unbatched() {
    let arts = synth_arts();
    let requests = trace(&arts, 1);
    let n = requests.len();
    assert!(n > 50, "trace too short: {n}");
    let rep = serve(
        &arts,
        &session(&arts),
        Backend::Engine,
        requests,
        "unused",
        ServeOpts { workers: 2, time_scale: 0.1, ..Default::default() },
    )
    .expect("serve");
    assert_eq!(rep.completed, n, "requests lost without batching");
    assert_eq!(rep.predictor, "mor", "report must name the active strategy");
    assert_eq!(rep.dropped, 0);
    assert_eq!(rep.shed, 0, "no deadline, nothing to shed");
    assert!(rep.conserved(), "completed + dropped + shed != submitted");
    assert!(rep.first_error.is_none());
    assert!((rep.batch_occupancy - 1.0).abs() < 1e-9, "max_batch=1 must not batch");
    assert!(rep.busy_s > 0.0 && rep.busy_s <= rep.duration_s + 1e-9);
    assert!(rep.throughput_rps > 0.0);
}

#[test]
fn serve_smoke_batched_matches_unbatched_answers() {
    let arts = synth_arts();
    let requests = trace(&arts, 2);
    let n = requests.len();
    let sess = session(&arts);
    let run = |max_batch: usize| {
        serve(
            &arts,
            &sess,
            Backend::Engine,
            requests.clone(),
            "unused",
            ServeOpts {
                workers: 2,
                time_scale: 0.1,
                max_batch,
                batch_wait_us: 500,
                ..Default::default()
            },
        )
        .expect("serve")
    };
    let unbatched = run(1);
    let batched = run(8);
    assert_eq!(unbatched.completed, n);
    assert_eq!(batched.completed, n, "requests lost with batching");
    assert_eq!(batched.dropped, 0);
    assert!(unbatched.conserved() && batched.conserved());
    // run_batch is bit-exact with run_sample, so per-request correctness
    // — and therefore accuracy — must be identical batched or not
    assert_eq!(unbatched.accuracy, batched.accuracy);
    assert!(batched.batch_occupancy >= 1.0);
}

#[test]
fn serve_closed_loop_completes_all() {
    let arts = synth_arts();
    let requests = trace(&arts, 3);
    let n = requests.len();
    let rep = serve(
        &arts,
        &session(&arts),
        Backend::Engine,
        requests,
        "unused",
        ServeOpts {
            workers: 2,
            max_batch: 4,
            batch_wait_us: 200,
            closed_loop: true,
            concurrency: 8,
            ..Default::default()
        },
    )
    .expect("serve");
    assert_eq!(rep.completed, n, "closed loop lost requests");
    assert_eq!(rep.dropped, 0);
    assert!(rep.conserved());
    // with 8 outstanding and batches of up to 4, real coalescing happens
    assert!(rep.batch_occupancy >= 1.0);
}

#[test]
fn serve_bursty_arrivals_complete() {
    let arts = synth_arts();
    let mut s = RequestStream::with_arrival(
        Arrival::Bursty { rate_on_per_s: 3000.0, mean_on_s: 0.05, mean_off_s: 0.1 },
        arts.data.n_test(),
        4,
    );
    let requests = s.generate(0.5);
    let n = requests.len();
    assert!(n > 20, "burst trace too short: {n}");
    let rep = serve(
        &arts,
        // dense baseline: accuracy vs self-consistent labels is 1.0
        &session(&arts).with_policy(None),
        Backend::Engine,
        requests,
        "unused",
        ServeOpts {
            workers: 2,
            time_scale: 0.1,
            max_batch: 8,
            batch_wait_us: 500,
            ..Default::default()
        },
    )
    .expect("serve");
    assert_eq!(rep.completed, n);
    assert_eq!(rep.dropped, 0);
    assert!(rep.conserved());
    assert_eq!(rep.accuracy, 1.0, "dense forward must reproduce its own labels");
    assert_eq!(rep.predictor, "none");
}

#[test]
fn serve_dense_batched_accuracy_is_exact() {
    // Batched dense serving over self-consistent labels: every answer
    // must match the per-sample forward that produced the labels.
    let arts = synth_arts();
    let requests = trace(&arts, 5);
    let n = requests.len();
    let rep = serve(
        &arts,
        &session(&arts).with_policy(None),
        Backend::Engine,
        requests,
        "unused",
        ServeOpts {
            workers: 1,
            time_scale: 0.02,
            max_batch: 16,
            // generous linger: even with coarse scheduler sleep granularity
            // stretching the compressed replay, batches must still form
            batch_wait_us: 5_000,
            ..Default::default()
        },
    )
    .expect("serve");
    assert_eq!(rep.completed, n);
    assert!(rep.conserved());
    assert_eq!(rep.accuracy, 1.0);
    // everything arrives almost at once with a 16-deep batcher: real
    // cross-request tiles must have formed
    assert!(
        rep.batch_occupancy > 1.0,
        "expected coalescing, occupancy {}",
        rep.batch_occupancy
    );
}

// ---- ServingTier: deterministic virtual-clock suites -----------------------
//
// Shared constants: every request costs SVC_US = 1 ms on the virtual
// clock, every model runs REPLICAS = 2 replicas, so one model's
// capacity is exactly 2 000 requests/s. The deadline is 20 ms, which
// with the per-lane admission bound
//   lane_depth * svc * w_sum / (w * replicas) + 2 * svc <= deadline
// caps a single weight-1 lane at depth 36 (then one in-flight push:
// high-water mark <= 37).

const SVC_US: u64 = 1000;
const REPLICAS: usize = 2;
const DEADLINE_MS: f64 = 20.0;

fn vsvc(n_models: usize) -> VirtualService {
    VirtualService { svc_us: vec![SVC_US; n_models], execute: false }
}

fn tier_builder(arts: &Artifacts, names: &[&str]) -> mor::coordinator::tier::TierBuilder {
    let sess = session(arts);
    let mut b = ServingTier::builder();
    for name in names {
        b = b.model(name, arts, &sess, REPLICAS);
    }
    b.deadline_ms(DEADLINE_MS)
}

fn steady_trace(arts: &Artifacts, rate: f64, dur_s: f64, tenant: usize, seed: u64) -> Vec<Request> {
    let mut s = RequestStream::with_arrival(
        Arrival::Steady { rate_per_s: rate },
        arts.data.n_test(),
        seed,
    )
    .for_tenant(tenant);
    s.generate(dur_s)
}

/// 0.8 s at 1 000 rps (half of one model's capacity) with an 8 000 rps
/// spike — 4x capacity — during [0.2 s, 0.5 s).
fn flash_trace(arts: &Artifacts, seed: u64) -> Vec<Request> {
    let mut s = RequestStream::with_arrival(
        Arrival::FlashCrowd {
            base_rate_per_s: 1000.0,
            spike_mult: 8.0,
            spike_start_s: 0.2,
            spike_dur_s: 0.3,
        },
        arts.data.n_test(),
        seed,
    );
    s.generate(0.8)
}

#[test]
fn tier_overload_sheds_and_keeps_accepted_p99_inside_deadline() {
    let arts = synth_arts();
    let tier = tier_builder(&arts, &["solo"]).finish();
    let trace = flash_trace(&arts, 11);
    let n = trace.len();
    assert!(n > 2000, "flash-crowd trace too short: {n}");
    let rep = tier.simulate(vec![trace], &vsvc(1)).expect("simulate");

    // conservation on an overload report: everything not completed was
    // shed, nothing silently vanished
    assert_eq!(rep.submitted, n);
    assert_eq!(rep.dropped, 0);
    assert!(rep.conserved(), "completed + dropped + shed != submitted");

    // 4x capacity must engage load shedding — and with conservative
    // admission doing its job, *only* admission sheds: an admitted
    // request always finishes inside the deadline, so expiry never fires
    assert!(rep.shed > 0, "4x-capacity spike did not shed");
    assert!(rep.shed_admission > 0);
    assert_eq!(rep.shed_expired, 0, "admission let an expiring request through");
    assert_eq!(rep.shed, rep.shed_admission + rep.shed_expired);

    // accepted requests keep their SLO: p99 (completed only — shed
    // requests have no latency) stays inside the 20 ms deadline, so
    // every completion counts toward goodput
    assert!(rep.completed > 0);
    assert!(rep.p99_ms <= DEADLINE_MS + 1e-9, "accepted p99 {} ms", rep.p99_ms);
    assert!((rep.goodput_rps - rep.throughput_rps).abs() < 1e-9);

    // backlog stays below the admission bound (depth 36 + 1 in-flight)
    assert!(rep.max_queue_depth <= 37, "queue depth {}", rep.max_queue_depth);
}

#[test]
fn tier_weighted_fairness_splits_goodput_two_to_one() {
    // one saturated model, two tenants at identical offered load but
    // 2:1 weights: weighted-fair dequeue + per-lane admission must
    // split goodput ~2:1 (each lane is throttled to its own share)
    let arts = synth_arts();
    let tier = tier_builder(&arts, &["shared"]).tenant("gold", 2).tenant("free", 1).finish();
    let gold = steady_trace(&arts, 4000.0, 0.5, 0, 21);
    let free = steady_trace(&arts, 4000.0, 0.5, 1, 22);
    let offered = gold.len() + free.len();
    let rep = tier.simulate(vec![merge(vec![gold, free])], &vsvc(1)).expect("simulate");

    assert_eq!(rep.submitted, offered);
    assert!(rep.conserved());
    assert_eq!(rep.per_tenant.len(), 2);
    let g = &rep.per_tenant[0];
    let f = &rep.per_tenant[1];
    assert_eq!(g.name, "gold");
    assert_eq!(f.name, "free");
    // 8 000 rps offered into 2 000 rps capacity: both classes shed...
    assert!(g.shed > 0 && f.shed > 0, "saturation must shed in both classes");
    assert!(g.completed > 0 && f.completed > 0, "no class may starve");
    // ...and the served split tracks the 2:1 weights within +/-20%
    let ratio = g.goodput_rps / f.goodput_rps;
    assert!(
        (1.6..=2.4).contains(&ratio),
        "goodput ratio {ratio:.3} (gold {:.0} rps, free {:.0} rps)",
        g.goodput_rps,
        f.goodput_rps
    );
}

#[test]
fn tier_flash_crowd_on_one_model_spares_the_other() {
    // model A takes a 4x-capacity flash crowd; model B idles at 25% of
    // its capacity. Shared-process multi-tenancy must not leak A's
    // overload into B: B sheds nothing and keeps a low p99 (its own
    // replicas serve their home queue first, stealing only when idle).
    let arts = synth_arts();
    let tier = tier_builder(&arts, &["hot", "cold"]).finish();
    let hot = flash_trace(&arts, 31);
    let cold = steady_trace(&arts, 500.0, 0.8, 0, 32);
    let n_cold = cold.len();
    let rep = tier.simulate(vec![hot, cold], &vsvc(2)).expect("simulate");

    assert!(rep.conserved());
    assert_eq!(rep.per_model.len(), 2);
    let (a, b) = (&rep.per_model[0], &rep.per_model[1]);
    assert_eq!(a.name, "hot");
    assert!(a.shed > 0, "the hot model must be the one shedding");
    assert_eq!(b.shed, 0, "flash crowd on 'hot' leaked shedding into 'cold'");
    assert_eq!(b.completed, n_cold, "'cold' lost requests to 'hot''s overload");
    // a cold request waits at most one stolen-request service: ~2 ms
    // worst case, far inside the deadline
    assert!(b.p99_ms < 5.0, "cold p99 {} ms", b.p99_ms);
}

#[test]
fn tier_work_stealing_drains_overload_with_foreign_replicas() {
    // model A offered 1.5x its capacity, model B completely idle, no
    // deadline (isolating stealing from shedding): with stealing B's
    // replicas double the service rate, so the backlog — and tail
    // latency — collapses versus the no-steal run.
    let arts = synth_arts();
    let trace = steady_trace(&arts, 3000.0, 0.3, 0, 41);
    let n = trace.len();
    let run = |steal: bool| {
        let sess = session(&arts);
        let tier = ServingTier::builder()
            .model("busy", &arts, &sess, REPLICAS)
            .model("idle", &arts, &sess, REPLICAS)
            .steal(steal)
            .finish();
        tier.simulate(vec![trace.clone(), Vec::new()], &vsvc(2)).expect("simulate")
    };
    let lone = run(false);
    let helped = run(true);
    // no deadline: nothing sheds, everything completes either way
    for rep in [&lone, &helped] {
        assert_eq!(rep.completed, n);
        assert_eq!(rep.shed, 0);
        assert!(rep.conserved());
    }
    // 1 000 rps of excess for 0.3 s piles up ~300 requests behind 2
    // replicas (~150 ms tail); 4 effective replicas never fall behind
    assert!(
        helped.p99_ms * 5.0 < lone.p99_ms,
        "stealing p99 {} ms vs lone p99 {} ms",
        helped.p99_ms,
        lone.p99_ms
    );
    assert!(helped.busy_s < lone.busy_s);
}

#[test]
fn tier_expiry_sheds_exactly_the_requests_that_cannot_finish() {
    // admission off, 100 requests burst-arrive at t=0 on 1 replica at
    // 1 ms each with a 20 ms deadline: requests 0..19 dequeue at
    // 0..19 ms and finish by 20 ms; from 20 ms on, `now + svc` exceeds
    // the deadline and the remaining 80 shed at dequeue — exactly.
    let arts = synth_arts();
    let sess = session(&arts);
    let tier = ServingTier::builder()
        .model("m", &arts, &sess, 1)
        .deadline_ms(DEADLINE_MS)
        .admission(false)
        .finish();
    let burst: Vec<Request> = (0..100)
        .map(|i| Request { id: i, sample_idx: (i % 32) as usize, arrival_us: 0, tenant: 0 })
        .collect();
    let rep = tier.simulate(vec![burst], &vsvc(1)).expect("simulate");

    assert_eq!(rep.submitted, 100);
    assert_eq!(rep.completed, 20);
    assert_eq!(rep.shed, 80);
    assert_eq!(rep.shed_expired, 80, "all shedding must be expiry (admission is off)");
    assert_eq!(rep.shed_admission, 0);
    assert!(rep.conserved());
    // the 20th completion lands exactly on the deadline — still good
    assert!((rep.p99_ms - 20.0).abs() < 1e-9);
    assert!((rep.goodput_rps - rep.throughput_rps).abs() < 1e-9);
}

#[test]
fn tier_simulation_is_reproducible() {
    // same seeds, same knobs, back-to-back on one tier: the virtual
    // clock makes the reports identical — including f64 stats — with
    // no state leaking between runs (queues are rebuilt per call)
    let arts = synth_arts();
    let tier = tier_builder(&arts, &["a", "b"]).tenant("gold", 2).tenant("free", 1).finish();
    let traces = || {
        vec![
            merge(vec![
                steady_trace(&arts, 2500.0, 0.4, 0, 51),
                steady_trace(&arts, 2500.0, 0.4, 1, 52),
            ]),
            flash_trace(&arts, 53),
        ]
    };
    let r1 = tier.simulate(traces(), &vsvc(2)).expect("simulate");
    let r2 = tier.simulate(traces(), &vsvc(2)).expect("simulate");

    assert!(r1.shed > 0, "pick an overloaded scenario so the assertion has teeth");
    assert_eq!(r1.completed, r2.completed);
    assert_eq!(r1.shed, r2.shed);
    assert_eq!(r1.shed_admission, r2.shed_admission);
    assert_eq!(r1.shed_expired, r2.shed_expired);
    assert_eq!(r1.max_queue_depth, r2.max_queue_depth);
    assert_eq!(r1.p50_ms, r2.p50_ms);
    assert_eq!(r1.p99_ms, r2.p99_ms);
    assert_eq!(r1.goodput_rps, r2.goodput_rps);
    for (t1, t2) in r1.per_tenant.iter().zip(&r2.per_tenant) {
        assert_eq!(t1.completed, t2.completed);
        assert_eq!(t1.shed, t2.shed);
        assert_eq!(t1.goodput_rps, t2.goodput_rps);
        assert_eq!(t1.p99_ms, t2.p99_ms);
    }
    for (m1, m2) in r1.per_model.iter().zip(&r2.per_model) {
        assert_eq!(m1.completed, m2.completed);
        assert_eq!(m1.shed, m2.shed);
    }
}

#[test]
fn tier_simulate_runs_real_inference_for_accuracy() {
    // execute: true routes every virtual completion through the actual
    // engine; a dense session over self-consistent labels must be exact
    let arts = synth_arts();
    let sess = session(&arts).with_policy(None);
    let tier = ServingTier::builder().model("dense", &arts, &sess, REPLICAS).finish();
    let trace = trace(&arts, 61);
    let n = trace.len();
    let rep = tier
        .simulate(vec![trace], &VirtualService { svc_us: vec![SVC_US], execute: true })
        .expect("simulate");
    assert_eq!(rep.completed, n);
    assert!(rep.conserved());
    assert_eq!(rep.predictor, "none");
    assert_eq!(rep.accuracy, 1.0, "dense forward must reproduce its own labels");
}

#[test]
fn tier_threaded_serve_smoke() {
    // the real-threads driver: two models, two tenants, no deadline —
    // everything must complete, conserve, and aggregate per group.
    // (Latency assertions live in the virtual-clock tests; wall-clock
    // timing here is smoke-level only.)
    let arts = synth_arts();
    let tier = tier_builder(&arts, &["a", "b"])
        .deadline_ms(0.0)
        .tenant("gold", 2)
        .tenant("free", 1)
        .time_scale(0.1)
        .finish();
    let traces = vec![
        merge(vec![
            steady_trace(&arts, 400.0, 0.25, 0, 71),
            steady_trace(&arts, 400.0, 0.25, 1, 72),
        ]),
        steady_trace(&arts, 400.0, 0.25, 0, 73),
    ];
    let n: usize = traces.iter().map(|t| t.len()).sum();
    assert!(n > 50, "trace too short: {n}");
    let rep = tier.serve(traces).expect("serve");

    assert_eq!(rep.submitted, n);
    assert_eq!(rep.completed, n, "no deadline: the tier must serve everything");
    assert_eq!((rep.dropped, rep.shed), (0, 0));
    assert!(rep.conserved());
    assert_eq!(rep.predictor, "mor");
    assert!((0.0..=1.0).contains(&rep.accuracy));
    assert!(rep.busy_s > 0.0);
    assert_eq!(rep.per_model.len(), 2);
    assert_eq!(rep.per_tenant.len(), 2);
    let by_tenant: usize = rep.per_tenant.iter().map(|g| g.completed).sum();
    let by_model: usize = rep.per_model.iter().map(|g| g.completed).sum();
    assert_eq!(by_tenant, n, "per-tenant accounting lost a completion");
    assert_eq!(by_model, n, "per-model accounting lost a completion");
}
