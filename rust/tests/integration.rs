//! Integration tests over the built artifacts (`make artifacts`).
//!
//! Every test skips gracefully (with a notice) when artifacts are missing,
//! so `cargo test` works on a fresh checkout; CI runs `make test`, which
//! builds artifacts first.

use mor::config::{Config, PredictorConfig};
use mor::model::Artifacts;
use mor::predictor::strategies::Strategy;
use mor::predictor::{choose_threshold, exec, MorPolicy, MorRun, RunOpts};
use mor::session::Session;
use mor::sim::Simulator;

fn artifacts_dir() -> String {
    std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn load(name: &str) -> Option<Artifacts> {
    match Artifacts::load(artifacts_dir(), name) {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("SKIP ({name}): {e}");
            None
        }
    }
}

#[test]
fn artifacts_load_for_all_models() {
    for name in mor::MODELS {
        let Some(a) = load(name) else { return };
        assert_eq!(a.meta.name, name);
        assert!(a.data.n_test() >= 256);
        assert!(a.data.n_calib() >= 64);
        assert!(!a.predictor.layers.is_empty());
        // predictor layer ids must be ReLU compute nodes of the model
        let relu = a.model.relu_layers();
        for (&l, lp) in &a.predictor.layers {
            assert!(relu.contains(&l), "{name}: predictor layer {l} is not a ReLU layer");
            assert_eq!(lp.neurons(), a.model.nodes[l].cout());
        }
        // MAC counts agree with the python-side meta
        let macs: u64 = a.model.mac_counts().iter().sum();
        assert_eq!(macs, a.meta.macs_per_sample, "{name}: MAC count mismatch rust vs python");
    }
}

#[test]
fn engine_accuracy_matches_python_int8() {
    // The rust functional engine must reproduce the python int8 accuracy
    // on the full test split (same integer dataflow contract).
    for name in mor::MODELS {
        let Some(a) = load(name) else { return };
        let dense = Session::build(&a.model).finish();
        let s = MorRun::evaluate(&a, &dense, a.data.n_test());
        let diff = (s.accuracy - a.meta.int8_accuracy).abs();
        assert!(
            diff < 0.02,
            "{name}: rust engine accuracy {:.3} vs python int8 {:.3}",
            s.accuracy,
            a.meta.int8_accuracy
        );
    }
}

#[test]
fn rust_clustering_reproduces_python_artifacts() {
    // The clustering is implemented twice (python offline, rust here);
    // both must produce identical clusters from the same weights.
    for name in ["tds", "cnn10"] {
        let Some(a) = load(name) else { return };
        for (&layer, lp) in &a.predictor.layers {
            let node = &a.model.nodes[layer];
            let filters = mor::cluster::node_filters(node);
            let got = mor::cluster::cluster_by_angle(&filters, 90.0);
            let want: Vec<Vec<usize>> = lp.clusters.clone();
            assert_eq!(
                got.len(),
                want.len(),
                "{name} layer {layer}: cluster count rust={} python={}",
                got.len(),
                want.len()
            );
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g[0], w[0], "{name} layer {layer}: proxy mismatch");
                let mut gs = g[1..].to_vec();
                let mut ws = w[1..].to_vec();
                gs.sort();
                ws.sort();
                assert_eq!(gs, ws, "{name} layer {layer}: member set mismatch");
            }
        }
    }
}

#[test]
fn predictor_accuracy_loss_within_budget() {
    // Paper: "the impact on DNN accuracy due to these mispredictions is
    // lower than 1% in our DNNs" — enforce a 1.5 pp budget at the chosen
    // per-model threshold on the test split.
    for name in mor::MODELS {
        let Some(a) = load(name) else { return };
        let n = 256.min(a.data.n_test());
        let thr = choose_threshold(&a, &PredictorConfig::default(), 3.2, 32);
        let sess = Session::from_artifacts(
            &a,
            PredictorConfig { threshold: thr, ..Default::default() },
        );
        let base = MorRun::evaluate(&a, &sess.with_policy(None), n);
        let s = MorRun::evaluate(&a, &sess, n);
        let loss_pp = (base.accuracy - s.accuracy) * 100.0;
        assert!(
            loss_pp < 1.5,
            "{name}: accuracy loss {loss_pp:.2} pp at T={thr}"
        );
        assert!(s.ops.macs_saved_frac() > 0.0, "{name}: no savings at T={thr}");
        // correctness of the accounting: done + skipped = total
        assert!(s.ops.macs_done <= s.ops.macs_total);
    }
}

#[test]
fn hybrid_dominates_binary_alone() {
    // Paper Fig 6 vs Fig 9: at equal threshold the hybrid must skip less
    // aggressively (both must agree) and therefore make FEWER wrong skips.
    let Some(a) = load("tds") else { return };
    let n = 128.min(a.data.n_test());
    let mk = |strategy: Strategy| {
        Session::from_artifacts(
            &a,
            PredictorConfig { threshold: 0.6, strategy, ..Default::default() },
        )
    };
    let bin = MorRun::evaluate(&a, &mk(Strategy::Binary), n);
    let hyb = MorRun::evaluate(&a, &mk(Strategy::Mor), n);
    let bin_wrong = bin.pred.frac(bin.pred.incorrect_zero);
    let hyb_wrong = hyb.pred.frac(hyb.pred.incorrect_zero);
    assert!(
        hyb_wrong <= bin_wrong + 1e-9,
        "hybrid makes more wrong skips ({hyb_wrong:.4}) than binary alone ({bin_wrong:.4})"
    );
    assert!(hyb.accuracy >= bin.accuracy - 0.01);
}

#[test]
fn simulator_speedup_on_real_models() {
    // Fig 13 direction: with real skip rates the MoR accelerator must be
    // at least as fast as the baseline, and strictly faster when skips
    // are non-trivial.
    let cfg = Config::default();
    for name in mor::MODELS {
        let Some(a) = load(name) else { return };
        let thr = choose_threshold(&a, &cfg.predictor, 3.2, 32);
        let pol = MorPolicy::new(
            &a.model,
            &a.predictor,
            PredictorConfig { threshold: thr, ..cfg.predictor.clone() },
        );
        let r = exec::run_sample(
            &a.model,
            Some(&pol),
            a.data.test_sample(0),
            RunOpts { oracle: false, collect_trace: true, ..Default::default() },
        );
        let sim = Simulator::new(cfg.clone());
        let b = sim.simulate_sample(&a.model, None, None);
        let m = sim.simulate_sample(&a.model, Some(&pol), Some(&r.traces));
        let speedup = b.cycles as f64 / m.cycles as f64;
        assert!(
            speedup > 0.98,
            "{name}: MoR slower than baseline ({speedup:.3})"
        );
        if m.neurons_skipped as f64 > 0.05 * (m.neurons_skipped + m.neurons_computed) as f64 {
            assert!(speedup > 1.0, "{name}: skips but no speedup");
        }
    }
}

#[test]
fn trace_consistency_with_ops() {
    // The trace the simulator replays must agree with the engine's own
    // accounting: skipped outputs in the trace == skipped count in stats.
    let Some(a) = load("cnn10") else { return };
    let pol = MorPolicy::new(
        &a.model,
        &a.predictor,
        PredictorConfig { threshold: 0.6, ..Default::default() },
    );
    let r = exec::run_sample(
        &a.model,
        Some(&pol),
        a.data.test_sample(3),
        RunOpts { oracle: true, collect_trace: true, ..Default::default() },
    );
    let skipped_in_trace: u64 = r
        .traces
        .iter()
        .map(|t| t.skipped.iter().filter(|&&s| s).count() as u64)
        .sum();
    let skipped_in_stats = r.pred.correct_zero + r.pred.incorrect_zero;
    assert_eq!(skipped_in_trace, skipped_in_stats);
}

#[test]
fn tiled_engine_matches_scalar_on_artifacts() {
    // Bit-identity of the tiled GEMM engine vs the per-neuron reference on
    // real models and real samples, across thread counts.
    let Some(a) = load("tds") else { return };
    let pol = MorPolicy::new(
        &a.model,
        &a.predictor,
        PredictorConfig { threshold: 0.6, ..Default::default() },
    );
    for i in 0..4 {
        let sample = a.data.test_sample(i);
        let base = RunOpts { oracle: true, collect_trace: true, ..Default::default() };
        let want = exec::run_sample(&a.model, Some(&pol), sample, base.scalar_ref());
        for threads in [1usize, 4] {
            let got = exec::run_sample(
                &a.model,
                Some(&pol),
                sample,
                RunOpts { threads, ..base },
            );
            assert_eq!(want.logits, got.logits, "sample {i}, {threads} threads");
            assert_eq!(want.pred, got.pred, "sample {i}");
            assert_eq!(want.ops, got.ops, "sample {i}");
            assert_eq!(want.traces, got.traces, "sample {i}");
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_runtime_matches_engine() {
    // The AOT HLO artifact (L1 Pallas kernels inside an L2 JAX graph) must
    // produce the same logits as the rust engine — the cross-layer
    // numerical contract of the whole repo.
    let Some(a) = load("tds") else { return };
    let rt = match mor::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: PJRT unavailable: {e}");
            return;
        }
    };
    let hlo = Artifacts::hlo_path(artifacts_dir(), "tds");
    if !hlo.exists() {
        eprintln!("SKIP: {} missing", hlo.display());
        return;
    }
    let exe = rt.load_hlo(hlo, a.meta.input_shape).expect("compile HLO");
    for i in 0..8 {
        let sample = a.data.test_sample(i);
        let pjrt = exe.forward(sample).expect("pjrt forward");
        let eng = exec::run_sample(
            &a.model,
            None,
            sample,
            RunOpts { oracle: false, collect_trace: false, ..Default::default() },
        );
        let max_diff = pjrt
            .iter()
            .zip(&eng.logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 1e-2,
            "sample {i}: PJRT vs engine logits diverge by {max_diff}"
        );
    }
}

#[test]
fn serving_coordinator_end_to_end() {
    // Offline (synthetic-artifact) coverage lives in
    // rust/tests/serving_pipeline.rs; this exercises the real tds bundle.
    let Some(a) = load("tds") else { return };
    let session = Session::from_artifacts(&a, PredictorConfig::default());
    let mut stream = mor::workload::RequestStream::new(400.0, a.data.n_test(), 5);
    let requests = stream.generate(0.5);
    let n = requests.len();
    assert!(n > 100);
    let rep = mor::coordinator::serve(
        &a,
        &session,
        mor::coordinator::Backend::Engine,
        requests,
        &artifacts_dir(),
        mor::coordinator::ServeOpts { workers: 4, ..Default::default() },
    )
    .expect("serve");
    assert_eq!(rep.completed, n, "requests dropped");
    assert_eq!(rep.dropped, 0);
    assert_eq!(rep.predictor, "mor");
    assert!(rep.accuracy > 0.5);
    assert!(rep.p99_ms < 5_000.0, "p99 {} ms", rep.p99_ms);
}

#[test]
fn fig1_band_matches_paper_shape() {
    // Paper Fig 1: 35–69% of MACs produce negative ReLU inputs (avg 55%).
    // Our scaled models must land in a compatible band (>20%, <85%).
    let mut fracs = Vec::new();
    for name in mor::MODELS {
        let Some(a) = load(name) else { return };
        let s = MorRun::evaluate(&a, &Session::build(&a.model).finish(), 64);
        let f = s.ops.neg_relu_macs as f64 / s.ops.macs_total as f64;
        assert!(
            (0.05..0.90).contains(&f),
            "{name}: negative-ReLU MAC fraction {f:.2} implausible"
        );
        fracs.push(f);
    }
    let avg = fracs.iter().sum::<f64>() / fracs.len() as f64;
    assert!((0.15..0.80).contains(&avg), "average {avg:.2} out of band");
}

#[test]
fn strategies_end_to_end_on_artifacts() {
    // `--predictor <name>` semantics over the real tds bundle: `none`
    // reproduces the dense baseline exactly, `oracle` skips with zero
    // wrong skips and dense-identical logits, and the realizable
    // strategies stay within their contracts.
    let Some(a) = load("tds") else { return };
    let n = 32.min(a.data.n_test());
    let mk = |strategy: Strategy| {
        Session::from_artifacts(
            &a,
            PredictorConfig { threshold: 0.6, strategy, ..Default::default() },
        )
    };
    let dense = MorRun::evaluate(&a, &mk(Strategy::None), n);
    let oracle = MorRun::evaluate(&a, &mk(Strategy::Oracle), n);
    assert_eq!(oracle.pred.incorrect_zero, 0, "oracle made a wrong skip");
    assert_eq!(oracle.pred.incorrect_nonzero, 0);
    assert_eq!(oracle.accuracy, dense.accuracy, "oracle changed answers");
    assert!(oracle.ops.macs_saved_frac() > 0.0);
    for strategy in [Strategy::Mor, Strategy::Binary, Strategy::Cluster] {
        let s = MorRun::evaluate(&a, &mk(strategy), n);
        // no realizable strategy can skip more true zeros than the oracle
        assert!(
            s.pred.correct_zero <= oracle.pred.correct_zero,
            "{strategy:?} skipped more than the oracle"
        );
        assert!(s.ops.macs_done <= dense.ops.macs_done);
    }
}
