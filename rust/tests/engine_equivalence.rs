//! Engine-equivalence property suite: the tiled row-batched GEMM engine
//! (prepacked weights, predict-then-evaluate tiles, optional row-tile
//! threading, dense or input-zero-skipping kernels) must produce
//! **bit-identical** logits, `OpsStats`, `PredStats` and skip traces to
//! the retained per-neuron scalar reference path, across random models,
//! random policies, every component toggle and every input-sparsity
//! mode.
//!
//! Runs fully offline — models come from `mor::model::synth`, no
//! `make artifacts` needed.

use mor::config::PredictorConfig;
use mor::model::synth;
use mor::predictor::strategies::Strategy;
use mor::predictor::{
    exec::run_sample, EngineSel, InputSparsity, MorPolicy, RunOpts, RunResult,
};
use mor::util::prop::property;
use mor::util::rng::Rng;

fn rand_input(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

/// Full structural comparison with a readable mismatch report.
fn diff(want: &RunResult, got: &RunResult) -> Option<String> {
    if want.logits != got.logits {
        return Some(format!(
            "logits differ: want {:?} got {:?}",
            want.logits, got.logits
        ));
    }
    if want.pred != got.pred {
        return Some(format!("pred stats differ: want {:?} got {:?}", want.pred, got.pred));
    }
    if want.ops != got.ops {
        return Some(format!("ops stats differ: want {:?} got {:?}", want.ops, got.ops));
    }
    if want.traces != got.traces {
        return Some("skip traces differ".to_string());
    }
    None
}

#[test]
fn tiled_engine_bit_identical_to_scalar_reference() {
    property("tiled GEMM == scalar reference", 40, |g| {
        let model = synth::random_model(g.rng());
        let params = synth::predictor_for(&model, g.seed);
        let (h, w, c) = model.input_shape;
        let x = rand_input(g.rng(), h * w * c);
        let cfg = PredictorConfig {
            threshold: *g.pick(&[0.0f32, 0.5, 0.9]),
            strategy: *g.pick(&Strategy::ALL),
            margin_sigmas: *g.pick(&[0.0f32, 1.0]),
            ..Default::default()
        };
        let pol = MorPolicy::new(&model, &params, cfg.clone());
        let oracle = g.bool();
        for policy_on in [false, true] {
            let policy = policy_on.then_some(&pol);
            let base = RunOpts {
                oracle,
                collect_trace: true,
                threads: 1,
                engine: EngineSel::ScalarRef,
                ..Default::default()
            };
            let want = run_sample(&model, policy, &x, base);
            for threads in [1usize, 3] {
                // the scalar reference ignores the input-sparsity mode,
                // so this also proves sparse == dense on the tiled side
                for mode in InputSparsity::ALL {
                    let got = run_sample(
                        &model,
                        policy,
                        &x,
                        RunOpts {
                            threads,
                            engine: EngineSel::Tiled,
                            input_sparsity: mode,
                            ..base
                        },
                    );
                    if let Some(msg) = diff(&want, &got) {
                        return Err(format!(
                            "policy_on={policy_on} threads={threads} oracle={oracle} \
                             strategy={:?} T={} input_sparsity={mode:?}: {msg}",
                            cfg.strategy, cfg.threshold
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn tiled_engine_deterministic_across_thread_counts() {
    // Threading must not change anything: same tiled result for 1..6
    // workers (stats merge in range order, outputs are disjoint slices).
    property("tiled engine thread-count invariance", 15, |g| {
        let model = synth::random_model(g.rng());
        let params = synth::predictor_for(&model, g.seed ^ 7);
        let (h, w, c) = model.input_shape;
        let x = rand_input(g.rng(), h * w * c);
        let pol = MorPolicy::new(&model, &params, PredictorConfig::default());
        let base = RunOpts { oracle: true, collect_trace: true, ..Default::default() };
        let want = run_sample(&model, Some(&pol), &x, base);
        for threads in [2usize, 5, 6] {
            let got = run_sample(&model, Some(&pol), &x, RunOpts { threads, ..base });
            if let Some(msg) = diff(&want, &got) {
                return Err(format!("threads={threads}: {msg}"));
            }
        }
        Ok(())
    });
}

#[test]
fn tiled_engine_on_cnn10_scale_model() {
    // One deep, wide model (cout > micro-kernel width, rows > tile size)
    // through both engines with the full policy machinery.
    let model = synth::cnn10_like(17);
    let params = synth::predictor_for(&model, 18);
    let mut rng = Rng::new(19);
    let (h, w, c) = model.input_shape;
    let x = rand_input(&mut rng, h * w * c);
    let pol = MorPolicy::new(
        &model,
        &params,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    let base = RunOpts { oracle: false, collect_trace: true, ..Default::default() };
    let want = run_sample(&model, Some(&pol), &x, base.scalar_ref());
    for threads in [1usize, 2, 4] {
        let got = run_sample(&model, Some(&pol), &x, RunOpts { threads, ..base });
        assert!(diff(&want, &got).is_none(), "{:?}", diff(&want, &got));
    }
    // sanity: the policy actually skipped something, so the masked GEMM
    // path (not just the dense path) was exercised
    assert!(want.ops.macs_done < want.ops.macs_total);
}
