//! Weight-sparsity property suite: the triple-sided engine's
//! weight-zero lane-elision kernels (`--weight-sparsity exact`) must be
//! **bit-identical** to the dense kernels (`off`) — logits, `OpsStats`
//! (including the data-derived `macs_skipped_weight_zero` counter),
//! `PredStats` and skip traces — across random models, controlled
//! per-model weight densities, strategies, input densities (so the
//! doubly-sparse index-intersection dot is exercised), batch sizes and
//! thread counts. A zero int8 weight lane contributes exactly 0 to the
//! integer dot, so the kernel choice can never be observable; these
//! tests pin that contract, plus the u16-overflow dense fallback and
//! the exact triple-sided MAC partition.
//!
//! Runs fully offline — models come from `mor::model::synth`, no
//! `make artifacts` needed.

use mor::config::PredictorConfig;
use mor::model::{synth, Model};
use mor::predictor::strategies::Strategy;
use mor::predictor::{
    exec::run_batch, exec::run_sample, EngineSel, InputSparsity, MorPolicy, RunOpts, RunResult,
    WeightSparsity,
};
use mor::util::prop::property;
use mor::util::rng::Rng;

/// Random input with a controlled zero fraction, so weight-zero and
/// input-zero lanes coincide inside the same patches.
fn sparse_input(rng: &mut Rng, n: usize, zero_pct: usize) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if (rng.int_in(0, 99) as usize) < zero_pct {
                0.0
            } else {
                rng.uniform(-1.0, 1.0) as f32
            }
        })
        .collect()
}

fn diff(want: &RunResult, got: &RunResult) -> Option<String> {
    if want.logits != got.logits {
        return Some(format!(
            "logits differ: want {:?} got {:?}",
            want.logits, got.logits
        ));
    }
    if want.pred != got.pred {
        return Some(format!("pred stats differ: want {:?} got {:?}", want.pred, got.pred));
    }
    if want.ops != got.ops {
        return Some(format!("ops stats differ: want {:?} got {:?}", want.ops, got.ops));
    }
    if want.traces != got.traces {
        return Some("skip traces differ".to_string());
    }
    None
}

#[test]
fn weight_sparse_kernels_bit_identical_across_densities() {
    property("weight-sparsity exact == off", 40, |g| {
        let mut model = synth::random_model(g.rng());
        // 0% zeroed (natural density) through 100% (every filter empty)
        let zero_pct = *g.pick(&[0u32, 30, 60, 90, 100]);
        synth::sparsify_weights(&mut model, g.seed, zero_pct);
        let params = synth::predictor_for(&model, g.seed);
        let (h, w, c) = model.input_shape;
        let x = sparse_input(g.rng(), h * w * c, *g.pick(&[0usize, 50, 90]));
        let cfg = PredictorConfig {
            threshold: *g.pick(&[0.0f32, 0.5, 0.9]),
            strategy: *g.pick(&Strategy::ALL),
            ..Default::default()
        };
        let pol = MorPolicy::new(&model, &params, cfg.clone());
        let policy = g.bool().then_some(&pol);
        let base = RunOpts {
            oracle: g.bool(),
            collect_trace: true,
            threads: 1,
            engine: EngineSel::Tiled,
            input_sparsity: *g.pick(&InputSparsity::ALL),
            weight_sparsity: WeightSparsity::Off,
        };
        let want = run_sample(&model, policy, &x, base);
        for threads in [1usize, 3] {
            let got = run_sample(
                &model,
                policy,
                &x,
                RunOpts { weight_sparsity: WeightSparsity::Exact, threads, ..base },
            );
            if let Some(msg) = diff(&want, &got) {
                return Err(format!(
                    "zero_pct={zero_pct} input_sparsity={:?} threads={threads} \
                     strategy={:?}: {msg}",
                    base.input_sparsity, cfg.strategy
                ));
            }
        }
        // the unplanned scalar reference agrees too (it never elides,
        // but counts the same weight-zero pool)
        let scalar = run_sample(&model, policy, &x, base.scalar_ref());
        if want.logits != scalar.logits || want.ops != scalar.ops {
            return Err(format!("scalar reference diverged at zero_pct={zero_pct}"));
        }
        Ok(())
    });
}

#[test]
fn weight_sparse_batches_bit_identical_to_per_sample() {
    // mixed-density batches over a sparsified model: tiles mix dense
    // and near-empty patches, so the doubly-sparse intersection kernel
    // and the weight-sparse dense-x kernel alternate within one tile
    let mut rng = Rng::new(0xBEE5);
    let mut model = synth::tiny_serving_model(21);
    // 85% zeros: below the weight-sparse crossover on every host, so
    // `Exact` really swaps kernels here
    synth::sparsify_weights(&mut model, 8, 85);
    let params = synth::predictor_for(&model, 22);
    let (h, w, c) = model.input_shape;
    let pol = MorPolicy::new(
        &model,
        &params,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    for b in [1usize, 5, 16] {
        let xs: Vec<Vec<f32>> = (0..b)
            .map(|i| sparse_input(&mut rng, h * w * c, (i * 25) % 125))
            .collect();
        let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        for ws in WeightSparsity::EXACT_MODES {
            let opts = RunOpts {
                oracle: true,
                collect_trace: true,
                weight_sparsity: ws,
                ..Default::default()
            };
            let got = run_batch(&model, Some(&pol), &inputs, opts);
            for (s, x) in inputs.iter().enumerate() {
                let want = run_sample(&model, Some(&pol), x, opts);
                assert!(
                    diff(&want, &got[s]).is_none(),
                    "b={b} sample={s} mode={ws:?}: {}",
                    diff(&want, &got[s]).unwrap()
                );
            }
        }
    }
}

#[test]
fn weight_zero_counter_is_mode_and_engine_independent() {
    // macs_skipped_weight_zero is a property of the data: identical
    // whichever kernel ran, and the scalar reference reports it too
    let mut rng = Rng::new(0xF00D);
    let mut model = synth::cnn10_like(41);
    synth::sparsify_weights(&mut model, 5, 60);
    let params = synth::predictor_for(&model, 42);
    let (h, w, c) = model.input_shape;
    let x = sparse_input(&mut rng, h * w * c, 50);
    let pol = MorPolicy::new(
        &model,
        &params,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    let base = RunOpts {
        oracle: false,
        collect_trace: false,
        weight_sparsity: WeightSparsity::Off,
        ..Default::default()
    };
    let want = run_sample(&model, Some(&pol), &x, base);
    // 60% zeroed weights: the weight-side ineffectual pool must be big
    assert!(want.ops.macs_skipped_weight_zero > 0);
    assert!(want.ops.macs_skipped_weight_zero <= want.ops.macs_done);
    for opts in [
        RunOpts { weight_sparsity: WeightSparsity::Exact, ..base },
        RunOpts { weight_sparsity: WeightSparsity::Exact, input_sparsity: InputSparsity::On, ..base },
        base.scalar_ref(),
    ] {
        let got = run_sample(&model, Some(&pol), &x, opts);
        assert_eq!(got.ops, want.ops);
        assert_eq!(got.logits, want.logits);
    }
}

#[test]
fn triple_sided_partition_is_exact() {
    // skipped-output + input-zero + weight-zero + effectual == total,
    // with every term nonzero, in every mode combination
    let mut rng = Rng::new(0xCAFE);
    let mut model = synth::cnn10_like(51);
    synth::sparsify_weights(&mut model, 6, 50);
    let params = synth::predictor_for(&model, 52);
    let (h, w, c) = model.input_shape;
    let x = sparse_input(&mut rng, h * w * c, 40);
    let pol = MorPolicy::new(
        &model,
        &params,
        PredictorConfig { threshold: 0.3, ..Default::default() },
    );
    for ws in WeightSparsity::EXACT_MODES {
        for is in InputSparsity::ALL {
            for engine in [EngineSel::Tiled, EngineSel::ScalarRef] {
                let opts = RunOpts {
                    weight_sparsity: ws,
                    input_sparsity: is,
                    engine,
                    ..Default::default()
                };
                let o = run_sample(&model, Some(&pol), &x, opts).ops;
                let skipped_output = o.macs_total - o.macs_done;
                assert!(skipped_output > 0, "{ws:?}/{is:?}/{engine:?}");
                assert!(o.macs_skipped_input_zero > 0);
                assert!(o.macs_skipped_weight_zero > 0);
                assert!(o.effectual_macs() > 0);
                assert_eq!(
                    skipped_output
                        + o.macs_skipped_input_zero
                        + o.macs_skipped_weight_zero
                        + o.effectual_macs(),
                    o.macs_total,
                    "{ws:?}/{is:?}/{engine:?}"
                );
            }
        }
    }
}

#[test]
fn all_zero_weights_run_on_empty_lane_lists() {
    // the degenerate case: every filter's lane list is empty, the whole
    // forward reduces to bias/BN terms — still bit-identical
    let mut model = synth::tiny_serving_model(33);
    synth::sparsify_weights(&mut model, 1, 100);
    let pf = model.prepacked().layer(0);
    assert!(pf.has_lanes());
    assert_eq!(pf.density(), 0.0);
    assert_eq!(pf.lanes(0).0.len(), 0);
    let (h, w, c) = model.input_shape;
    let mut rng = Rng::new(34);
    let x = sparse_input(&mut rng, h * w * c, 0);
    let off = run_sample(
        &model,
        None,
        &x,
        RunOpts { weight_sparsity: WeightSparsity::Off, ..Default::default() },
    );
    let on = run_sample(
        &model,
        None,
        &x,
        RunOpts { weight_sparsity: WeightSparsity::Exact, ..Default::default() },
    );
    assert_eq!(off.logits, on.logits);
    assert_eq!(off.ops, on.ops);
    // every performed MAC with a nonzero input lane is weight-zero
    assert_eq!(
        off.ops.macs_skipped_input_zero + off.ops.macs_skipped_weight_zero,
        off.ops.macs_done
    );
    assert_eq!(off.ops.effectual_macs(), 0);
}

#[test]
fn u16_overflow_k_falls_back_to_dense_kernels() {
    // k_len > u16::MAX + 1: lane indices cannot be represented, so the
    // prepack skips the lane lists (masks stay) and the plan must keep
    // the dense kernels even in `exact` mode — results identical
    const K: usize = (u16::MAX as usize + 1) + 64;
    let mut model = Model::new(
        "overflow_fc".into(),
        1.0 / 127.0,
        (1, 1, K),
        vec![synth::dense_node(K, 2, 5)],
    );
    synth::sparsify_weights(&mut model, 3, 50);
    assert!(!model.prepacked().layer(0).has_lanes());
    let mut rng = Rng::new(6);
    let x = sparse_input(&mut rng, K, 50);
    let base = RunOpts::default();
    let want = run_sample(&model, None, &x, base);
    // the bitmask weight-zero accounting still works above the lane cap
    assert!(want.ops.macs_skipped_weight_zero > 0);
    for opts in [
        RunOpts { weight_sparsity: WeightSparsity::Exact, ..base },
        RunOpts { weight_sparsity: WeightSparsity::Exact, input_sparsity: InputSparsity::On, ..base },
        RunOpts { weight_sparsity: WeightSparsity::Exact, ..base.scalar_ref() },
    ] {
        let got = run_sample(&model, None, &x, opts);
        assert_eq!(want.logits, got.logits);
        assert_eq!(want.ops, got.ops);
    }
}

#[test]
fn session_threshold_pruning_matches_manually_pruned_model() {
    // `Threshold(t)` is exactly: prune at build, then run `Exact`
    use mor::session::Session;
    let model = synth::tiny_serving_model(61);
    let t = 0.02f32;
    let mut pruned = model.clone();
    pruned.prune_weights_below(t);
    let (h, w, c) = model.input_shape;
    let mut rng = Rng::new(62);
    let x = sparse_input(&mut rng, h * w * c, 30);
    let want = Session::build(&pruned)
        .weight_sparsity(WeightSparsity::Exact)
        .finish()
        .run_sample(&x);
    let got = Session::build(&model)
        .weight_sparsity(WeightSparsity::Threshold(t))
        .finish()
        .run_sample(&x);
    assert_eq!(want.logits, got.logits);
    assert_eq!(want.ops, got.ops);
}
