//! Batch-equivalence property suite: [`mor::predictor::exec::run_batch`]
//! must be **bit-identical** to mapping `run_sample` over the batch —
//! logits, `OpsStats`, `PredStats` and skip traces, per sample — for
//! batch sizes 1..16 (ragged final tiles included), every policy toggle,
//! any thread count, and every input-sparsity kernel mode. This is the
//! correctness contract that lets the
//! serving coordinator coalesce cross-request micro-batches without
//! changing a single served answer.
//!
//! Runs fully offline — models come from `mor::model::synth`, no
//! `make artifacts` needed.

use mor::config::PredictorConfig;
use mor::model::synth;
use mor::predictor::strategies::Strategy;
use mor::predictor::{
    exec::run_batch, exec::run_sample, EngineSel, InputSparsity, MorPolicy, RunOpts, RunResult,
};
use mor::util::prop::property;
use mor::util::rng::Rng;

fn rand_input(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

fn diff(want: &RunResult, got: &RunResult) -> Option<String> {
    if want.logits != got.logits {
        return Some(format!(
            "logits differ: want {:?} got {:?}",
            want.logits, got.logits
        ));
    }
    if want.pred != got.pred {
        return Some(format!("pred stats differ: want {:?} got {:?}", want.pred, got.pred));
    }
    if want.ops != got.ops {
        return Some(format!("ops stats differ: want {:?} got {:?}", want.ops, got.ops));
    }
    if want.traces != got.traces {
        return Some("skip traces differ".to_string());
    }
    None
}

#[test]
fn run_batch_bit_identical_to_per_sample_run() {
    property("run_batch == per-sample run_sample", 30, |g| {
        let model = synth::random_model(g.rng());
        let params = synth::predictor_for(&model, g.seed);
        let (h, w, c) = model.input_shape;
        let b = g.usize(1, 16);
        let xs: Vec<Vec<f32>> = (0..b).map(|_| rand_input(g.rng(), h * w * c)).collect();
        let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let cfg = PredictorConfig {
            threshold: *g.pick(&[0.0f32, 0.5, 0.9]),
            strategy: *g.pick(&Strategy::ALL),
            margin_sigmas: *g.pick(&[0.0f32, 1.0]),
            ..Default::default()
        };
        let pol = MorPolicy::new(&model, &params, cfg);
        let policy = g.bool().then_some(&pol);
        let opts = RunOpts {
            oracle: g.bool(),
            collect_trace: true,
            threads: *g.pick(&[1usize, 3]),
            engine: EngineSel::Tiled,
            // batching must stay invisible whatever kernel flavour runs
            input_sparsity: *g.pick(&InputSparsity::ALL),
        };
        let got = run_batch(&model, policy, &inputs, opts);
        if got.len() != b {
            return Err(format!("expected {b} results, got {}", got.len()));
        }
        for (s, x) in inputs.iter().enumerate() {
            let want = run_sample(&model, policy, x, opts);
            if let Some(msg) = diff(&want, &got[s]) {
                return Err(format!("sample {s}/{b} threads={}: {msg}", opts.threads));
            }
        }
        Ok(())
    });
}

#[test]
fn run_batch_every_size_1_to_16() {
    // The acceptance sweep: one fixed model, every batch size 1..=16 —
    // covers tiles that end exactly on a sample boundary, tiles that
    // straddle several samples, and the ragged final tile.
    let mut rng = Rng::new(0xBA7C);
    let model = synth::tiny_serving_model(31);
    let params = synth::predictor_for(&model, 32);
    let (h, w, c) = model.input_shape;
    let pol = MorPolicy::new(
        &model,
        &params,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    let opts = RunOpts { oracle: true, collect_trace: true, ..Default::default() };
    for b in 1..=16usize {
        let xs: Vec<Vec<f32>> = (0..b).map(|_| rand_input(&mut rng, h * w * c)).collect();
        let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let got = run_batch(&model, Some(&pol), &inputs, opts);
        assert_eq!(got.len(), b);
        for (s, x) in inputs.iter().enumerate() {
            let want = run_sample(&model, Some(&pol), x, opts);
            assert!(
                diff(&want, &got[s]).is_none(),
                "b={b} sample {s}: {}",
                diff(&want, &got[s]).unwrap()
            );
        }
    }
}

#[test]
fn run_batch_scalar_ref_engine_matches_too() {
    // The scalar reference engine takes the per-sample path inside
    // run_batch; results must still line up one-to-one.
    let mut rng = Rng::new(0x5CA1);
    let model = synth::random_model(&mut rng);
    let params = synth::predictor_for(&model, 77);
    let (h, w, c) = model.input_shape;
    let pol = MorPolicy::new(&model, &params, PredictorConfig::default());
    let xs: Vec<Vec<f32>> = (0..5).map(|_| rand_input(&mut rng, h * w * c)).collect();
    let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
    let opts = RunOpts {
        oracle: true,
        collect_trace: true,
        threads: 1,
        engine: EngineSel::ScalarRef,
        ..Default::default()
    };
    let got = run_batch(&model, Some(&pol), &inputs, opts);
    for (s, x) in inputs.iter().enumerate() {
        let want = run_sample(&model, Some(&pol), x, opts);
        assert!(diff(&want, &got[s]).is_none(), "sample {s}");
    }
}

#[test]
fn run_batch_empty_input_is_empty() {
    let model = synth::tiny_serving_model(1);
    let out = run_batch(&model, None, &[], RunOpts::default());
    assert!(out.is_empty());
}
