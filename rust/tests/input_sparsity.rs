//! Input-sparsity property suite: the triple-sided engine's
//! input-zero-skipping kernels (`--input-sparsity on|auto`) must be
//! **bit-identical** to the dense kernels (`off`) — logits, `OpsStats`
//! (including the data-derived `macs_skipped_input_zero` counter),
//! `PredStats` and skip traces — across random models, strategies,
//! controlled input densities, batch sizes and thread counts. A zero
//! int8 lane contributes exactly 0 to the integer dot, so the kernel
//! choice can never be observable; these tests pin that contract.
//!
//! Runs fully offline — models come from `mor::model::synth`, no
//! `make artifacts` needed.

use mor::config::PredictorConfig;
use mor::model::synth;
use mor::predictor::strategies::Strategy;
use mor::predictor::{
    exec::run_batch, exec::run_sample, EngineSel, InputSparsity, MorPolicy, RunOpts, RunResult,
};
use mor::util::prop::property;
use mor::util::rng::Rng;

/// Random input with a controlled zero fraction: quantized-to-zero
/// lanes appear in the very first layer's patches, not only after ReLU.
fn sparse_input(rng: &mut Rng, n: usize, zero_pct: usize) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if (rng.int_in(0, 99) as usize) < zero_pct {
                0.0
            } else {
                rng.uniform(-1.0, 1.0) as f32
            }
        })
        .collect()
}

fn diff(want: &RunResult, got: &RunResult) -> Option<String> {
    if want.logits != got.logits {
        return Some(format!(
            "logits differ: want {:?} got {:?}",
            want.logits, got.logits
        ));
    }
    if want.pred != got.pred {
        return Some(format!("pred stats differ: want {:?} got {:?}", want.pred, got.pred));
    }
    if want.ops != got.ops {
        return Some(format!("ops stats differ: want {:?} got {:?}", want.ops, got.ops));
    }
    if want.traces != got.traces {
        return Some("skip traces differ".to_string());
    }
    None
}

#[test]
fn sparse_kernels_bit_identical_across_densities() {
    property("input-sparsity on/auto == off", 40, |g| {
        let model = synth::random_model(g.rng());
        let params = synth::predictor_for(&model, g.seed);
        let (h, w, c) = model.input_shape;
        // 0% zeros (fully dense) through 100% zeros (all-zero input)
        let zero_pct = *g.pick(&[0usize, 30, 60, 90, 100]);
        let x = sparse_input(g.rng(), h * w * c, zero_pct);
        let cfg = PredictorConfig {
            threshold: *g.pick(&[0.0f32, 0.5, 0.9]),
            strategy: *g.pick(&Strategy::ALL),
            ..Default::default()
        };
        let pol = MorPolicy::new(&model, &params, cfg.clone());
        let policy = g.bool().then_some(&pol);
        let base = RunOpts {
            oracle: g.bool(),
            collect_trace: true,
            threads: 1,
            engine: EngineSel::Tiled,
            input_sparsity: InputSparsity::Off,
            ..Default::default()
        };
        let want = run_sample(&model, policy, &x, base);
        for mode in [InputSparsity::On, InputSparsity::Auto] {
            for threads in [1usize, 3] {
                let got = run_sample(
                    &model,
                    policy,
                    &x,
                    RunOpts { input_sparsity: mode, threads, ..base },
                );
                if let Some(msg) = diff(&want, &got) {
                    return Err(format!(
                        "zero_pct={zero_pct} mode={mode:?} threads={threads} \
                         strategy={:?}: {msg}",
                        cfg.strategy
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sparse_batches_bit_identical_to_per_sample() {
    // mixed-density batches: tiles hold dense and near-empty patches
    // side by side, so the per-row kernel choice (Auto) flips within
    // one tile — batching must still be invisible
    let mut rng = Rng::new(0x5Aa5);
    let model = synth::tiny_serving_model(21);
    let params = synth::predictor_for(&model, 22);
    let (h, w, c) = model.input_shape;
    let pol = MorPolicy::new(
        &model,
        &params,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    for b in [1usize, 5, 16] {
        let xs: Vec<Vec<f32>> = (0..b)
            .map(|i| sparse_input(&mut rng, h * w * c, (i * 25) % 125))
            .collect();
        let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        for mode in InputSparsity::ALL {
            let opts = RunOpts {
                oracle: true,
                collect_trace: true,
                input_sparsity: mode,
                ..Default::default()
            };
            let got = run_batch(&model, Some(&pol), &inputs, opts);
            for (s, x) in inputs.iter().enumerate() {
                let want = run_sample(&model, Some(&pol), x, opts);
                assert!(
                    diff(&want, &got[s]).is_none(),
                    "b={b} sample={s} mode={mode:?}: {}",
                    diff(&want, &got[s]).unwrap()
                );
            }
        }
    }
}

#[test]
fn input_zero_counter_is_mode_and_engine_independent() {
    // macs_skipped_input_zero is a property of the data: identical
    // whichever kernel ran, and the scalar reference reports it too
    let mut rng = Rng::new(0xF00D);
    let model = synth::cnn10_like(41);
    let params = synth::predictor_for(&model, 42);
    let (h, w, c) = model.input_shape;
    let x = sparse_input(&mut rng, h * w * c, 50);
    let pol = MorPolicy::new(
        &model,
        &params,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    let base = RunOpts {
        oracle: false,
        collect_trace: false,
        input_sparsity: InputSparsity::Off,
        ..Default::default()
    };
    let want = run_sample(&model, Some(&pol), &x, base);
    // deep post-ReLU stack: the ineffectual-input pool must be visible
    assert!(want.ops.macs_skipped_input_zero > 0);
    assert!(want.ops.macs_skipped_input_zero <= want.ops.macs_done);
    for opts in [
        RunOpts { input_sparsity: InputSparsity::On, ..base },
        RunOpts { input_sparsity: InputSparsity::Auto, ..base },
        base.scalar_ref(),
    ] {
        let got = run_sample(&model, Some(&pol), &x, opts);
        assert_eq!(got.ops, want.ops);
        assert_eq!(got.logits, want.logits);
    }
}

#[test]
fn all_zero_input_runs_and_skips_everything_ineffectual() {
    // the degenerate case: every patch of the first layer is all-zero,
    // so in `on` mode the whole layer runs on empty lane lists
    let model = synth::tiny_serving_model(33);
    let (h, w, c) = model.input_shape;
    let x = vec![0.0f32; h * w * c];
    let off = run_sample(
        &model,
        None,
        &x,
        RunOpts { input_sparsity: InputSparsity::Off, ..Default::default() },
    );
    let on = run_sample(
        &model,
        None,
        &x,
        RunOpts { input_sparsity: InputSparsity::On, ..Default::default() },
    );
    assert_eq!(off.logits, on.logits);
    assert_eq!(off.ops, on.ops);
    // layer-0 MACs are all ineffectual (zero input lanes)
    let k0 = model.nodes[0].k_len() as u64;
    let rows0 = (h * w) as u64; // stride-1 SAME conv: one row per position
    let cout0 = model.nodes[0].cout() as u64;
    assert!(off.ops.macs_skipped_input_zero >= k0 * rows0 * cout0);
}
