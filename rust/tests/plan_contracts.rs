//! Contracts of the plan/execute split (`mor::plan`):
//!
//! * planned execution is bit-identical to the unplanned `ScalarRef`
//!   oracle on compile-time edge cases (layers with fewer rows than a
//!   tile, an all-skip layer under the `oracle` strategy);
//! * a threshold re-plan (`Session::with_threshold`) reuses the packed
//!   rookie sign bits AND the compiled plan;
//! * workspace checkout/return is aliasing-free under concurrent serve
//!   workers and the pool grows exactly to the peak contention;
//! * the plan's liveness analysis keeps peak live activation tensors
//!   per sample O(1), not O(layers);
//! * the steady-state forward loop performs **zero heap allocations**
//!   after warmup — asserted with a counting global allocator.

use mor::config::PredictorConfig;
use mor::model::synth;
use mor::model::{Model, Node};
use mor::plan;
use mor::predictor::strategies::{Strategy, ZeroPredictor};
use mor::predictor::{exec, EngineSel, RunOpts, WeightSparsity};
use mor::session::Session;
use mor::util::alloc_count::{allocs_on_this_thread, CountingAlloc};
use mor::util::rng::Rng;
use std::sync::{Arc, Barrier};

// Per-thread allocation counting (other test threads in this binary
// don't disturb the measured thread) — see mor::util::alloc_count.
#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn rand_input(model: &Model, seed: u64) -> Vec<f32> {
    let (h, w, c) = model.input_shape;
    let mut rng = Rng::new(seed);
    (0..h * w * c).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

fn assert_same(a: &mor::predictor::RunResult, b: &mor::predictor::RunResult, what: &str) {
    assert_eq!(a.logits, b.logits, "{what}: logits");
    assert_eq!(a.pred, b.pred, "{what}: pred stats");
    assert_eq!(a.ops, b.ops, "{what}: ops stats");
    assert_eq!(a.traces, b.traces, "{what}: traces");
}

/// Layers whose row count is below `TILE_ROWS` (an FC head has exactly
/// one output row per sample) must plan and execute bit-exactly — the
/// ragged "tile" is the only tile.
#[test]
fn plan_single_row_layers_match_scalar_oracle() {
    let model = synth::tiny_serving_model(41); // FC head: 1 row
    let params = synth::predictor_for(&model, 42);
    let x = rand_input(&model, 43);
    for strategy in Strategy::ALL {
        let base = Session::build(&model)
            .params(&params)
            .strategy(strategy)
            .threshold(0.5)
            .oracle(true)
            .collect_trace(true)
            .finish();
        let want = base
            .with_opts(RunOpts { engine: EngineSel::ScalarRef, ..base.opts() })
            .run_sample(&x);
        let got = base.run_sample(&x);
        assert_same(&got, &want, strategy.name());
    }
}

/// An FC model whose first layer's folded-BN shift forces every ReLU
/// input negative: under the `oracle` strategy the whole layer is
/// skipped (every output is a true zero).
fn all_zero_layer_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let n = 6usize;
    let w1: Vec<i8> = (0..8 * n).map(|_| rng.int8()).collect();
    let w2: Vec<i8> = (0..n * 4).map(|_| rng.int8()).collect();
    Model::new(
        "all_zero_l0".into(),
        1.0 / 127.0,
        (1, 1, 8),
        vec![
            Node::Fc {
                cin: 8,
                cout: n,
                sw: 0.01,
                sx: 1.0 / 127.0,
                w: w1,
                // shift −1000 ≪ any dequantized dot: every pre-activation
                // is negative, every ReLU output is a true zero
                bn: Some((vec![1.0; n], vec![-1000.0; n])),
                relu: true,
                res_from: None,
                consumes: -1,
            },
            Node::Fc {
                cin: n,
                cout: 4,
                sw: 0.02,
                sx: 0.05,
                w: w2,
                bn: None,
                relu: false,
                res_from: None,
                consumes: 0,
            },
        ],
    )
}

#[test]
fn plan_all_skip_layer_under_oracle() {
    let model = all_zero_layer_model(47);
    let params = synth::predictor_for(&model, 48);
    let x = rand_input(&model, 49);
    let sess = Session::build(&model)
        .params(&params)
        .strategy(Strategy::Oracle)
        .collect_trace(true)
        .finish();
    let r = sess.run_sample(&x);
    let scalar = sess
        .with_opts(RunOpts { engine: EngineSel::ScalarRef, ..sess.opts() })
        .run_sample(&x);
    assert_same(&r, &scalar, "all-skip oracle");
    // the entire predictable layer was skipped, correctly
    assert_eq!(r.pred.relu_outputs, 6);
    assert_eq!(r.pred.correct_zero, 6);
    assert_eq!(r.pred.incorrect_zero, 0);
    // only the (non-ReLU) head performed MACs
    assert_eq!(r.ops.macs_done, 6 * 4);
    // and the logits equal the dense forward's (skipped zeros ARE zeros)
    let dense = Session::build(&model).finish().run_sample(&x);
    assert_eq!(r.logits, dense.logits);
}

/// `with_threshold` must not re-pack rookie sign bits NOR recompile the
/// plan — the policied-layer set and every frozen decision survive a
/// threshold change — and the derived session must match a from-scratch
/// build at that threshold bit for bit.
#[test]
fn threshold_replan_reuses_plan_and_packed_bits() {
    let model = synth::tiny_serving_model(53);
    let arts = synth::artifacts_for(model, 54, 2, 2);
    let cfg = PredictorConfig { threshold: 0.9, ..Default::default() };
    let base = Session::from_artifacts(&arts, cfg);
    let derived = base.with_threshold(0.2);
    assert!(Arc::ptr_eq(base.plan().unwrap(), derived.plan().unwrap()));
    for (l, st) in &base.policy().unwrap().layers {
        assert!(Arc::ptr_eq(
            &st.packed_w,
            &derived.policy().unwrap().layers[l].packed_w
        ));
    }
    let fresh = Session::from_artifacts(
        &arts,
        PredictorConfig { threshold: 0.2, ..Default::default() },
    );
    let x = rand_input(fresh.model(), 55);
    assert_same(&derived.run_sample(&x), &fresh.run_sample(&x), "re-threshold");
}

/// N workers checking out concurrently get N distinct workspaces (the
/// pool grows to peak contention), returns land back in the free list,
/// and a later checkout reuses instead of growing.
#[test]
fn workspace_pool_grows_under_contention_without_aliasing() {
    let model = synth::tiny_serving_model(59);
    let sess = Session::build(&model).finish();
    let pool = sess.workspace_pool();
    const N: usize = 6;
    let barrier = Arc::new(Barrier::new(N));
    std::thread::scope(|sc| {
        for t in 0..N {
            let sess = sess.clone();
            let barrier = Arc::clone(&barrier);
            sc.spawn(move || {
                let mut ws = sess.checkout_workspace();
                // hold all N concurrently so the pool must grow to N
                barrier.wait();
                // exclusive &mut access: run a real forward in each
                let x = rand_input(sess.model(), 60 + t as u64);
                let r = sess.run_batch_in(&mut ws, &[x.as_slice()]);
                assert_eq!(r.len(), 1);
                barrier.wait();
            });
        }
    });
    assert_eq!(pool.created(), N, "pool must grow exactly to peak contention");
    assert_eq!(pool.available(), N, "every workspace returned on drop");
    {
        let _ws = sess.checkout_workspace();
        assert_eq!(pool.available(), N - 1, "checkout reuses a pooled workspace");
    }
    assert_eq!(pool.created(), N, "no growth without contention");
    assert_eq!(pool.available(), N);
}

/// The liveness analysis keeps live activation tensors per sample O(1):
/// a 10-node chain ping-pongs 2 slots; a residual branch adds exactly
/// one more — never one per layer.
#[test]
fn peak_live_tensors_per_sample_is_o1() {
    let chain = synth::cnn10_like(61);
    let plan = plan::compile(&chain, None, RunOpts::default());
    let compute_layers = chain.nodes.iter().filter(|n| n.is_compute()).count();
    assert_eq!(plan.n_slots, 2, "a pure chain needs exactly 2 ping-pong slots");
    assert!(compute_layers >= 9, "cnn10_like should be deep");
    assert!(
        plan.n_slots < compute_layers,
        "peak live tensors must not scale with depth"
    );
    // random graphs (incl. pools and FC heads) stay O(1) too
    let mut rng = Rng::new(62);
    for _ in 0..20 {
        let m = synth::random_model(&mut rng);
        let p = plan::compile(&m, None, RunOpts::default());
        assert!(p.n_slots <= 3, "random model needed {} slots", p.n_slots);
    }
}

/// The zero-allocation contract: after warmup, the planned forward
/// (single-threaded, no tracing — the serving worker configuration)
/// performs no heap allocation at all: no output tensors, no quantized
/// buffers, no per-row scratch, no result envelopes. The compressed-
/// weight kernels (`WeightSparsity::Exact` on a sparsified model, so
/// the weight-sparse plan decision actually fires) honour the same
/// contract — the lane lists live in the shared prepack, not in
/// per-request scratch.
#[test]
fn steady_state_forward_makes_zero_allocations() {
    for (strategy, ws_mode) in [
        (Strategy::None, WeightSparsity::Off),
        (Strategy::Mor, WeightSparsity::Off),
        (Strategy::None, WeightSparsity::Exact),
        (Strategy::Mor, WeightSparsity::Exact),
    ] {
        let mut model = synth::tiny_serving_model(67);
        if ws_mode == WeightSparsity::Exact {
            // 90% zeros: density lands below the weight-sparse
            // crossover on every host, so the compressed kernels run
            synth::sparsify_weights(&mut model, 69, 90);
        }
        let params = synth::predictor_for(&model, 68);
        let sess = Session::build(&model)
            .params(&params)
            .strategy(strategy)
            .threshold(0.5)
            .oracle(false)
            .collect_trace(false)
            .threads(1)
            .weight_sparsity(ws_mode)
            .finish();
        let xs: Vec<Vec<f32>> = (0..4).map(|i| rand_input(&model, 70 + i)).collect();
        let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let mut ws = sess.checkout_workspace();
        let mut results = Vec::new();
        // warmup: buffers grow to their high-water marks
        sess.run_batch_into(&mut ws, &inputs, &mut results);
        sess.run_batch_into(&mut ws, &inputs, &mut results);
        let want = results.iter().map(|r| r.logits.clone()).collect::<Vec<_>>();

        let before = allocs_on_this_thread();
        // steady batches AND fluctuating micro-batch sizes (shrunk
        // result envelopes park in the workspace and come back): the
        // lingering batcher's normal behavior must not allocate either
        for &take in &[4usize, 2, 4, 1, 3, 4] {
            sess.run_batch_into(&mut ws, &inputs[..take], &mut results);
            assert_eq!(results.len(), take);
        }
        let after = allocs_on_this_thread();
        assert_eq!(
            after - before,
            0,
            "steady-state forward allocated ({strategy:?} strategy, {ws_mode:?} weights)"
        );
        // and it still computes the right thing
        for (r, w) in results.iter().zip(&want) {
            assert_eq!(&r.logits, w);
        }
    }
}

/// The free-function path (`exec::run_batch`) compiles a throwaway plan
/// per call; it must agree with the session's cached-plan path exactly.
#[test]
fn session_cached_plan_matches_per_call_compile() {
    let model = synth::tiny_serving_model(71);
    let params = synth::predictor_for(&model, 72);
    let sess = Session::build(&model)
        .params(&params)
        .threshold(0.5)
        .collect_trace(true)
        .finish();
    let xs: Vec<Vec<f32>> = (0..5).map(|i| rand_input(&model, 73 + i)).collect();
    let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
    let via_session = sess.run_batch(&inputs);
    let via_exec = exec::run_batch(sess.model(), sess.policy(), &inputs, sess.opts());
    for (a, b) in via_session.iter().zip(&via_exec) {
        assert_same(a, b, "session vs free function");
    }
}
