//! Bench for paper Fig 13 (a+b): speedup and energy savings of the MoR
//! accelerator vs the baseline (paper: 1.2x / 16.5% on average), plus a
//! wall-clock micro-benchmark of the cycle simulator itself.
mod common;
use mor::config::Config;
use mor::util::bench::bench_with;

fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let cfg = Config::default();
    let (t, _) = mor::figures::fig13(&zoo, 4, &cfg);
    t.print();
    t.write_csv(&common::out_dir(), "fig13_speedup_energy").ok();

    println!("\n-- simulator wall-clock --");
    let a = &zoo[0];
    let sim = mor::sim::Simulator::new(cfg);
    let timing = bench_with(&format!("{} baseline sim", a.meta.name), 1, 0.4, &mut || {
        std::hint::black_box(sim.simulate_sample(&a.model, None, None));
    });
    timing.report();
}
