//! Bench for paper Table 1: the simulated accelerator configuration.
mod common;
use mor::config::Config;
fn main() {
    let t = mor::figures::table1(&Config::default());
    t.print();
    t.write_csv(&common::out_dir(), "table1_config").ok();
}
