//! Bench for paper Fig 6: binary predictor alone — accuracy loss vs %
//! operations saved across the correlation threshold sweep (1.0 → 0.6).
mod common;
use mor::predictor::strategies::Strategy;
fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let t = mor::figures::threshold_sweep(&zoo, 32, Strategy::Binary);
    t.print();
    t.write_csv(&common::out_dir(), "fig06_threshold_sweep").ok();
}
