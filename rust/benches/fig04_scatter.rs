//! Bench for paper Fig 4: binary-vs-base dot product scatter for one
//! TDS neuron (the paper's example has r = 0.78).
mod common;
fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let tds = zoo.iter().find(|a| a.meta.name == "tds").unwrap_or(&zoo[0]);
    let t = mor::figures::fig04(tds, 6);
    println!("=== {} ===", t.title);
    println!("({} scatter points; CSV written for plotting)", t.rows.len());
    t.write_csv(&common::out_dir(), "fig04_scatter").ok();
    // print the correlation the series carries as the headline number
    let xs: Vec<f64> = t.rows.iter().map(|r| r[0].parse().unwrap()).collect();
    let ys: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let (mut sxx, mut syy, mut sxy) = (0.0, 0.0, 0.0);
    for i in 0..xs.len() {
        sxx += (xs[i] - mx) * (xs[i] - mx);
        syy += (ys[i] - my) * (ys[i] - my);
        sxy += (xs[i] - mx) * (ys[i] - my);
    }
    println!("measured Pearson r over the plotted series: {:.3}", sxy / (sxx * syy).sqrt());
}
