//! §Perf serving bench: capacity and tail latency of the coordinator as a
//! function of micro-batch size and worker count.
//!
//! Drives the serving pipeline **closed-loop** (issue-on-completion, a
//! full pipeline of `2 * workers * max_batch` outstanding requests) so
//! the measured rps is service capacity, not arrival-rate replay. Uses
//! the real cnn10 artifacts when `make artifacts` has run, otherwise a
//! synthetic cnn10-scale bundle — the emitted `BENCH_serving.json`
//! (override the path with `MOR_BENCH_SERVING_OUT`) is always complete
//! and machine-diffable across PRs.
mod common;

use mor::config::PredictorConfig;
use mor::coordinator::{serve, Backend, ServeOpts};
use mor::model::{synth, Artifacts};
use mor::session::Session;
use mor::workload::RequestStream;

const WORKERS: [usize; 2] = [1, 4];
const BATCHES: [usize; 4] = [1, 4, 8, 16];
const REQUESTS_PER_CONFIG: usize = 192;

fn workload() -> (Artifacts, String) {
    if let Some(zoo) = common::load_zoo() {
        if let Some(a) = zoo.into_iter().find(|a| a.meta.name == "cnn10") {
            return (a, "cnn10".to_string());
        }
    }
    // synthetic fallback: cnn10-scale model, self-consistent labels
    (
        synth::artifacts_for(synth::cnn10_like(21), 22, 64, 4),
        "cnn10-synth".to_string(),
    )
}

fn main() {
    let (arts, label) = workload();
    println!("serving bench on {label}: closed loop, {REQUESTS_PER_CONFIG} requests per config");

    // one session for the whole sweep: model cloned and prepacked once,
    // policy prepared once, shared read-only by every worker config
    let session = Session::from_artifacts(
        &arts,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    let mut rows: Vec<String> = Vec::new();
    for &workers in &WORKERS {
        for &max_batch in &BATCHES {
            // arrival times are ignored in closed loop; the stream only
            // supplies ids + sample indices
            let mut stream = RequestStream::new(1000.0, arts.data.n_test(), 42);
            let mut requests = stream.generate(10.0);
            requests.truncate(REQUESTS_PER_CONFIG);
            let n = requests.len();
            let rep = serve(
                &arts,
                &session,
                Backend::Engine,
                requests,
                "unused",
                ServeOpts {
                    workers,
                    max_batch,
                    batch_wait_us: 500,
                    closed_loop: true,
                    concurrency: 2 * workers * max_batch,
                    ..Default::default()
                },
            )
            .expect("serve");
            assert_eq!(rep.completed, n, "bench dropped requests");
            println!(
                "  workers={workers} batch<={max_batch:<2} → {:>7.1} rps | occupancy {:>5.2} | \
                 p50 {:>7.2} ms p99 {:>7.2} ms",
                rep.throughput_rps, rep.batch_occupancy, rep.p50_ms, rep.p99_ms
            );
            rows.push(format!(
                "    {{\"workers\": {workers}, \"max_batch\": {max_batch}, \
                 \"predictor\": \"{}\", \
                 \"rps\": {:.2}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \
                 \"mean_service_ms\": {:.3}, \"batch_occupancy\": {:.3}, \
                 \"dropped\": {}}}",
                rep.predictor,
                rep.throughput_rps,
                rep.p50_ms,
                rep.p99_ms,
                rep.mean_service_ms,
                rep.batch_occupancy,
                rep.dropped
            ));
        }
    }

    let out_path = std::env::var("MOR_BENCH_SERVING_OUT")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    let mut js = String::new();
    js.push_str("{\n");
    js.push_str("  \"bench\": \"perf_serving\",\n");
    js.push_str(&format!("  \"model\": \"{label}\",\n"));
    js.push_str(&format!("  \"predictor\": \"{}\",\n", session.predictor_name()));
    js.push_str(&format!("  \"requests_per_config\": {REQUESTS_PER_CONFIG},\n"));
    js.push_str(&format!(
        "  \"threads_available\": {},\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    ));
    js.push_str("  \"mode\": \"closed_loop\",\n");
    js.push_str("  \"configs\": [\n");
    js.push_str(&rows.join(",\n"));
    js.push_str("\n  ]\n}\n");
    match std::fs::write(&out_path, &js) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
